"""Metric top-k retrieval tests: fused kernel vs oracle, serving stack.

Kernel checks run in interpret mode on CPU (TPU is the lowering target);
the sharded engine agreement check runs in a subprocess with 8 forced host
devices (dry-run rule: never force device count in the main process).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.metric_topk import (metric_topk, metric_topk_naive,
                                       metric_topk_ref, metric_topk_xla,
                                       project_gallery)
from repro.serve import (FakeClock, GalleryIndex, MicroBatcher,
                         RetrievalEngine)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _data(Nq, M, d, k, seed=0):
    rng = np.random.RandomState(seed)
    L = jnp.asarray(0.3 * rng.randn(k, d), jnp.float32)
    q = jnp.asarray(rng.randn(Nq, d), jnp.float32)
    G = jnp.asarray(rng.randn(M, d), jnp.float32)
    return L, q, G


class TestMetricTopkKernel:
    @pytest.mark.parametrize("Nq,M,d,k,K", [
        (64, 1024, 128, 64, 10),     # even tiles
        (16, 300, 40, 12, 5),        # nothing divides the tile sizes
        (7, 129, 33, 9, 3),          # tiny + odd everything
        (200, 2048, 96, 48, 20),     # queries over several tiles
        (128, 512, 128, 128, 1),     # k_top = 1
        (8, 96, 24, 8, 96),          # k_top = M (full sort)
    ])
    def test_matches_ref(self, Nq, M, d, k, K):
        L, q, G = _data(Nq, M, d, k, seed=Nq + M)
        gp, gn = project_gallery(L, G)
        d_ref, i_ref = metric_topk_ref(q @ L.T, gp, K, gn)
        d_ker, i_ker = metric_topk(L, q, gp, gn, k_top=K)
        np.testing.assert_array_equal(np.asarray(i_ker), np.asarray(i_ref))
        np.testing.assert_allclose(np.asarray(d_ker), np.asarray(d_ref),
                                   rtol=1e-4, atol=1e-4)
        # distances come back ascending
        dk = np.asarray(d_ker)
        assert (np.diff(dk, axis=1) >= -1e-6).all()

    def test_matches_naive_per_pair_baseline(self):
        # the textbook per-pair metric application agrees with the
        # factored/pre-projected path the index serves
        L, q, G = _data(12, 200, 32, 16)
        gp, gn = project_gallery(L, G)
        _, i_ker = metric_topk(L, q, gp, gn, k_top=8)
        d_nv, i_nv = metric_topk_naive(L, q, G, 8, chunk=5)
        np.testing.assert_array_equal(np.asarray(i_ker), np.asarray(i_nv))

    def test_bf16_inputs(self):
        L, q, G = _data(16, 256, 64, 32)
        gp, gn = project_gallery(L, G)
        d_ref, i_ref = metric_topk_ref(q @ L.T, gp, 5, gn)
        d_ker, i_ker = metric_topk(L.astype(jnp.bfloat16),
                                   q.astype(jnp.bfloat16), gp, gn, k_top=5)
        # bf16 projection perturbs distances; neighbor sets stay mostly put
        overlap = np.mean([
            len(set(np.asarray(i_ker)[i]) & set(np.asarray(i_ref)[i])) / 5
            for i in range(16)])
        assert overlap > 0.8

    def test_k_top_larger_than_gallery_raises(self):
        L, q, G = _data(4, 16, 8, 4)
        gp, gn = project_gallery(L, G)
        with pytest.raises(ValueError):
            metric_topk(L, q, gp, gn, k_top=17)

    def test_padded_gallery_rows_never_returned(self):
        # M=130 pads to 256 inside the kernel; all returned indices real
        L, q, G = _data(9, 130, 16, 8)
        gp, gn = project_gallery(L, G)
        _, idx = metric_topk(L, q, gp, gn, k_top=130)
        assert np.asarray(idx).max() < 130
        assert np.asarray(idx).min() >= 0


class TestServingStack:
    def test_engine_matches_xla_path_and_buckets(self):
        L, q, G = _data(20, 500, 48, 16)
        index = GalleryIndex.build(L, G)
        d_ref, i_ref = metric_topk_xla(L, q, index.gp, index.gn, 7)
        eng = RetrievalEngine(index, k_top=7, buckets=(8, 32))
        dists, idxs = eng.search(q)          # 20 pads to bucket 32
        np.testing.assert_array_equal(idxs, np.asarray(i_ref))
        np.testing.assert_allclose(dists, np.asarray(d_ref),
                                   rtol=1e-5, atol=1e-5)
        d1, i1 = eng.search(np.asarray(q[3]))   # single-vector request
        np.testing.assert_array_equal(i1, np.asarray(i_ref)[3])
        assert eng.stats()["n_queries"] == 21

    def test_engine_pallas_backend_agrees(self):
        L, q, G = _data(16, 400, 40, 24)
        index = GalleryIndex.build(L, G)
        xla = RetrievalEngine(index, k_top=6, backend="xla").search(q)
        pal = RetrievalEngine(index, k_top=6, backend="pallas").search(q)
        np.testing.assert_array_equal(pal[1], xla[1])
        np.testing.assert_allclose(pal[0], xla[0], rtol=1e-4, atol=1e-4)

    @staticmethod
    def _drain(clock, futs, max_wait_s, guard_s=60.0):
        """Advance the fake clock until every future resolves: wait for
        the worker to park on its coalescing timeout, then push time past
        it. Condition-driven (wait_for_waiters), never sleep-driven."""
        import time as _time
        guard = _time.monotonic() + guard_s
        while not all(f.done() for f in futs):
            assert _time.monotonic() < guard, "futures never resolved"
            try:
                # short rendezvous: the worker may resolve everything and
                # park untimed between our doneness check and this wait
                clock.wait_for_waiters(1, timeout=0.2)
            except TimeoutError:
                continue
            clock.advance(max_wait_s * 2)

    def test_microbatcher_coalesces_and_preserves_results(self):
        L, q, G = _data(30, 300, 32, 16)
        index = GalleryIndex.build(L, G)
        eng = RetrievalEngine(index, k_top=5)
        ref_d, ref_i = eng.search(q)
        clock = FakeClock()
        mb = MicroBatcher(eng, max_batch=16, max_wait_ms=20.0, clock=clock)
        futs = [mb.submit(np.asarray(q[i]), k_top=3) for i in range(30)]
        # virtual time is frozen, so the worker can only dispatch a batch
        # once it is *full* — coalescing is now exact, not probabilistic:
        # 30 submits at max_batch=16 form precisely [16, 14]
        self._drain(clock, futs, mb.max_wait_s)
        for i, f in enumerate(futs):
            d, idx = f.result(timeout=60)
            assert idx.shape == (3,)
            np.testing.assert_array_equal(idx, ref_i[i, :3])
        assert mb.close()
        assert mb.n_batches == 2, "fake-clock coalescing must be exact"
        assert list(mb.batch_sizes) == [16, 14]
        with pytest.raises(RuntimeError):
            mb.submit(np.asarray(q[0]))

    def test_batcher_survives_cancelled_future(self):
        # a rider cancelled while pending must not kill the worker thread
        L, q, G = _data(8, 100, 16, 8)
        eng = RetrievalEngine(GalleryIndex.build(L, G), k_top=3)
        eng.warmup()
        clock = FakeClock()
        mb = MicroBatcher(eng, max_batch=4, max_wait_ms=200.0, clock=clock)
        try:
            doomed = mb.submit(np.asarray(q[0]))
            assert doomed.cancel()
            alive = [mb.submit(np.asarray(q[i])) for i in range(1, 8)]
            self._drain(clock, alive, mb.max_wait_s)
            for f in alive:
                d, idx = f.result(timeout=30)   # resolved if worker lives
                assert idx.shape == (3,)
            assert doomed.cancelled()
        finally:
            assert mb.close()

    def test_batcher_rejects_oversized_k(self):
        L, q, G = _data(4, 64, 16, 8)
        eng = RetrievalEngine(GalleryIndex.build(L, G), k_top=5)
        mb = MicroBatcher(eng)
        try:
            with pytest.raises(ValueError):
                mb.submit(np.asarray(q[0]), k_top=9)
        finally:
            assert mb.close()


@pytest.mark.slow
class TestShardedEngine:
    @pytest.fixture(scope="class")
    def subprocess_result(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run(
            [sys.executable,
             os.path.join(REPO, "tests", "_serve_subprocess_check.py")],
            capture_output=True, text=True, env=env, timeout=600)
        assert proc.returncode == 0, \
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("SERVE_CHECK_OK")][0]
        return json.loads(line[len("SERVE_CHECK_OK "):])

    def test_sharded_matches_single_device(self, subprocess_result):
        assert subprocess_result["sharded_matches_single"]
        assert subprocess_result["n_shards"] == 8

    def test_engine_runs_on_sharded_index(self, subprocess_result):
        assert subprocess_result["engine_on_sharded_index"]

    def test_sharded_ivf_matches_single_device(self, subprocess_result):
        assert subprocess_result["ivf_sharded_matches_single"]
