"""The ``scan_impl`` knob through the serving stack.

Pins the tentpole's serving contract: ``scan_impl="pallas"`` (interpret
mode on CPU) answers **identically** to ``scan_impl="xla"`` through
IVFPQIndex (bit-identical — both routes share kernels/pq_adc) and
IVFIndex (ids exact, distances to f32 rounding), composes with the
exact-rerank ladder and the ExactIndex oracle, survives MutableIndex
compaction and snapshot round-trips, and rejects falsy/unknown values
at every entry point instead of silently remapping them (the k_top=0
bug class).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.serve import (ExactIndex, IVFIndex, IVFPQIndex, MutableIndex,
                         load_index, save_index)
from repro.serve.scan import SCAN_IMPLS, resolve_scan_impl


@pytest.fixture(scope="module")
def data():
    rng = np.random.RandomState(0)
    d, k, M = 20, 10, 300
    L = (0.3 * rng.randn(k, d)).astype(np.float32)
    G = rng.randn(M, d).astype(np.float32)
    Q = rng.randn(7, d).astype(np.float32)
    return L, G, Q


def test_resolve_scan_impl_contract():
    assert resolve_scan_impl("xla") == "xla"
    assert resolve_scan_impl("pallas") == "pallas"
    assert resolve_scan_impl("xla", "pallas") == "pallas"
    assert resolve_scan_impl("auto") in ("xla", "pallas")
    # `is None` defers to the default; explicit falsy values raise
    assert resolve_scan_impl("pallas", None) == "pallas"
    for bad in ("", 0, False, "fused"):
        with pytest.raises(ValueError, match="scan_impl"):
            resolve_scan_impl("auto", bad)
        with pytest.raises(ValueError, match="scan_impl"):
            resolve_scan_impl(bad)


def test_ivf_pallas_matches_xla(data):
    L, G, Q = data
    ivf = IVFIndex.build(L, jnp.asarray(G), n_clusters=8, nprobe=3)
    d_x, i_x = ivf.topk(Q, 5, scan_impl="xla")
    d_p, i_p = ivf.topk(Q, 5, scan_impl="pallas")
    np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_p))
    np.testing.assert_allclose(np.asarray(d_x), np.asarray(d_p),
                               rtol=1e-4, atol=1e-4)


def test_ivfpq_pallas_bit_identical(data):
    L, G, Q = data
    pq = IVFPQIndex.build(L, jnp.asarray(G), n_clusters=8, nprobe=3,
                          n_subspaces=5, bits=6, rerank_depth=12)
    for kw in ({}, {"rerank": 0}, {"nprobe": 8}):
        d_x, i_x = pq.topk(Q, 5, scan_impl="xla", **kw)
        d_p, i_p = pq.topk(Q, 5, scan_impl="pallas", **kw)
        np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_p))
        np.testing.assert_array_equal(np.asarray(d_x), np.asarray(d_p))


def test_ivfpq_pallas_host_store_bit_identical(data):
    L, G, Q = data
    pq = IVFPQIndex.build(L, jnp.asarray(G), n_clusters=8, nprobe=3,
                          n_subspaces=5, rerank_depth=12, store="host")
    d_x, i_x = pq.topk(Q, 5, scan_impl="xla")
    d_p, i_p = pq.topk(Q, 5, scan_impl="pallas")
    np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_p))
    np.testing.assert_array_equal(np.asarray(d_x), np.asarray(d_p))


def test_ivfpq_pallas_full_probe_matches_exact_oracle(data):
    # full probe + full-depth rerank under the kernel path must equal
    # the exact scan — the same oracle the XLA path pins
    L, G, Q = data
    exact = ExactIndex.build(L, jnp.asarray(G))
    pq = IVFPQIndex.build(L, jnp.asarray(G), n_clusters=8, nprobe=8,
                          n_subspaces=5, rerank_depth=len(G))
    _, i_e = exact.topk(Q, 5)
    _, i_p = pq.topk(Q, 5, nprobe=8, rerank=len(G), scan_impl="pallas")
    np.testing.assert_array_equal(np.asarray(i_e), np.asarray(i_p))


def test_build_default_flows_to_topk(data):
    L, G, Q = data
    pq = IVFPQIndex.build(L, jnp.asarray(G), n_clusters=8, nprobe=3,
                          n_subspaces=5, scan_impl="pallas")
    assert pq.scan_impl == "pallas"
    d_p, i_p = pq.topk(Q, 5)                 # default = build setting
    d_x, i_x = pq.topk(Q, 5, scan_impl="xla")
    np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_p))
    np.testing.assert_array_equal(np.asarray(d_x), np.asarray(d_p))


def test_falsy_scan_impl_rejected_everywhere(data):
    L, G, Q = data
    ivf = IVFIndex.build(L, jnp.asarray(G), n_clusters=8, nprobe=3)
    pq = IVFPQIndex.build(L, jnp.asarray(G), n_clusters=8, nprobe=3,
                          n_subspaces=5)
    for bad in ("", 0, "kernel"):
        with pytest.raises(ValueError, match="scan_impl"):
            IVFIndex.build(L, jnp.asarray(G), n_clusters=8,
                           scan_impl=bad)
        with pytest.raises(ValueError, match="scan_impl"):
            IVFPQIndex.build(L, jnp.asarray(G), n_clusters=8,
                             n_subspaces=5, scan_impl=bad)
        with pytest.raises(ValueError, match="scan_impl"):
            ivf.topk(Q, 5, scan_impl=bad)
        with pytest.raises(ValueError, match="scan_impl"):
            pq.topk(Q, 5, scan_impl=bad)
    assert "auto" in SCAN_IMPLS and len(SCAN_IMPLS) == 3


def test_mutable_compaction_preserves_scan_impl(data):
    L, G, _ = data
    mut = MutableIndex.build(L, G, base="ivfpq", n_clusters=8, nprobe=3,
                             n_subspaces=5, scan_impl="pallas",
                             auto_compact_delta=0.0,
                             auto_compact_dead=0.0)
    assert mut.scan_impl == "pallas"
    rng = np.random.RandomState(1)
    mut.upsert(rng.randn(4, G.shape[1]).astype(np.float32))
    mut.delete(mut.live_ids()[:2])
    assert mut.compact()
    assert mut.base.scan_impl == "pallas"     # headroom fold
    # spill path (rebuild) keeps it too
    mut.upsert(rng.randn(2 * len(G), G.shape[1]).astype(np.float32))
    assert mut.compact()
    assert mut.base.scan_impl == "pallas"
    assert mut.n_rebuilds >= 1


def test_snapshot_roundtrip_preserves_scan_impl(tmp_path, data):
    L, G, Q = data
    for build in (
            lambda: IVFIndex.build(L, jnp.asarray(G), n_clusters=8,
                                   nprobe=3, scan_impl="pallas"),
            lambda: IVFPQIndex.build(L, jnp.asarray(G), n_clusters=8,
                                     nprobe=3, n_subspaces=5,
                                     scan_impl="pallas")):
        index = build()
        path = str(tmp_path / type(index).__name__)
        save_index(index, path)
        loaded = load_index(path)
        assert loaded.scan_impl == "pallas"
        d0, i0 = index.topk(Q, 5)
        d1, i1 = loaded.topk(Q, 5)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_mutable_topk_forwards_scan_impl(data):
    L, G, Q = data
    mut = MutableIndex.build(L, G, base="ivfpq", n_clusters=8, nprobe=3,
                             n_subspaces=5, auto_compact_delta=0.0,
                             auto_compact_dead=0.0)
    mut.upsert(np.random.RandomState(2)
               .randn(3, G.shape[1]).astype(np.float32))
    d_x, i_x = mut.topk(Q, 5, scan_impl="xla")
    d_p, i_p = mut.topk(Q, 5, scan_impl="pallas")
    np.testing.assert_array_equal(i_x, i_p)
    np.testing.assert_array_equal(d_x, d_p)
    with pytest.raises(ValueError, match="scan_impl"):
        mut.topk(Q, 5, scan_impl="")
