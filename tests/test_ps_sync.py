"""PS distribution-layer tests.

Multi-device SPMD semantics (BSP identical copies, local-SGD drift/merge,
SSP convergence) run in a subprocess with 8 forced host devices so the main
pytest process keeps the real single-device view (dry-run rule).
The threaded asynchronous simulator (paper §4.2) is tested in-process.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dml
from repro.core.ps import simulator
from repro.data import pairs as pairdata

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.slow      # subprocess training runs


class TestSPMDSync:
    @pytest.fixture(scope="class")
    def subprocess_result(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run(
            [sys.executable, os.path.join(REPO, "tests", "_ps_subprocess_check.py")],
            capture_output=True, text=True, env=env, timeout=600)
        assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
        line = [l for l in proc.stdout.splitlines() if l.startswith("PS_CHECK_OK")][0]
        return json.loads(line[len("PS_CHECK_OK "):])

    def test_bsp_converges_and_copies_identical(self, subprocess_result):
        r = subprocess_result
        assert r["bsp_identical"]
        assert r["bsp_loss_last"] < 0.2 * r["bsp_loss_first"]

    def test_local_sgd_drifts_and_merges(self, subprocess_result):
        assert subprocess_result["local_drift_and_merge"]

    def test_all_modes_beat_euclidean_ap(self, subprocess_result):
        r = subprocess_result
        for k in ("ap_bsp", "ap_local", "ap_ssp"):
            assert r[k] > r["ap_euclidean"]


class TestAsyncSimulator:
    """The paper's actual async PS (threads + queues), at toy scale."""

    @pytest.fixture(scope="class")
    def setup(self):
        cfg = pairdata.PairDatasetConfig(
            n_samples=400, feat_dim=24, n_classes=4, noise=1.0, seed=0)
        train_pairs, eval_pairs = pairdata.train_eval_split(
            cfg, 1500, 1500, 400, 400)
        dcfg = dml.DMLConfig(feat_dim=24, proj_dim=12)
        L0 = np.asarray(dml.init_params(dcfg, jax.random.PRNGKey(0)))
        return train_pairs, eval_pairs, L0

    def test_async_ps_converges(self, setup):
        train_pairs, eval_pairs, L0 = setup
        cfg = simulator.AsyncPSConfig(n_workers=3, lr=5e-2, batch_size=128,
                                      steps_per_worker=80)
        L, trace = simulator.run_async_dml(cfg, train_pairs, L0)
        assert len(trace) == 3 * 80
        # early-vs-late minibatch loss drops
        early = np.mean([t[2] for t in trace[:30]])
        late = np.mean([t[2] for t in trace[-30:]])
        assert late < 0.5 * early
        # learned metric beats Euclidean on held-out AP
        xs = jnp.asarray(eval_pairs["xs"]); ys = jnp.asarray(eval_pairs["ys"])
        lab = jnp.asarray(eval_pairs["sim"])
        ap = float(dml.average_precision(
            dml.pair_scores(jnp.asarray(L), xs, ys), lab))
        ap_e = float(dml.average_precision(
            dml.pair_scores_euclidean(xs, ys), lab))
        assert ap > ap_e

    def test_all_workers_contribute(self, setup):
        train_pairs, _, L0 = setup
        cfg = simulator.AsyncPSConfig(n_workers=4, lr=2e-2, batch_size=64,
                                      steps_per_worker=20)
        _, trace = simulator.run_async_dml(cfg, train_pairs, L0)
        workers_seen = {t[1] for t in trace}
        assert workers_seen == {0, 1, 2, 3}
