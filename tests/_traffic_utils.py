"""Shared test double for the serving front end (scheduler/batcher tests).

A ``FakeEngine`` stands in for RetrievalEngine so front-end tests control
the engine's behavior exactly:

  * ``gate``    — a cleared gate blocks ``search`` until the test opens
                  it, pinning the worker inside the engine so queues can
                  be stuffed/inspected deterministically;
  * ``entered`` — set when a search begins (the test's rendezvous that
                  the worker is parked in the engine);
  * ``fail``    — when True, ``search`` raises (typed-failure paths);
  * ``calls``   — every served batch as (ids, topk kwargs), where a
                  query's id is its vector's first element — so tests can
                  assert exactly which requests reached the engine, in
                  what order, under which degradation knobs.

No jax, no device work: front-end logic only.
"""

from __future__ import annotations

import threading
from types import SimpleNamespace

import numpy as np


class FakeEngine:
    def __init__(self, d: int = 4, k_top: int = 8):
        self.k_top = k_top
        self.backend = "xla"
        self.buckets = (8,)
        self.index = SimpleNamespace(
            L=np.zeros((2, d), np.float32), version=0, size=1000,
            n_shards=1)
        self.frontend = None
        self.gate = threading.Event()
        self.gate.set()
        self.entered = threading.Event()
        self.fail = False
        self._lock = threading.Lock()
        self.calls: list = []

    def search(self, qs, k_top=None, **topk_kw):
        self.entered.set()
        assert self.gate.wait(timeout=60), "test gate never opened"
        with self._lock:
            if self.fail:
                raise RuntimeError("injected engine failure")
            ids = [int(q[0]) for q in np.asarray(qs)]
            self.calls.append((ids, dict(topk_kw)))
        n = len(qs)
        k = self.k_top if k_top is None else k_top
        dists = np.zeros((n, k), np.float32)
        idxs = np.tile(np.arange(k, dtype=np.int32), (n, 1))
        return dists, idxs

    def served_ids(self):
        """Flat id list, engine arrival order."""
        with self._lock:
            return [i for ids, _ in self.calls for i in ids]

    def call_kwargs(self):
        with self._lock:
            return [kw for _, kw in self.calls]


def make_query(d: int, rid: int) -> np.ndarray:
    """A query vector carrying its request id in element 0."""
    q = np.zeros((d,), np.float32)
    q[0] = rid
    return q
