"""Mutable-gallery lifecycle tests: upserts, deletes, compaction,
snapshots, metric hot-swap, and the engine integration.

The contract under test, from ISSUE/ROADMAP "gallery mutation": a
MutableIndex over either base must agree *exactly* with a from-scratch
rebuild over the live rows after any upsert/delete sequence — before and
after compaction — and a snapshot must reload to bit-for-bit identical
answers at the same version.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.serve import (ExactIndex, IVFIndex, MutableIndex,
                         RetrievalEngine, load_index, recall_at_k,
                         save_index)
from repro.serve.snapshot import l_fingerprint

D, K = 24, 12


def _data(M=400, seed=0, n_blobs=12):
    rng = np.random.RandomState(seed)
    centers = 3.0 * rng.randn(n_blobs, D).astype(np.float32)
    G = centers[rng.randint(0, n_blobs, M)] \
        + 0.3 * rng.randn(M, D).astype(np.float32)
    L = (0.3 * rng.randn(K, D)).astype(np.float32)
    q = G[rng.randint(0, M, 9)] + 0.1 * rng.randn(9, D).astype(np.float32)
    return L, G, q, rng


def _rebuild_topk(mut, queries, k_top):
    """Ground truth: a from-scratch ExactIndex over the *live raw rows*
    in ascending-external-id order (requires retain_raw)."""
    ids = mut.live_ids()
    rows = np.empty((len(ids), mut.raw_base.shape[1]), np.float32)
    for r, e in enumerate(ids.tolist()):
        kind, i = mut._loc[int(e)]
        rows[r] = mut.raw_base[i] if kind == "base" else mut.raw_delta[i]
    ref = ExactIndex.build(mut.L, jnp.asarray(rows))
    d, i = ref.topk(jnp.asarray(queries), k_top)
    return np.asarray(d), ids[np.asarray(i)]


def _assert_matches_rebuild(mut, queries, k_top=10, **kw):
    d_ref, i_ref = _rebuild_topk(mut, queries, k_top)
    d, i = mut.topk(jnp.asarray(queries), k_top, **kw)
    np.testing.assert_array_equal(i, i_ref)
    # ids exact; distances to fp tolerance (the IVF gather scores with an
    # einsum whose accumulation order differs from the exact matmul —
    # same tolerance test_serve_index pins for IVF vs ExactIndex)
    np.testing.assert_allclose(d, d_ref, rtol=1e-4, atol=1e-3)


def _mut(base="exact", M=400, seed=0, **kw):
    L, G, q, rng = _data(M=M, seed=seed)
    base_kw = dict(n_clusters=8, nprobe=8) if base == "ivf" else {}
    mut = MutableIndex.build(L, G, base=base, retain_raw=True,
                             auto_compact_delta=0, auto_compact_dead=0,
                             **base_kw, **kw)
    return mut, G, q, rng


class TestMutableLifecycle:
    # ivf runs at nprobe == n_clusters (exact pruning) so rebuild
    # agreement is well-defined for both bases
    @pytest.mark.parametrize("base", ["exact", "ivf"])
    def test_upsert_delete_update_matches_rebuild(self, base):
        mut, G, q, rng = _mut(base)
        _assert_matches_rebuild(mut, q)

        new_ids = mut.upsert(rng.randn(37, D).astype(np.float32))
        _assert_matches_rebuild(mut, q)

        mut.delete(np.arange(25))                       # base tombstones
        mut.delete(new_ids[:5])                         # delta tombstones
        _assert_matches_rebuild(mut, q)

        # update = upsert of an existing id: old slot dies, new row serves
        mut.upsert(rng.randn(4, D).astype(np.float32),
                   ids=np.asarray([30, 31, *new_ids[5:7]]))
        _assert_matches_rebuild(mut, q)
        assert mut.size == 400 + 37 - 25 - 5

    @pytest.mark.parametrize("base", ["exact", "ivf"])
    def test_compaction_preserves_answers(self, base):
        mut, G, q, rng = _mut(base)
        mut.upsert(rng.randn(30, D).astype(np.float32))
        mut.delete(np.arange(20))
        d_pre, i_pre = mut.topk(jnp.asarray(q), 10)
        assert mut.compact()
        assert mut.delta_rows == 0 and mut.tombstones == 0
        d_post, i_post = mut.topk(jnp.asarray(q), 10)
        np.testing.assert_array_equal(i_post, i_pre)
        np.testing.assert_array_equal(d_post, d_pre)
        _assert_matches_rebuild(mut, q)
        assert not mut.compact()                        # clean -> no-op

    @pytest.mark.parametrize("base", ["exact", "ivf"])
    def test_random_sequence_property(self, base):
        # seeded random op stream; rebuild-agreement is the invariant
        mut, G, q, rng = _mut(base, M=300, seed=3)
        for step in range(12):
            op = rng.randint(0, 3)
            if op == 0:
                mut.upsert(rng.randn(rng.randint(1, 30), D)
                           .astype(np.float32))
            elif op == 1 and mut.size > 60:
                live = mut.live_ids()
                mut.delete(rng.choice(live, rng.randint(1, 20),
                                      replace=False))
            else:
                live = mut.live_ids()
                pick = rng.choice(live, rng.randint(1, 10), replace=False)
                mut.upsert(rng.randn(len(pick), D).astype(np.float32),
                           ids=pick)
            if step % 4 == 3:
                mut.compact()
            _assert_matches_rebuild(mut, q)

    def test_ivf_headroom_fold_vs_spill_rebuild(self):
        mut, G, q, rng = _mut("ivf")
        cap_free = mut.base.n_clusters * mut.base.cap - mut.base.size
        mut.upsert(rng.randn(min(cap_free, 20), D).astype(np.float32))
        mut.compact()
        assert mut.n_compactions == 1 and mut.n_rebuilds == 0
        _assert_matches_rebuild(mut, q)
        # overflow the total headroom -> the fold spills -> k-means rebuild
        mut.upsert(rng.randn(cap_free + 50, D).astype(np.float32))
        mut.compact()
        assert mut.n_rebuilds == 1
        _assert_matches_rebuild(mut, q)

    def test_ivf_modest_nprobe_recall_under_churn(self):
        mut, G, q, rng = _mut("ivf", M=2000)
        mut.upsert(G[rng.randint(0, 2000, 100)]
                   + 0.1 * rng.randn(100, D).astype(np.float32))
        mut.delete(rng.choice(2000, 100, replace=False))
        d_ref, i_ref = _rebuild_topk(mut, q, 10)
        _, i_a = mut.topk(jnp.asarray(q), 10, nprobe=4)
        assert recall_at_k(i_a, i_ref) >= 0.9

    def test_version_bumps_per_batch(self):
        mut, G, q, rng = _mut()
        v0 = mut.version
        mut.upsert(rng.randn(3, D).astype(np.float32))
        assert mut.version == v0 + 1                    # one bump per batch
        mut.delete(np.asarray([0, 1]))
        assert mut.version == v0 + 2
        mut.compact()
        assert mut.version == v0 + 3

    def test_auto_compaction_thresholds(self):
        L, G, q, rng = _data()
        mut = MutableIndex.build(L, G, base="exact",
                                 auto_compact_delta=0.05,
                                 auto_compact_dead=0)
        mut.upsert(rng.randn(30, D).astype(np.float32))  # > 5% of 400
        assert mut.n_compactions == 1 and mut.delta_rows == 0
        assert mut.base.size == 430

    def test_validation_errors(self):
        mut, G, q, rng = _mut()
        with pytest.raises(ValueError):
            mut.topk(jnp.asarray(q), 0)
        with pytest.raises(ValueError):
            mut.topk(jnp.asarray(q), mut.size + 1)
        with pytest.raises(KeyError):
            mut.delete(np.asarray([10**9]))             # unknown id
        with pytest.raises(ValueError):
            mut.delete(np.asarray([1, 1]))              # duplicate batch
        with pytest.raises(ValueError):
            mut.upsert(rng.randn(2, D).astype(np.float32),
                       ids=np.asarray([-1, 5]))         # negative id
        with pytest.raises(NotImplementedError):
            # sharded bases are not wrappable (single-host subsystem)
            class FakeSharded:
                n_shards = 2
            MutableIndex(FakeSharded(), mut.L)

    def test_deleted_ids_are_reusable(self):
        mut, G, q, rng = _mut()
        mut.delete(np.asarray([7]))
        assert not mut.contains(7)
        mut.upsert(rng.randn(1, D).astype(np.float32), ids=np.asarray([7]))
        assert mut.contains(7)
        _assert_matches_rebuild(mut, q)


class TestSnapshot:
    @pytest.mark.parametrize("kind", ["exact", "ivf", "mutable",
                                      "mutable_ivf"])
    def test_round_trip_bit_for_bit(self, kind, tmp_path):
        L, G, q, rng = _data()
        if kind == "exact":
            index = ExactIndex.build(L, jnp.asarray(G))
        elif kind == "ivf":
            index = IVFIndex.build(L, jnp.asarray(G), n_clusters=8,
                                   nprobe=8)
        else:
            base = "ivf" if kind == "mutable_ivf" else "exact"
            index = _mut(base)[0]
            index.upsert(rng.randn(17, D).astype(np.float32))
            index.delete(np.arange(9))
        d_ref, i_ref = index.topk(jnp.asarray(q), 10)
        save_index(index, str(tmp_path))
        restored = load_index(str(tmp_path))
        assert restored.version == index.version
        assert restored.size == index.size
        d, i = restored.topk(jnp.asarray(q), 10)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(d_ref))

    def test_mutate_save_load_mutate_property(self, tmp_path):
        # build -> mutate -> save -> load -> the restored index keeps
        # serving AND keeps mutating exactly like the original
        mut, G, q, rng = _mut()
        mut.upsert(rng.randn(21, D).astype(np.float32))
        mut.delete(np.arange(11))
        save_index(mut, str(tmp_path))
        restored = load_index(str(tmp_path))
        more = rng.randn(5, D).astype(np.float32)
        ids_a = mut.upsert(more)
        ids_b = restored.upsert(more)
        np.testing.assert_array_equal(ids_a, ids_b)     # same next_id state
        d_a, i_a = mut.topk(jnp.asarray(q), 10)
        d_b, i_b = restored.topk(jnp.asarray(q), 10)
        np.testing.assert_array_equal(i_a, i_b)
        np.testing.assert_array_equal(d_a, d_b)
        restored.compact()
        _assert_matches_rebuild(restored, q)

    def test_fingerprint_guard(self, tmp_path):
        L, G, q, rng = _data()
        index = ExactIndex.build(L, jnp.asarray(G))
        save_index(index, str(tmp_path))
        load_index(str(tmp_path), expect_L=L)           # matching L: fine
        with pytest.raises(ValueError, match="fingerprint"):
            load_index(str(tmp_path), expect_L=L + 0.1)
        assert l_fingerprint(L) != l_fingerprint(L + 0.1)

    def test_missing_manifest_refused(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            load_index(str(tmp_path))


class TestMetricHotSwap:
    @pytest.mark.parametrize("base", ["exact", "ivf"])
    def test_swap_matches_fresh_build(self, base):
        mut, G, q, rng = _mut(base)
        mut.upsert(rng.randn(15, D).astype(np.float32))
        mut.delete(np.arange(10))
        L2 = (0.3 * rng.randn(K, D)).astype(np.float32)
        v0 = mut.version
        mut.swap_metric(L2, block_rows=128)             # exercise blocking
        assert mut.version > v0 and mut.n_swaps == 1
        assert np.array_equal(np.asarray(mut.L), L2)
        _assert_matches_rebuild(mut, q)                 # rebuild under L2

    def test_swap_requires_retained_raw(self):
        L, G, q, rng = _data()
        mut = MutableIndex.build(L, G, base="exact", retain_raw=False)
        with pytest.raises(ValueError, match="retain_raw"):
            mut.swap_metric(L)

    def test_swap_dimension_check(self):
        mut, G, q, rng = _mut()
        with pytest.raises(ValueError):
            mut.swap_metric(np.zeros((K, D + 1), np.float32))


class TestEngineIntegration:
    def _engine(self, **kw):
        mut, G, q, rng = _mut(M=200)
        return RetrievalEngine(mut, k_top=5, **kw), mut, q, rng

    def test_cache_flush_on_each_mutation_batch(self):
        eng, mut, q, rng = self._engine(cache_size=64)
        eng.search(q)
        eng.search(q)
        assert eng.stats()["cache_hits"] == 9
        mut.upsert(rng.randn(1, D).astype(np.float32))  # version bump
        eng.search(q)                                   # must recompute
        st = eng.stats()
        assert st["cache_hits"] == 9 and st["cache_misses"] == 18
        mut.delete(np.asarray([0]))
        eng.search(q)
        assert eng.stats()["cache_misses"] == 27
        mut.compact()
        eng.search(q)
        assert eng.stats()["cache_misses"] == 36

    def test_mutation_visible_through_engine(self):
        eng, mut, q, rng = self._engine(cache_size=64)
        row = (10.0 + 0.01 * rng.randn(D)).astype(np.float32)
        (ext,) = mut.upsert(row).tolist()
        d, i = eng.search(row)                          # its own neighbor
        assert i[0] == ext
        mut.delete(np.asarray([ext]))
        d, i = eng.search(row)                          # cached? no: flushed
        assert i[0] != ext

    def test_stats_surface_lifecycle_counters(self):
        eng, mut, q, rng = self._engine()
        mut.upsert(rng.randn(7, D).astype(np.float32))
        mut.delete(np.asarray([3]))
        st = eng.stats()
        assert st["delta_rows"] == 7
        assert st["tombstones"] == 1
        assert st["compactions"] == 0
        mut.compact()
        assert eng.stats()["compactions"] == 1
        # plain indexes don't grow the keys
        plain = RetrievalEngine(ExactIndex.build(mut.L, jnp.asarray(
            np.random.RandomState(0).randn(50, D).astype(np.float32))),
            k_top=5)
        assert "delta_rows" not in plain.stats()

    def test_batcher_front_door(self):
        from repro.serve import MicroBatcher
        eng, mut, q, rng = self._engine()
        batcher = MicroBatcher(eng, max_batch=8, max_wait_ms=1.0)
        futs = [batcher.submit(qr) for qr in q]
        ref_d, ref_i = mut.topk(jnp.asarray(q), 5)
        for r, fut in enumerate(futs):
            d, i = fut.result(timeout=30)
            np.testing.assert_array_equal(i, ref_i[r])
        batcher.close()
