"""Cross-layer conformance suite for the (d_out, d_in) metric-factor contract.

One parameterized suite over every MetricIndex backend (Exact / IVF /
IVFPQ / Mutable-over-each) × {square L, rectangular L, identity}:

  (a) factored-distance oracle — ``topk`` under L equals ``topk`` under
      the identity factor on pre-projected rows: d(x, y) = ||Lx - Ly||²
      means projecting first and scanning with I_{d_out} must return the
      same neighbors;
  (b) golden square-L bit-identity — answers match the pre-refactor
      stack exactly (fixtures in tests/golden/, regenerated only when a
      behavior change is intentional);
  (c) ``swap_metric`` square→rect→square round-trips agree with fresh
      builds at each rank (the retained raw rows make rank changes
      legal);
  (d) snapshots record ``l_shape`` and reject a rank-mismatched
      ``expect_L`` with a structural error, before the fingerprint gate;

plus the up-front L validation regressions (transposed / 1-D factors
used to die deep inside a jit with an opaque dot-dimension error).
"""

import importlib.util
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dml
from repro.serve import scan, snapshot
from repro.serve.engine import RetrievalEngine
from repro.serve.index import ExactIndex
from repro.serve.ivf import IVFIndex
from repro.serve.mutable import MutableIndex
from repro.serve.pq import IVFPQIndex

D_IN = 24
M = 240
NQ = 6
KTOP = 5

# nprobe == n_clusters and rerank == M: every row is visited and the
# exact rerank covers the whole candidate pool, so approximate backends
# are deterministic oracles regardless of how k-means falls out
IVF_KW = dict(n_clusters=8, nprobe=8, seed=0)
PQ_KW = dict(n_clusters=8, nprobe=8, seed=0, n_subspaces=5, bits=6,
             rerank_depth=M, store="device")

BACKENDS = ("exact", "ivf", "ivfpq",
            "mutable_exact", "mutable_ivf", "mutable_ivfpq")
L_KINDS = ("square", "rect", "identity")


def _data():
    rs = np.random.RandomState(7)
    gallery = rs.randn(M, D_IN).astype(np.float32)
    queries = rs.randn(NQ, D_IN).astype(np.float32)
    up_rows = rs.randn(8, D_IN).astype(np.float32)
    return gallery, queries, up_rows


def _make_L(kind: str) -> np.ndarray:
    rs = np.random.RandomState(11)
    if kind == "square":
        return (rs.randn(D_IN, D_IN) / np.sqrt(D_IN)).astype(np.float32)
    if kind == "rect":
        return (rs.randn(10, D_IN) / np.sqrt(D_IN)).astype(np.float32)
    return np.eye(D_IN, dtype=np.float32)


def _build(backend: str, L, gallery, up_rows=None):
    """Build one backend; mutable flavors get churn (upserts + deletes)."""
    if backend == "exact":
        return ExactIndex.build(L, jnp.asarray(gallery))
    if backend == "ivf":
        return IVFIndex.build(L, jnp.asarray(gallery), **IVF_KW)
    if backend == "ivfpq":
        return IVFPQIndex.build(L, jnp.asarray(gallery), **PQ_KW)
    base = backend.split("_", 1)[1]
    kw = {"exact": {}, "ivf": IVF_KW, "ivfpq": PQ_KW}[base]
    mut = MutableIndex.build(L, gallery, base=base, retain_raw=True, **kw)
    if up_rows is not None:
        mut.upsert(up_rows)                     # external ids M..M+7
        mut.delete([2, 17, M + 1])
    return mut


# -- (a) the factored-distance oracle ----------------------------------------

@pytest.mark.parametrize("l_kind", L_KINDS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_topk_matches_identity_on_preprojected(backend, l_kind):
    gallery, queries, up_rows = _data()
    L = _make_L(l_kind)
    d_out = L.shape[0]
    idx = _build(backend, L, gallery, up_rows)
    d1, i1 = idx.topk(jnp.asarray(queries), KTOP)

    eye = np.eye(d_out, dtype=np.float32)
    oracle = _build(backend, eye, gallery @ L.T,
                    None if up_rows is None else up_rows @ L.T)
    d2, i2 = oracle.topk(jnp.asarray(queries @ L.T), KTOP)

    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-5, atol=1e-5)


def test_exact_pallas_backend_rect_rank_parity():
    """The fused metric_topk kernel serves rectangular L too: ids match
    the XLA path exactly at a non-lane-aligned low rank."""
    gallery, queries, _ = _data()
    L = _make_L("rect")
    idx = _build("exact", L, gallery)
    d_x, i_x = idx.topk(jnp.asarray(queries), KTOP, backend="xla")
    d_p, i_p = idx.topk(jnp.asarray(queries), KTOP, backend="pallas")
    np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_p))
    np.testing.assert_allclose(np.asarray(d_x), np.asarray(d_p),
                               rtol=1e-4, atol=1e-4)


# -- (b) golden square-L bit-identity ----------------------------------------

def _load_golden_gen():
    path = os.path.join(os.path.dirname(__file__), "golden",
                        "gen_l_contract_golden.py")
    spec = importlib.util.spec_from_file_location("gen_l_contract_golden",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_square_l_bit_identical_to_golden():
    gen = _load_golden_gen()
    with np.load(gen.GOLDEN) as z:
        inputs = {k: z[k] for k in ("gallery", "queries", "L", "up_rows")}
        golden = {name: (z[f"dist_{name}"], z[f"ids_{name}"])
                  for name in ("exact", "ivf", "ivfpq", "mutable_exact",
                               "mutable_ivf", "mutable_ivfpq")}
    cases = gen.build_cases(inputs)
    for name, (d, i) in cases.items():
        gd, gi = golden[name]
        np.testing.assert_array_equal(np.asarray(i), gi, err_msg=name)
        np.testing.assert_array_equal(np.asarray(d, np.float32), gd,
                                      err_msg=name)


# -- (c) swap_metric rank round trip -----------------------------------------

@pytest.mark.parametrize("base", ("exact", "ivf", "ivfpq"))
def test_swap_metric_rank_round_trip(base):
    gallery, queries, up_rows = _data()
    L_sq, L_rect = _make_L("square"), _make_L("rect")
    kw = {"exact": {}, "ivf": IVF_KW, "ivfpq": PQ_KW}[base]

    mut = MutableIndex.build(L_sq, gallery, base=base, retain_raw=True,
                             **kw)
    mut.swap_metric(L_rect)                       # square -> rect
    fresh_rect = MutableIndex.build(L_rect, gallery, base=base,
                                    retain_raw=True, **kw)
    d_s, i_s = mut.topk(jnp.asarray(queries), KTOP)
    d_f, i_f = fresh_rect.topk(jnp.asarray(queries), KTOP)
    np.testing.assert_array_equal(i_s, i_f)
    np.testing.assert_array_equal(d_s, d_f)

    # mutation keeps working at the new rank (the delta buffer must be
    # re-sized to the new d_out, not the stale pre-swap one)
    ids = mut.upsert(up_rows)
    assert mut.delta_gp.shape[1] == L_rect.shape[0]
    mut.delete(ids[:2])

    mut.swap_metric(L_sq)                         # rect -> square, churn kept
    # mirror the same churn on a fresh square index: external ids line up,
    # and answers agree (allclose: the fresh index still holds the churn
    # in its delta buffer while the swap compacted it into the base)
    fresh_sq = MutableIndex.build(L_sq, gallery, base=base,
                                  retain_raw=True, **kw)
    fresh_sq.upsert(up_rows)
    fresh_sq.delete(ids[:2])
    d_s, i_s = mut.topk(jnp.asarray(queries), KTOP)
    d_f, i_f = fresh_sq.topk(jnp.asarray(queries), KTOP)
    np.testing.assert_array_equal(i_s, i_f)
    np.testing.assert_allclose(d_s, d_f, rtol=1e-5, atol=1e-5)


# -- (d) snapshot l_shape + rank-mismatch rejection --------------------------

@pytest.mark.parametrize("l_kind", ("square", "rect"))
@pytest.mark.parametrize("backend", ("exact", "mutable_ivf"))
def test_snapshot_preserves_l_shape(tmp_path, backend, l_kind):
    gallery, queries, up_rows = _data()
    L = _make_L(l_kind)
    idx = _build(backend, L, gallery, up_rows)
    manifest = snapshot.save_index(idx, str(tmp_path))
    assert manifest["l_shape"] == list(L.shape)

    loaded = snapshot.load_index(str(tmp_path), expect_L=L)
    d1, i1 = idx.topk(jnp.asarray(queries), KTOP)
    d2, i2 = loaded.topk(jnp.asarray(queries), KTOP)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_snapshot_rejects_rank_mismatched_expect_l(tmp_path):
    gallery, _, _ = _data()
    L_rect = _make_L("rect")
    idx = ExactIndex.build(L_rect, jnp.asarray(gallery))
    snapshot.save_index(idx, str(tmp_path))
    # wrong rank: the structural (shape) diagnosis, not the fingerprint one
    with pytest.raises(ValueError, match="rank-mismatched"):
        snapshot.load_index(str(tmp_path), expect_L=_make_L("square"))
    # same shape, different values: still the fingerprint gate
    other = _make_L("rect") + 1.0
    with pytest.raises(ValueError, match="fingerprint"):
        snapshot.load_index(str(tmp_path), expect_L=other)


# -- validation regressions (transposed / 1-D L used to die inside jit) ------

def test_project_queries_rejects_bad_l():
    _, queries, _ = _data()
    L = _make_L("rect")
    with pytest.raises(ValueError, match="d_in"):
        scan.project_queries(jnp.asarray(L.T), jnp.asarray(queries))
    with pytest.raises(ValueError, match="2-D"):
        scan.project_queries(jnp.asarray(L[0]), jnp.asarray(queries))


@pytest.mark.parametrize("build", (
    lambda L, g: ExactIndex.build(L, jnp.asarray(g)),
    lambda L, g: IVFIndex.build(L, jnp.asarray(g), **IVF_KW),
    lambda L, g: IVFPQIndex.build(L, jnp.asarray(g), **PQ_KW),
    lambda L, g: MutableIndex.build(L, g, base="exact"),
), ids=("exact", "ivf", "ivfpq", "mutable"))
def test_index_build_rejects_bad_l(build):
    gallery, _, _ = _data()
    L = _make_L("rect")
    with pytest.raises(ValueError, match="d_in"):
        build(jnp.asarray(L.T), gallery)          # transposed
    with pytest.raises(ValueError, match="2-D"):
        build(jnp.asarray(L[0]), gallery)         # 1-D


def test_square_transposed_l_names_the_transposition():
    """A square-but-transposed factor can't be caught by shape alone, but
    a (d_in, d_out) rectangular transposition gets the explicit hint."""
    gallery, _, _ = _data()
    bad = _make_L("rect").T                       # (24, 10): rows == d_in
    with pytest.raises(ValueError, match="transposed"):
        ExactIndex.build(jnp.asarray(bad), jnp.asarray(gallery))


def test_from_projected_rejects_dout_mismatch():
    gallery, _, _ = _data()
    L = _make_L("rect")                           # d_out = 10
    gp = (gallery @ _make_L("square").T).astype(np.float32)   # dim 24
    gn = np.sum(gp * gp, axis=1).astype(np.float32)
    with pytest.raises(ValueError, match="d_out"):
        ExactIndex.from_projected(L, gp, gn)
    with pytest.raises(ValueError, match="d_out"):
        IVFIndex.build_projected(L, gp, gn, **IVF_KW)
    with pytest.raises(ValueError, match="d_out"):
        IVFPQIndex.build_projected(L, gp, gn, **PQ_KW)


def test_swap_metric_rejects_bad_l():
    gallery, _, _ = _data()
    mut = MutableIndex.build(_make_L("square"), gallery, base="exact",
                             retain_raw=True)
    with pytest.raises(ValueError, match="d_in"):
        mut.swap_metric(_make_L("rect").T)
    with pytest.raises(ValueError, match="2-D"):
        mut.swap_metric(_make_L("rect")[0])


# -- the low-rank trainer knob -----------------------------------------------

def test_dml_config_l_rank_knob():
    cfg = dml.DMLConfig(feat_dim=64, l_rank=16)
    assert cfg.proj_dim == 16
    L = dml.init_params(cfg, jax.random.PRNGKey(0))
    assert L.shape == (16, 64)
    # M = L^T L is PSD by construction at any rank — no projection step
    w = np.linalg.eigvalsh(np.asarray(dml.M_from_L(L)))
    assert w.min() >= -1e-5
    assert np.sum(w > 1e-6) <= 16

    assert dml.DMLConfig(feat_dim=64).proj_dim == 64     # square default
    with pytest.raises(ValueError, match="disagree"):
        dml.DMLConfig(feat_dim=64, proj_dim=32, l_rank=16)
    with pytest.raises(ValueError, match="1..feat_dim"):
        dml.DMLConfig(feat_dim=64, l_rank=0)
    with pytest.raises(ValueError, match="1..feat_dim"):
        dml.DMLConfig(feat_dim=64, l_rank=65)


def test_lowrank_l_serves_through_engine():
    """A rectangular trained-shape L drops into the engine unchanged and
    stats report the (d_out, d_in) shape."""
    gallery, queries, _ = _data()
    L = _make_L("rect")
    engine = RetrievalEngine(ExactIndex.build(L, jnp.asarray(gallery)),
                             k_top=KTOP)
    d, i = engine.search(queries[:2])
    assert np.asarray(i).shape == (2, KTOP)
    assert engine.stats()["l_shape"] == [10, D_IN]
