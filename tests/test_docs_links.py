"""Docs link check: no dead relative links in README.md / docs/*.md.

Markdown links of the form ``[text](target)`` where ``target`` is a
relative path must resolve to a real file (anchors and external URLs are
skipped). Runs in the tier-1 suite and as its own CI step, so a doc
rename or move that orphans a link fails fast.
"""

import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _doc_files():
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return files


def test_docs_exist():
    # the documents the serving subsystem promises (PR 4's docs pass)
    for name in ("README.md", "docs/architecture.md", "docs/serving.md",
                 "docs/kernels.md"):
        assert (REPO / name).is_file(), f"missing doc {name}"


def test_no_dead_relative_links():
    dead = []
    for doc in _doc_files():
        for target in LINK.findall(doc.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]          # strip anchors
            if not path:
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                dead.append(f"{doc.relative_to(REPO)} -> {target}")
    assert not dead, "dead relative links:\n  " + "\n  ".join(dead)


if __name__ == "__main__":                          # CI: standalone run
    test_docs_exist()
    test_no_dead_relative_links()
    print(f"docs link check: {len(_doc_files())} files OK")
