"""Unit tests for the core DML objectives (paper Eq. 1-4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dml
from repro.data import pairs as pairdata

jax.config.update("jax_enable_x64", False)


def _toy(n=64, d=16, k=8, seed=0):
    rng = np.random.RandomState(seed)
    xs = rng.randn(n, d).astype(np.float32)
    ys = rng.randn(n, d).astype(np.float32)
    sim = (rng.rand(n) < 0.5).astype(np.int32)
    L = 0.3 * rng.randn(k, d).astype(np.float32)
    return jnp.asarray(L), jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(sim)


class TestObjective:
    def test_matches_M_form(self):
        L, xs, ys, _ = _toy()
        d2_L = dml.mahalanobis_sqdist(L, xs, ys)
        d2_M = dml.mahalanobis_sqdist_M(dml.M_from_L(L), xs, ys)
        np.testing.assert_allclose(d2_L, d2_M, rtol=1e-4, atol=1e-5)

    def test_pair_losses_structure(self):
        L, xs, ys, sim = _toy()
        losses = dml.pair_losses(L, xs, ys, sim, lam=2.0, margin=1.0)
        d2 = dml.mahalanobis_sqdist(L, xs, ys)
        expected = np.where(np.asarray(sim) == 1, np.asarray(d2),
                            2.0 * np.maximum(0.0, 1.0 - np.asarray(d2)))
        np.testing.assert_allclose(losses, expected, rtol=1e-5, atol=1e-6)

    def test_analytic_grad_matches_autodiff(self):
        L, xs, ys, sim = _toy()
        g_auto = jax.grad(dml.objective)(L, xs, ys, sim, 1.5, 1.0)
        g_analytic = dml.analytic_grad(L, xs, ys, sim, 1.5, 1.0)
        np.testing.assert_allclose(g_auto, g_analytic, rtol=1e-4, atol=1e-5)

    def test_zero_L_hinge_fully_active(self):
        _, xs, ys, sim = _toy()
        L0 = jnp.zeros((8, 16))
        losses = dml.pair_losses(L0, xs, ys, sim, lam=1.0, margin=1.0)
        # similar pairs -> 0 loss, dissimilar -> full margin
        np.testing.assert_allclose(
            losses, np.where(np.asarray(sim) == 1, 0.0, 1.0), atol=1e-6)

    def test_M_from_L_is_psd(self):
        L, *_ = _toy()
        w = np.linalg.eigvalsh(np.asarray(dml.M_from_L(L)))
        assert (w >= -1e-5).all()

    def test_psd_project(self):
        rng = np.random.RandomState(0)
        A = rng.randn(12, 12).astype(np.float32)
        A = 0.5 * (A + A.T)
        P = np.asarray(dml.psd_project(jnp.asarray(A)))
        w = np.linalg.eigvalsh(P)
        assert (w >= -1e-5).all()
        # projection is idempotent
        P2 = np.asarray(dml.psd_project(jnp.asarray(P)))
        np.testing.assert_allclose(P, P2, atol=1e-4)


class TestTriplet:
    def test_triplet_margin_semantics(self):
        rng = np.random.RandomState(1)
        a = jnp.asarray(rng.randn(32, 16).astype(np.float32))
        p = a + 0.01  # positives essentially at the anchor
        n = jnp.asarray(rng.randn(32, 16).astype(np.float32)) * 10.0
        L = jnp.eye(8, 16)
        losses = dml.triplet_losses(L, a, p, n, margin=1.0)
        # far negatives, near positives -> hinge inactive for most
        assert float(jnp.mean(losses == 0.0)) > 0.5


class TestEval:
    def test_average_precision_perfect(self):
        scores = jnp.asarray([3.0, 2.0, 1.0, 0.0])
        labels = jnp.asarray([1, 1, 0, 0])
        assert float(dml.average_precision(scores, labels)) == pytest.approx(1.0)

    def test_average_precision_random_is_half(self):
        rng = np.random.RandomState(0)
        scores = jnp.asarray(rng.randn(2000).astype(np.float32))
        labels = jnp.asarray((rng.rand(2000) < 0.5).astype(np.int32))
        ap = float(dml.average_precision(scores, labels))
        assert 0.4 < ap < 0.6

    def test_pr_curve_monotone_recall(self):
        rng = np.random.RandomState(0)
        prec, rec = dml.precision_recall_curve(
            rng.randn(500), (rng.rand(500) < 0.5).astype(int))
        assert (np.diff(rec) >= -1e-9).all()
        assert rec[-1] == pytest.approx(1.0)


class TestTrainingImprovesMetric:
    def test_sgd_on_blobs_beats_euclidean(self):
        cfg = pairdata.PairDatasetConfig(
            n_samples=600, feat_dim=32, n_classes=5, noise=1.2, seed=3)
        train_pairs, eval_pairs = pairdata.train_eval_split(
            cfg, 2000, 2000, 500, 500)
        from repro.core.ps.trainer import train_dml_single
        dcfg = dml.DMLConfig(feat_dim=32, proj_dim=16)
        L, hist = train_dml_single(dcfg, train_pairs, steps=150,
                                   batch_size=256, lr=5e-2)
        xs = jnp.asarray(eval_pairs["xs"]); ys = jnp.asarray(eval_pairs["ys"])
        labels = jnp.asarray(eval_pairs["sim"])
        ap_learned = float(dml.average_precision(dml.pair_scores(L, xs, ys), labels))
        ap_euclid = float(dml.average_precision(
            dml.pair_scores_euclidean(xs, ys), labels))
        assert hist[-1]["loss"] < hist[0]["loss"]
        assert ap_learned > ap_euclid + 0.02


class TestPairSampling:
    """data/pairs.py dedup satellite: self-pairs are masked, duplicate
    constraints are dropped, and seeded draws are deterministic."""

    def _labels(self, n=500, c=7, seed=0):
        return np.random.RandomState(seed).randint(0, c, n).astype(np.int32)

    def test_no_self_pairs_and_no_duplicates(self):
        y = self._labels()
        idx = pairdata.sample_pair_indices(y, 800, 800, seed=0)
        assert (idx["a"] != idx["b"]).all()
        # unordered (a, b) constraints are unique within each of S and D
        for want in (1, 0):
            m = idx["sim"] == want
            lo = np.minimum(idx["a"][m], idx["b"][m])
            hi = np.maximum(idx["a"][m], idx["b"][m])
            keys = lo * len(y) + hi
            assert len(np.unique(keys)) == len(keys)

    def test_labels_respected(self):
        y = self._labels()
        idx = pairdata.sample_pair_indices(y, 400, 400, seed=1)
        sim = idx["sim"] == 1
        assert (y[idx["a"][sim]] == y[idx["b"][sim]]).all()
        assert (y[idx["a"][~sim]] != y[idx["b"][~sim]]).all()

    def test_seeded_determinism(self):
        y = self._labels()
        i1 = pairdata.sample_pair_indices(y, 500, 500, seed=42)
        i2 = pairdata.sample_pair_indices(y, 500, 500, seed=42)
        for k in ("a", "b", "sim"):
            np.testing.assert_array_equal(i1[k], i2[k])
        i3 = pairdata.sample_pair_indices(y, 500, 500, seed=43)
        assert not np.array_equal(i1["a"], i3["a"])

    def test_sample_pairs_matches_contract(self):
        rng = np.random.RandomState(0)
        x = rng.randn(300, 8).astype(np.float32)
        y = self._labels(300, 5)
        pairs = pairdata.sample_pairs(x, y, 200, 200, seed=2)
        assert pairs["xs"].shape == (400, 8)
        assert pairs["sim"].sum() == 200
        # no self-pair can produce an identical feature row pair here
        assert (np.abs(pairs["xs"] - pairs["ys"]).sum(1) > 0).all()

    def test_exhaustion_raises(self):
        y = np.zeros(8, np.int32)       # one class: max C(8,2)=28 pairs
        with pytest.raises(ValueError, match="distinct"):
            pairdata.sample_pair_indices(y, 29, 0, seed=0)

    def test_near_exhaustion_fills(self):
        y = np.zeros(10, np.int32)      # exactly C(10,2)=45 similar pairs
        idx = pairdata.sample_pair_indices(y, 45, 0, seed=0)
        lo = np.minimum(idx["a"], idx["b"])
        hi = np.maximum(idx["a"], idx["b"])
        assert len(np.unique(lo * 10 + hi)) == 45

    def test_batches_have_distinct_constraints(self):
        y = self._labels(400, 6)
        idx = pairdata.sample_pair_indices(y, 600, 600, seed=0)
        stream = pairdata.pair_batches(
            {"a": idx["a"], "b": idx["b"], "sim": idx["sim"]},
            batch_size=128, seed=0, balanced=False)
        batch = next(stream)
        keys = np.asarray(batch["a"]) * 400 + np.asarray(batch["b"])
        assert len(np.unique(keys)) == len(keys)
