"""Property-based tests (hypothesis) on the serving front end.

Random interleavings of submit / clock-advance / queue-pressure must never
violate the scheduler's invariants:

  * admission is bounded — a class queue never exceeds its cap, and a
    submit is rejected iff the queue is full at that instant;
  * expiry is exact — under a frozen drain clock, a request is served iff
    its deadline is still ahead of the clock, expired otherwise (never
    both, never neither: no silent drops);
  * ordering — every dispatched batch is non-decreasing in priority, and
    within one class requests reach the engine in FIFO submit order;
  * the load controller only moves one ladder level at a time, only in
    the direction its watermark justifies, and only after its hysteresis
    window elapsed on the injected clock.

The worker is pinned inside a gated FakeEngine while the op sequence
runs, so queue state evolves exactly as modeled — no timing races.
"""

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from _traffic_utils import FakeEngine, make_query  # noqa: E402
from repro.serve import (DeadlineExceededError, FakeClock,  # noqa: E402
                         LoadController, PriorityClass, RejectedError,
                         RequestScheduler)

SETTINGS = dict(max_examples=25, deadline=None)

D = 4
PLUG = 10 ** 6
CLASSES = (
    PriorityClass("interactive", priority=0, deadline_s=0.05, queue_cap=3),
    PriorityClass("batch", priority=1, deadline_s=0.2, queue_cap=4),
    PriorityClass("mining", priority=2, deadline_s=1.0, queue_cap=5),
)
PRIORITY_OF = {c.name: c.priority for c in CLASSES}
LADDER = ({}, {"nprobe": 4}, {"nprobe": 2})

_op = st.one_of(
    st.tuples(st.just("submit"), st.integers(0, len(CLASSES) - 1),
              st.sampled_from([0.01, 0.08, 5.0])),       # deadline_s
    st.tuples(st.just("advance"), st.sampled_from([0.005, 0.02, 0.1])),
)


class TestSchedulerInterleavings:
    @given(st.lists(_op, min_size=1, max_size=40))
    @settings(**SETTINGS)
    def test_invariants_hold_under_any_interleaving(self, ops):
        clock = FakeClock()
        eng = FakeEngine(d=D)
        sched = RequestScheduler(
            eng, classes=CLASSES, max_batch=4, max_wait_ms=0.0,
            clock=clock, ladder=LADDER, high_watermark=6, low_watermark=1,
            degrade_window_s=0.01, restore_window_s=0.02)
        # pin the worker inside the engine so the op sequence sees exact,
        # model-checkable queue state (nothing drains until we say so)
        eng.gate.clear()
        plug = sched.submit(make_query(D, PLUG), priority="mining",
                            deadline_s=60.0)
        assert eng.entered.wait(10), "worker never reached the engine"

        depth = {c.name: 0 for c in CLASSES}   # queued while pinned
        submit_order = {c.name: [] for c in CLASSES}
        records = {}                           # rid -> (cls, t_deadline, fut)
        rid = 0
        try:
            for op in ops:
                if op[0] == "advance":
                    clock.advance(op[1])
                    continue
                _, ci, dl = op
                cls = CLASSES[ci]
                was_full = depth[cls.name] >= cls.queue_cap
                try:
                    fut = sched.submit(make_query(D, rid),
                                       priority=cls.name, deadline_s=dl)
                except RejectedError:
                    # bounded admission, and never spurious rejection
                    assert was_full
                    continue
                assert not was_full, "queue exceeded its cap"
                depth[cls.name] += 1
                submit_order[cls.name].append(rid)
                records[rid] = (cls.name, clock.now() + dl, fut)
                rid += 1
        finally:
            eng.gate.set()                     # unpin before the join
            assert sched.close(timeout=30, drain=True)

        t_final = clock.now()                  # frozen through the drain
        served = [i for i in eng.served_ids() if i != PLUG]
        assert plug.result(timeout=0)

        # exact expiry + exactly-once + no silent drops
        for r, (cls_name, t_dl, fut) in records.items():
            assert fut.done()
            if t_dl <= t_final:
                with pytest.raises(DeadlineExceededError):
                    fut.result(timeout=0)
                assert r not in served, "expired request reached the engine"
            else:
                dists, idxs = fut.result(timeout=0)
                assert idxs.shape == (eng.k_top,)
        assert len(served) == len(set(served)), "request served twice"

        # every batch non-decreasing in priority; FIFO within a class
        for ids, knobs in eng.calls:
            prios = [PRIORITY_OF[records[i][0]] for i in ids if i != PLUG]
            assert prios == sorted(prios)
            assert knobs in [dict(lv) for lv in LADDER]
        for cls_name, order in submit_order.items():
            expect = [r for r in order if records[r][1] > t_final]
            got = [i for i in served if records[i][0] == cls_name]
            assert got == expect

        obs = sched.observability()
        assert obs["queue_depth"] == 0 and obs["closed"]
        n_expired = sum(1 for _, t_dl, _ in records.values()
                        if t_dl <= t_final)
        assert obs["expired"] == n_expired
        assert (sum(c["completed"] for c in obs["classes"].values())
                == len(served) + 1)            # + the plug


class TestLoadControllerInterleavings:
    @given(st.lists(
        st.tuples(st.sampled_from([0, 3, 10]),           # depth regime
                  st.sampled_from([0.0, 0.005, 0.02, 0.1])),
        min_size=1, max_size=60))
    @settings(**SETTINGS)
    def test_ladder_moves_are_justified_and_windowed(self, steps):
        clock = FakeClock()
        c = LoadController(LADDER, clock, high_watermark=5, low_watermark=1,
                           degrade_window_s=0.01, restore_window_s=0.03)
        for dep, dt in steps:
            clock.advance(dt)
            before = c.level
            knobs = c.observe(dep)
            assert 0 <= c.level < len(LADDER)
            assert knobs == LADDER[c.level]
            assert abs(c.level - before) <= 1
            if c.level > before:
                assert dep > 5                 # degrade only when over
            if c.level < before:
                assert dep <= 1                # restore only when drained
        for tr in c.transitions:
            assert abs(tr.level_to - tr.level_from) == 1
        # hysteresis: each move's window elapses after the previous move
        # (ladder moves reset both windows — no free-fall to the floor)
        prev_t = 0.0
        for tr in c.transitions:
            window = (c.degrade_window_s if tr.level_to > tr.level_from
                      else c.restore_window_s)
            assert tr.t - prev_t >= window - 1e-9
            prev_t = tr.t


class TestRectangularRankKernelParity:
    """The (d_out, d_in) metric contract holds at *every* rank: random
    rectangular factors with d_out <= d_in through the index builds, then
    Pallas (interpret) vs XLA scan parity — ids exact, PR 7's bit-level
    contract unchanged by the low-rank generalization."""

    @staticmethod
    def _case(seed, d_in, d_out, n_rows=64, n_q=3):
        import numpy as np

        rs = np.random.RandomState(seed)
        L = (rs.randn(d_out, d_in) / np.sqrt(d_in)).astype(np.float32)
        g = rs.randn(n_rows, d_in).astype(np.float32)
        q = rs.randn(n_q, d_in).astype(np.float32)
        return L, g, q

    @given(st.integers(0, 2 ** 16), st.integers(2, 24), st.data())
    @settings(max_examples=10, deadline=None)
    def test_ivf_scan_parity_at_any_rank(self, seed, d_in, data):
        import jax.numpy as jnp
        import numpy as np

        from repro.serve.ivf import IVFIndex

        d_out = data.draw(st.integers(1, d_in), label="d_out")
        L, g, q = self._case(seed, d_in, d_out)
        idx = IVFIndex.build(L, jnp.asarray(g), n_clusters=4, nprobe=3,
                             seed=0)
        d_x, i_x = idx.topk(jnp.asarray(q), 5, scan_impl="xla")
        d_p, i_p = idx.topk(jnp.asarray(q), 5, scan_impl="pallas")
        np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_p))
        np.testing.assert_allclose(np.asarray(d_x), np.asarray(d_p),
                                   rtol=1e-4, atol=1e-4)

    @given(st.integers(0, 2 ** 16), st.integers(2, 24), st.data())
    @settings(max_examples=10, deadline=None)
    def test_pq_adc_parity_at_any_rank(self, seed, d_in, data):
        import jax.numpy as jnp
        import numpy as np

        from repro.serve.pq import IVFPQIndex

        d_out = data.draw(st.integers(1, d_in), label="d_out")
        n_sub = data.draw(st.integers(1, d_out), label="n_subspaces")
        L, g, q = self._case(seed, d_in, d_out)
        idx = IVFPQIndex.build(L, jnp.asarray(g), n_clusters=4, nprobe=3,
                               seed=0, n_subspaces=n_sub, bits=4,
                               rerank_depth=0)
        # rerank=0: pure ADC, where the scan contract is bit-identical
        d_x, i_x = idx.topk(jnp.asarray(q), 5, scan_impl="xla", rerank=0)
        d_p, i_p = idx.topk(jnp.asarray(q), 5, scan_impl="pallas",
                            rerank=0)
        np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_p))
        np.testing.assert_array_equal(np.asarray(d_x), np.asarray(d_p))
