"""The paper's motivating applications (§1): the learned metric must improve
kNN classification and k-means clustering over raw Euclidean distance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dml, eval_tasks
from repro.core.ps.trainer import train_dml_single
from repro.data import pairs as pairdata


@pytest.fixture(scope="module")
def trained_metric():
    cfg = pairdata.PairDatasetConfig(
        n_samples=1200, feat_dim=48, n_classes=6, kind="noisy_subspace",
        noise=0.5, seed=0)
    x, y = pairdata.make_features(cfg)
    train_x, train_y = x[:900], y[:900]
    test_x, test_y = x[900:], y[900:]
    idx = pairdata.sample_pair_indices(train_y, 4000, 4000, seed=1)
    train_pairs = {"xs": train_x[idx["a"]], "ys": train_x[idx["b"]],
                   "sim": idx["sim"]}
    dcfg = dml.DMLConfig(feat_dim=48, proj_dim=24)
    L, _ = train_dml_single(dcfg, train_pairs, steps=250, batch_size=256,
                            lr=2e-2, seed=0)
    return L, train_x, train_y, test_x, test_y


class TestKNN:
    def test_learned_metric_beats_euclidean(self, trained_metric):
        L, train_x, train_y, test_x, test_y = trained_metric
        acc_l = eval_tasks.knn_accuracy(L, train_x, train_y, test_x, test_y)
        acc_e = eval_tasks.knn_accuracy(None, train_x, train_y,
                                        test_x, test_y)
        assert acc_l > acc_e + 0.1, (acc_l, acc_e)
        assert acc_l > 0.8

    def test_topk_selection_matches_full_argsort(self):
        """Regression: knn_classify uses lax.top_k (k-selection) instead
        of a full argsort over the (n_test, n_train) distance matrix —
        the neighbor sets and predictions must agree with the old path."""
        from repro.kernels.pairwise_dist import metric_sqdist_matrix
        rng = np.random.RandomState(3)
        train_x = rng.randn(160, 24).astype(np.float32)
        train_y = rng.randint(0, 5, 160).astype(np.int32)
        test_x = rng.randn(48, 24).astype(np.float32)
        L = 0.4 * rng.randn(12, 24).astype(np.float32)
        for k in (1, 5, 16):
            D = metric_sqdist_matrix(L, jnp.asarray(test_x),
                                     jnp.asarray(train_x))
            nn_old = np.asarray(jnp.argsort(D, axis=1)[:, :k])
            _, nn_new = jax.lax.top_k(-D, k)
            np.testing.assert_array_equal(np.asarray(nn_new), nn_old)
            pred = eval_tasks.knn_classify(L, train_x, train_y, test_x,
                                           k=k)
            votes = train_y[nn_old]
            expect = np.array([np.argmax(np.bincount(v, minlength=5))
                               for v in votes])
            np.testing.assert_array_equal(np.asarray(pred), expect)

    def test_knn_perfect_on_separated_data(self):
        rng = np.random.RandomState(0)
        centers = 10 * rng.randn(3, 8)
        y = rng.randint(0, 3, 120)
        x = centers[y] + 0.1 * rng.randn(120, 8)
        acc = eval_tasks.knn_accuracy(None, x[:80], y[:80], x[80:], y[80:],
                                      k=3)
        assert acc == 1.0


class TestClustering:
    def test_learned_metric_improves_purity(self, trained_metric):
        L, train_x, train_y, _, _ = trained_metric
        a_l, _ = eval_tasks.metric_kmeans(L, train_x, 6, seed=0)
        a_e, _ = eval_tasks.metric_kmeans(None, train_x, 6, seed=0)
        p_l = eval_tasks.clustering_purity(a_l, train_y)
        p_e = eval_tasks.clustering_purity(a_e, train_y)
        assert p_l > p_e + 0.1, (p_l, p_e)

    def test_purity_bounds(self):
        labels = np.array([0, 0, 1, 1])
        assert eval_tasks.clustering_purity(np.array([0, 0, 1, 1]),
                                            labels) == 1.0
        assert eval_tasks.clustering_purity(np.array([0, 0, 0, 0]),
                                            labels) == 0.5


class TestTripletExtension:
    """Paper §4: the framework 'can be easily extended to support
    triple-wise constraints' — train with the triplet objective end to end."""

    def test_triplet_training_beats_euclidean(self):
        from repro.core import losses
        from repro.optim import sgd
        cfg = pairdata.PairDatasetConfig(
            n_samples=900, feat_dim=32, n_classes=5, kind="noisy_subspace",
            noise=0.5, seed=3)
        x, y = pairdata.make_features(cfg)
        tr_x, tr_y, te_x, te_y = x[:700], y[:700], x[700:], y[700:]
        tri = pairdata.sample_triplet_indices(tr_y, 6000, seed=0)
        stream = pairdata.triplet_batches_from_indices(tr_x, tri, 256, seed=0)
        dcfg = dml.DMLConfig(feat_dim=32, proj_dim=16)
        L = dml.init_params(dcfg, jax.random.PRNGKey(0))
        opt = sgd(2e-2)
        opt_state = opt.init(L)

        @jax.jit
        def step(L, opt_state, batch):
            (loss, _), g = jax.value_and_grad(
                lambda p, b: losses.dml_triplet_loss(p, b), has_aux=True)(
                    L, batch)
            u, opt_state = opt.update(g, opt_state, L)
            return L + u, opt_state, loss

        first = last = None
        for t in range(200):
            L, opt_state, loss = step(L, opt_state, next(stream))
            first = float(loss) if first is None else first
            last = float(loss)
        assert last < 0.5 * first
        acc_l = eval_tasks.knn_accuracy(L, tr_x, tr_y, te_x, te_y)
        acc_e = eval_tasks.knn_accuracy(None, tr_x, tr_y, te_x, te_y)
        assert acc_l > acc_e + 0.05, (acc_l, acc_e)
