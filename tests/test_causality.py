"""Causality property across architecture families: for causal models,
logits at position t must be invariant to any change in tokens after t.
For the encoder (bidirectional) the opposite must hold. This catches mask
bugs, scan off-by-ones and cache/window mistakes in one sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model

CAUSAL_ARCHS = ["yi-6b", "command-r-35b", "gemma-7b", "smollm-135m",
                "granite-moe-1b-a400m", "qwen3-moe-30b-a3b",
                "rwkv6-1.6b", "zamba2-2.7b"]


@pytest.mark.parametrize("arch", CAUSAL_ARCHS)
def test_future_tokens_do_not_leak(arch):
    cfg = reduced(get_config(arch)).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    B, T, cut = 2, 24, 11
    toks = rng.randint(0, cfg.vocab_size, (B, T)).astype(np.int32)
    toks2 = toks.copy()
    toks2[:, cut:] = rng.randint(0, cfg.vocab_size, (B, T - cut))
    la, _ = model.apply(params, {"tokens": jnp.asarray(toks)})
    lb, _ = model.apply(params, {"tokens": jnp.asarray(toks2)})
    # positions < cut see identical context
    np.testing.assert_allclose(np.asarray(la[:, :cut]),
                               np.asarray(lb[:, :cut]),
                               rtol=1e-4, atol=1e-4)
    # sanity: future positions DO differ (inputs differ)
    assert float(jnp.max(jnp.abs(la[:, cut:] - lb[:, cut:]))) > 1e-4


def test_sliding_window_is_still_causal():
    cfg = reduced(get_config("yi-6b")).replace(dtype="float32",
                                               attention="sliding", window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    B, T, cut = 2, 32, 17
    toks = rng.randint(0, cfg.vocab_size, (B, T)).astype(np.int32)
    toks2 = toks.copy()
    toks2[:, cut:] = rng.randint(0, cfg.vocab_size, (B, T - cut))
    la, _ = model.apply(params, {"tokens": jnp.asarray(toks)})
    lb, _ = model.apply(params, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(np.asarray(la[:, :cut]),
                               np.asarray(lb[:, :cut]), rtol=1e-4, atol=1e-4)


def test_encoder_is_bidirectional():
    cfg = reduced(get_config("hubert-xlarge")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    B, T, cut = 2, 16, 8
    e1 = rng.randn(B, T, cfg.d_model).astype(np.float32)
    e2 = e1.copy()
    e2[:, cut:] += rng.randn(B, T - cut, cfg.d_model).astype(np.float32)
    la, _ = model.apply(params, {"embeddings": jnp.asarray(e1)})
    lb, _ = model.apply(params, {"embeddings": jnp.asarray(e2)})
    # bidirectional: EARLY positions must change too
    assert float(jnp.max(jnp.abs(la[:, :cut] - lb[:, :cut]))) > 1e-4
