"""Integration guard for the dry-run machinery: compiles one real config on
the 256-chip production mesh in a subprocess (512 forced host devices) and
checks the record's invariants — so regressions in sharding rules, the HLO
parser or the roofline derivation fail CI, not the next full sweep."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.slow      # 512-device compile; -m "not slow" skips

_CHILD = r"""
import json
from repro.launch.dryrun import dryrun_one
rec = dryrun_one("smollm-135m", "train_4k", multi_pod=False)
print("DRYRUN_OK " + json.dumps(rec))
"""


@pytest.fixture(scope="module")
def record():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("DRYRUN_OK")][0]
    return json.loads(line[len("DRYRUN_OK "):])


def test_compiles_on_production_mesh(record):
    assert record["status"] == "ok"
    assert record["n_chips"] == 256
    assert record["mesh"] == {"data": 16, "model": 16}


def test_fits_hbm(record):
    assert record["memory"]["temp_size"] < 16 * 2**30
    assert record["memory"]["argument_size"] < 16 * 2**30


def test_loop_corrected_flops_sane(record):
    """HLO dot FLOPs must cover at least fwd+bwd model FLOPs (6ND) and stay
    within an order of magnitude of it (attention + remat overhead)."""
    model_flops_per_chip = 6 * 110e6 * 256 * 4096 / 256  # non-embed params
    hlo = record["flops_per_chip"]
    assert hlo > 0.8 * model_flops_per_chip, (hlo, model_flops_per_chip)
    assert hlo < 100 * model_flops_per_chip


def test_collectives_present_and_loop_multiplied(record):
    c = record["collectives"]
    assert c["total_bytes"] > 0
    # FSDP all-gathers fire once per layer per pass: far more than a handful
    assert sum(c["counts"].values()) > 50


def test_roofline_terms_consistent(record):
    t = record["roofline"]
    assert t["compute_s"] == pytest.approx(
        record["flops_per_chip"] / 197e12, rel=1e-6)
    assert t["memory_s"] == pytest.approx(
        record["hbm_bytes_per_chip"] / 819e9, rel=1e-6)
    assert t["dominant"] in ("compute", "memory", "collective")
