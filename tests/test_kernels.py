"""Pallas kernel tests: shape/dtype sweeps + allclose against ref.py oracles
(kernels execute in interpret mode on CPU; TPU is the lowering target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dml_pair import (dml_pair_fused, dml_pair_loss_fused,
                                    dml_pair_loss_reference, dml_pair_ref)
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.pairwise_dist import (metric_sqdist_matrix,
                                         pairwise_sqdist, pairwise_sqdist_ref)


class TestDMLPairKernel:
    @pytest.mark.parametrize("B,k,d", [
        (8, 8, 8), (64, 32, 48), (256, 128, 512), (100, 60, 780),
        (512, 600, 780), (32, 100, 224),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_loss_matches_oracle(self, B, k, d, dtype):
        rng = np.random.RandomState(B + k + d)
        L = jnp.asarray(0.2 * rng.randn(k, d), dtype)
        xs = jnp.asarray(rng.randn(B, d), dtype)
        ys = jnp.asarray(rng.randn(B, d), dtype)
        sim = jnp.asarray((rng.rand(B) < 0.5).astype(np.int32))
        ref = dml_pair_loss_reference(L.astype(jnp.float32),
                                      xs.astype(jnp.float32),
                                      ys.astype(jnp.float32), sim, 1.3, 1.0)
        out = dml_pair_loss_fused(L.astype(jnp.float32),
                                  xs.astype(jnp.float32),
                                  ys.astype(jnp.float32), sim, 1.3, 1.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=1e-5)

    @pytest.mark.parametrize("B,k,d", [(64, 32, 48), (256, 128, 512),
                                       (100, 60, 780)])
    def test_gradients_match_oracle(self, B, k, d):
        rng = np.random.RandomState(7)
        L = jnp.asarray(0.2 * rng.randn(k, d), jnp.float32)
        xs = jnp.asarray(rng.randn(B, d), jnp.float32)
        ys = jnp.asarray(rng.randn(B, d), jnp.float32)
        sim = jnp.asarray((rng.rand(B) < 0.5).astype(np.int32))
        g_ref = jax.grad(dml_pair_loss_reference, argnums=(0, 1, 2))(
            L, xs, ys, sim, 1.3, 1.0)
        g_out = jax.grad(dml_pair_loss_fused, argnums=(0, 1, 2))(
            L, xs, ys, sim, 1.3, 1.0)
        for a, b in zip(g_ref, g_out):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-3, atol=1e-5)

    def test_pad_path_zero_contribution(self):
        # B not divisible by the tile: padding must not change the mean
        rng = np.random.RandomState(0)
        B, k, d = 37, 16, 24
        L = jnp.asarray(0.3 * rng.randn(k, d), jnp.float32)
        xs = jnp.asarray(rng.randn(B, d), jnp.float32)
        ys = jnp.asarray(rng.randn(B, d), jnp.float32)
        sim = jnp.asarray(np.ones(B, np.int32))
        ref = dml_pair_loss_reference(L, xs, ys, sim)
        out = dml_pair_loss_fused(L, xs, ys, sim)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)

    def test_raw_kernel_outputs(self):
        rng = np.random.RandomState(1)
        B, k, d = 256, 128, 512
        L = jnp.asarray(0.2 * rng.randn(k, d), jnp.float32)
        xs = jnp.asarray(rng.randn(B, d), jnp.float32)
        ys = jnp.asarray(rng.randn(B, d), jnp.float32)
        sim = jnp.asarray((rng.rand(B) < 0.5).astype(np.int32))
        losses, d2, proj = dml_pair_fused(L, xs, ys, sim, lam=1.0, margin=1.0,
                                          block_b=64, block_k=64, block_d=128)
        l_ref, d2_ref, p_ref = dml_pair_ref(L, xs, ys, sim)
        np.testing.assert_allclose(losses, l_ref, rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(d2, d2_ref, rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(proj, p_ref, rtol=2e-5, atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,T,H,K,dh", [
        (2, 128, 4, 4, 64),      # MHA
        (2, 128, 8, 2, 64),      # GQA 4:1
        (1, 256, 4, 1, 32),      # MQA
        (2, 64, 4, 4, 128),
        (1, 512, 16, 4, 64),
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_oracle(self, B, T, H, K, dh, causal):
        rng = np.random.RandomState(T + H)
        q = jnp.asarray(rng.randn(B, T, H, dh), jnp.float32)
        k = jnp.asarray(rng.randn(B, T, K, dh), jnp.float32)
        v = jnp.asarray(rng.randn(B, T, K, dh), jnp.float32)
        ref = attention_ref(q, k, v, causal=causal)
        out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=2e-5)

    @pytest.mark.parametrize("window", [32, 64, 128])
    def test_sliding_window(self, window):
        rng = np.random.RandomState(window)
        q = jnp.asarray(rng.randn(1, 256, 4, 32), jnp.float32)
        k = jnp.asarray(rng.randn(1, 256, 4, 32), jnp.float32)
        v = jnp.asarray(rng.randn(1, 256, 4, 32), jnp.float32)
        ref = attention_ref(q, k, v, causal=True, window=window)
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=2e-5)

    def test_bf16_inputs(self):
        rng = np.random.RandomState(3)
        q = jnp.asarray(rng.randn(2, 128, 4, 64), jnp.bfloat16)
        k = jnp.asarray(rng.randn(2, 128, 4, 64), jnp.bfloat16)
        v = jnp.asarray(rng.randn(2, 128, 4, 64), jnp.bfloat16)
        ref = attention_ref(q, k, v, causal=True)
        out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=5e-2, atol=5e-2)


class TestPairwiseDist:
    @pytest.mark.parametrize("N,M,k", [
        (64, 64, 32), (256, 128, 512), (128, 256, 64), (512, 512, 600),
    ])
    def test_matches_oracle(self, N, M, k):
        rng = np.random.RandomState(N + M)
        xp = jnp.asarray(rng.randn(N, k), jnp.float32)
        yp = jnp.asarray(rng.randn(M, k), jnp.float32)
        from repro.kernels.pairwise_dist.ops import _largest_tile
        out = pairwise_sqdist(xp, yp, block_n=_largest_tile(N),
                              block_m=_largest_tile(M),
                              block_c=_largest_tile(k))
        ref = pairwise_sqdist_ref(xp, yp)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-3)

    def test_metric_matrix_consistent_with_dml(self):
        from repro.core import dml
        rng = np.random.RandomState(0)
        L = jnp.asarray(0.3 * rng.randn(16, 24), jnp.float32)
        x = jnp.asarray(rng.randn(40, 24), jnp.float32)
        D = metric_sqdist_matrix(L, x, x)
        # diagonal = self-distance = 0, and matches dml.mahalanobis_sqdist
        np.testing.assert_allclose(np.asarray(jnp.diagonal(D)), 0.0,
                                   atol=1e-3)
        d2 = dml.mahalanobis_sqdist(L, x[:1].repeat(40, 0), x)
        np.testing.assert_allclose(np.asarray(D[0]), np.asarray(d2),
                                   rtol=1e-4, atol=1e-3)
