"""Multi-device PS sync checks, run in a subprocess with 8 host devices.

Invoked by tests/test_ps_sync.py. Exits non-zero on failure; prints a JSON
summary on success. Kept standalone so the main pytest process stays at one
device (dry-run rule: never force device count globally).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import json  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import dml  # noqa: E402
from repro.core import losses as losses_mod  # noqa: E402
from repro.core.ps import sync, trainer  # noqa: E402
from repro.data import pairs as pairdata  # noqa: E402
from repro.data.loader import partition_pairs  # noqa: E402
from repro.optim import sgd  # noqa: E402


def main():
    assert jax.device_count() == 8, jax.device_count()
    out = {}

    cfg = pairdata.PairDatasetConfig(
        n_samples=400, feat_dim=24, n_classes=4, noise=1.0, seed=0)
    train_pairs, eval_pairs = pairdata.train_eval_split(cfg, 1500, 1500, 400, 400)
    dcfg = dml.DMLConfig(feat_dim=24, proj_dim=12)

    # --- BSP with P workers must equal single-device SGD on the merged batch
    # (sanity of the "server aggregation = all-reduce" mapping).
    ps_cfg = sync.PSConfig(n_workers=4, sync="bsp")
    tcfg = trainer.DMLTrainConfig(dml=dcfg, ps=ps_cfg, batch_size=128,
                                  steps=60, lr=5e-2)
    L_bsp, hist_bsp = trainer.train_dml_distributed(tcfg, train_pairs)
    assert hist_bsp[-1]["loss"] < hist_bsp[0]["loss"], "BSP loss did not drop"
    out["bsp_loss_first"] = hist_bsp[0]["loss"]
    out["bsp_loss_last"] = hist_bsp[-1]["loss"]

    # BSP keeps worker copies bit-identical
    state = sync.init_state(sgd(0.05), dml.init_params(dcfg, jax.random.PRNGKey(0)),
                            ps_cfg)
    mesh = sync.make_worker_mesh(4)
    step = sync.make_train_step(lambda p, b: losses_mod.dml_pair_loss(p, b),
                                sgd(0.05), ps_cfg, mesh)
    batches = trainer._stacked_batches(partition_pairs(train_pairs, 4), 64, seed=0)
    for _ in range(3):
        state, _ = step(state, next(batches))
    pstack = np.asarray(state.params)
    for w in range(1, 4):
        np.testing.assert_allclose(pstack[0], pstack[w], rtol=0, atol=0)
    out["bsp_identical"] = True

    # --- Local SGD (tau=5): copies drift between syncs, merge on sync steps
    ps_local = sync.PSConfig(n_workers=4, sync="local", tau=5)
    tcfg_l = trainer.DMLTrainConfig(dml=dcfg, ps=ps_local, batch_size=128,
                                    steps=60, lr=5e-2)
    L_loc, hist_loc = trainer.train_dml_distributed(tcfg_l, train_pairs)
    assert hist_loc[-1]["loss"] < hist_loc[0]["loss"], "local-SGD loss did not drop"
    out["local_loss_last"] = hist_loc[-1]["loss"]

    state = sync.init_state(sgd(0.05), dml.init_params(dcfg, jax.random.PRNGKey(1)),
                            ps_local)
    step_l = sync.make_train_step(lambda p, b: losses_mod.dml_pair_loss(p, b),
                                  sgd(0.05), ps_local, mesh)
    batches = trainer._stacked_batches(partition_pairs(train_pairs, 4), 64, seed=1)
    # after 2 steps (not a sync step), copies must differ
    for _ in range(2):
        state, _ = step_l(state, next(batches))
    pstack = np.asarray(state.params)
    assert np.abs(pstack[0] - pstack[1]).max() > 1e-7, "local copies did not drift"
    # after 5 steps (sync step), copies must coincide
    for _ in range(3):
        state, _ = step_l(state, next(batches))
    pstack = np.asarray(state.params)
    np.testing.assert_allclose(pstack[0], pstack[3], atol=1e-6)
    out["local_drift_and_merge"] = True

    # --- SSP (s=3) converges too
    ps_ssp = sync.PSConfig(n_workers=4, sync="ssp", staleness=3)
    tcfg_s = trainer.DMLTrainConfig(dml=dcfg, ps=ps_ssp, batch_size=128,
                                    steps=60, lr=5e-2)
    L_ssp, hist_ssp = trainer.train_dml_distributed(tcfg_s, train_pairs)
    assert hist_ssp[-1]["loss"] < hist_ssp[0]["loss"], "SSP loss did not drop"
    out["ssp_loss_last"] = hist_ssp[-1]["loss"]

    # --- all three beat Euclidean on held-out AP
    xs, ys = jnp.asarray(eval_pairs["xs"]), jnp.asarray(eval_pairs["ys"])
    lab = jnp.asarray(eval_pairs["sim"])
    ap_e = float(dml.average_precision(dml.pair_scores_euclidean(xs, ys), lab))
    for name, L in [("bsp", L_bsp), ("local", L_loc), ("ssp", L_ssp)]:
        ap = float(dml.average_precision(dml.pair_scores(L, xs, ys), lab))
        out[f"ap_{name}"] = ap
        assert ap > ap_e, f"{name}: AP {ap} <= euclidean {ap_e}"
    out["ap_euclidean"] = ap_e

    print("PS_CHECK_OK " + json.dumps(out))


if __name__ == "__main__":
    main()
