"""Tenant router tests: N metrics over one shared gallery.

Everything is sized tiny (M ~ 120 rows, d_in = 8) and seeded, so view
builds are fast and deterministic — which is exactly the property the
promote-equals-fresh-build oracle leans on.
"""

import numpy as np
import pytest

from repro.obs import MetricsRegistry
from repro.serve import (ExactIndex, RequestScheduler, RetrievalEngine,
                         TenantError, TenantFingerprintError, TenantRouter,
                         attach_view, load_tenants, save_tenants)

M, D = 120, 8
K = 5


@pytest.fixture
def feats():
    rng = np.random.RandomState(0)
    return rng.randn(M, D).astype(np.float32)


def _L(seed, d_out=4):
    return (0.3 * np.random.RandomState(seed)
            .randn(d_out, D)).astype(np.float32)


def _router(feats, **kw):
    kw.setdefault("k_top", K)
    return TenantRouter(feats, **kw)


def _oracle(L, feats, q, k=K):
    """Exact top-k over ALL rows under metric L, as (dists, ids)."""
    eng = RetrievalEngine(ExactIndex.build(L, feats), k_top=k)
    return eng.search(q)


IVF_KW = dict(n_clusters=4, nprobe=4)


class TestServing:
    @pytest.mark.parametrize("backend,kw", [
        ("exact", {}), ("ivf", IVF_KW)])
    def test_search_matches_exact_oracle(self, feats, backend, kw):
        router = _router(feats)
        router.add_tenant("a", _L(1), backend=backend, build_kwargs=kw)
        q = feats[3] + 0.01
        dists, ids = router.search("a", q)
        o_dists, o_ids = _oracle(_L(1), feats, q)
        np.testing.assert_array_equal(ids, o_ids)
        np.testing.assert_allclose(dists, o_dists, rtol=1e-5)

    def test_lazy_warm_and_idempotence(self, feats):
        router = _router(feats)
        t = router.add_tenant("a", _L(1))
        assert not t.warm and t.engine is None
        router.search("a", feats[0])        # first query builds
        assert t.warm
        eng = t.engine
        router.warm("a")                    # fresh: no rebuild
        assert t.engine is eng
        assert router.observability()["tenants"]["a"]["warm"]

    def test_per_tenant_caches_never_collide(self, feats):
        """The SAME query bytes against two tenants must hit two
        different caches and return each tenant's own answer."""
        router = _router(feats)
        router.add_tenant("a", _L(1))
        router.add_tenant("b", _L(2))
        q = feats[7] + 0.02
        _, ids_a = router.search("a", q)
        _, ids_b = router.search("b", q)
        assert not np.array_equal(ids_a, ids_b), \
            "distinct metrics should rank differently (test setup)"
        # repeat: both hits, each from its OWN cache, answers unchanged
        _, ids_a2 = router.search("a", q)
        _, ids_b2 = router.search("b", q)
        np.testing.assert_array_equal(ids_a, ids_a2)
        np.testing.assert_array_equal(ids_b, ids_b2)
        for name in ("a", "b"):
            st = router.tenant(name).engine.stats()
            assert st["cache_hits"] == 1 and st["cache_misses"] == 1

    def test_submit_via_scheduler_equals_direct_search(self, feats):
        router = _router(feats)
        router.add_tenant("a", _L(1), deadline_s=30.0)
        router.add_tenant("b", _L(2), backend="ivf", build_kwargs=IVF_KW,
                          deadline_s=30.0)
        sched = RequestScheduler(router.warm("a").engine,
                                 registry=router.registry,
                                 max_wait_ms=0.0, degrade=False)
        router.attach_scheduler(sched)
        try:
            qs = feats[:6] + 0.01
            futs = [(name, i, router.submit(name, qs[i]))
                    for i, name in enumerate(["a", "b", "a", "b", "a",
                                              "b"])]
            for name, i, fut in futs:
                dists, ids = fut.result(timeout=30)
                o_dists, o_ids = router.search(name, qs[i])
                np.testing.assert_array_equal(ids, o_ids)
                np.testing.assert_allclose(dists, o_dists, rtol=1e-5)
            assert set(sched.routes()) == {"a", "b"}
        finally:
            sched.close()

    def test_submit_without_scheduler_raises(self, feats):
        router = _router(feats)
        router.add_tenant("a", _L(1))
        with pytest.raises(TenantError, match="scheduler"):
            router.submit("a", feats[0])


class TestGalleryMutation:
    def test_extend_gives_stable_ids_and_staleness(self, feats):
        router = _router(feats)
        router.add_tenant("a", _L(1))
        router.warm("a")
        gen0 = router.generation
        new = np.full((3, D), 9.0, np.float32)
        new_ids = router.extend(new)
        np.testing.assert_array_equal(new_ids, [M, M + 1, M + 2])
        assert router.generation == gen0 + 1
        assert router.observability()["tenants"]["a"]["stale"]
        # a query near the new rows must now find them, by stable id
        _, ids = router.search("a", new[0])
        assert set(new_ids.tolist()) <= set(ids.tolist())

    def test_remove_tombstones_and_ids_survive(self, feats):
        router = _router(feats)
        router.add_tenant("a", _L(1))
        q = feats[3] + 0.001
        _, ids = router.search("a", q)
        victim = int(ids[0])
        assert router.remove([victim]) == 1
        assert router.remove([victim]) == 0     # already dead
        _, ids2 = router.search("a", q)         # lazy rebuild
        assert victim not in ids2.tolist()
        # survivors keep their original ids (positions in the store)
        assert set(ids2.tolist()) <= set(range(M)) - {victim}
        with pytest.raises(TenantError, match="out of range"):
            router.remove([M + 50])


class TestShadow:
    def test_deterministic_sampling_and_overlap(self, feats):
        router = _router(feats)
        router.add_tenant("a", _L(1))
        arm = router.register_shadow("a", _L(1), sample_rate=0.5)
        for i in range(8):
            router.search("a", feats[i] + 0.01)
        # rate 0.5 -> exactly every 2nd request mirrored, no RNG
        assert arm.n_mirrored == 4
        # identical L => identical answers => overlap exactly 1.0
        assert arm.stats()["overlap_at_k"] == 1.0
        snap = router.registry.snapshot()
        mirrored = snap["counters"]["shadow_mirrored_total"]["values"]
        assert mirrored == {"tenant=a": 4.0}

    def test_promote_is_bit_identical_to_fresh_build(self, feats):
        router = _router(feats)
        router.add_tenant("a", _L(1), backend="ivf", build_kwargs=IVF_KW)
        router.search("a", feats[0])
        L_cand = _L(9)
        router.register_shadow("a", L_cand, sample_rate=1.0)
        router.search("a", feats[1])            # mirrored once
        t = router.promote("a")
        assert t.shadow is None
        assert t.fingerprint != _router(feats).add_tenant(
            "x", _L(1)).fingerprint
        fresh = _router(feats)
        fresh.add_tenant("f", L_cand, backend="ivf", build_kwargs=IVF_KW)
        probe = feats[:16] + 0.01
        d_live, i_live = router.search("a", probe)
        d_fresh, i_fresh = fresh.search("f", probe)
        np.testing.assert_array_equal(i_live, i_fresh)
        np.testing.assert_array_equal(d_live, d_fresh)

    def test_promote_cold_tenant_and_errors(self, feats):
        router = _router(feats)
        router.add_tenant("a", _L(1))
        with pytest.raises(TenantError, match="no shadow"):
            router.promote("a")
        router.register_shadow("a", _L(9))
        t = router.promote("a")                 # never served live
        assert t.warm and t.shadow is None
        _, ids = router.search("a", feats[0])
        _, o_ids = _oracle(_L(9), feats, feats[0])
        np.testing.assert_array_equal(ids, o_ids)
        with pytest.raises(TenantError, match="sample_rate"):
            router.register_shadow("a", _L(9), sample_rate=0.0)


class TestSnapshots:
    def test_multi_tenant_round_trip(self, feats, tmp_path):
        router = _router(feats)
        router.add_tenant("a", _L(1))
        router.add_tenant("b", _L(2), backend="ivf", build_kwargs=IVF_KW)
        router.add_tenant("cold", _L(3))
        router.warm("a")
        router.warm("b")
        save_tenants(router, str(tmp_path))

        back = load_tenants(str(tmp_path))
        assert set(back.tenants()) == {"a", "b", "cold"}
        assert back.tenant("a").warm and back.tenant("b").warm
        assert not back.tenant("cold").warm     # cold stays cold
        q = feats[5] + 0.01
        for name in ("a", "b", "cold"):
            d0, i0 = router.search(name, q)
            d1, i1 = back.search(name, q)
            np.testing.assert_array_equal(i0, i1)
            np.testing.assert_allclose(d0, d1, rtol=1e-6)

    def test_stale_views_persist_as_cold(self, feats, tmp_path):
        router = _router(feats)
        router.add_tenant("a", _L(1))
        router.warm("a")
        router.extend(np.ones((2, D), np.float32))  # view now stale
        save_tenants(router, str(tmp_path))
        back = load_tenants(str(tmp_path))
        assert not back.tenant("a").warm
        assert back.gallery_rows == M + 2

    def test_attach_fingerprint_mismatch_rejected(self, feats, tmp_path):
        router = _router(feats)
        router.add_tenant("a", _L(1))
        router.warm("a")
        save_tenants(router, str(tmp_path))
        other = _router(feats)
        other.add_tenant("a", _L(2))            # DIFFERENT factor
        with pytest.raises(TenantFingerprintError):
            attach_view(other, "a", str(tmp_path / "tenant_a"))
        assert not other.tenant("a").warm

    def test_load_with_swapped_factors_typed_error(self, feats, tmp_path):
        router = _router(feats)
        router.add_tenant("a", _L(1))
        save_tenants(router, str(tmp_path))
        # corrupt: overwrite factors.npz with a different L
        np.savez(str(tmp_path / "factors.npz"), a=_L(2))
        with pytest.raises(TenantFingerprintError,
                           match="different saves"):
            load_tenants(str(tmp_path))


class TestAccountingAndObs:
    def test_memory_counts_gallery_once(self, feats):
        router = _router(feats)
        for i, name in enumerate(("a", "b", "c")):
            router.add_tenant(name, _L(i + 1))
            router.warm(name)
        mem = router.memory()
        assert mem["gallery"] >= feats.nbytes
        assert set(mem["tenants"]) == {"a", "b", "c"}
        assert mem["total"] == (mem["gallery"]
                                + sum(mem["tenants"].values()))
        # the win: total < 3 independent stacks each holding raw + view
        independent = sum(mem["gallery"] + v
                          for v in mem["tenants"].values())
        assert mem["total"] < independent

    def test_engine_series_carry_tenant_labels(self, feats):
        router = _router(feats)
        router.add_tenant("a", _L(1))
        router.add_tenant("b", _L(2))
        router.search("a", feats[0])
        router.search("b", feats[0])
        snap = router.registry.snapshot()
        reqs = snap["counters"]["engine_requests_total"]["values"]
        assert set(reqs) == {"tenant=a", "tenant=b"}
        assert snap["counters"]["tenant_requests_total"]["values"] == {
            "tenant=a": 1.0, "tenant=b": 1.0}

    def test_validation_errors(self, feats):
        router = _router(feats)
        with pytest.raises(TenantError, match="invalid tenant name"):
            router.add_tenant("bad#name", _L(1))
        with pytest.raises(TenantError, match="unknown backend"):
            router.add_tenant("a", _L(1), backend="faiss")
        with pytest.raises(TenantError, match="L must be"):
            router.add_tenant("a", np.zeros((4, D + 1), np.float32))
        router.add_tenant("a", _L(1))
        with pytest.raises(TenantError, match="already registered"):
            router.add_tenant("a", _L(2))
        with pytest.raises(TenantError, match="unknown tenant"):
            router.tenant("zzz")
        with pytest.raises(TenantError, match="gallery must be"):
            TenantRouter(np.zeros((M,), np.float32))
