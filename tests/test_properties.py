"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dml
from repro.optim import adam, adamw, sgd, momentum, clip_by_global_norm, apply_updates

SETTINGS = dict(max_examples=25, deadline=None)


def _arrays(draw, B, d, k, seed):
    rng = np.random.RandomState(seed)
    L = jnp.asarray(0.3 * rng.randn(k, d), jnp.float32)
    xs = jnp.asarray(rng.randn(B, d), jnp.float32)
    ys = jnp.asarray(rng.randn(B, d), jnp.float32)
    sim = jnp.asarray((rng.rand(B) < 0.5).astype(np.int32))
    return L, xs, ys, sim


class TestDMLInvariants:
    @given(st.integers(2, 32), st.integers(2, 16), st.integers(2, 12),
           st.integers(0, 10**6))
    @settings(**SETTINGS)
    def test_distances_nonnegative_and_psd(self, B, d, k, seed):
        k = min(k, d)
        L, xs, ys, _ = _arrays(None, B, d, k, seed)
        d2 = dml.mahalanobis_sqdist(L, xs, ys)
        assert (np.asarray(d2) >= -1e-5).all()
        # M = L^T L is PSD regardless of L — the factorization's point
        w = np.linalg.eigvalsh(np.asarray(dml.M_from_L(L)))
        assert (w >= -1e-4 * max(1.0, abs(w).max())).all()

    @given(st.integers(2, 32), st.integers(2, 16), st.integers(2, 12),
           st.integers(0, 10**6))
    @settings(**SETTINGS)
    def test_identity_of_indiscernibles(self, B, d, k, seed):
        k = min(k, d)
        L, xs, _, _ = _arrays(None, B, d, k, seed)
        d2 = dml.mahalanobis_sqdist(L, xs, xs)
        np.testing.assert_allclose(np.asarray(d2), 0.0, atol=1e-5)

    @given(st.integers(2, 32), st.integers(2, 16), st.integers(2, 12),
           st.integers(0, 10**6))
    @settings(**SETTINGS)
    def test_symmetry(self, B, d, k, seed):
        k = min(k, d)
        L, xs, ys, _ = _arrays(None, B, d, k, seed)
        np.testing.assert_allclose(
            np.asarray(dml.mahalanobis_sqdist(L, xs, ys)),
            np.asarray(dml.mahalanobis_sqdist(L, ys, xs)), rtol=1e-5,
            atol=1e-6)

    @given(st.integers(2, 32), st.integers(2, 16), st.integers(2, 12),
           st.integers(0, 10**6), st.floats(0.1, 5.0))
    @settings(**SETTINGS)
    def test_loss_nonnegative_and_lambda_monotone(self, B, d, k, seed, lam):
        k = min(k, d)
        L, xs, ys, sim = _arrays(None, B, d, k, seed)
        l1 = dml.pair_losses(L, xs, ys, sim, lam=lam)
        l2 = dml.pair_losses(L, xs, ys, sim, lam=lam * 2)
        assert (np.asarray(l1) >= 0).all()
        assert (np.asarray(l2) >= np.asarray(l1) - 1e-6).all()

    @given(st.integers(2, 24), st.integers(2, 12), st.integers(2, 10),
           st.integers(0, 10**6))
    @settings(**SETTINGS)
    def test_analytic_grad_equals_autodiff(self, B, d, k, seed):
        k = min(k, d)
        L, xs, ys, sim = _arrays(None, B, d, k, seed)
        g1 = jax.grad(dml.objective)(L, xs, ys, sim, 1.0, 1.0)
        g2 = dml.analytic_grad(L, xs, ys, sim, 1.0, 1.0)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-3, atol=1e-5)

    @given(st.integers(0, 10**6))
    @settings(**SETTINGS)
    def test_ap_bounds(self, seed):
        rng = np.random.RandomState(seed)
        n = rng.randint(4, 200)
        scores = jnp.asarray(rng.randn(n).astype(np.float32))
        labels = jnp.asarray((rng.rand(n) < 0.5).astype(np.int32))
        if int(labels.sum()) == 0:
            return
        ap = float(dml.average_precision(scores, labels))
        assert 0.0 <= ap <= 1.0 + 1e-6


class TestOptimizerInvariants:
    @given(st.sampled_from(["sgd", "momentum", "adam", "adamw"]),
           st.integers(0, 10**6))
    @settings(**SETTINGS)
    def test_descends_quadratic(self, name, seed):
        rng = np.random.RandomState(seed)
        target = jnp.asarray(rng.randn(8).astype(np.float32))
        opt = {"sgd": sgd(0.1), "momentum": momentum(0.05),
               "adam": adam(0.1), "adamw": adamw(0.1, weight_decay=0.0)}[name]
        x = jnp.zeros(8)
        state = opt.init(x)
        loss = lambda p: jnp.sum(jnp.square(p - target))
        l0 = float(loss(x))
        for _ in range(60):
            g = jax.grad(loss)(x)
            upd, state = opt.update(g, state, x)
            x = apply_updates(x, upd)
        assert float(loss(x)) < 0.2 * l0

    @given(st.floats(0.1, 10.0), st.integers(0, 10**6))
    @settings(**SETTINGS)
    def test_clip_norm_bound(self, max_norm, seed):
        rng = np.random.RandomState(seed)
        g = {"a": jnp.asarray(rng.randn(5, 3).astype(np.float32)),
             "b": jnp.asarray(rng.randn(7).astype(np.float32))}
        clipped, gn = clip_by_global_norm(g, max_norm)
        cn = float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                                for x in jax.tree.leaves(clipped))))
        assert cn <= max_norm * (1 + 1e-4)


class TestCheckpointRoundtrip:
    @given(st.integers(0, 10**6))
    @settings(max_examples=10, deadline=None)
    def test_roundtrip(self, seed):
        import tempfile
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        rng = np.random.RandomState(seed)
        tree = {
            "a": jnp.asarray(rng.randn(4, 3).astype(np.float32)),
            "nested": {"b": jnp.asarray(rng.randint(0, 10, 5)),
                       "c": jnp.asarray(rng.randn(2, 2, 2).astype(np.float32))},
        }
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, step=3, tree=tree)
            restored, step = restore_checkpoint(d, tree)
            assert step == 3
            for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
