"""SSD chunk Pallas kernel: shape/dtype sweeps vs the sequential oracle,
plus integration with the Mamba2 layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels.ssd_chunk import ssd_core, ssd_scan, ssd_scan_ref
from repro.models import mamba2


class TestSSDKernel:
    @pytest.mark.parametrize("G,T,p,n,Q", [
        (4, 64, 16, 8, 16), (2, 128, 64, 64, 32), (8, 96, 32, 16, 48),
        (1, 256, 64, 64, 128), (3, 32, 8, 8, 32),
    ])
    def test_matches_oracle(self, G, T, p, n, Q):
        rng = np.random.RandomState(G + T)
        xs = jnp.asarray(rng.randn(G, T, p), jnp.float32)
        Bm = jnp.asarray(rng.randn(G, T, n), jnp.float32)
        Cm = jnp.asarray(rng.randn(G, T, n), jnp.float32)
        dt = jnp.asarray(np.abs(rng.randn(G, T)) * 0.1, jnp.float32)
        la = jnp.asarray(-np.abs(rng.randn(G, T)) * 0.5, jnp.float32)
        y, hf = ssd_scan(xs, Bm, Cm, dt, la, chunk=Q)
        yr, hr = ssd_scan_ref(xs, Bm, Cm, dt, la)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(hf), np.asarray(hr),
                                   rtol=1e-4, atol=1e-4)

    def test_bf16_inputs(self):
        rng = np.random.RandomState(0)
        G, T, p, n = 2, 64, 32, 16
        xs = jnp.asarray(rng.randn(G, T, p), jnp.bfloat16)
        Bm = jnp.asarray(rng.randn(G, T, n), jnp.bfloat16)
        Cm = jnp.asarray(rng.randn(G, T, n), jnp.bfloat16)
        dt = jnp.asarray(np.abs(rng.randn(G, T)) * 0.1, jnp.float32)
        la = jnp.asarray(-np.abs(rng.randn(G, T)) * 0.5, jnp.float32)
        y, _ = ssd_scan(xs, Bm, Cm, dt, la, chunk=16)
        yr, _ = ssd_scan_ref(xs, Bm, Cm, dt, la)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_state_continuity_across_chunks(self):
        """Final state from chunked kernel == running two half-scans."""
        rng = np.random.RandomState(1)
        G, T, p, n = 2, 64, 16, 8
        args = (jnp.asarray(rng.randn(G, T, p), jnp.float32),
                jnp.asarray(rng.randn(G, T, n), jnp.float32),
                jnp.asarray(rng.randn(G, T, n), jnp.float32),
                jnp.asarray(np.abs(rng.randn(G, T)) * 0.1, jnp.float32),
                jnp.asarray(-np.abs(rng.randn(G, T)) * 0.5, jnp.float32))
        _, h_full = ssd_scan(*args, chunk=16)
        _, h_ref = ssd_scan_ref(*args)
        np.testing.assert_allclose(np.asarray(h_full), np.asarray(h_ref),
                                   rtol=1e-4, atol=1e-5)


class TestMamba2KernelPath:
    def test_kernel_path_matches_chunked_jnp(self):
        cfg = reduced(get_config("zamba2-2.7b")).replace(
            dtype="float32", ssm_tile_dtype="float32")
        m = mamba2.init_mamba2(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(2, 64, cfg.d_model), jnp.float32)
        out_jnp = mamba2.apply_mamba2(m, x, cfg, chunk=16)
        out_ker = mamba2.apply_mamba2_kernel(m, x, cfg, chunk=16)
        np.testing.assert_allclose(np.asarray(out_ker), np.asarray(out_jnp),
                                   rtol=2e-3, atol=2e-4)
