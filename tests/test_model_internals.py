"""Model-internal oracles: chunked forms vs exact sequential recurrences,
attention paths, MoE dispatch vs dense reference, sharding rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import attention, mamba2, moe, rwkv6
from repro.sharding.partition import logical_to_physical


def _cfg(name, **kw):
    return reduced(get_config(name)).replace(dtype="float32", **kw)


class TestAttentionPaths:
    @pytest.mark.parametrize("causal,kind,window", [
        (True, "full", 0), (True, "sliding", 24), (False, "full", 0)])
    def test_chunked_equals_naive(self, causal, kind, window):
        cfg = _cfg("yi-6b", causal=causal, attention=kind,
                   window=window or 4096)
        rng = np.random.RandomState(0)
        B, T, H, K, dh = 2, 128, cfg.n_heads, cfg.kv_heads, cfg.dim_per_head
        q = jnp.asarray(rng.randn(B, T, H, dh), jnp.float32)
        k = jnp.asarray(rng.randn(B, T, K, dh), jnp.float32)
        v = jnp.asarray(rng.randn(B, T, K, dh), jnp.float32)
        ref = attention.attend_naive(q, k, v, cfg)
        out = attention.attend_chunked(q, k, v, cfg, q_chunk=32, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)

    def test_chunked_is_differentiable(self):
        cfg = _cfg("yi-6b")
        rng = np.random.RandomState(0)
        q = jnp.asarray(rng.randn(1, 64, 4, 32), jnp.float32)
        k = jnp.asarray(rng.randn(1, 64, 2, 32), jnp.float32)
        v = jnp.asarray(rng.randn(1, 64, 2, 32), jnp.float32)

        def f(q, k, v):
            return jnp.sum(attention.attend_chunked(q, k, v, cfg,
                                                    q_chunk=16, kv_chunk=16))

        def f_ref(q, k, v):
            return jnp.sum(attention.attend_naive(q, k, v, cfg))

        g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-4)

    def test_ring_buffer_cache_sliding(self):
        cfg = _cfg("yi-6b", attention="sliding", window=8)
        c = attention.init_cache(cfg, batch=2, max_seq=100, dtype=jnp.float32)
        assert c.k.shape[1] == 8  # ring buffer, not max_seq


class TestMamba2:
    def test_chunked_equals_sequential(self):
        cfg = _cfg("zamba2-2.7b", ssm_tile_dtype="float32")
        m = mamba2.init_mamba2(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(2, 64, cfg.d_model), jnp.float32)
        out_c = mamba2.apply_mamba2(m, x, cfg, chunk=16)
        out_r = mamba2.apply_mamba2_ref(m, x, cfg)
        np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                                   rtol=1e-3, atol=1e-4)

    def test_bf16_tiles_close_to_ref(self):
        cfg = _cfg("zamba2-2.7b", ssm_tile_dtype="bfloat16")
        m = mamba2.init_mamba2(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(2, 64, cfg.d_model), jnp.float32)
        out_c = mamba2.apply_mamba2(m, x, cfg, chunk=16)
        out_r = mamba2.apply_mamba2_ref(m, x, cfg)
        rel = float(jnp.max(jnp.abs(out_c - out_r))) / float(
            jnp.max(jnp.abs(out_r)))
        assert rel < 0.03, rel

    def test_decode_matches_prefill(self):
        cfg = _cfg("zamba2-2.7b", ssm_tile_dtype="float32")
        m = mamba2.init_mamba2(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(1)
        B, T = 2, 12
        x = jnp.asarray(rng.randn(B, T, cfg.d_model), jnp.float32)
        full = mamba2.apply_mamba2(m, x, cfg, chunk=4)
        cache = mamba2.init_cache(cfg, B, dtype=jnp.float32)
        outs = []
        for t in range(T):
            y, cache = mamba2.decode_step(m, x[:, t:t + 1], cache, cfg)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   rtol=1e-3, atol=1e-4)


class TestRWKV6:
    def test_chunked_equals_sequential(self):
        cfg = _cfg("rwkv6-1.6b")
        p = rwkv6.init_rwkv6(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        x = jnp.asarray(0.5 * rng.randn(2, 64, cfg.d_model), jnp.float32)
        out_c = rwkv6.apply_rwkv6(p, x, cfg, chunk=16)
        out_r = rwkv6.apply_rwkv6_ref(p, x, cfg)
        np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                                   rtol=1e-3, atol=1e-4)

    def test_state_carries_context(self):
        # decoding with the state must differ from decoding from scratch —
        # i.e. the wkv state actually carries history
        cfg = _cfg("rwkv6-1.6b")
        p = rwkv6.init_rwkv6(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(2)
        x = jnp.asarray(0.5 * rng.randn(1, 1, cfg.d_model), jnp.float32)
        fresh = rwkv6.init_cache(cfg, 1, dtype=jnp.float32)
        # random (not constant) bump: the per-head group norm nearly cancels
        # uniform shifts of S, which would make this test vacuous
        bump = jax.random.normal(jax.random.PRNGKey(5), fresh.S.shape)
        warm = fresh._replace(S=fresh.S + bump)
        y1, _ = rwkv6.decode_step(p, x, fresh, cfg)
        y2, _ = rwkv6.decode_step(p, x, warm, cfg)
        assert float(jnp.max(jnp.abs(y1 - y2))) > 1e-6


class TestMoE:
    def test_grouped_equals_dense_when_capacity_ample(self):
        cfg = _cfg("granite-moe-1b-a400m")
        p = moe.init_moe(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(2, 16, cfg.d_model), jnp.float32)
        y_g, aux_g = moe.apply_moe(p, x, cfg, mesh=None)
        y_d, aux_d = moe.apply_moe_dense(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_d),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(float(aux_g), float(aux_d), rtol=1e-4)

    def test_aux_loss_uniform_router_is_one(self):
        # perfectly uniform routing gives aux ~ E * E*(1/E)*(1/E)*k/k = 1
        cfg = _cfg("granite-moe-1b-a400m")
        p = moe.init_moe(cfg, jax.random.PRNGKey(0))
        p = dict(p, router=jnp.zeros_like(p["router"]))
        x = jnp.asarray(np.random.RandomState(0).randn(2, 64, cfg.d_model),
                        jnp.float32)
        _, aux = moe.apply_moe(p, x, cfg)
        assert 0.9 < float(aux) < 1.3


class TestShardingRules:
    def test_divisibility_fallback(self):
        import jax as _jax
        mesh = _jax.make_mesh((1, 1), ("data", "model"))
        # shape divides: sharded; doesn't: replicated
        spec = logical_to_physical(("heads", None), mesh, shape=(9, 4))
        assert spec == jax.sharding.PartitionSpec("model", None) or \
            spec == jax.sharding.PartitionSpec(None, None)

    def test_nondividing_heads_replicate(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # 9 heads on a 16-wide axis can't shard -> None (simulated with
        # explicit size check against a fake shape)
        from repro.sharding import partition
        spec = partition.logical_to_physical(("heads",), mesh, shape=(9,))
        # model axis size 1 divides anything; use a synthetic rule check:
        spec16 = partition.logical_to_physical(
            ("heads",), jax.make_mesh((1,), ("model",)), shape=(9,))
        assert spec16 is not None  # smoke: callable under any mesh
