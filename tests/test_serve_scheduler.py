"""Traffic-shaped front end tests: admission, priorities, deadlines,
degradation, close semantics, stats — all deterministic on FakeClock.

Choreography pattern: a cleared FakeEngine ``gate`` pins the worker
inside the engine (rendezvous via ``entered``), the test stuffs/advances/
inspects queues in a known state, then opens the gate. With virtual time
frozen, pop order, batch contents, and controller decisions are exact —
no sleeps, no timing-window asserts anywhere in this file.
"""

import threading
from concurrent.futures import CancelledError
from types import SimpleNamespace

import numpy as np
import pytest

from _traffic_utils import FakeEngine, make_query
from repro.serve import (DeadlineExceededError, FakeClock, LatencyWindow,
                         LoadController, MicroBatcher, PriorityClass,
                         RejectedError, RequestScheduler, default_ladder)

D = 4


def _scheduler(eng, clock, **kw):
    kw.setdefault("max_wait_ms", 0.0)
    return RequestScheduler(eng, clock=clock, **kw)


def _plug(eng, sched, rid=999):
    """Park the worker inside the engine: close the gate, submit a plug
    request, and wait until the engine reports the worker entered."""
    eng.gate.clear()
    eng.entered.clear()
    fut = sched.submit(make_query(D, rid), priority="mining")
    assert eng.entered.wait(timeout=30), "worker never reached the engine"
    return fut


class TestPriorityAndDeadlines:
    def test_batch_formed_priority_first_fifo_within_class(self):
        eng = FakeEngine(d=D)
        sched = _scheduler(eng, FakeClock(), max_batch=16, degrade=False)
        try:
            plug = _plug(eng, sched)
            # submit in deliberately inverted priority order while the
            # worker is pinned; they all sit queued
            subs = [(100, "batch"), (101, "batch"), (200, "mining"),
                    (10, "interactive"), (11, "interactive")]
            futs = [sched.submit(make_query(D, r), priority=p)
                    for r, p in subs]
            eng.gate.set()
            plug.result(timeout=30)
            for f in futs:
                f.result(timeout=30)
            # one coalesced batch after the plug, highest-priority first,
            # FIFO within each class
            assert eng.calls[1][0] == [10, 11, 100, 101, 200]
        finally:
            assert sched.close()

    def test_expired_fail_fast_and_never_reach_engine(self):
        eng = FakeEngine(d=D)
        clock = FakeClock()
        sched = _scheduler(eng, clock, degrade=False)
        try:
            plug = _plug(eng, sched)
            doomed = [sched.submit(make_query(D, r), deadline_s=0.05)
                      for r in (1, 2, 3)]
            alive = sched.submit(make_query(D, 4), deadline_s=10.0)
            clock.advance(0.1)          # expire the 0.05s deadlines
            eng.gate.set()
            plug.result(timeout=30)
            assert alive.result(timeout=30)[1].shape == (eng.k_top,)
            for f in doomed:
                with pytest.raises(DeadlineExceededError):
                    f.result(timeout=30)
            assert eng.served_ids() == [999, 4], \
                "expired requests must never occupy a batch slot"
            st = sched.stats()["classes"]["interactive"]
            assert st["expired"] == 3 and st["completed"] == 1
        finally:
            assert sched.close()

    def test_submit_validation(self):
        eng = FakeEngine(d=D)
        sched = _scheduler(eng, FakeClock(), degrade=False)
        try:
            with pytest.raises(ValueError):
                sched.submit(make_query(D, 0), priority="vip")
            with pytest.raises(ValueError):
                sched.submit(make_query(D, 0), k_top=0)
            with pytest.raises(ValueError):
                sched.submit(make_query(D, 0), k_top=eng.k_top + 1)
            with pytest.raises(ValueError):
                sched.submit(make_query(D, 0), deadline_s=0.0)
            with pytest.raises(ValueError):
                sched.submit(np.zeros((D + 1,), np.float32))
        finally:
            assert sched.close()


class TestAdmissionControl:
    def test_bounded_queue_rejects_typed(self):
        eng = FakeEngine(d=D)
        classes = (PriorityClass("interactive", 0, 1.0, queue_cap=2),
                   PriorityClass("mining", 2, 10.0, queue_cap=8))
        sched = _scheduler(eng, FakeClock(), classes=classes,
                           degrade=False)
        try:
            plug = _plug(eng, sched)        # mining: leaves interactive
            ok = [sched.submit(make_query(D, r)) for r in (1, 2)]
            with pytest.raises(RejectedError):
                sched.submit(make_query(D, 3))
            st = sched.stats()["classes"]["interactive"]
            assert st["rejected"] == 1 and st["queue_depth"] == 2
            eng.gate.set()
            for f in ok + [plug]:
                f.result(timeout=30)
            # a rejected request never held a slot: both admitted ones
            # (and only those) were served
            assert 3 not in eng.served_ids()
        finally:
            assert sched.close()

    def test_rejection_is_synchronous_no_future_leak(self):
        eng = FakeEngine(d=D)
        classes = (PriorityClass("interactive", 0, 1.0, queue_cap=1),)
        sched = _scheduler(eng, FakeClock(), classes=classes,
                           degrade=False)
        try:
            eng.gate.clear()
            eng.entered.clear()
            f1 = sched.submit(make_query(D, 1))
            assert eng.entered.wait(timeout=30)
            f2 = sched.submit(make_query(D, 2))     # fills the queue
            with pytest.raises(RejectedError):
                sched.submit(make_query(D, 3))
            eng.gate.set()
            assert f1.result(timeout=30) and f2.result(timeout=30)
        finally:
            assert sched.close()


class TestCloseSemantics:
    def test_close_reports_failure_then_success(self):
        eng = FakeEngine(d=D)
        sched = _scheduler(eng, FakeClock(), degrade=False)
        plug = _plug(eng, sched)
        # worker is pinned inside the engine: join must time out and
        # close must SAY so (the old batcher close swallowed this)
        assert sched.close(timeout=0.2) is False
        eng.gate.set()
        assert sched.close(timeout=30) is True
        assert plug.result(timeout=30)

    def test_close_drain_false_fails_pending_typed(self):
        eng = FakeEngine(d=D)
        sched = _scheduler(eng, FakeClock(), degrade=False)
        plug = _plug(eng, sched)
        pending = [sched.submit(make_query(D, r)) for r in (1, 2, 3)]
        sched.close(timeout=0.0, drain=False)   # workers still pinned
        for f in pending:                       # failed immediately
            with pytest.raises(RejectedError):
                f.result(timeout=30)
        eng.gate.set()
        assert sched.close(timeout=30) is True
        assert plug.result(timeout=30)          # in-flight one completes
        assert eng.served_ids() == [999]
        with pytest.raises(RejectedError):
            sched.submit(make_query(D, 4))

    def test_batcher_close_reports_failure_then_success(self):
        eng = FakeEngine(d=D)
        mb = MicroBatcher(eng, max_batch=4, max_wait_ms=0.0,
                          clock=FakeClock())
        eng.gate.clear()
        eng.entered.clear()
        fut = mb.submit(make_query(D, 1))
        assert eng.entered.wait(timeout=30)
        assert mb.close(timeout=0.2) is False   # worker stuck in engine
        eng.gate.set()
        assert mb.close(timeout=30) is True
        assert fut.result(timeout=30)


class TestDegradation:
    def test_controller_degrade_and_restore_windows(self):
        clock = FakeClock()
        ladder = ({}, {"nprobe": 4}, {"nprobe": 2})
        c = LoadController(ladder, clock, high_watermark=8,
                           low_watermark=2, degrade_window_s=0.05,
                           restore_window_s=0.5)
        assert c.observe(20) == {}              # starts the over-window
        clock.advance(0.04)
        assert c.observe(20) == {}              # window not elapsed yet
        clock.advance(0.02)
        assert c.observe(20) == {"nprobe": 4}   # sustained -> degrade
        # each ladder step resets the window: pressure must be sustained
        # again before degrading deeper (no free-fall to the floor)
        assert c.observe(20) == {"nprobe": 4}
        clock.advance(0.06)
        assert c.observe(20) == {"nprobe": 2}   # sustained again -> deeper
        clock.advance(1.0)
        assert c.observe(20) == {"nprobe": 2}   # ladder floor holds
        assert c.observe(5) == {"nprobe": 2}    # between marks: hold
        assert c.observe(0) == {"nprobe": 2}    # starts the under-window
        clock.advance(0.6)
        assert c.observe(0) == {"nprobe": 4}    # drained -> restore
        assert c.observe(0) == {"nprobe": 4}    # restore window reset too
        clock.advance(0.6)
        assert c.observe(0) == {}
        levels = [(t.level_from, t.level_to) for t in c.transitions]
        assert levels == [(0, 1), (1, 2), (2, 1), (1, 0)]
        assert all(t.reason for t in c.transitions)
        # timestamps come from the fake clock, monotone
        ts = [t.t for t in c.transitions]
        assert ts == sorted(ts)

    def test_degrade_knobs_reach_engine(self):
        eng = FakeEngine(d=D)
        sched = _scheduler(
            eng, FakeClock(), max_batch=2, degrade=True,
            ladder=({}, {"nprobe": 2}), high_watermark=2, low_watermark=1,
            degrade_window_s=0.0)
        try:
            plug = _plug(eng, sched)
            futs = [sched.submit(make_query(D, r)) for r in range(8)]
            eng.gate.set()
            plug.result(timeout=30)
            for f in futs:
                f.result(timeout=30)
            # depth at observe time: 0 (plug), then 6, 4, 2, 0 — the
            # second sustained-high observation flips to level 1 and the
            # knob rides every batch from there
            assert eng.call_kwargs() == [{}, {}, {"nprobe": 2},
                                         {"nprobe": 2}, {"nprobe": 2}]
            st = sched.stats()
            assert st["degradation_level"] == 1
            assert st["degradation_knobs"] == {"nprobe": 2}
            assert st["n_transitions"] == 1
            tr = sched.controller.transitions[0]
            assert (tr.level_from, tr.level_to) == (0, 1)
            assert tr.queue_depth == 4
        finally:
            assert sched.close()

    def test_default_ladder_from_index_knobs(self):
        ivf = SimpleNamespace(nprobe=8, cap=16)
        assert default_ladder(ivf, k_top=10) == (
            {}, {"nprobe": 4}, {"nprobe": 2})
        # PQ bases get a rerank-only first rung: halving the exact-refine
        # depth is the cheapest quality lever, so try it before touching
        # recall-critical nprobe
        pq = SimpleNamespace(nprobe=8, cap=16, rerank_depth=64)
        assert default_ladder(pq, k_top=10) == (
            {}, {"rerank": 32},
            {"nprobe": 4, "rerank": 32}, {"nprobe": 2, "rerank": 16})
        # rerank floors at k_top (so the rung vanishes when the build
        # depth is already at the floor), nprobe floors at
        # ceil(k_top / cap)
        assert default_ladder(pq, k_top=40, n_levels=4) == (
            {}, {"rerank": 40},
            {"nprobe": 4, "rerank": 40}, {"nprobe": 3, "rerank": 40})
        assert default_ladder(SimpleNamespace(nprobe=8, cap=16,
                                              rerank_depth=10),
                              k_top=10) == (
            {}, {"nprobe": 4, "rerank": 10}, {"nprobe": 2, "rerank": 10})
        # MutableIndex wrapper: knobs come from .base
        wrapped = SimpleNamespace(base=ivf)
        assert default_ladder(wrapped, k_top=10) == (
            {}, {"nprobe": 4}, {"nprobe": 2})
        # exact index: no knobs to trade -> single full-quality level
        assert default_ladder(SimpleNamespace(), k_top=10) == ({},)
        # duplicate-flat levels collapse
        assert default_ladder(SimpleNamespace(nprobe=2, cap=16),
                              k_top=10) == ({}, {"nprobe": 1})

    def test_ladder_validation(self):
        clock = FakeClock()
        with pytest.raises(ValueError):
            LoadController(({"nprobe": 2},), clock)     # level 0 not {}
        with pytest.raises(ValueError):
            LoadController(({},), clock, high_watermark=4,
                           low_watermark=4)


class TestStatsObservability:
    def test_latency_window_percentiles_on_known_samples(self):
        w = LatencyWindow(maxlen=1024)
        samples = [0.010, 0.020, 0.030, 0.040, 0.100]
        for s in samples:
            w.record(s)
        assert w.percentile(50.0) == pytest.approx(
            np.percentile(samples, 50.0))
        p50, p99 = w.percentile((50.0, 99.0))
        assert p50 == pytest.approx(0.030)
        assert p99 == pytest.approx(np.percentile(samples, 99.0))
        assert len(w) == 5
        # empty window reports NaN, not a crash
        empty = LatencyWindow()
        assert np.isnan(empty.percentile(99.0))
        assert all(np.isnan(v) for v in empty.percentile((50.0, 99.0)))
        # bounded: only the newest maxlen samples count
        small = LatencyWindow(maxlen=3)
        for s in (1.0, 2.0, 3.0, 4.0):
            small.record(s)
        assert small.percentile(50.0) == pytest.approx(3.0)

    def test_scheduler_latency_percentiles_on_fake_clock(self):
        # latency = resolve time - submit time in *virtual* seconds: the
        # plugged worker holds the batch while we advance a known amount
        eng = FakeEngine(d=D)
        clock = FakeClock()
        sched = _scheduler(eng, clock, degrade=False)
        try:
            plug = _plug(eng, sched)
            fut = sched.submit(make_query(D, 1), deadline_s=10.0)
            clock.advance(0.25)
            eng.gate.set()
            plug.result(timeout=30)
            fut.result(timeout=30)
            st = sched.stats()["classes"]["interactive"]
            assert st["p50_ms"] == pytest.approx(250.0)
            assert st["p99_ms"] == pytest.approx(250.0)
        finally:
            assert sched.close()

    def test_counters_monotone_and_race_free_under_concurrent_submit(self):
        eng = FakeEngine(d=D)
        sched = _scheduler(eng, FakeClock(), max_batch=8, degrade=False)
        stop = threading.Event()
        errs: list = []

        def client(tid):
            try:
                for i in range(200):
                    try:
                        sched.submit(make_query(D, tid * 1000 + i))
                    except RejectedError:
                        pass
            except Exception as e:          # pragma: no cover
                errs.append(e)

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(4)]
        for t in threads:
            t.start()
        prev: dict = {}
        counter_keys = ("admitted", "rejected", "expired", "completed",
                        "failed", "cancelled")
        # reader races the submitters + worker; every snapshot must be
        # well-formed and counters must never move backwards
        while any(t.is_alive() for t in threads):
            snap = sched.observability()
            for name, cls in snap["classes"].items():
                for key in counter_keys:
                    assert cls[key] >= prev.get((name, key), 0)
                    prev[(name, key)] = cls[key]
                assert cls["completed"] + cls["expired"] <= cls["admitted"]
        for t in threads:
            t.join()
        assert not errs
        assert sched.close()
        snap = sched.observability()["classes"]["interactive"]
        # drain-close: every admitted request resolved
        assert snap["admitted"] == 800 - snap["rejected"]
        assert snap["admitted"] == (snap["completed"] + snap["expired"]
                                    + snap["cancelled"] + snap["failed"])

    def test_engine_stats_embeds_frontend_block(self):
        import jax.numpy as jnp
        from repro.serve import ExactIndex, RetrievalEngine
        rng = np.random.RandomState(0)
        G = rng.randn(200, 8).astype(np.float32)
        L = 0.3 * rng.randn(4, 8).astype(np.float32)
        eng = RetrievalEngine(ExactIndex.build(jnp.asarray(L),
                                               jnp.asarray(G)), k_top=3)
        assert "frontend" not in eng.stats()
        sched = RequestScheduler(eng, clock=FakeClock(), max_wait_ms=0.0)
        try:
            fut = sched.submit(G[0])
            d, i = fut.result(timeout=60)
            ref_d, ref_i = eng.search(G[0])
            np.testing.assert_array_equal(i, ref_i)
            fe = eng.stats()["frontend"]
            assert fe["classes"]["interactive"]["completed"] == 1
            assert fe["degradation_level"] == 0
            assert fe["queue_depth"] == 0
        finally:
            assert sched.close()

    def test_engine_cache_keys_include_degradation_knobs(self):
        import jax.numpy as jnp
        from repro.serve import IVFIndex, RetrievalEngine
        rng = np.random.RandomState(0)
        G = rng.randn(512, 16).astype(np.float32)
        L = 0.3 * rng.randn(8, 16).astype(np.float32)
        eng = RetrievalEngine(
            IVFIndex.build(jnp.asarray(L), jnp.asarray(G), n_clusters=8,
                           nprobe=8),
            k_top=5, cache_size=64)
        q = G[0]
        eng.search(q)                       # miss
        eng.search(q)                       # hit (same knobs)
        assert (eng.cache_hits, eng.cache_misses) == (1, 1)
        eng.search(q, nprobe=1)             # same bytes, new knobs
        assert eng.cache_misses == 2, \
            "degraded lookup must not be served from the full-quality key"
        eng.search(q, nprobe=1)             # hit on the degraded key
        assert eng.cache_hits == 2
        assert len(eng._cache) == 2
        d_full, i_full = eng.search(q)      # still the full-quality entry
        np.testing.assert_array_equal(
            i_full, eng.search(q, nprobe=8)[1])


class TestStressInterleavings:
    """Satellite: N submitters racing close/cancel/engine-exception
    events. The invariants hold under EVERY interleaving, so the test is
    assertion-deterministic even though the schedule itself races."""

    N_THREADS = 6
    N_PER = 40

    def _storm(self, submit_one, clock):
        futs: list = []
        futs_lock = threading.Lock()
        rejected = [0]

        def client(tid):
            for i in range(self.N_PER):
                rid = tid * 1000 + i
                try:
                    f = submit_one(rid)
                except (RejectedError, RuntimeError):
                    with futs_lock:         # typed admission pushback
                        rejected[0] += 1
                    continue
                with futs_lock:
                    futs.append(f)
                if i % 7 == 3:
                    f.cancel()              # client walks away
        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(self.N_THREADS)]
        for t in threads:
            t.start()
        return threads, futs, rejected

    def _assert_exactly_once(self, futs, allowed_errors):
        outcomes = {"result": 0, "error": 0, "cancelled": 0}
        for f in futs:
            assert f.done(), "an admitted future never resolved"
            if f.cancelled():
                outcomes["cancelled"] += 1
                continue
            err = f.exception(timeout=0)
            if err is None:
                assert f.result(timeout=0)[1].shape[0] > 0
                outcomes["result"] += 1
            else:
                assert isinstance(err, allowed_errors), repr(err)
                outcomes["error"] += 1
        assert sum(outcomes.values()) == len(futs)
        return outcomes

    def test_scheduler_storm_every_future_resolves_exactly_once(self):
        eng = FakeEngine(d=D)
        clock = FakeClock()
        classes = (PriorityClass("interactive", 0, 5.0, queue_cap=64),)
        sched = RequestScheduler(eng, classes=classes, max_batch=8,
                                 max_wait_ms=1.0, clock=clock,
                                 degrade=False)
        threads, futs, rejected = self._storm(
            lambda rid: sched.submit(make_query(D, rid)), clock)
        # race engine failures and time against the storm: whatever the
        # interleaving, outcomes stay typed and exactly-once
        for _ in range(10):
            eng.fail = not eng.fail
            clock.advance(0.8)
        eng.fail = False
        for t in threads:
            t.join()
        assert sched.close(timeout=60) is True, "worker did not survive"
        outcomes = self._assert_exactly_once(
            futs, (RuntimeError, DeadlineExceededError))
        st = sched.observability()["classes"]["interactive"]
        assert st["admitted"] == len(futs)
        assert st["rejected"] == rejected[0]
        assert st["admitted"] == (st["completed"] + st["expired"]
                                  + st["failed"] + st["cancelled"])
        assert outcomes["result"] == st["completed"]
        # the engine kept getting work after failures were injected
        assert eng.calls, "no batch ever reached the engine"

    def test_batcher_storm_every_future_resolves_exactly_once(self):
        eng = FakeEngine(d=D)
        clock = FakeClock()
        mb = MicroBatcher(eng, max_batch=8, max_wait_ms=1.0, clock=clock)
        threads, futs, _ = self._storm(
            lambda rid: mb.submit(make_query(D, rid)), clock)
        for _ in range(10):
            eng.fail = not eng.fail
            clock.advance(0.01)
        eng.fail = False
        for t in threads:
            t.join()
        assert mb.close(timeout=60) is True, "worker did not survive"
        self._assert_exactly_once(futs, (RuntimeError,))
        assert sum(mb.batch_sizes) <= len(futs)

    def test_cancelled_future_raises_cancelled_error_to_caller(self):
        eng = FakeEngine(d=D)
        sched = _scheduler(eng, FakeClock(), degrade=False)
        try:
            plug = _plug(eng, sched)
            doomed = sched.submit(make_query(D, 1))
            assert doomed.cancel()
            eng.gate.set()
            plug.result(timeout=30)
            with pytest.raises(CancelledError):
                doomed.result(timeout=30)
            assert 1 not in eng.served_ids()
        finally:
            assert sched.close()


class TestTenantRoutes:
    def test_routed_batches_never_mix_and_serve_route_engine(self):
        eng = FakeEngine(d=D)
        route_eng = FakeEngine(d=D)
        sched = _scheduler(eng, FakeClock(), max_batch=16, degrade=False)
        try:
            sched.add_route("a", route_eng)
            assert sched.routes() == ("a",)
            plug = _plug(eng, sched)
            futs = [sched.submit(make_query(D, rid),
                                 route=("a" if rid % 2 else None))
                    for rid in range(1, 7)]
            eng.gate.set()
            route_eng.gate.set()
            for f in futs:
                f.result(timeout=30)
            plug.result(timeout=30)
            # every request served by ITS route's engine, no cross-talk
            assert set(eng.served_ids()) == {999, 2, 4, 6}
            assert set(route_eng.served_ids()) == {1, 3, 5}
            # and no single engine call mixed routes (batch purity):
            # each engine only ever saw its own population, per call
            for ids, _ in route_eng.calls:
                assert all(i % 2 for i in ids)
        finally:
            assert sched.close()

    def test_route_validation_and_unknown_route(self):
        eng = FakeEngine(d=D)
        small = FakeEngine(d=D, k_top=2)    # tighter k than the default
        sched = _scheduler(eng, FakeClock(), degrade=False)
        try:
            sched.add_route("small", small)
            with pytest.raises(ValueError, match="unknown route"):
                sched.submit(make_query(D, 1), route="nope")
            # k validated against the ROUTE engine, not the default
            with pytest.raises(ValueError, match="k_top"):
                sched.submit(make_query(D, 1), k_top=5, route="small")
            sched.submit(make_query(D, 1), k_top=5)     # default: fine
        finally:
            assert sched.close()

    def test_tenant_outcomes_in_observability(self):
        eng = FakeEngine(d=D)
        route_eng = FakeEngine(d=D)
        sched = _scheduler(eng, FakeClock(), degrade=False)
        try:
            sched.add_route("a", route_eng)
            plug = _plug(eng, sched)
            futs = [sched.submit(make_query(D, rid), route="a")
                    for rid in (1, 2)]
            eng.gate.set()
            route_eng.gate.set()
            for f in futs:
                f.result(timeout=30)
            plug.result(timeout=30)
            tn = sched.observability()["tenants"]["a"]
            assert tn["admitted"] == 2
            assert tn["completed"] == 2
        finally:
            assert sched.close()

    def test_pq_route_gets_rerank_first_rung(self):
        eng = FakeEngine(d=D)
        pq_eng = FakeEngine(d=D)
        pq_eng.index = SimpleNamespace(
            L=np.zeros((2, D), np.float32), version=0, size=1000,
            n_shards=1, nprobe=8, cap=16, rerank_depth=64)
        sched = _scheduler(eng, FakeClock(), degrade=True)
        try:
            sched.add_route("pq", pq_eng)
            _, ctrl = sched._resolve_route("pq")
            assert ctrl.ladder[1] == {"rerank": 32}     # cheapest lever
        finally:
            assert sched.close()
