"""Closed-loop hard-pair mining: miner label filters, semi-hard band,
stream determinism, engine-cache refresh semantics, and mined-vs-uniform
convergence on a tiny dataset."""

import numpy as np
import pytest

from repro.core import dml, eval_tasks
from repro.core.ps import sync
from repro.core.ps.trainer import DMLTrainConfig, train_dml_distributed
from repro.data import pairs as pairdata
from repro.mining import (ClosedLoopConfig, ClosedLoopTrainer,
                          CurriculumSchedule, HardPairMiner, MinerConfig,
                          MinedPairSource)
from repro.serve import ExactIndex, RetrievalEngine


def _blobs(n=600, d=16, c=6, noise=0.3, seed=0):
    cfg = pairdata.PairDatasetConfig(n_samples=n, feat_dim=d, n_classes=c,
                                     kind="class_blobs", noise=noise,
                                     seed=seed)
    return pairdata.make_features(cfg)


def _miner(x, y, cfg=None, L=None):
    if L is None:
        L = np.eye(x.shape[1], dtype=np.float32)
    engine = RetrievalEngine(ExactIndex.build(L, x))
    return HardPairMiner(engine, x, y, cfg, warmup=False)


class TestMinerFilters:
    def test_label_correctness(self):
        x, y = _blobs()
        m = _miner(x, y, MinerConfig(k_neighbors=15, max_negatives=2,
                                     max_positives=2))
        res = m.mine(n_queries=200, seed=0)
        p = res.pairs
        assert res.n_pairs > 0
        neg = p["sim"] == 0
        pos = p["sim"] == 1
        # every hard negative is different-class, every positive same
        assert (y[p["a"][neg]] != y[p["b"][neg]]).all()
        assert (y[p["a"][pos]] == y[p["b"][pos]]).all()
        # never a self-pair
        assert (p["a"] != p["b"]).all()
        # stats account for every pair
        assert res.stats["n_hard_neg"] + res.stats["n_hard_pos"] \
            == res.n_pairs

    def test_positives_are_knn_violations(self):
        """Mined positives are same-class rows *outside* the anchor's
        current neighborhood (the pairs a kNN eval scores wrong)."""
        x, y = _blobs(noise=1.5)      # overlap so violations exist
        k = 10
        m = _miner(x, y, MinerConfig(k_neighbors=k, max_negatives=0,
                                     max_positives=3))
        res = m.mine(n_queries=150, seed=1)
        p = res.pairs
        assert res.n_pairs > 0
        _, nbr = m.engine.search(x[p["a"]], k_top=k + 1)
        for row, b in zip(np.asarray(nbr), p["b"]):
            assert b not in row       # outside the served neighborhood

    def test_semi_hard_band_respects_margin(self):
        x, y = _blobs(n=400, noise=1.0)
        k, margin = 20, 2.0
        m = _miner(x, y, MinerConfig(k_neighbors=k, margin=margin,
                                     semi_hard=True,
                                     fallback_nearest=False,
                                     max_negatives=3, max_positives=0))
        res = m.mine(n_queries=150, seed=0)
        p = res.pairs
        assert res.n_pairs > 0
        assert res.stats["n_fallback_neg"] == 0
        # recompute each anchor's neighborhood under the same (identity)
        # metric and check every mined negative sits in the band
        # [d(farthest same-class in neighborhood), +margin)
        d_all, i_all = m.engine.search(x[p["a"]], k_top=k + 1)
        for row_d, row_i, a, b in zip(d_all, i_all, p["a"], p["b"]):
            keep = row_i != a
            row_d, row_i = row_d[keep], row_i[keep]
            same = y[row_i] == y[a]
            d_pos = row_d[same].max() if same.any() else 0.0
            d_neg = float(np.sum((x[a] - x[b]) ** 2))
            assert d_pos <= d_neg + 1e-4
            assert d_neg < d_pos + margin + 1e-4

    def test_fallback_covers_out_of_band_anchors(self):
        # well-separated blobs + a neighborhood wide enough to reach
        # other classes: nearest negatives sit far outside the band, so
        # strict semi-hard starves and fallback kicks in
        x, y = _blobs(n=300, noise=0.05)
        m_strict = _miner(x, y, MinerConfig(k_neighbors=80, margin=1e-6,
                                            fallback_nearest=False,
                                            max_negatives=1,
                                            max_positives=0))
        m_fb = _miner(x, y, MinerConfig(k_neighbors=80, margin=1e-6,
                                        fallback_nearest=True,
                                        max_negatives=1,
                                        max_positives=0))
        r_strict = m_strict.mine(n_queries=100, seed=0)
        r_fb = m_fb.mine(n_queries=100, seed=0)
        assert r_fb.stats["n_hard_neg"] > r_strict.stats["n_hard_neg"]
        assert r_fb.stats["n_fallback_neg"] > 0

    def test_miner_deterministic(self):
        x, y = _blobs()
        r1 = _miner(x, y).mine(n_queries=100, seed=7)
        r2 = _miner(x, y).mine(n_queries=100, seed=7)
        for k in ("a", "b", "sim"):
            np.testing.assert_array_equal(r1.pairs[k], r2.pairs[k])

    def test_engine_qps_surfaced(self):
        x, y = _blobs(n=300)
        m = _miner(x, y)
        res = m.mine(n_queries=64, seed=0)
        assert res.stats["engine_qps"] > 0
        assert res.stats["mine_busy_s"] > 0
        assert m.engine.stats()["n_queries"] >= 64


class TestMinedPairSource:
    def _source(self, x, y, pool):
        src = MinedPairSource(x, y, CurriculumSchedule(
            warmup_steps=1, ramp_steps=2, max_mined_frac=0.5))
        src.set_pool(pool)
        return src

    def test_deterministic_under_seed(self):
        x, y = _blobs(n=400)
        pool = _miner(x, y).mine(n_queries=100, seed=0)
        s1 = self._source(x, y, pool).worker_streams(2, 32, seed=5)
        s2 = self._source(x, y, pool).worker_streams(2, 32, seed=5)
        for _ in range(6):
            for a, b in zip(s1, s2):
                ba, bb = next(a), next(b)
                for k in ("xs", "ys", "sim"):
                    np.testing.assert_array_equal(np.asarray(ba[k]),
                                                  np.asarray(bb[k]))

    def test_batch_contract_and_curriculum(self):
        x, y = _blobs(n=400)
        pool = _miner(x, y).mine(n_queries=100, seed=0)
        src = self._source(x, y, pool)
        (stream,) = src.worker_streams(1, 64, seed=0)
        b0 = next(stream)             # warmup: pure uniform
        assert b0["xs"].shape == (64, x.shape[1])
        assert b0["sim"].shape == (64,)
        assert set(np.asarray(b0["sim"]).tolist()) <= {0, 1}
        assert src.schedule.mined_frac(0) == 0.0
        assert src.schedule.mined_frac(3) == 0.5

    def test_pool_swap_picked_up_mid_stream(self):
        x, y = _blobs(n=400)
        pool = _miner(x, y).mine(n_queries=100, seed=0)
        src = self._source(x, y, pool)
        (stream,) = src.worker_streams(1, 32, seed=0)
        next(stream)
        v = src.pool_version
        src.set_pool({"a": np.array([0]), "b": np.array([1]),
                      "sim": np.array([0])})
        assert src.pool_version == v + 1
        next(stream)                  # no restart needed

    def test_trainer_accepts_source(self):
        x, y = _blobs(n=300, d=8, c=4)
        pool = _miner(x, y).mine(n_queries=64, seed=0)
        src = self._source(x, y, pool)
        cfg = DMLTrainConfig(dml=dml.DMLConfig(feat_dim=8, proj_dim=4),
                             ps=sync.PSConfig(n_workers=1),
                             batch_size=64, steps=8, lr=1e-2,
                             log_every=4)
        L, hist = train_dml_distributed(cfg, src)
        assert L.shape == (4, 8)
        # mined batches are deliberately harder than uniform ones, so
        # the raw loss value is not monotone — just pin that the run
        # trained on the source's batches end to end
        assert len(hist) == 3 and np.isfinite(hist[-1]["loss"])


class TestClosedLoop:
    def _cfg(self, d=16, steps=30, **kw):
        return ClosedLoopConfig(
            train=DMLTrainConfig(dml=dml.DMLConfig(feat_dim=d, proj_dim=8),
                                 ps=sync.PSConfig(n_workers=1),
                                 batch_size=64, steps=steps, lr=1e-2,
                                 log_every=10),
            miner=MinerConfig(k_neighbors=10),
            schedule=CurriculumSchedule(warmup_steps=4, ramp_steps=8,
                                        max_mined_frac=0.5),
            mine_queries=128, **kw)

    def test_refresh_bumps_version_and_flushes_cache(self):
        x, y = _blobs(n=400)
        clt = ClosedLoopTrainer(self._cfg(refresh_every=10), x, y)
        eng = clt.engine
        q = x[:4]
        eng.search(q)
        eng.search(q)                 # second hit comes from the LRU
        assert eng.cache_hits > 0 and len(eng._cache) > 0
        v0 = eng.index.version
        L_new = 0.1 * np.ones((8, 16), np.float32)
        clt.refresh(L_new, step=0)
        assert eng.index.version > v0
        hits0 = eng.cache_hits
        eng.search(q)                 # lazy flush fires here
        assert eng.cache_hits == hits0
        assert clt.source.pool_size > 0

    def test_frozen_base_refresh_rebuilds(self):
        x, y = _blobs(n=300)
        clt = ClosedLoopTrainer(self._cfg(index="exact",
                                          refresh_every=10), x, y)
        idx0 = clt.engine.index
        clt.refresh(0.1 * np.ones((8, 16), np.float32), step=0)
        assert clt.engine.index is not idx0

    def test_mutable_ivf_loop_runs(self):
        x, y = _blobs(n=512, c=4)
        cfg = self._cfg(steps=20, index="mutable-ivf",
                        index_kwargs=dict(n_clusters=8, nprobe=8),
                        refresh_every=8)
        clt = ClosedLoopTrainer(cfg, x, y)
        L, hist = clt.run()
        assert hist["summary"]["n_refreshes"] >= 2
        # each swap_metric refresh rebuilt the IVF base under a fresh L
        assert clt.engine.index.n_swaps >= 1
        assert np.isfinite(hist["steps"][-1]["loss"])

    def test_plateau_policy_triggers(self):
        x, y = _blobs(n=300)
        # loss on separated blobs flattens fast; the plateau policy must
        # fire even with periodic refresh disabled
        cfg = self._cfg(steps=40, refresh_every=0, plateau_window=6,
                        plateau_tol=0.5, min_refresh_gap=5)
        _, hist = ClosedLoopTrainer(cfg, x, y).run()
        assert hist["summary"]["n_refreshes"] >= 2

    def test_history_records_staleness(self):
        x, y = _blobs(n=300)
        _, hist = ClosedLoopTrainer(self._cfg(refresh_every=10), x,
                                    y).run()
        stal = [h["staleness"] for h in hist["steps"]]
        assert max(stal) < 10
        assert "mean_staleness" in hist["summary"]
        assert hist["summary"]["total_mined_pairs"] > 0

    def test_no_policy_rejected(self):
        with pytest.raises(ValueError, match="staleness policy"):
            self._cfg(refresh_every=0, plateau_window=0)


class TestConvergenceSmoke:
    def test_mined_not_worse_than_uniform_tiny(self):
        """Tiny-scale version of benchmarks/mining_convergence.py: at an
        equal (small) step budget, mined+curriculum ends at least as
        accurate as uniform sampling."""
        cfg = pairdata.PairDatasetConfig(
            n_samples=2000, feat_dim=48, n_classes=32,
            kind="noisy_subspace", noise=0.3, seed=0)
        x, y = pairdata.make_features(cfg)
        tr_x, tr_y, te_x, te_y = x[:1600], y[:1600], x[1600:], y[1600:]
        tcfg = DMLTrainConfig(
            dml=dml.DMLConfig(feat_dim=48, proj_dim=12),
            ps=sync.PSConfig(n_workers=1), batch_size=128, steps=60,
            lr=3e-3, log_every=20)
        idx = pairdata.sample_pair_indices(tr_y, 8000, 8000, seed=1)
        uni = {"xs": tr_x[idx["a"]], "ys": tr_x[idx["b"]],
               "sim": idx["sim"]}
        L_u, _ = train_dml_distributed(tcfg, uni)
        ccfg = ClosedLoopConfig(
            train=tcfg,
            miner=MinerConfig(k_neighbors=15, max_negatives=1,
                              max_positives=3),
            schedule=CurriculumSchedule(warmup_steps=5, ramp_steps=10,
                                        max_mined_frac=0.7),
            refresh_every=10, mine_queries=1600)
        L_m, hist = ClosedLoopTrainer(ccfg, tr_x, tr_y).run()
        acc_u = eval_tasks.knn_accuracy(L_u, tr_x, tr_y, te_x, te_y, k=5)
        acc_m = eval_tasks.knn_accuracy(L_m, tr_x, tr_y, te_x, te_y, k=5)
        assert hist["summary"]["n_refreshes"] >= 4
        assert acc_m >= acc_u - 0.02, (acc_m, acc_u)


class TestMinerFrontend:
    def test_frontend_routed_mining_equals_direct(self):
        """Mining through the scheduler's ``mining`` class must produce
        the exact same pairs as hitting the engine directly — the front
        end shapes the load, it must not change the answers."""
        from repro.serve import RequestScheduler
        x, y = _blobs(n=300)
        k = 10
        L = np.eye(x.shape[1], dtype=np.float32)
        engine = RetrievalEngine(ExactIndex.build(L, x), k_top=k + 1)
        cfg = MinerConfig(k_neighbors=k, max_negatives=2,
                          max_positives=2)
        direct = HardPairMiner(engine, x, y, cfg, warmup=False)
        r_direct = direct.mine(n_queries=64, seed=3)

        sched = RequestScheduler(engine, max_wait_ms=0.0, degrade=False)
        try:
            routed = HardPairMiner(engine, x, y, cfg, warmup=False,
                                   frontend=sched)
            r_routed = routed.mine(n_queries=64, seed=3)
        finally:
            sched.close()
        assert r_routed.stats["n_dropped"] == 0
        for key in ("a", "b", "sim"):
            np.testing.assert_array_equal(r_direct.pairs[key],
                                          r_routed.pairs[key])

    def test_shed_anchors_mine_nothing_and_are_counted(self):
        """Anchors the front end rejects come back unserved: they must
        be dropped (never mined into fake pairs) and counted."""
        from concurrent.futures import Future
        x, y = _blobs(n=300)
        k = 10
        L = np.eye(x.shape[1], dtype=np.float32)
        engine = RetrievalEngine(ExactIndex.build(L, x), k_top=k + 1)

        class SheddingFrontend:
            """Every 2nd submit rejected at admission, like a full
            mining queue would."""
            def __init__(self):
                self.n = 0

            def submit(self, row, k_top, priority):
                self.n += 1
                if self.n % 2 == 0:
                    raise RuntimeError("queue full")
                fut = Future()
                d, i = engine.search(row, k_top=k_top)
                fut.set_result((d, i))
                return fut

        cfg = MinerConfig(k_neighbors=k, max_negatives=2,
                          max_positives=2)
        m = HardPairMiner(engine, x, y, cfg, warmup=False,
                          frontend=SheddingFrontend())
        res = m.mine(n_queries=64, seed=3)
        assert res.stats["n_dropped"] == 32
        assert res.n_pairs > 0
        # every surviving pair references only SERVED anchors — no -1
        # ids or inf distances leaked into the pair set
        assert (res.pairs["a"] >= 0).all() and (res.pairs["b"] >= 0).all()

    def test_oversized_neighborhood_rejected_with_frontend(self):
        from repro.serve import RequestScheduler
        x, y = _blobs(n=100)
        engine = RetrievalEngine(ExactIndex.build(
            np.eye(x.shape[1], dtype=np.float32), x), k_top=5)
        sched = RequestScheduler(engine, max_wait_ms=0.0, degrade=False)
        try:
            with pytest.raises(ValueError, match="k_top"):
                HardPairMiner(engine, x, y,
                              MinerConfig(k_neighbors=10),
                              warmup=False, frontend=sched)
        finally:
            sched.close()


class TestClosedLoopRouter:
    def _cfg(self, d=8, **kw):
        return ClosedLoopConfig(
            train=DMLTrainConfig(dml=dml.DMLConfig(feat_dim=d, proj_dim=4),
                                 ps=sync.PSConfig(n_workers=1),
                                 batch_size=64, steps=10, lr=1e-2,
                                 log_every=10),
            miner=MinerConfig(k_neighbors=10),
            schedule=CurriculumSchedule(warmup_steps=2, ramp_steps=4,
                                        max_mined_frac=0.5),
            mine_queries=64, refresh_every=10, **kw)

    def test_refresh_promotes_through_shadow(self):
        """A metric-swapping refresh registers the fresh L as the
        tenant's shadow arm, mirrors probe traffic, and promotes — the
        serving tenant's metric tracks training via the shadow path."""
        from repro.serve import TenantRouter
        x, y = _blobs(n=200, d=8, c=4)
        router = TenantRouter(x, k_top=10)
        router.add_tenant("prod", np.eye(8, dtype=np.float32))
        router.search("prod", x[0])
        fp0 = router.tenant("prod").fingerprint

        clt = ClosedLoopTrainer(self._cfg(), x, y, router=router,
                                tenant="prod", shadow_probe=4)
        L_new = (0.1 * np.random.RandomState(3)
                 .randn(4, 8)).astype(np.float32)
        rec = clt.refresh(L_new, step=10)
        assert rec["promoted_tenant"] == "prod"
        assert rec["shadow"]["n_mirrored"] >= 1
        t = router.tenant("prod")
        assert t.fingerprint != fp0 and t.shadow is None
        np.testing.assert_array_equal(t.L, L_new)
        # the live tenant now answers under the promoted metric
        _, ids = router.search("prod", x[:3])
        eng = RetrievalEngine(ExactIndex.build(L_new, x), k_top=10)
        _, o_ids = eng.search(x[:3])
        np.testing.assert_array_equal(ids, np.asarray(o_ids))

    def test_router_validation(self):
        from repro.serve import TenantRouter
        x, y = _blobs(n=120, d=8, c=4)
        router = TenantRouter(x)
        router.add_tenant("prod", np.eye(8, dtype=np.float32))
        with pytest.raises(ValueError, match="together"):
            ClosedLoopTrainer(self._cfg(), x, y, router=router)
        with pytest.raises(Exception):
            ClosedLoopTrainer(self._cfg(), x, y, router=router,
                              tenant="nope")
        wrong = TenantRouter(np.zeros((50, 6), np.float32))
        wrong.add_tenant("prod", np.eye(6, dtype=np.float32))
        with pytest.raises(ValueError, match="d_in"):
            ClosedLoopTrainer(self._cfg(), x, y, router=wrong,
                              tenant="prod")
