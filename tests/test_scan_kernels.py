"""Parity tests for the fused segment-scan kernels (pq_adc, ivf_scan).

Contract (docs/kernels.md): ``pq_adc_topk`` returns **bit-identical**
arrays on its kernel and XLA paths (the sequential-subspace-sum
reference fixes the rounding order, so array_equal on distances is the
assertion, not allclose); ``ivf_scan_topk`` matches on indices exactly
and on distances to f32 rounding (its k-contraction tree differs
between paths). Ragged shapes are the point: segment fill below
capacity, capacity not a multiple of the tile, kk larger than any
single segment's real rows, and the full 1..8-bit code range.

Kernels run in interpret mode here (CPU CI) — the same kernel logic the
TPU path compiles, minus the mosaic lowering.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels._dispatch import topk_by_distance
from repro.kernels.ivf_scan import ivf_scan_topk
from repro.kernels.metric_topk.kernel import BIG
from repro.kernels.pq_adc import pq_adc_topk


def _segments(rng, C, cap, fill_lo, fill_hi):
    """Random per-cluster fills (possibly empty segments) + global ids."""
    fills = rng.randint(fill_lo, fill_hi + 1, size=C)
    ids = np.full((C, cap), -1, np.int32)
    nid = 0
    for c in range(C):
        ids[c, :fills[c]] = np.arange(nid, nid + fills[c])
        nid += fills[c]
    return fills, ids


def _pq_case(seed, Nq, C, cap, S, bits, nprobe, fill_lo, fill_hi):
    rng = np.random.RandomState(seed)
    K = 1 << bits
    fills, ids = _segments(rng, C, cap, fill_lo, fill_hi)
    codes = np.zeros((C, cap, S), np.uint8)
    t = np.full((C, cap), BIG, np.float32)
    for c in range(C):
        n = fills[c]
        codes[c, :n] = rng.randint(0, K, (n, S))
        t[c, :n] = rng.randn(n).astype(np.float32)
    tables = rng.randn(Nq, S * K).astype(np.float32)
    dc = np.abs(rng.randn(Nq, nprobe)).astype(np.float32)
    probes = np.stack([rng.choice(C, nprobe, replace=False)
                       for _ in range(Nq)]).astype(np.int32)
    return (jnp.asarray(tables), jnp.asarray(dc), jnp.asarray(probes),
            jnp.asarray(codes), jnp.asarray(t), jnp.asarray(ids))


def _ivf_case(seed, Nq, C, cap, k, nprobe, fill_lo, fill_hi):
    rng = np.random.RandomState(seed)
    fills, ids = _segments(rng, C, cap, fill_lo, fill_hi)
    g = np.zeros((C, cap, k), np.float32)
    gn = np.full((C, cap), BIG, np.float32)
    for c in range(C):
        n = fills[c]
        g[c, :n] = rng.randn(n, k).astype(np.float32)
        gn[c, :n] = np.sum(g[c, :n] ** 2, axis=1)
    qp = rng.randn(Nq, k).astype(np.float32)
    probes = np.stack([rng.choice(C, nprobe, replace=False)
                       for _ in range(Nq)]).astype(np.int32)
    return (jnp.asarray(qp), jnp.asarray(probes), jnp.asarray(g),
            jnp.asarray(gn), jnp.asarray(ids))


# (Nq, C, cap, S, bits, nprobe, kk, block_m, fill_lo, fill_hi)
PQ_CASES = [
    # multi-tile segments, full fill
    (5, 6, 32, 4, 8, 3, 7, 16, 32, 32),
    # cap not a multiple of the tile -> whole-segment tile fallback
    (3, 5, 24, 3, 8, 2, 5, 16, 10, 24),
    # kk exceeds any single segment's real rows (sentinels surface)
    (4, 7, 16, 2, 8, 2, 32, 8, 0, 5),
    # 1-bit and 2-bit codes (K = 2, 4)
    (3, 4, 16, 5, 1, 2, 6, 8, 8, 16),
    (3, 4, 16, 5, 2, 2, 6, 8, 8, 16),
    # kk == the whole candidate pool, odd subspace count
    (2, 4, 8, 3, 4, 3, 24, 8, 2, 8),
]


@pytest.mark.parametrize(
    "Nq,C,cap,S,bits,nprobe,kk,block_m,fill_lo,fill_hi", PQ_CASES)
def test_pq_adc_kernel_bit_identical(Nq, C, cap, S, bits, nprobe, kk,
                                     block_m, fill_lo, fill_hi):
    args = _pq_case(0, Nq, C, cap, S, bits, nprobe, fill_lo, fill_hi)
    d_x, i_x = pq_adc_topk(*args, kk=kk, block_q=2, block_m=block_m,
                           use_kernel=False)
    d_k, i_k = pq_adc_topk(*args, kk=kk, block_q=2, block_m=block_m,
                           use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_k))
    np.testing.assert_array_equal(np.asarray(d_x), np.asarray(d_k))


# (Nq, C, cap, k, nprobe, kk, block_m, fill_lo, fill_hi)
IVF_CASES = [
    (5, 6, 32, 12, 3, 7, 16, 32, 32),          # multi-tile, full fill
    (3, 5, 24, 8, 2, 5, 16, 10, 24),           # cap % tile != 0
    (4, 7, 16, 5, 2, 32, 8, 0, 5),             # kk > real segment rows
    (2, 4, 8, 130, 3, 24, 8, 2, 8),            # k > one lane, full pool
]


@pytest.mark.parametrize("Nq,C,cap,k,nprobe,kk,block_m,fill_lo,fill_hi",
                         IVF_CASES)
def test_ivf_scan_kernel_parity(Nq, C, cap, k, nprobe, kk, block_m,
                                fill_lo, fill_hi):
    args = _ivf_case(0, Nq, C, cap, k, nprobe, fill_lo, fill_hi)
    d_x, i_x = ivf_scan_topk(*args, kk=kk, block_q=2, block_m=block_m,
                             use_kernel=False)
    d_k, i_k = ivf_scan_topk(*args, kk=kk, block_q=2, block_m=block_m,
                             use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_k))
    np.testing.assert_allclose(np.asarray(d_x), np.asarray(d_k),
                               rtol=1e-4, atol=1e-4)


def test_pq_adc_rejects_bad_kk():
    args = _pq_case(1, 2, 4, 8, 2, 4, 2, 8, 8)
    for kk in (0, -3):
        with pytest.raises(ValueError, match="kk"):
            pq_adc_topk(*args, kk=kk)
    with pytest.raises(ValueError, match="kk"):
        pq_adc_topk(*args, kk=2 * 8 + 1)       # > nprobe * cap


def test_ivf_scan_rejects_bad_kk():
    args = _ivf_case(1, 2, 4, 8, 6, 2, 0, 8)
    for kk in (0, -3):
        with pytest.raises(ValueError, match="kk"):
            ivf_scan_topk(*args, kk=kk)
    with pytest.raises(ValueError, match="kk"):
        ivf_scan_topk(*args, kk=2 * 8 + 1)


def test_pq_adc_sentinels_masked_to_minus_one():
    # a nearly-empty gallery: most returned slots must be (BIG-ish, -1),
    # never a duplicated real id (the streaming-merge knockout hazard)
    args = _pq_case(2, 3, 4, 8, 3, 4, 2, 0, 1)
    d_k, i_k = pq_adc_topk(*args, kk=12, use_kernel=True, interpret=True)
    i_k = np.asarray(i_k)
    d_k = np.asarray(d_k)
    for q in range(i_k.shape[0]):
        real = i_k[q][i_k[q] >= 0]
        assert len(real) == len(set(real.tolist())), \
            f"duplicate real ids in query {q}: {i_k[q]}"
    assert (i_k[d_k >= BIG] == -1).all()


class TestTopkContractProperty:
    """Hypothesis: the kernel's output equals the one tie-break contract
    (scan.topk_by_distance over the brute-force candidate matrix)."""

    @pytest.fixture(autouse=True)
    def _hyp(self):
        pytest.importorskip("hypothesis", reason="hypothesis not "
                            "installed (pip install -r "
                            "requirements-dev.txt)")

    def test_pq_adc_matches_topk_by_distance(self):
        from hypothesis import given, settings, strategies as st

        @given(st.integers(0, 10**6), st.integers(1, 4),
               st.integers(1, 3), st.integers(1, 8))
        @settings(max_examples=15, deadline=None)
        def prop(seed, Nq, nprobe, bits):
            C, cap, S = max(nprobe, 3), 8, 3
            tables, dc, probes, codes, t, ids = _pq_case(
                seed, Nq, C, cap, S, bits, nprobe, 0, cap)
            kk = min(5, nprobe * cap)
            d_k, i_k = pq_adc_topk(tables, dc, probes, codes, t, ids,
                                   kk=kk, use_kernel=True, interpret=True)
            # brute-force candidates in the same probe-major order, with
            # the same sequential subspace sum
            tb, dcn = np.asarray(tables), np.asarray(dc)
            pr, cd = np.asarray(probes), np.asarray(codes)
            tn, idn = np.asarray(t), np.asarray(ids)
            K = 1 << bits
            cand_d = np.empty((Nq, nprobe * cap), np.float32)
            cand_i = np.empty((Nq, nprobe * cap), np.int32)
            for q in range(Nq):
                col = 0
                for j in range(nprobe):
                    c = pr[q, j]
                    for r in range(cap):
                        ip = np.float32(0.0)
                        for s in range(S):
                            ip = np.float32(
                                ip + tb[q, s * K + cd[c, r, s]])
                        d = np.float32(
                            np.float32(dcn[q, j] + tn[c, r])
                            - np.float32(2.0) * ip)
                        cand_d[q, col] = max(d, np.float32(0.0))
                        cand_i[q, col] = idn[c, r]
                        col += 1
            d_o, i_o = topk_by_distance(jnp.asarray(cand_d),
                                        jnp.asarray(cand_i), kk)
            i_o = np.where(np.asarray(d_o) >= BIG, -1, np.asarray(i_o))
            np.testing.assert_array_equal(np.asarray(i_k), i_o)
            np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_o),
                                       rtol=1e-5, atol=1e-5)

        prop()
