"""End-to-end behaviour tests for the framework: full training loops over
the public API, serving, checkpoint resume, dry-run machinery, HLO parser."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config, get_shape, reduced
from repro.configs.base import RunConfig
from repro.data.tokens import token_stream
from repro.launch import steps
from repro.models import build_model

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.slow      # full loops; deselect with -m "not slow"


class TestTrainLoopEndToEnd:
    def test_lm_training_learns_structure(self):
        """Markov token data: loss must drop substantially over 40 steps."""
        cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
        model = build_model(cfg)
        run = RunConfig(lr=3e-3, warmup=5, total_steps=80, remat=False)
        opt = steps.make_optimizer(run)
        params = model.init(jax.random.PRNGKey(0))
        state = steps.TrainState(params, opt.init(params),
                                 jnp.zeros((), jnp.int32))
        step = jax.jit(steps.make_train_step(model, opt, run, loss_chunks=2))
        stream = token_stream(cfg.vocab_size, 8, 64, seed=0)
        losses = []
        for _ in range(80):
            state, m = step(state, next(stream))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < 0.85 * np.mean(losses[:5])

    def test_checkpoint_resume_bitexact(self):
        cfg = reduced(get_config("gemma-7b")).replace(dtype="float32")
        model = build_model(cfg)
        run = RunConfig(lr=1e-3, warmup=0, total_steps=10, remat=False)
        opt = steps.make_optimizer(run)
        params = model.init(jax.random.PRNGKey(0))
        state = steps.TrainState(params, opt.init(params),
                                 jnp.zeros((), jnp.int32))
        step = jax.jit(steps.make_train_step(model, opt, run, loss_chunks=2))
        stream = token_stream(cfg.vocab_size, 2, 32, seed=1)
        batches = [next(stream) for _ in range(6)]
        for b in batches[:3]:
            state, _ = step(state, b)
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 3, {"params": state.params,
                                   "opt": state.opt_state})
            sA = state
            for b in batches[3:]:
                sA, _ = step(sA, b)
            restored, _ = restore_checkpoint(
                d, {"params": state.params, "opt": state.opt_state})
            sB = steps.TrainState(restored["params"], restored["opt"],
                                  jnp.asarray(3, jnp.int32))
            for b in batches[3:]:
                sB, _ = step(sB, b)
        for a, b in zip(jax.tree.leaves(sA.params), jax.tree.leaves(sB.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestServePath:
    def test_generation_loop(self):
        cfg = reduced(get_config("yi-6b")).replace(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B = 2
        cache = model.init_decode_cache(B, max_seq=24)
        decode = jax.jit(model.decode_step)
        toks = jnp.zeros((B,), jnp.int32)
        for t in range(20):
            logits, cache = decode(params, cache, toks, jnp.int32(t))
            toks = jnp.argmax(logits, axis=-1)
            assert logits.shape == (B, cfg.vocab_size)
            assert np.isfinite(np.asarray(logits)).all()


class TestDryrunMachinery:
    def test_input_specs_shapes(self):
        for arch in ("yi-6b", "hubert-xlarge", "rwkv6-1.6b"):
            cfg = get_config(arch)
            for shape_name in ("train_4k", "prefill_32k"):
                shape = get_shape(shape_name)
                specs = steps.input_specs(cfg, shape)
                for v in specs.values():
                    assert isinstance(v, jax.ShapeDtypeStruct)
                key = ("embeddings" if cfg.input_kind == "embeddings"
                       else "tokens")
                assert specs[key].shape[0] == shape.global_batch

    def test_skip_reasons(self):
        assert steps.skip_reason(get_config("hubert-xlarge"),
                                 get_shape("decode_32k"))
        assert steps.skip_reason(get_config("yi-6b"),
                                 get_shape("decode_32k")) is None

    def test_effective_config_long_context(self):
        cfg = steps.effective_config(get_config("yi-6b"),
                                     get_shape("long_500k"))
        assert cfg.attention == "sliding"
        cfg2 = steps.effective_config(get_config("rwkv6-1.6b"),
                                      get_shape("long_500k"))
        assert cfg2.attention == "none"

    def test_dryrun_artifacts_complete_and_clean(self):
        """The committed artifacts must cover all 40 combos with no errors."""
        for name in ("dryrun_16x16.json", "dryrun_pod2x16x16.json"):
            path = os.path.join(REPO, "benchmarks", "artifacts", name)
            if not os.path.exists(path):
                pytest.skip(f"{name} not generated yet")
            with open(path) as f:
                recs = json.load(f)
            combo = {k: v for k, v in recs.items()
                     if v.get("shape") != "paper_batch"}
            assert len(combo) >= 40, len(combo)
            assert all(v["status"] in ("ok", "skipped")
                       for v in combo.values())
            skipped = sorted(k for k, v in combo.items()
                             if v["status"] == "skipped")
            assert skipped == ["hubert-xlarge|decode_32k",
                               "hubert-xlarge|long_500k"]


class TestHLOAnalysis:
    def test_dot_flops_on_real_module(self):
        from repro.launch import hlo_analysis

        compiled = jax.jit(lambda a, b: a @ b).lower(
            jax.ShapeDtypeStruct((8, 16), jnp.float32),
            jax.ShapeDtypeStruct((16, 32), jnp.float32)).compile()
        s = hlo_analysis.collective_summary(compiled.as_text())
        assert s["dot_flops"] >= 2 * 8 * 16 * 32

    def test_trip_count_multiplication(self):
        from repro.launch import hlo_analysis

        def f(x):
            def body(c, _):
                return c @ c, None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y

        compiled = jax.jit(f).lower(
            jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
        s = hlo_analysis.collective_summary(compiled.as_text())
        # 7 iterations x 2*16^3 flops each
        assert s["dot_flops"] >= 7 * 2 * 16 ** 3


class TestDMLSystemIntegration:
    def test_fused_kernel_in_training_loop(self):
        """The Pallas fused loss trains identically to the jnp path."""
        from repro.kernels.dml_pair import (dml_pair_loss_fused,
                                            dml_pair_loss_reference)
        rng = np.random.RandomState(0)
        d, k, B = 48, 24, 64
        L0 = jnp.asarray(0.1 * rng.randn(k, d), jnp.float32)
        xs = jnp.asarray(rng.randn(B, d), jnp.float32)
        ys = jnp.asarray(rng.randn(B, d), jnp.float32)
        sim = jnp.asarray((rng.rand(B) < 0.5).astype(np.int32))

        def train(loss_fn, L):
            for _ in range(10):
                g = jax.grad(loss_fn)(L, xs, ys, sim)
                L = L - 0.05 * g
            return L

        La = train(lambda *a: dml_pair_loss_fused(*a), L0)
        Lb = train(lambda *a: dml_pair_loss_reference(*a), L0)
        np.testing.assert_allclose(np.asarray(La), np.asarray(Lb),
                                   rtol=1e-4, atol=1e-5)
