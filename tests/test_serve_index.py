"""Index-hierarchy tests: k-means build, IVF pruned retrieval, engine cache.

The IVF contract under test: at ``nprobe == n_clusters`` the pruned path
is an exact scan (indices identical to ExactIndex), and at modest nprobe
on clustered data it keeps recall high while visiting a fraction of the
gallery. The sharded variants run in the slow subprocess check
(tests/_serve_subprocess_check.py, asserted from test_metric_topk.py).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.serve import (ExactIndex, GalleryIndex, IVFIndex, MetricIndex,
                         RetrievalEngine, kmeans_projected, recall_at_k)


def _clustered(M, d, n_blobs, noise=0.3, seed=0):
    rng = np.random.RandomState(seed)
    centers = 3.0 * rng.randn(n_blobs, d).astype(np.float32)
    blob = rng.randint(0, n_blobs, M)
    pts = centers[blob] + noise * rng.randn(M, d).astype(np.float32)
    return jnp.asarray(pts, jnp.float32), centers, rng


class TestKMeans:
    def test_objective_decreases_and_shapes(self):
        gp, _, _ = _clustered(1200, 16, 12)
        cent, assign, obj = kmeans_projected(gp, 8, iters=8, seed=1)
        assert cent.shape == (8, 16)
        assert assign.shape == (1200,)
        assert int(assign.min()) >= 0 and int(assign.max()) < 8
        obj = np.asarray(obj)
        assert obj[-1] < obj[0]
        assert (np.diff(obj) <= 1e-5).all(), "Lloyd objective increased"

    def test_blocked_assignment_matches_unblocked(self):
        gp, _, _ = _clustered(700, 8, 6, seed=3)
        c1, a1, _ = kmeans_projected(gp, 4, iters=5, seed=0, block_rows=128)
        c2, a2, _ = kmeans_projected(gp, 4, iters=5, seed=0,
                                     block_rows=4096)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))

    def test_empty_cluster_reseed(self):
        # 6 distinct points tiled: at most 6 occupiable centroids for 8
        # clusters -> the reseed path must keep every centroid finite and
        # every assignment in range instead of dividing by zero
        base = np.asarray(np.random.RandomState(0).randn(6, 4), np.float32)
        gp = jnp.asarray(np.tile(base, (40, 1)))
        cent, assign, obj = kmeans_projected(gp, 8, iters=6, seed=2,
                                             init="random")
        assert np.isfinite(np.asarray(cent)).all()
        assert np.isfinite(np.asarray(obj)).all()
        a = np.asarray(assign)
        assert a.min() >= 0 and a.max() < 8

    def test_random_init_supported(self):
        gp, _, _ = _clustered(300, 8, 4)
        cent, _, _ = kmeans_projected(gp, 4, iters=4, init="random")
        assert cent.shape == (4, 8)
        with pytest.raises(ValueError):
            kmeans_projected(gp, 4, init="mystery")

    def test_more_clusters_than_rows_raises(self):
        gp, _, _ = _clustered(10, 4, 2)
        with pytest.raises(ValueError):
            kmeans_projected(gp, 11)


class TestIVFIndex:
    def _build(self, M=600, d=32, k=16, n_clusters=8, seed=0, **kw):
        G, _, rng = _clustered(M, d, 24, seed=seed)
        L = jnp.asarray(0.3 * rng.randn(k, d), jnp.float32)
        q = jnp.asarray(np.asarray(G)[rng.randint(0, M, 20)]
                        + 0.1 * rng.randn(20, d).astype(np.float32))
        return (L, G, q, ExactIndex.build(L, G),
                IVFIndex.build(L, G, n_clusters=n_clusters, seed=0, **kw))

    def test_full_probe_matches_exact(self):
        _, _, q, exact, ivf = self._build()
        d_e, i_e = exact.topk(q, 10)
        d_f, i_f = ivf.topk(q, 10, nprobe=ivf.n_clusters)
        np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_e))
        np.testing.assert_allclose(np.asarray(d_f), np.asarray(d_e),
                                   rtol=1e-4, atol=1e-3)
        d = np.asarray(d_f)
        assert (np.diff(d, axis=1) >= -1e-5).all(), "not ascending"

    def test_recall_at_modest_nprobe(self):
        _, _, q, exact, ivf = self._build(M=4000, n_clusters=16)
        _, i_e = exact.topk(q, 10)
        _, i_a = ivf.topk(q, 10, nprobe=4)
        assert recall_at_k(i_a, i_e) >= 0.9

    def test_protocol_and_alias(self):
        _, _, _, exact, ivf = self._build()
        assert isinstance(exact, MetricIndex)
        assert isinstance(ivf, MetricIndex)
        assert GalleryIndex is ExactIndex
        assert ivf.size == exact.size == 600
        assert ivf.n_shards == 1

    def test_balanced_capacity_bounds_segments(self):
        # one dominant blob would blow up an uncapped segment; the build
        # must spill it and keep cap near cap_factor * M/C
        rng = np.random.RandomState(7)
        hot = 0.2 * rng.randn(900, 16).astype(np.float32)
        cold = 6.0 + 0.2 * rng.randn(100, 16).astype(np.float32)
        G = jnp.asarray(np.concatenate([hot, cold]))
        L = jnp.asarray(np.eye(16, dtype=np.float32))
        ivf = IVFIndex.build(L, G, n_clusters=8, cap_factor=1.25)
        assert ivf.cap <= 168     # ceil(1.25 * 1000/8) rounded to 8
        ids = np.asarray(ivf.ids_pad)
        real = ids[ids >= 0]
        assert len(real) == 1000 == len(np.unique(real)), \
            "every gallery row must live in exactly one segment slot"

    def test_pallas_backend_rejected(self):
        _, _, q, _, ivf = self._build()
        with pytest.raises(NotImplementedError):
            ivf.topk(q, 5, backend="pallas")

    def test_oversized_k_top_raises(self):
        _, _, q, _, ivf = self._build()
        with pytest.raises(ValueError):
            ivf.topk(q, 601)
        with pytest.raises(ValueError):
            ivf.topk(q, ivf.cap * 1 + 1, nprobe=1)   # > nprobe*cap pool

    def test_block_q_chunking_invariant(self):
        # query chunk size is a perf knob; results must not depend on it
        L, G, q, _, ivf = self._build()
        d1, i1 = ivf.topk(q, 7, nprobe=3)
        ivf2 = IVFIndex.build(L, G, n_clusters=8, seed=0)
        ivf2.block_q = 4
        d2, i2 = ivf2.topk(q, 7, nprobe=3)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-5, atol=1e-5)


class TestEngineCache:
    def _engine(self, **kw):
        rng = np.random.RandomState(0)
        L = jnp.asarray(0.3 * rng.randn(8, 16), jnp.float32)
        G = jnp.asarray(rng.randn(200, 16), jnp.float32)
        q = rng.randn(6, 16).astype(np.float32)
        return RetrievalEngine(ExactIndex.build(L, G), k_top=5, **kw), q

    def test_repeat_batch_hits_without_device_work(self):
        eng, q = self._engine(cache_size=64)
        d1, i1 = eng.search(q)
        busy = eng.busy_s
        d2, i2 = eng.search(q)          # all rows cached
        assert eng.busy_s == busy       # no device call
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_array_equal(d1, d2)
        st = eng.stats()
        assert st["cache_hits"] == 6 and st["cache_misses"] == 6
        assert st["cache_entries"] == 6

    def test_distinct_k_is_a_distinct_key(self):
        eng, q = self._engine(cache_size=64)
        eng.search(q[0])
        eng.search(q[0], k_top=3)
        assert eng.stats()["cache_misses"] == 2
        eng.search(q[0], k_top=3)
        assert eng.stats()["cache_hits"] == 1

    def test_lru_eviction_bounded(self):
        eng, _ = self._engine(cache_size=4)
        rng = np.random.RandomState(1)
        for _ in range(10):
            eng.search(rng.randn(16).astype(np.float32))
        assert eng.stats()["cache_entries"] == 4

    def test_version_bump_invalidates(self):
        eng, q = self._engine(cache_size=64)
        eng.search(q)
        eng.search(q)
        assert eng.stats()["cache_hits"] == 6
        eng.index.version += 1          # e.g. gallery mutated / swapped
        eng.search(q)                   # must recompute, not serve stale
        st = eng.stats()
        assert st["cache_hits"] == 6 and st["cache_misses"] == 12

    def test_caller_mutation_does_not_poison_cache(self):
        eng, q = self._engine(cache_size=64)
        ref_d, ref_i = map(np.copy, eng.search(q))
        d2, i2 = eng.search(q)          # served from cache (writable)
        d2[:] = 0.0
        i2[:] = -7          # caller scribbles on its results
        d3, i3 = eng.search(q)          # must still be pristine
        assert eng.stats()["cache_hits"] == 12
        np.testing.assert_array_equal(i3, ref_i)
        np.testing.assert_array_equal(d3, ref_d)

    def test_device_qps_excludes_cache_hits(self):
        eng, q = self._engine(cache_size=64)
        eng.search(q)
        eng.search(q)
        st = eng.stats()
        assert st["n_queries"] == 12
        assert st["n_device_queries"] == 6
        assert st["qps"] == pytest.approx(6 / st["busy_s"])

    def test_cache_disabled(self):
        eng, q = self._engine(cache_size=0)
        eng.search(q)
        eng.search(q)
        st = eng.stats()
        assert st["cache_hits"] == 0 and st["cache_entries"] == 0

    def test_empty_batch(self):
        for cache_size in (64, 0):
            eng, _ = self._engine(cache_size=cache_size)
            d, i = eng.search(np.zeros((0, 16), np.float32))
            assert d.shape == (0, 5) and i.shape == (0, 5)

    def test_index_swap_invalidates(self):
        # a freshly built replacement index also has version == 0; the
        # cache must key on index identity, not version alone
        eng, q = self._engine(cache_size=64)
        rng = np.random.RandomState(9)
        other = ExactIndex.build(eng.index.L,
                                 jnp.asarray(rng.randn(200, 16), jnp.float32))
        eng.search(q)
        eng.index = other
        d, i = eng.search(q)            # must requery, not serve gallery A
        st = eng.stats()
        assert st["cache_hits"] == 0 and st["cache_misses"] == 12
        d_ref, i_ref = other.topk(jnp.asarray(q), 5)
        np.testing.assert_array_equal(i, np.asarray(i_ref))

    def test_engine_over_ivf_index(self):
        rng = np.random.RandomState(0)
        L = jnp.asarray(0.3 * rng.randn(8, 16), jnp.float32)
        G, _, _ = _clustered(800, 16, 10)
        exact = RetrievalEngine(ExactIndex.build(L, G), k_top=5)
        ivf = RetrievalEngine(
            IVFIndex.build(L, G, n_clusters=4, nprobe=4), k_top=5)
        q = rng.randn(9, 16).astype(np.float32)
        _, i_e = exact.search(q)
        _, i_a = ivf.search(q)          # nprobe == n_clusters -> exact
        np.testing.assert_array_equal(i_a, i_e)
        assert ivf.stats()["index"] == "IVFIndex"


class TestEngineValidation:
    def _engine(self, **kw):
        rng = np.random.RandomState(0)
        L = jnp.asarray(0.3 * rng.randn(8, 16), jnp.float32)
        G = jnp.asarray(rng.randn(200, 16), jnp.float32)
        q = rng.randn(6, 16).astype(np.float32)
        return RetrievalEngine(ExactIndex.build(L, G), k_top=5, **kw), q

    def test_k_top_zero_rejected(self):
        # regression: `k_top or self.k_top` silently mapped an explicit
        # k_top=0 to the engine default instead of rejecting it
        eng, q = self._engine()
        with pytest.raises(ValueError, match="k_top"):
            eng.search(q, k_top=0)
        with pytest.raises(ValueError, match="k_top"):
            eng.search(q, k_top=-3)
        d, i = eng.search(q)                    # default path unharmed
        assert i.shape == (6, 5)

    def test_batcher_k_top_zero_rejected(self):
        from repro.serve import MicroBatcher
        eng, q = self._engine()
        batcher = MicroBatcher(eng)
        try:
            with pytest.raises(ValueError, match="k_top"):
                batcher.submit(q[0], k_top=0)
            assert batcher.submit(q[0]).result(timeout=30)[1].shape == (5,)
        finally:
            batcher.close()

    def test_engine_ctor_k_top_validated(self):
        rng = np.random.RandomState(0)
        idx = ExactIndex.build(
            jnp.asarray(0.3 * rng.randn(8, 16), jnp.float32),
            jnp.asarray(rng.randn(50, 16), jnp.float32))
        with pytest.raises(ValueError, match="k_top"):
            RetrievalEngine(idx, k_top=0)

    def test_warmup_accepts_k_list(self):
        eng, q = self._engine()
        eng.warmup(ks=[2, 5])                   # pre-compile non-default k
        d, i = eng.search(q, k_top=2)
        assert i.shape == (6, 2)
        with pytest.raises(ValueError, match="k_top"):
            eng.warmup(ks=[0])


@pytest.mark.slow
class TestIVFRecallSweep:
    def test_recall_monotone_in_nprobe(self):
        G, _, rng = _clustered(30_000, 48, 128, seed=5)
        L = jnp.asarray(0.2 * rng.randn(24, 48), jnp.float32)
        q = jnp.asarray(np.asarray(G)[rng.randint(0, 30_000, 64)]
                        + 0.1 * rng.randn(64, 48).astype(np.float32))
        exact = ExactIndex.build(L, G)
        ivf = IVFIndex.build(L, G, n_clusters=32, seed=0)
        _, i_e = exact.topk(q, 10)
        recalls = [recall_at_k(ivf.topk(q, 10, nprobe=p)[1], i_e)
                   for p in (1, 2, 4, 8, 16, 32)]
        assert recalls[-1] == 1.0       # full probe == exact
        assert recalls[0] >= 0.5
        assert all(b >= a - 0.02 for a, b in zip(recalls, recalls[1:])), \
            f"recall not (weakly) monotone in nprobe: {recalls}"
        assert max(recalls[:3]) >= 0.9  # modest nprobe already >= 0.9
