"""Product-quantization tests: PQ primitives, IVFPQ retrieval, lifecycle.

The contracts under test:

  * ProductQuantizer round trip — encode/decode reconstruction error is
    bounded (and is exactly the per-subspace nearest-codeword error).
  * ADC scoring — summing sqdist-table entries at a row's codes equals
    decode-then-score within f32 tolerance (subspaces are orthogonal
    coordinate blocks, so the identity is exact in real arithmetic).
  * IVFPQIndex at nprobe == n_clusters with full-depth rerank equals
    ExactIndex on indices — the same oracle IVFIndex pins.
  * Snapshot round trip is bit-for-bit (frozen and mutable-wrapped).
  * MutableIndex over an IVFPQ base agrees with an exact-base oracle
    through upserts/deletes and across compaction.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.serve import (ExactIndex, IVFPQIndex, MutableIndex,
                         ProductQuantizer, RetrievalEngine, load_index,
                         recall_at_k, save_index)


def _clustered(M, d, n_blobs, noise=0.3, seed=0):
    rng = np.random.RandomState(seed)
    centers = 3.0 * rng.randn(n_blobs, d).astype(np.float32)
    blob = rng.randint(0, n_blobs, M)
    pts = centers[blob] + noise * rng.randn(M, d).astype(np.float32)
    return jnp.asarray(pts, jnp.float32), centers, rng


def _setup(M=3000, d=48, k=24, n_blobs=24, seed=0):
    pts, centers, rng = _clustered(M, d, n_blobs, seed=seed)
    L = jnp.asarray(0.2 * rng.randn(k, d), jnp.float32)
    q = jnp.asarray(centers[rng.randint(0, n_blobs, 12)]
                    + 0.3 * rng.randn(12, d), jnp.float32)
    return pts, L, q, rng


class TestProductQuantizer:
    def test_round_trip_error_bounded(self):
        rng = np.random.RandomState(0)
        vecs = jnp.asarray(rng.randn(2000, 32).astype(np.float32))
        pq = ProductQuantizer.train(vecs, n_subspaces=8, bits=8, iters=8)
        codes = pq.encode(vecs)
        assert codes.dtype == jnp.uint8
        assert codes.shape == (2000, 8)
        dec = pq.decode(codes)
        assert dec.shape == (2000, 32)
        rel = float(jnp.mean(jnp.sum(jnp.square(vecs - dec), 1))
                    / jnp.mean(jnp.sum(jnp.square(vecs), 1)))
        # 256 codewords per 4-dim subspace on unit-variance gaussians:
        # well under 15% relative squared error (typically ~7%)
        assert rel < 0.15, f"round-trip rel sq error {rel:.3f}"

    def test_more_bits_reduce_error(self):
        rng = np.random.RandomState(1)
        vecs = jnp.asarray(rng.randn(1500, 16).astype(np.float32))
        errs = []
        for bits in (2, 4, 8):
            pq = ProductQuantizer.train(vecs, n_subspaces=4, bits=bits,
                                        iters=6)
            dec = pq.decode(pq.encode(vecs))
            errs.append(float(jnp.mean(jnp.sum(jnp.square(vecs - dec),
                                               1))))
        assert errs[0] > errs[1] > errs[2]

    def test_adc_matches_decode_then_score(self):
        rng = np.random.RandomState(2)
        vecs = jnp.asarray(rng.randn(600, 24).astype(np.float32))
        q = jnp.asarray(rng.randn(9, 24).astype(np.float32))
        pq = ProductQuantizer.train(vecs, n_subspaces=6, bits=6, iters=6)
        codes = pq.encode(vecs)
        dec = np.asarray(pq.decode(codes))
        adc = np.asarray(pq.adc(pq.sqdist_tables(q), codes))
        ref = np.sum((np.asarray(q)[:, None, :] - dec[None]) ** 2, axis=2)
        np.testing.assert_allclose(adc, ref, rtol=1e-4, atol=1e-3)

    def test_ip_tables_linear_identity(self):
        # <q, decode(c)> must equal the summed ip-table entries — the
        # linearity ADC's probe-independent tables rely on
        rng = np.random.RandomState(3)
        vecs = jnp.asarray(rng.randn(300, 20).astype(np.float32))
        q = jnp.asarray(rng.randn(5, 20).astype(np.float32))
        pq = ProductQuantizer.train(vecs, n_subspaces=5, bits=5, iters=5)
        codes = pq.encode(vecs)
        ips = np.asarray(pq.adc(pq.ip_tables(q), codes))
        ref = np.asarray(q) @ np.asarray(pq.decode(codes)).T
        np.testing.assert_allclose(ips, ref, rtol=1e-4, atol=1e-3)

    def test_dim_not_divisible_by_subspaces(self):
        rng = np.random.RandomState(4)
        vecs = jnp.asarray(rng.randn(400, 15).astype(np.float32))
        pq = ProductQuantizer.train(vecs, n_subspaces=4, bits=4, iters=4)
        dec = pq.decode(pq.encode(vecs))
        assert dec.shape == (400, 15)       # pad columns sliced back off

    def test_tiny_training_set_pads_codebook(self):
        rng = np.random.RandomState(5)
        vecs = jnp.asarray(rng.randn(10, 8).astype(np.float32))
        pq = ProductQuantizer.train(vecs, n_subspaces=2, bits=8, iters=3)
        assert pq.codebooks.shape == (2, 256, 4)
        codes = pq.encode(vecs)
        assert int(codes.max()) < 256

    def test_validation(self):
        vecs = jnp.zeros((10, 8), jnp.float32)
        with pytest.raises(ValueError):
            ProductQuantizer.train(vecs, bits=9)
        with pytest.raises(ValueError):
            ProductQuantizer.train(vecs, n_subspaces=9)
        with pytest.raises(ValueError):
            ProductQuantizer.train(jnp.zeros((0, 8), jnp.float32))


class TestIVFPQIndex:
    def test_full_probe_full_rerank_matches_exact(self):
        pts, L, q, _ = _setup()
        exact = ExactIndex.build(L, pts)
        idx = IVFPQIndex.build(L, pts, n_clusters=12, nprobe=12,
                               rerank_depth=pts.shape[0], cap_factor=1.5)
        d_e, i_e = exact.topk(q, 10)
        d_p, i_p = idx.topk(q, 10)
        np.testing.assert_array_equal(np.asarray(i_p), np.asarray(i_e))
        np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_e),
                                   rtol=1e-4, atol=1e-4)

    def test_host_store_matches_device_store(self):
        pts, L, q, _ = _setup(seed=6)
        kw = dict(n_clusters=12, nprobe=4, rerank_depth=30, seed=0)
        dev = IVFPQIndex.build(L, pts, store="device", **kw)
        host = IVFPQIndex.build(L, pts, store="host", **kw)
        d_d, i_d = dev.topk(q, 10)
        d_h, i_h = host.topk(q, 10)
        np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_h))
        np.testing.assert_allclose(np.asarray(d_d), np.asarray(d_h),
                                   rtol=1e-5, atol=1e-5)

    def test_rerank_recall_beats_raw(self):
        pts, L, q, _ = _setup(M=4000, n_blobs=32)
        exact = ExactIndex.build(L, pts)
        idx = IVFPQIndex.build(L, pts, n_clusters=32, nprobe=8,
                               n_subspaces=4, bits=4, rerank_depth=40)
        _, i_e = exact.topk(q, 10)
        _, i_raw = idx.topk(q, 10, rerank=0)
        _, i_rr = idx.topk(q, 10)
        r_raw = recall_at_k(i_raw, i_e)
        r_rr = recall_at_k(i_rr, i_e)
        # coarse 4x4-bit codes leave raw ADC ordering lossy; the exact
        # rerank must recover (nearly) the probed-set ceiling
        assert r_rr >= r_raw
        assert r_rr >= 0.9

    def test_rerank_distances_are_exact(self):
        pts, L, q, _ = _setup(seed=7)
        exact = ExactIndex.build(L, pts)
        idx = IVFPQIndex.build(L, pts, n_clusters=12, nprobe=12,
                               rerank_depth=25)
        d_p, i_p = idx.topk(q, 10)
        d_e, i_e = exact.topk(q, 10)
        # full probe: candidate sets cover the true top-10 whenever the
        # ADC top-25 does; wherever ids agree the distances must be the
        # exact factored distances, not ADC approximations
        same = np.asarray(i_p) == np.asarray(i_e)
        np.testing.assert_allclose(np.asarray(d_p)[same],
                                   np.asarray(d_e)[same],
                                   rtol=1e-4, atol=1e-4)

    def test_compression_accounting(self):
        pts, L, _, _ = _setup()
        idx = IVFPQIndex.build(L, pts, n_clusters=12, n_subspaces=4,
                               bits=8)
        assert idx.pq.code_bytes == 4
        assert idx.code_bytes_per_row == 8          # + the f32 t term
        k = 24
        assert idx.compression_ratio == (4 * k + 4) / 8
        # scanned device segments really are uint8 codes
        assert idx.codes_pad.dtype == jnp.uint8

    def test_validation_and_protocol(self):
        from repro.serve import MetricIndex
        pts, L, q, _ = _setup()
        idx = IVFPQIndex.build(L, pts, n_clusters=12, nprobe=2)
        assert isinstance(idx, MetricIndex)
        with pytest.raises(NotImplementedError):
            idx.topk(q, 5, backend="pallas")
        with pytest.raises(ValueError):
            idx.topk(q, pts.shape[0] + 1)
        with pytest.raises(ValueError):
            IVFPQIndex.build(L, pts, n_clusters=12, store="ram")
        with pytest.raises(ValueError):
            idx.topk(q, 5, nprobe=0)    # explicit 0 must not mean default

    def test_engine_integration(self):
        pts, L, q, _ = _setup()
        idx = IVFPQIndex.build(L, pts, n_clusters=12, nprobe=12,
                               rerank_depth=pts.shape[0])
        eng = RetrievalEngine(idx, k_top=10)
        eng.warmup()
        d, i = eng.search(np.asarray(q))
        d_e, i_e = ExactIndex.build(L, pts).topk(q, 10)
        np.testing.assert_array_equal(i, np.asarray(i_e))
        st = eng.stats()
        assert st["compression_ratio"] == idx.compression_ratio
        assert st["code_bytes_per_row"] == idx.code_bytes_per_row


class TestIVFPQSnapshot:
    def test_frozen_round_trip_bit_for_bit(self, tmp_path):
        pts, L, q, _ = _setup()
        idx = IVFPQIndex.build(L, pts, n_clusters=12, nprobe=4,
                               rerank_depth=30)
        d0, i0 = idx.topk(q, 10)
        save_index(idx, str(tmp_path))
        restored = load_index(str(tmp_path))
        assert isinstance(restored, IVFPQIndex)
        assert restored.store == idx.store
        assert restored.rerank_depth == idx.rerank_depth
        d1, i1 = restored.topk(q, 10)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))

    def test_mutable_round_trip_bit_for_bit(self, tmp_path):
        pts, L, q, rng = _setup()
        mut = MutableIndex.build(L, np.asarray(pts), base="ivfpq",
                                 n_clusters=12, nprobe=12,
                                 rerank_depth=3000, retain_raw=True)
        mut.upsert(np.asarray(pts)[:40] + 0.01)
        mut.delete(mut.live_ids()[:25])
        d0, i0 = mut.topk(q, 10)
        save_index(mut, str(tmp_path))
        restored = load_index(str(tmp_path))
        d1, i1 = restored.topk(q, 10)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
        # the restored index keeps mutating correctly
        restored.upsert(np.asarray(pts)[:3] + 0.02)
        assert restored.size == mut.size + 3


class TestMutableOverIVFPQ:
    def _mirrors(self, pts, L, **kw):
        mut = MutableIndex.build(L, np.asarray(pts), base="ivfpq",
                                 n_clusters=12, nprobe=12,
                                 rerank_depth=5000, cap_factor=1.5,
                                 **kw)
        oracle = MutableIndex.build(L, np.asarray(pts), base="exact")
        return mut, oracle

    def test_upsert_delete_matches_oracle(self):
        pts, L, q, rng = _setup()
        mut, oracle = self._mirrors(pts, L)
        fresh = np.asarray(pts)[rng.randint(0, 3000, 60)] + 0.01
        ids = mut.upsert(fresh)
        oracle.upsert(fresh, ids=ids)
        retire = rng.choice(mut.live_ids(), 80, replace=False)
        mut.delete(retire)
        oracle.delete(retire)
        d_m, i_m = mut.topk(q, 10)
        d_o, i_o = oracle.topk(q, 10)
        np.testing.assert_array_equal(i_m, i_o)
        np.testing.assert_allclose(d_m, d_o, rtol=1e-4, atol=1e-4)

    def test_compaction_agreement(self):
        pts, L, q, rng = _setup()
        mut, oracle = self._mirrors(pts, L)
        fresh = np.asarray(pts)[rng.randint(0, 3000, 50)] + 0.01
        ids = mut.upsert(fresh)
        oracle.upsert(fresh, ids=ids)
        retire = rng.choice(mut.live_ids(), 70, replace=False)
        mut.delete(retire)
        oracle.delete(retire)
        d_pre, i_pre = mut.topk(q, 10)
        assert mut.compact()
        assert mut.delta_rows == 0 and mut.tombstones == 0
        d_post, i_post = mut.topk(q, 10)
        # headroom fold re-encodes delta rows with the frozen codebooks;
        # rerank re-scores exactly, so answers must not move
        np.testing.assert_array_equal(i_pre, i_post)
        np.testing.assert_allclose(d_pre, d_post, rtol=1e-4, atol=1e-4)
        d_o, i_o = oracle.topk(q, 10)
        np.testing.assert_array_equal(i_post, i_o)

    def test_spill_triggers_codebook_rebuild(self):
        pts, L, q, rng = _setup(M=600)
        mut = MutableIndex.build(L, np.asarray(pts), base="ivfpq",
                                 n_clusters=6, nprobe=6,
                                 rerank_depth=5000, cap_factor=1.05,
                                 auto_compact_delta=0.0,
                                 auto_compact_dead=0.0)
        oracle = MutableIndex.build(L, np.asarray(pts), base="exact")
        fresh = np.asarray(pts)[rng.randint(0, 600, 400)] + 0.01
        ids = mut.upsert(fresh)
        oracle.upsert(fresh, ids=ids)
        mut.compact()
        assert mut.n_rebuilds == 1          # headroom spill -> retrain
        d_m, i_m = mut.topk(q, 10)
        d_o, i_o = oracle.topk(q, 10)
        np.testing.assert_array_equal(i_m, i_o)

    def test_raw_adc_base_rejected(self):
        pts, L, _, _ = _setup(M=500)
        idx = IVFPQIndex.build(L, pts, n_clusters=6, rerank_depth=0)
        with pytest.raises(ValueError):
            MutableIndex(idx, L)

    def test_raw_adc_query_rejected(self):
        # the per-call escape hatch must be closed too: rerank=0 through
        # the wrapper would merge approximate base distances against the
        # exact delta scan
        pts, L, q, _ = _setup(M=500)
        mut = MutableIndex.build(L, np.asarray(pts), base="ivfpq",
                                 n_clusters=6, rerank_depth=20)
        mut.upsert(np.asarray(pts)[:5] + 0.01)
        with pytest.raises(ValueError):
            mut.topk(q, 5, rerank=0)
        mut.topk(q, 5, rerank=10)           # nonzero depths stay allowed

    def test_nprobe_zero_rejected_through_wrapper(self):
        # nprobe=0 must raise, not silently skip the base scan
        pts, L, q, _ = _setup(M=500)
        mut = MutableIndex.build(L, np.asarray(pts), base="ivfpq",
                                 n_clusters=6, rerank_depth=20)
        with pytest.raises(ValueError):
            mut.topk(q, 5, nprobe=0)

    def test_engine_stats_through_wrapper(self):
        pts, L, q, _ = _setup(M=500)
        mut = MutableIndex.build(L, np.asarray(pts), base="ivfpq",
                                 n_clusters=6, rerank_depth=20)
        eng = RetrievalEngine(mut, k_top=5)
        eng.search(np.asarray(q))
        st = eng.stats()
        # compression figures must survive the MutableIndex wrapper
        assert st["compression_ratio"] == mut.base.compression_ratio
        assert st["code_bytes_per_row"] == mut.base.code_bytes_per_row
        assert "delta_rows" in st

    def test_encode_chunking_invariant(self):
        rng = np.random.RandomState(8)
        vecs = jnp.asarray(rng.randn(1000, 16).astype(np.float32))
        pq = ProductQuantizer.train(vecs, n_subspaces=4, bits=6, iters=5)
        np.testing.assert_array_equal(
            np.asarray(pq.encode(vecs, block_rows=128)),
            np.asarray(pq.encode(vecs, block_rows=100000)))

    def test_swap_metric_over_ivfpq(self):
        pts, L, q, rng = _setup()
        mut = MutableIndex.build(L, np.asarray(pts), base="ivfpq",
                                 n_clusters=12, nprobe=12,
                                 rerank_depth=5000, retain_raw=True)
        L2 = jnp.asarray(0.2 * rng.randn(24, 48), jnp.float32)
        mut.swap_metric(L2)
        assert isinstance(mut.base, IVFPQIndex)
        fresh = IVFPQIndex.build(L2, pts, n_clusters=12, nprobe=12,
                                 rerank_depth=5000)
        d_m, i_m = mut.topk(q, 10)
        d_f, i_f = fresh.topk(q, 10)
        ext = mut.live_ids()
        np.testing.assert_array_equal(ext[np.asarray(i_f)], i_m)
