"""Per-architecture smoke tests: reduced variant (2 layers, d_model<=256,
<=4 experts), one forward + one train step on CPU, asserting shapes and
finiteness. One test per assigned architecture (spec requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, reduced
from repro.configs.base import RunConfig
from repro.launch import steps
from repro.models import build_model

ARCHS = list_configs()
B, T = 2, 32


def _batch(cfg, rng):
    if cfg.input_kind == "embeddings":
        return {
            "embeddings": jnp.asarray(
                rng.randn(B, T, cfg.d_model).astype(np.float32)),
            "labels": jnp.asarray(
                rng.randint(0, cfg.vocab_size, (B, T)).astype(np.int32)),
        }
    return {
        "tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (B, T)).astype(np.int32)),
        "labels": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (B, T)).astype(np.int32)),
    }


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch):
        cfg = reduced(get_config(arch)).replace(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, np.random.RandomState(0))
        logits, aux = model.apply(params, batch)
        assert logits.shape == (B, T, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        assert np.isfinite(float(aux["moe_aux"]))

    def test_train_step_decreases_loss_and_no_nans(self, arch):
        cfg = reduced(get_config(arch)).replace(dtype="float32")
        model = build_model(cfg)
        run = RunConfig(lr=5e-3, warmup=0, total_steps=20, remat=False)
        opt = steps.make_optimizer(run)
        params = model.init(jax.random.PRNGKey(0))
        state = steps.TrainState(params, opt.init(params),
                                 jnp.zeros((), jnp.int32))
        step = jax.jit(steps.make_train_step(model, opt, run, loss_chunks=2))
        rng = np.random.RandomState(1)
        batch = _batch(cfg, rng)  # fixed batch: loss must drop when repeated
        first = None
        for i in range(5):
            state, metrics = step(state, batch)
            loss = float(metrics["loss"])
            assert np.isfinite(loss), (arch, i)
            first = loss if first is None else first
        assert loss < first, (arch, first, loss)
        for leaf in jax.tree.leaves(state.params):
            assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).has_decode])
def test_decode_matches_full_forward(arch):
    cfg = reduced(get_config(arch)).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 0,
                              cfg.vocab_size)
    full_logits, _ = model.apply(params, {"tokens": toks})
    cache = model.init_decode_cache(B, max_seq=16)
    step = jax.jit(model.decode_step)
    errs = []
    for t in range(16):
        lg, cache = step(params, cache, toks[:, t], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    assert max(errs) < 5e-2, (arch, max(errs))


def test_encoder_has_no_decode():
    cfg = reduced(get_config("hubert-xlarge"))
    model = build_model(cfg)
    with pytest.raises(ValueError, match="encoder-only"):
        model.init_decode_cache(2, 16)


def test_reduced_respects_limits():
    for arch in ARCHS:
        r = reduced(get_config(arch))
        assert r.n_layers == 2
        assert r.d_model <= 512
        assert r.n_experts <= 4
