"""Unified observability layer (obs/): metric instruments, registry
export, deterministic tracing, and the instrumentation threaded through
engine -> scheduler -> index lifecycle.

Everything time-dependent runs on FakeClock, so durations, histogram
contents, and span windows are asserted *exactly* — no sleeps, no
approx-latency flakiness.
"""

import json
import math
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.obs import (DEFAULT_LATENCY_BUCKETS, MetricsRegistry, NULL_SPAN,
                       Tracer, index_memory, log_buckets, merge_snapshots,
                       parse_label_key, percentile, span_names)
from repro.serve import (ExactIndex, FakeClock, IVFIndex, MutableIndex,
                         RequestScheduler, RetrievalEngine, load_index,
                         save_index)


# ---------------------------------------------------------------------------
# percentile: THE deduped implementation (satellite: the old
# sorted[int(n * q) - 1] underflowed to the minimum at small n)


class TestPercentile:
    def test_small_n_high_percentile_is_not_the_minimum(self):
        # regression: with n=2, int(2 * 0.99) - 1 == 0 -> the *minimum*
        # was reported as p99. Interpolation must stay near the max.
        assert percentile([10.0, 20.0], 99.0) == pytest.approx(19.9)
        assert percentile([10.0, 20.0], 50.0) == pytest.approx(15.0)

    def test_single_sample_every_q(self):
        for q in (0.0, 50.0, 99.0, 100.0):
            assert percentile([7.5], q) == 7.5

    def test_empty_is_nan(self):
        assert math.isnan(percentile([], 99.0))
        out = percentile([], (50.0, 99.0))
        assert len(out) == 2 and all(math.isnan(v) for v in out)

    def test_matches_numpy_and_sequence_q(self):
        rng = np.random.RandomState(0)
        vals = rng.randn(101).tolist()
        assert percentile(vals, 90.0) == pytest.approx(
            float(np.percentile(vals, 90.0)))
        p50, p99 = percentile(vals, (50.0, 99.0))
        assert p50 == pytest.approx(float(np.percentile(vals, 50.0)))
        assert p99 == pytest.approx(float(np.percentile(vals, 99.0)))


class TestLogBuckets:
    def test_default_spans_serving_range(self):
        b = log_buckets()
        assert b == DEFAULT_LATENCY_BUCKETS
        assert b[0] == pytest.approx(1e-4)
        assert b[-1] == pytest.approx(60.0, rel=0.5)
        assert list(b) == sorted(set(b))

    def test_bad_range_raises(self):
        with pytest.raises(ValueError):
            log_buckets(lo=0.0)
        with pytest.raises(ValueError):
            log_buckets(lo=1.0, hi=0.5)


# ---------------------------------------------------------------------------
# instruments + registry


class TestInstruments:
    def test_counter_exact_and_monotone(self):
        reg = MetricsRegistry(clock=FakeClock())
        c = reg.counter("reqs_total", labelnames=("cls",))
        c.inc(cls="a")
        c.inc(2.5, cls="a")
        c.inc(cls="b")
        assert c.value(cls="a") == 3.5
        assert c.value(cls="b") == 1.0
        assert c.total() == 4.5
        with pytest.raises(ValueError):
            c.inc(-1.0, cls="a")
        with pytest.raises(ValueError):
            c.inc(cls="a", extra="nope")     # undeclared label

    def test_gauge_set_inc(self):
        reg = MetricsRegistry(clock=FakeClock())
        g = reg.gauge("depth")
        g.set(4)
        g.inc(-1.5)
        assert g.value() == 2.5

    def test_histogram_exact_bucket_placement(self):
        reg = MetricsRegistry(clock=FakeClock())
        h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.0, 2.5, 100.0):
            h.observe(v)
        # bisect_left: a boundary value lands in its own bucket
        assert h.counts() == [2, 0, 1, 1]
        assert h.count() == 4
        assert h.sum() == 0.5 + 1.0 + 2.5 + 100.0
        # upper-bound percentile readout; overflow bucket reads inf
        assert h.percentile(50.0) == 1.0
        assert h.percentile(100.0) == float("inf")
        assert math.isnan(reg.histogram("empty",
                                        buckets=(1.0,)).percentile(50.0))

    def test_registry_get_or_create_idempotent(self):
        reg = MetricsRegistry(clock=FakeClock())
        c1 = reg.counter("x_total", labelnames=("cls",))
        assert reg.counter("x_total", labelnames=("cls",)) is c1
        with pytest.raises(ValueError):
            reg.gauge("x_total")                       # kind collision
        with pytest.raises(ValueError):
            reg.counter("x_total", labelnames=("other",))
        h = reg.histogram("h", buckets=(1.0, 2.0))
        assert reg.histogram("h") is h                 # buckets omitted ok
        with pytest.raises(ValueError):
            reg.histogram("h", buckets=(1.0, 3.0))

    def test_label_key_round_trip(self):
        reg = MetricsRegistry(clock=FakeClock())
        c = reg.counter("y_total", labelnames=("cls", "outcome"))
        c.inc(cls="interactive", outcome="completed")
        (key,) = c.label_keys()
        assert parse_label_key(key) == {"cls": "interactive",
                                        "outcome": "completed"}

    def test_threaded_increments_are_exact(self):
        # satellite: the engine's old bare-attribute counters lost
        # increments under concurrent read-modify-write; the registry
        # lock makes totals exact, not approximate
        reg = MetricsRegistry(clock=FakeClock())
        c = reg.counter("stress_total")
        h = reg.histogram("stress_lat", buckets=(1.0,))
        n_threads, n_each = 8, 1000

        def work():
            for _ in range(n_each):
                c.inc()
                h.observe(0.5)

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value() == n_threads * n_each
        assert h.counts() == [n_threads * n_each, 0]


class TestRegistryExport:
    def _reg(self):
        clock = FakeClock(t0=100.0)
        reg = MetricsRegistry(clock=clock)
        reg.counter("a_total", "help a", labelnames=("cls",)).inc(
            3, cls="x")
        reg.gauge("g").set(7)
        reg.histogram("h", buckets=(1.0, 2.0)).observe(1.5)
        reg.event("boot", detail="ok")
        return reg, clock

    def test_snapshot_schema_and_collectors(self):
        reg, clock = self._reg()
        reg.register_collector(lambda: reg.gauge("derived").set(42))
        snap = reg.snapshot()
        assert set(snap) == {"t", "counters", "gauges", "histograms",
                             "events"}
        assert snap["t"] == 100.0
        assert snap["counters"]["a_total"]["values"] == {"cls=x": 3.0}
        assert snap["gauges"]["derived"]["values"][""] == 42.0
        cell = snap["histograms"]["h"]["values"][""]
        assert cell == {"counts": [0, 1, 0], "sum": 1.5, "count": 1}
        (ev,) = snap["events"]
        assert ev["event"] == "boot" and ev["detail"] == "ok"
        assert ev["t"] == 100.0

    def test_events_bounded_oldest_dropped(self):
        reg = MetricsRegistry(clock=FakeClock(), max_events=4)
        for i in range(6):
            reg.event("e", i=i)
        evs = reg.events("e")
        assert [e["i"] for e in evs] == [2, 3, 4, 5]

    def test_merge_counters_add_gauges_later_wins(self):
        reg_a, _ = self._reg()
        reg_b, _ = self._reg()
        reg_b.gauge("g").set(9)
        merged = merge_snapshots(reg_a.snapshot(), reg_b.snapshot())
        assert merged["counters"]["a_total"]["values"]["cls=x"] == 6.0
        assert merged["gauges"]["g"]["values"][""] == 9.0
        cell = merged["histograms"]["h"]["values"][""]
        assert cell == {"counts": [0, 2, 0], "sum": 3.0, "count": 2}
        assert [e["event"] for e in merged["events"]] == ["boot", "boot"]

    def test_merge_bucket_mismatch_raises(self):
        reg_a, _ = self._reg()
        other = MetricsRegistry(clock=FakeClock())
        other.histogram("h", buckets=(1.0, 3.0)).observe(0.5)
        with pytest.raises(ValueError):
            merge_snapshots(reg_a.snapshot(), other.snapshot())

    def test_exposition_cumulative_buckets(self):
        reg, _ = self._reg()
        text = reg.exposition()
        assert "# TYPE a_total counter" in text
        assert 'a_total{cls="x"} 3' in text
        assert "# TYPE h histogram" in text
        assert 'h_bucket{le="1"} 0' in text
        assert 'h_bucket{le="2"} 1' in text
        assert 'h_bucket{le="+Inf"} 1' in text      # cumulative
        assert "h_sum 1.5" in text and "h_count 1" in text

    def test_write_snapshot_round_trips(self, tmp_path):
        reg, _ = self._reg()
        path = tmp_path / "snap.json"
        written = reg.write_snapshot(str(path))
        assert json.loads(path.read_text()) == written


# ---------------------------------------------------------------------------
# tracing


class TestTracer:
    def test_fake_clock_exact_span_windows(self):
        clock = FakeClock()
        tracer = Tracer(clock=clock, sample_rate=1.0)
        tr = tracer.start_trace()
        assert tr.sampled and tr.root.t_start == 0.0
        clock.advance(1.0)
        sp = tr.span("queue").set_attrs(cls="interactive")
        clock.advance(0.5)
        sp.end()
        sp.end()                             # idempotent: first end wins
        clock.advance(0.25)
        assert sp.t_start == 1.0 and sp.t_end == 1.5
        assert sp.duration == 0.5
        tracer.finish(tr)
        (d,) = tracer.drain()
        assert d["trace_id"] == tr.trace_id
        assert span_names(d) == ["request", "queue"]
        assert d["root"]["t_end"] == 1.75
        assert d["root"]["children"][0]["attrs"] == {"cls": "interactive"}

    def test_deterministic_sampling_every_fourth(self):
        tracer = Tracer(clock=FakeClock(), sample_rate=0.25)
        sampled = [tracer.start_trace().sampled for _ in range(8)]
        assert sampled == [False, False, False, True] * 2
        assert tracer.n_minted == 8 and tracer.n_sampled == 2

    def test_rate_edges_and_validation(self):
        assert not any(Tracer(clock=FakeClock(),
                              sample_rate=0.0).start_trace().sampled
                       for _ in range(3))
        t1 = Tracer(clock=FakeClock(), sample_rate=1.0)
        assert all(t1.start_trace().sampled for _ in range(3))
        with pytest.raises(ValueError):
            Tracer(clock=FakeClock(), sample_rate=1.5)

    def test_force_bypasses_sampling(self):
        tracer = Tracer(clock=FakeClock(), sample_rate=0.0)
        tr = tracer.start_trace("refresh", force=True)
        assert tr.sampled and tr.root.name == "refresh"

    def test_unsampled_spans_are_null_and_free(self):
        tracer = Tracer(clock=FakeClock(), sample_rate=0.0)
        tr = tracer.start_trace()
        sp = tr.span("anything")
        assert sp is NULL_SPAN
        assert sp.child("x").set_attrs(a=1).end() is NULL_SPAN
        tracer.finish(tr)                    # dropped, not buffered
        assert tracer.drain() == []

    def test_trace_ids_unique_and_ring_bounded(self):
        tracer = Tracer(clock=FakeClock(), sample_rate=1.0, max_traces=4)
        ids = set()
        for _ in range(10):
            tr = tracer.start_trace()
            ids.add(tr.trace_id)
            tracer.finish(tr)
        assert len(ids) == 10
        assert len(tracer.drain()) == 4      # oldest evicted

    def test_write_jsonl(self, tmp_path):
        tracer = Tracer(clock=FakeClock(), sample_rate=1.0)
        for _ in range(3):
            tracer.finish(tracer.start_trace())
        path = tmp_path / "traces.jsonl"
        assert tracer.write_jsonl(str(path), append=False) == 3
        lines = path.read_text().splitlines()
        assert len(lines) == 3
        assert all("trace_id" in json.loads(ln) for ln in lines)


# ---------------------------------------------------------------------------
# engine instrumentation (FakeClock-exact: the stub index advances the
# clock inside topk, so measured device time is known to the bit)

_DT = 1.0 / 128.0       # exactly representable: sums stay exact


class _StubIndex:
    """MetricIndex test double whose topk advances a FakeClock by a
    known amount — device time becomes deterministic."""

    def __init__(self, clock, d=4, size=100, dt=_DT):
        self.L = np.zeros((2, d), np.float32)
        self.version = 0
        self.size = size
        self.n_shards = 1
        self.scan_impl = "xla"
        self.nprobe = 3
        self._clock = clock
        self._dt = dt

    def topk(self, queries, k_top, backend="xla", **kw):
        self._clock.advance(self._dt)
        n = queries.shape[0]
        dists = np.zeros((n, k_top), np.float32)
        idxs = np.tile(np.arange(k_top, dtype=np.int32), (n, 1))
        return dists, idxs


class TestEngineObs:
    def test_busy_time_and_histogram_exact(self):
        clock = FakeClock()
        eng = RetrievalEngine(_StubIndex(clock), k_top=5, cache_size=0,
                              buckets=(8,), clock=clock)
        q = np.zeros((3, 4), np.float32)
        eng.search(q)
        eng.search(q)
        assert eng.busy_s == 2 * _DT
        assert eng.n_requests == 2
        assert eng.n_queries == 6 and eng.n_device_queries == 6
        h = eng.registry.histogram("engine_search_seconds")
        assert h.count() == 2 and h.sum() == 2 * _DT

    def test_search_span_tree_and_attrs(self):
        clock = FakeClock()
        eng = RetrievalEngine(_StubIndex(clock), k_top=5, cache_size=16,
                              buckets=(8,), clock=clock)
        tracer = Tracer(clock=clock, sample_rate=1.0)
        q = np.ones((3, 4), np.float32)

        tr = tracer.start_trace()
        eng.search(q, span=tr.root)          # miss -> full device path
        tracer.finish(tr)
        (d,) = tracer.drain()
        assert span_names(d) == ["request", "cache_lookup", "pad",
                                 "device_topk"]
        lookup, pad, topk = d["root"]["children"]
        assert lookup["attrs"] == {"hit": False, "rows": 3}
        assert pad["attrs"] == {"rows": 3, "bucket": 8}
        assert topk["attrs"] == {"batch": 8, "k": 5, "scan_impl": "xla",
                                 "nprobe": 3, "rerank_depth": None}
        assert topk["t_end"] - topk["t_start"] == _DT

        tr2 = tracer.start_trace()
        eng.search(q, span=tr2.root)         # repeat -> full cache hit
        tracer.finish(tr2)
        (d2,) = tracer.drain()
        assert span_names(d2) == ["request", "cache_lookup"]
        assert d2["root"]["children"][0]["attrs"] == {"hit": True,
                                                      "rows": 3}

    def test_concurrent_search_counters_exact(self):
        # the data-race satellite at the engine level: concurrent
        # callers must never lose a counter increment
        clock = FakeClock()
        eng = RetrievalEngine(_StubIndex(clock), k_top=5, cache_size=0,
                              buckets=(8,), clock=clock)
        n_threads, n_each, rows = 8, 50, 2

        def work():
            q = np.zeros((rows, 4), np.float32)
            for _ in range(n_each):
                eng.search(q)

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert eng.n_requests == n_threads * n_each
        assert eng.n_queries == n_threads * n_each * rows
        assert eng.n_device_queries == n_threads * n_each * rows

    def test_stats_is_a_view_over_the_registry(self):
        rng = np.random.RandomState(0)
        L = jnp.asarray(0.3 * rng.randn(8, 16), jnp.float32)
        G = jnp.asarray(rng.randn(64, 16), jnp.float32)
        eng = RetrievalEngine(ExactIndex.build(L, G), k_top=5)
        q = rng.randn(4, 16).astype(np.float32)
        eng.search(q)
        eng.search(q)
        st = eng.stats()
        reg = eng.registry
        assert st["n_requests"] == 2
        assert st["n_queries"] == reg.counter(
            "engine_queries_total").value() == 8
        assert st["cache_hits"] == reg.counter(
            "engine_cache_hits_total").value() == 4
        assert st["cache_misses"] == 4
        assert st["busy_s"] == reg.counter(
            "engine_busy_seconds_total").value()

    def test_memory_gauges_follow_index_swap(self):
        rng = np.random.RandomState(0)
        L = jnp.asarray(0.3 * rng.randn(8, 16), jnp.float32)
        G = jnp.asarray(rng.randn(64, 16), jnp.float32)
        eng = RetrievalEngine(ExactIndex.build(L, G), k_top=5)
        snap = eng.registry.snapshot()
        mem = snap["gauges"]["index_memory_bytes"]["values"]
        expect = index_memory(eng.index)
        assert mem["component=gallery"] == expect["gallery"] > 0
        assert mem["component=delta"] == 0
        # swap to an index with no resident arrays: bytes must zero out,
        # not dangle at the old backend's values
        eng.index = _StubIndex(FakeClock())
        mem2 = eng.registry.snapshot()["gauges"][
            "index_memory_bytes"]["values"]
        assert all(v == 0 for v in mem2.values())


class TestIndexMemory:
    def _build(self):
        rng = np.random.RandomState(0)
        L = jnp.asarray(0.3 * rng.randn(8, 16), jnp.float32)
        G = jnp.asarray(rng.randn(200, 16), jnp.float32)
        return L, G, rng

    def test_exact_components(self):
        L, G, _ = self._build()
        idx = ExactIndex.build(L, G)
        mem = index_memory(idx)
        assert mem["gallery"] == idx.gp.nbytes + idx.gn.nbytes
        assert "codes" not in mem and "delta" not in mem

    def test_ivf_has_centroids(self):
        L, G, _ = self._build()
        ivf = IVFIndex.build(L, G, n_clusters=8, seed=0)
        mem = index_memory(ivf)
        assert mem["centroids"] == ivf.centroids.nbytes
        assert mem["gallery"] > 0

    def test_mutable_adds_delta_and_host_store(self):
        L, G, rng = self._build()
        mut = MutableIndex.build(L, G, retain_raw=True,
                                 auto_compact_delta=0, auto_compact_dead=0)
        base_mem = index_memory(mut.base)
        mut.upsert(rng.randn(10, 16).astype(np.float32))
        mem = index_memory(mut)
        assert mem["delta"] > 0
        assert mem["host_store"] > 0
        assert mem["gallery"] == base_mem["gallery"]


# ---------------------------------------------------------------------------
# lifecycle events (mutable index + snapshot persistence)


class TestLifecycleEvents:
    def _mut(self, reg):
        rng = np.random.RandomState(0)
        L = jnp.asarray(0.3 * rng.randn(8, 16), jnp.float32)
        G = jnp.asarray(rng.randn(200, 16), jnp.float32)
        mut = MutableIndex.build(L, G, retain_raw=True,
                                 auto_compact_delta=0, auto_compact_dead=0)
        mut.registry = reg
        return mut, rng

    def test_compaction_event(self):
        reg = MetricsRegistry(clock=FakeClock())
        mut, rng = self._mut(reg)
        mut.upsert(rng.randn(10, 16).astype(np.float32))
        mut.delete(np.arange(5))
        assert mut.compact()
        (ev,) = reg.events("index_compaction")
        assert ev["delta_rows"] == 10 and ev["tombstones"] == 5
        assert ev["size"] == mut.size
        assert reg.counter("index_lifecycle_total",
                           labelnames=("event",)).value(
                               event="compaction") == 1

    def test_swap_metric_event(self):
        reg = MetricsRegistry(clock=FakeClock())
        mut, rng = self._mut(reg)
        L2 = jnp.asarray(0.3 * rng.randn(8, 16), jnp.float32)
        mut.swap_metric(L2)
        (ev,) = reg.events("index_swap_metric")
        assert ev["rows"] == mut.size

    def test_snapshot_save_load_events(self, tmp_path):
        reg = MetricsRegistry(clock=FakeClock())
        mut, _ = self._mut(reg)
        save_index(mut, str(tmp_path))
        (ev,) = reg.events("index_snapshot_save")
        assert ev["size"] == mut.size
        reg2 = MetricsRegistry(clock=FakeClock())
        load_index(str(tmp_path), registry=reg2)
        (ev2,) = reg2.events("index_snapshot_load")
        assert ev2["size"] == mut.size


# ---------------------------------------------------------------------------
# end-to-end: trace-id propagation scheduler -> engine, sampling knob


class TestSchedulerTracing:
    def _stack(self, sample_rate):
        rng = np.random.RandomState(0)
        L = jnp.asarray(0.3 * rng.randn(8, 16), jnp.float32)
        G = jnp.asarray(rng.randn(128, 16), jnp.float32)
        eng = RetrievalEngine(ExactIndex.build(L, G), k_top=5,
                              buckets=(8,))
        eng.tracer.sample_rate = sample_rate
        sched = RequestScheduler(eng, max_wait_ms=1.0, degrade=False)
        return eng, sched, rng

    def test_trace_covers_submit_to_device_topk(self):
        eng, sched, rng = self._stack(sample_rate=1.0)
        futs = [sched.submit(rng.randn(16).astype(np.float32))
                for _ in range(5)]
        for f in futs:
            f.result(timeout=30)
        sched.close()
        traces = eng.tracer.drain()
        assert len(traces) == 5
        assert len({t["trace_id"] for t in traces}) == 5
        for t in traces:
            names = span_names(t)
            assert names[:2] == ["request", "queue"]
            assert t["root"]["attrs"]["outcome"] == "completed"
            assert t["root"]["attrs"]["cls"] == "interactive"
        # the batch's carrier rider records the full engine path — the
        # ISSUE's acceptance span set
        full = [t for t in traces
                if {"batch", "engine", "device_topk"} <=
                set(span_names(t))]
        assert full, "no trace covers batch -> engine -> device_topk"
        # spans nest: every child window sits inside its parent's
        t = full[0]

        def check(span):
            for c in span["children"]:
                assert span["t_start"] <= c["t_start"]
                assert c["t_end"] <= span["t_end"]
                check(c)

        check(t["root"])

    def test_sampling_rate_honored_end_to_end(self):
        eng, sched, rng = self._stack(sample_rate=0.5)
        futs = [sched.submit(rng.randn(16).astype(np.float32))
                for _ in range(6)]
        for f in futs:
            f.result(timeout=30)
        sched.close()
        assert eng.tracer.n_minted == 6
        assert eng.tracer.n_sampled == 3
        assert len(eng.tracer.drain()) == 3

    def test_zero_rate_mints_nothing(self):
        eng, sched, rng = self._stack(sample_rate=0.0)
        sched.submit(rng.randn(16).astype(np.float32)).result(timeout=30)
        sched.close()
        assert eng.tracer.n_minted == 0      # perf guard: no mint at all
        assert eng.tracer.drain() == []

    def test_registry_snapshot_spans_the_whole_stack(self):
        # the ISSUE's acceptance snapshot: one snapshot from a scheduler
        # run holds front-end, engine, and index figures together
        eng, sched, rng = self._stack(sample_rate=1.0)
        futs = [sched.submit(rng.randn(16).astype(np.float32))
                for _ in range(4)]
        for f in futs:
            f.result(timeout=30)
        sched.close()
        snap = eng.registry.snapshot()
        assert snap["counters"]["engine_requests_total"]["values"]
        assert snap["counters"]["frontend_requests_total"]["values"][
            "cls=interactive,outcome=completed"] == 4.0
        assert "cls=interactive" in snap["histograms"][
            "frontend_latency_seconds"]["values"]
        assert "cls=interactive" in snap["gauges"][
            "frontend_queue_depth"]["values"]
        assert "" in snap["gauges"]["frontend_degradation_level"]["values"]
        assert snap["gauges"]["index_memory_bytes"]["values"][
            "component=gallery"] > 0


class TestMetricsReport:
    def test_render_smoke(self):
        from repro.launch.metrics_report import render
        rng = np.random.RandomState(0)
        L = jnp.asarray(0.3 * rng.randn(8, 16), jnp.float32)
        G = jnp.asarray(rng.randn(64, 16), jnp.float32)
        eng = RetrievalEngine(ExactIndex.build(L, G), k_top=5)
        q = rng.randn(4, 16).astype(np.float32)
        eng.search(q)
        eng.search(q)
        eng.registry.event("index_compaction", size=64)
        text = render(eng.registry.snapshot())
        assert "== serving ==" in text
        assert "hit rate" in text
        assert "== index memory ==" in text
        assert "index_compaction" in text
