"""Sharded retrieval checks, run in a subprocess with 8 host devices.

Invoked by tests/test_metric_topk.py. Builds the same gallery index sharded
over a (data=8, model=1) mesh and unsharded, and asserts the shard_map
local-topk + global-merge query path agrees exactly with the single-device
path (indices identical, distances allclose), including when k_top exceeds
the per-shard row count. Prints a JSON summary on success. Standalone so
the main pytest process keeps the real single-device view (dry-run rule).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import json  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.launch.mesh import make_local_mesh  # noqa: E402
from repro.serve import (GalleryIndex, IVFIndex,  # noqa: E402
                         RetrievalEngine)


def main():
    assert jax.device_count() == 8, jax.device_count()
    out = {}
    rng = np.random.RandomState(0)
    k, d, M, Nq = 24, 56, 4096, 32
    L = jnp.asarray(0.3 * rng.randn(k, d), jnp.float32)
    G = jnp.asarray(rng.randn(M, d), jnp.float32)
    q = jnp.asarray(rng.randn(Nq, d), jnp.float32)

    mesh = make_local_mesh()                    # (data=8, model=1)
    sharded = GalleryIndex.build(L, G, mesh=mesh)
    assert sharded.n_shards == 8, sharded.n_shards
    single = GalleryIndex.build(L, G)

    for k_top in (1, 10, 600):                  # 600 > M/8: exhausts shards
        ds, is_ = sharded.topk(q, k_top)
        du, iu = single.topk(q, k_top)
        assert (np.asarray(is_) == np.asarray(iu)).all(), \
            f"k_top={k_top}: sharded indices != single-device"
        np.testing.assert_allclose(np.asarray(ds), np.asarray(du),
                                   rtol=1e-5, atol=1e-5)
    out["sharded_matches_single"] = True
    out["n_shards"] = sharded.n_shards

    # the engine runs unchanged on a sharded index
    eng = RetrievalEngine(sharded, k_top=5)
    dists, idxs = eng.search(q)
    du, iu = single.topk(q, 5)
    assert (idxs == np.asarray(iu)).all()
    assert eng.stats()["n_shards"] == 8
    out["engine_on_sharded_index"] = True

    # IVF: whole-cluster sharding must agree with the single-device path,
    # and full probe must agree with the exact scan
    ivf_s = IVFIndex.build(L, G, n_clusters=16, nprobe=4, seed=0, mesh=mesh)
    ivf_1 = IVFIndex.build(L, G, n_clusters=16, nprobe=4, seed=0)
    assert ivf_s.n_shards == 8, ivf_s.n_shards
    for k_top, nprobe in ((1, 4), (10, 4), (10, 16)):
        ds, is_ = ivf_s.topk(q, k_top, nprobe=nprobe)
        du, iu = ivf_1.topk(q, k_top, nprobe=nprobe)
        assert (np.asarray(is_) == np.asarray(iu)).all(), \
            f"k_top={k_top} nprobe={nprobe}: sharded IVF != single-device"
        np.testing.assert_allclose(np.asarray(ds), np.asarray(du),
                                   rtol=1e-4, atol=1e-3)
    _, i_full = ivf_s.topk(q, 10, nprobe=16)
    _, i_ex = single.topk(q, 10)
    assert (np.asarray(i_full) == np.asarray(i_ex)).all(), \
        "sharded IVF full probe != exact scan"
    out["ivf_sharded_matches_single"] = True

    print("SERVE_CHECK_OK " + json.dumps(out))


if __name__ == "__main__":
    main()
