"""Ablation: PS consistency model x sync period — metric quality vs
communication volume. Quantifies the paper's core systems trade-off
end-to-end: asynchronous/periodic sync buys a ~tau reduction in parameter
traffic at (near-)zero quality cost.

Runs in a subprocess with 8 forced host devices (worker axis) so the main
process keeps the single-device view. Results ->
benchmarks/artifacts/ablation_sync.json.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

ART = os.path.join(os.path.dirname(__file__), "artifacts")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import dml, losses
from repro.core.ps import sync, trainer
from repro.data import pairs as pairdata
from repro.optim import sgd

P = 8
cfgd = pairdata.PairDatasetConfig(n_samples=800, feat_dim=32, n_classes=5,
                                  kind="noisy_subspace", noise=0.5, seed=0)
train_pairs, eval_pairs = pairdata.train_eval_split(cfgd, 6000, 6000,
                                                    1500, 1500)
dcfg = dml.DMLConfig(feat_dim=32, proj_dim=16)
xs = jnp.asarray(eval_pairs["xs"]); ys = jnp.asarray(eval_pairs["ys"])
lab = jnp.asarray(eval_pairs["sim"])
L_bytes = dcfg.proj_dim * dcfg.feat_dim * 4
STEPS = 120

out = {}
for name, ps_cfg in [
    ("bsp", sync.PSConfig(n_workers=P, sync="bsp")),
    ("local_tau4", sync.PSConfig(n_workers=P, sync="local", tau=4)),
    ("local_tau16", sync.PSConfig(n_workers=P, sync="local", tau=16)),
    ("ssp_s4", sync.PSConfig(n_workers=P, sync="ssp", staleness=4)),
]:
    tcfg = trainer.DMLTrainConfig(dml=dcfg, ps=ps_cfg, batch_size=128,
                                  steps=STEPS, lr=3e-2)
    L, hist = trainer.train_dml_distributed(tcfg, train_pairs)
    ap = float(dml.average_precision(dml.pair_scores(L, xs, ys), lab))
    # parameter-sync traffic per worker over the run (model bytes per merge)
    if ps_cfg.sync == "bsp":
        merges = STEPS
    elif ps_cfg.sync == "local":
        merges = STEPS // ps_cfg.tau
    else:
        merges = STEPS  # ssp emulation still merges gradients every step
    out[name] = {"ap": ap, "final_loss": hist[-1]["loss"],
                 "param_sync_bytes": merges * L_bytes,
                 "merges": merges}
print("ABLATION_OK " + json.dumps(out))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("ABLATION_OK")][0]
    out = json.loads(line[len("ABLATION_OK "):])
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "ablation_sync.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    out = run()
    print("sync_mode,ap,final_loss,param_sync_bytes")
    for k, v in out.items():
        print(f"{k},{v['ap']:.4f},{v['final_loss']:.4f},"
              f"{v['param_sync_bytes']}")
    # the paper's trade-off: periodic sync keeps quality within 2 AP points
    # of BSP while cutting parameter traffic by tau
    assert out["local_tau16"]["ap"] > out["bsp"]["ap"] - 0.02
    ratio = (out["bsp"]["param_sync_bytes"]
             / out["local_tau16"]["param_sync_bytes"])
    assert ratio >= 15, ratio  # ~tau (floor(steps/tau) merges)


if __name__ == "__main__":
    main()
