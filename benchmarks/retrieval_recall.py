"""IVF recall@k vs QPS frontier against the exact scan baseline.

Builds a clustered synthetic gallery (M=50k mixture of Gaussians — the
regime cluster pruning is designed for), an ExactIndex and an IVFIndex
over the same learned-style projection, then sweeps ``nprobe`` and
reports, per point, the recall@10 against exact ground truth and the
measured QPS. The frontier is the serving knob: pick the cheapest nprobe
whose recall clears the product bar.

Prints ``recall,<nprobe>,<qps>,<recall@10>,<speedup_vs_exact>`` CSV lines
like the other benchmark sections, and asserts the paper-scale claim this
repo pins in CI: some nprobe reaches >= 2x the exact scan's QPS at
recall@10 >= 0.9.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

# gallery M x d, projection k, C coarse clusters, query batches of NQ
M, D, KPROJ, C, NQ, KTOP = 50_000, 64, 32, 64, 64, 10
N_BLOBS = 256           # latent components (>> C: clusters merge whole
SWEEP = (1, 2, 4, 8, 16)  # blobs instead of splitting one blob's neighbors)


def _time(fn, *args, iters: int = 10):
    jax.block_until_ready(fn(*args))            # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main():
    from repro.serve import ExactIndex, IVFIndex, recall_at_k

    rng = np.random.RandomState(0)
    centers = 3.0 * rng.randn(N_BLOBS, D).astype(np.float32)
    blob = rng.randint(0, N_BLOBS, M)
    gallery = jnp.asarray(centers[blob] + 0.3 * rng.randn(M, D), jnp.float32)
    L = jnp.asarray(0.2 * rng.randn(KPROJ, D), jnp.float32)
    qblob = rng.randint(0, N_BLOBS, NQ)
    queries = jnp.asarray(centers[qblob] + 0.3 * rng.randn(NQ, D),
                          jnp.float32)

    exact = ExactIndex.build(L, gallery)
    t0 = time.perf_counter()
    ivf = IVFIndex.build(L, gallery, n_clusters=C, iters=10, seed=0,
                         cap_factor=1.5)
    print(f"ivf build (kmeans {C} clusters over {M} rows, cap {ivf.cap}): "
          f"{time.perf_counter() - t0:.2f}s")

    d_exact, i_exact = exact.topk(queries, KTOP)
    t_exact = _time(lambda q: exact.topk(q, KTOP), queries)
    print(f"exact scan: {NQ / t_exact:.0f} qps ({t_exact * 1e3:.2f} "
          f"ms/batch{NQ})")

    print("\nsection,nprobe,qps,recall_at_10,speedup_vs_exact")
    frontier = []
    for nprobe in SWEEP:
        if nprobe > ivf.n_clusters:
            continue
        _, ids = ivf.topk(queries, KTOP, nprobe=nprobe)
        rec = recall_at_k(ids, i_exact)
        t = _time(lambda q: ivf.topk(q, KTOP, nprobe=nprobe), queries)
        speedup = t_exact / t
        frontier.append((nprobe, NQ / t, rec, speedup))
        print(f"recall,{nprobe},{NQ / t:.0f},{rec:.3f},{speedup:.2f}")

    # full probe is the correctness oracle: indices must match exact
    # (few queries: the oracle gather materializes Nq * C*cap rows)
    _, i_full = ivf.topk(queries[:8], KTOP, nprobe=ivf.n_clusters)
    assert (np.asarray(i_full) == np.asarray(i_exact)[:8]).all(), \
        "IVF at nprobe == n_clusters != exact scan"
    print("full-probe oracle: indices match exact scan  [OK]")

    best = max((s for n, q, r, s in frontier if r >= 0.9), default=0.0)
    print(f"best speedup at recall@10 >= 0.9: {best:.2f}x")
    assert best >= 2.0, \
        f"IVF did not reach 2x exact QPS at recall>=0.9 (best {best:.2f}x)"


if __name__ == "__main__":
    main()
