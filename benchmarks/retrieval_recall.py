"""IVF and IVF-PQ recall@k vs QPS frontiers against the exact scan.

Builds a clustered synthetic gallery (mixture of Gaussians — the regime
cluster pruning is designed for), an ExactIndex, an IVFIndex, and an
IVFPQIndex over the same learned-style projection, then sweeps ``nprobe``
for both approximate backends and reports, per point, the recall@10
against exact ground truth and the measured QPS. The frontiers are the
serving knob: pick the cheapest point whose recall clears the product
bar.

The PQ sweep uses a finer coarse partition than the IVF one (C_PQ >
C_IVF): compressed segments make each probed row ~16x cheaper to gather,
so the same byte budget affords more, smaller, better-targeted clusters —
that is the compression payoff this benchmark pins, not just the raw
per-row byte count.

Prints CSV lines like the other benchmark sections:

  recall,<nprobe>,<qps>,<recall@10>,<speedup_vs_exact>         (IVF)
  recall_pq,<nprobe>,<qps_raw>,<recall_raw>,<qps_rr>,<recall_rr> (IVFPQ)

A second axis compares the segment-scan implementations (the
``scan_impl`` knob on both ANN indexes): the XLA chunked scan vs the
auto-resolved default — the fused Pallas kernels (kernels/pq_adc,
kernels/ivf_scan) on TPU, the same XLA path elsewhere (interpret-mode
Pallas is a correctness tool, orders of magnitude slower, so it is
never *timed* off-TPU; bit-identity of the explicit "pallas" path is
asserted on a small query subset instead). Results land in
``BENCH_retrieval.json`` (``--out`` overrides; benchmarks/check_bench.py
gates CI on regressions against the committed baseline).

CI-pinned claims (``--smoke`` runs a CI-sized version of the same code
paths):

  * IVF reaches >= 2x the exact scan's QPS at recall@10 >= 0.9, and full
    probe matches the exact scan on indices (PR 2's claims, kept).
  * IVFPQ at its operating point: raw ADC recall@10 >= 0.85, reranked
    recall@10 >= 0.95 at >= 2x the QPS of the cheapest IVF sweep point
    reaching 0.95, with code bytes <= 1/8 of the full-precision row.
  * IVFPQ at full probe + full rerank matches the exact scan on indices.
  * The ADC kernel path ("pallas", interpret off-TPU) is bit-identical
    to the XLA path, and the auto-resolved scan QPS is no worse than
    the explicit XLA scan (>= 0.9x noise guard; on TPU this is the
    kernel-vs-XLA comparison the tentpole targets).
  * Low-rank rank sweep (d' in {D, D/2, D/4}, SVD-truncated factors of
    a decaying-spectrum square L): d' = D/4 keeps recall@10 >= 0.9
    with rerank on while shrinking the full-precision projected
    gallery (the rerank store) >= 2x.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _time(fn, *args, iters: int = 10):
    jax.block_until_ready(fn(*args))            # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        # block every iteration: async dispatch otherwise overlaps
        # queued work and the measured numbers track Python dispatch,
        # not device time (it also matches serving, where the engine
        # blocks per batch)
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def main(smoke: bool = False, out: str = None):
    from repro.serve import (ExactIndex, IVFIndex, IVFPQIndex,
                             recall_at_k)
    from repro.serve.scan import resolve_scan_impl

    # gallery M x d, projection k, C coarse clusters, batches of NQ.
    # The gallery stays at 50k in --smoke (the pinned claims are about
    # this scale — smaller galleries make the exact scan too cheap to
    # beat 2x); smoke only trims the sweeps and timing iterations.
    M, D, KPROJ, NQ, KTOP = 50_000, 64, 32, 64, 10
    # latent components (>> C_IVF: clusters merge whole blobs instead of
    # splitting one blob's neighbors)
    N_BLOBS, C_IVF, C_PQ = 256, 64, 256
    if smoke:   # CI-sized: same code paths and claim structure
        SWEEP, SWEEP_PQ = (1, 2, 4), (1, 2)
        ITERS = 5
    else:
        SWEEP, SWEEP_PQ = (1, 2, 4, 8, 16), (1, 2, 4, 8)
        ITERS = 10
    N_SUB, BITS, RERANK = 16, 8, 2 * KTOP

    rng = np.random.RandomState(0)
    centers = 3.0 * rng.randn(N_BLOBS, D).astype(np.float32)
    blob = rng.randint(0, N_BLOBS, M)
    gallery = jnp.asarray(centers[blob] + 0.3 * rng.randn(M, D), jnp.float32)
    L = jnp.asarray(0.2 * rng.randn(KPROJ, D), jnp.float32)
    qblob = rng.randint(0, N_BLOBS, NQ)
    queries = jnp.asarray(centers[qblob] + 0.3 * rng.randn(NQ, D),
                          jnp.float32)

    exact = ExactIndex.build(L, gallery)
    t0 = time.perf_counter()
    ivf = IVFIndex.build(L, gallery, n_clusters=C_IVF, iters=10, seed=0,
                         cap_factor=1.5)
    print(f"ivf build (kmeans {C_IVF} clusters over {M} rows, cap "
          f"{ivf.cap}): {time.perf_counter() - t0:.2f}s")

    d_exact, i_exact = exact.topk(queries, KTOP)
    t_exact = _time(lambda q: exact.topk(q, KTOP), queries, iters=ITERS)
    print(f"exact scan: {NQ / t_exact:.0f} qps ({t_exact * 1e3:.2f} "
          f"ms/batch{NQ})")

    print("\nsection,nprobe,qps,recall_at_10,speedup_vs_exact")
    frontier = []
    for nprobe in SWEEP:
        if nprobe > ivf.n_clusters:
            continue
        _, ids = ivf.topk(queries, KTOP, nprobe=nprobe)
        rec = recall_at_k(ids, i_exact)
        t = _time(lambda q: ivf.topk(q, KTOP, nprobe=nprobe), queries,
                  iters=ITERS)
        speedup = t_exact / t
        frontier.append((nprobe, NQ / t, rec, speedup))
        print(f"recall,{nprobe},{NQ / t:.0f},{rec:.3f},{speedup:.2f}")

    # full probe is the correctness oracle: indices must match exact
    # (few queries: the oracle gather materializes Nq * C*cap rows)
    _, i_full = ivf.topk(queries[:8], KTOP, nprobe=ivf.n_clusters)
    assert (np.asarray(i_full) == np.asarray(i_exact)[:8]).all(), \
        "IVF at nprobe == n_clusters != exact scan"
    print("full-probe oracle: indices match exact scan  [OK]")

    best = max((s for n, q, r, s in frontier if r >= 0.9), default=0.0)
    print(f"best speedup at recall@10 >= 0.9: {best:.2f}x")
    assert best >= 2.0, \
        f"IVF did not reach 2x exact QPS at recall>=0.9 (best {best:.2f}x)"

    # --- IVF-PQ frontier -------------------------------------------------
    t0 = time.perf_counter()
    pq = IVFPQIndex.build(L, gallery, n_clusters=C_PQ, nprobe=1,
                          n_subspaces=N_SUB, bits=BITS,
                          rerank_depth=RERANK, store="device", iters=10,
                          seed=0, cap_factor=1.5)
    print(f"\nivfpq build ({C_PQ} clusters, cap {pq.cap}, "
          f"{N_SUB} x {BITS}-bit codes, {pq.pq.code_bytes} B/row vs "
          f"{4 * KPROJ} full, rerank {RERANK}): "
          f"{time.perf_counter() - t0:.2f}s")

    print("section,nprobe,qps_raw,recall_raw,qps_rerank,recall_rerank")
    frontier_pq = []
    for nprobe in SWEEP_PQ:
        if nprobe > pq.n_clusters:
            continue
        _, i_raw = pq.topk(queries, KTOP, nprobe=nprobe, rerank=0)
        _, i_rr = pq.topk(queries, KTOP, nprobe=nprobe)
        r_raw = recall_at_k(i_raw, i_exact)
        r_rr = recall_at_k(i_rr, i_exact)
        t_raw = _time(lambda q: pq.topk(q, KTOP, nprobe=nprobe, rerank=0),
                      queries, iters=ITERS)
        t_rr = _time(lambda q: pq.topk(q, KTOP, nprobe=nprobe), queries,
                     iters=ITERS)
        frontier_pq.append((nprobe, NQ / t_raw, r_raw, NQ / t_rr, r_rr))
        print(f"recall_pq,{nprobe},{NQ / t_raw:.0f},{r_raw:.3f},"
              f"{NQ / t_rr:.0f},{r_rr:.3f}")

    # full probe + full-depth rerank is the PQ correctness oracle
    _, i_pq_full = pq.topk(queries[:8], KTOP, nprobe=pq.n_clusters,
                           rerank=M)
    assert (np.asarray(i_pq_full) == np.asarray(i_exact)[:8]).all(), \
        "IVFPQ at full probe + full rerank != exact scan"
    print("pq full-probe+rerank oracle: indices match exact scan  [OK]")

    # pinned claims: code budget, raw ADC quality, reranked quality at
    # >= 2x the cheapest IVF operating point that clears the same bar
    assert pq.pq.code_bytes * 8 <= 4 * KPROJ, \
        f"code bytes {pq.pq.code_bytes} > 1/8 of row ({4 * KPROJ} B)"
    ivf_at_95 = max((q for n, q, r, s in frontier if r >= 0.95),
                    default=None)
    # the 2x claim must actually be gated: an IVF sweep that never
    # reaches 0.95 would silently skip the ratio assertion below
    assert ivf_at_95 is not None, \
        "no IVF sweep point reached recall@10 >= 0.95 (2x claim ungated)"
    pq_best = max(((q_rr, r_raw, r_rr) for n, q_raw, r_raw, q_rr, r_rr
                   in frontier_pq if r_rr >= 0.95 and r_raw >= 0.85),
                  default=None)
    assert pq_best is not None, \
        "no IVFPQ sweep point reached raw>=0.85 and rerank>=0.95"
    q_pq, r_raw, r_rr = pq_best
    print(f"ivfpq operating point: raw recall {r_raw:.3f}, reranked "
          f"{r_rr:.3f} at {q_pq:.0f} qps; cheapest ivf@0.95: "
          f"{ivf_at_95:.0f} qps")
    assert r_raw >= 0.85 and r_rr >= 0.95
    ratio = q_pq / ivf_at_95
    print(f"ivfpq speedup over ivf at recall@10 >= 0.95: {ratio:.2f}x "
          f"(codes {pq.compression_ratio:.1f}x smaller)")
    assert ratio >= 2.0, \
        f"IVFPQ did not reach 2x IVF QPS at recall>=0.95 ({ratio:.2f}x)"

    # --- scan_impl: fused kernel path vs XLA scan ------------------------
    # bit-identity first: the explicit "pallas" path (interpret mode
    # off-TPU — far too slow to time, but it runs the real kernel logic)
    # must reproduce the XLA scan exactly. Few queries on purpose.
    np_pq, np_ivf = SWEEP_PQ[-1], SWEEP[-1]
    qsub = queries[:4]
    d_x, i_x = pq.topk(qsub, KTOP, nprobe=np_pq, scan_impl="xla")
    d_p, i_p = pq.topk(qsub, KTOP, nprobe=np_pq, scan_impl="pallas")
    assert np.array_equal(np.asarray(i_x), np.asarray(i_p)) and \
        np.array_equal(np.asarray(d_x), np.asarray(d_p)), \
        "pq_adc kernel path != XLA ADC path (bit-identity broken)"
    d_x, i_x = ivf.topk(qsub, KTOP, nprobe=np_ivf, scan_impl="xla")
    d_p, i_p = ivf.topk(qsub, KTOP, nprobe=np_ivf, scan_impl="pallas")
    assert np.array_equal(np.asarray(i_x), np.asarray(i_p)), \
        "ivf_scan kernel path != XLA scan on indices"
    assert np.allclose(np.asarray(d_x), np.asarray(d_p), rtol=1e-4,
                       atol=1e-4), "ivf_scan kernel distances drifted"
    print("\nscan_impl=pallas parity vs xla (pq bitwise, ivf ids)  [OK]")

    # QPS: explicit XLA scan vs the auto-resolved default ("pallas" on
    # TPU — the kernel-vs-XLA race this benchmark exists for — and "xla"
    # elsewhere, where the two columns should tie)
    impl_auto = resolve_scan_impl("auto")
    t_pq_x = _time(lambda q: pq.topk(q, KTOP, nprobe=np_pq,
                                     scan_impl="xla"), queries,
                   iters=ITERS)
    t_pq_k = _time(lambda q: pq.topk(q, KTOP, nprobe=np_pq,
                                     scan_impl=impl_auto), queries,
                   iters=ITERS)
    t_ivf_x = _time(lambda q: ivf.topk(q, KTOP, nprobe=np_ivf,
                                       scan_impl="xla"), queries,
                    iters=ITERS)
    t_ivf_k = _time(lambda q: ivf.topk(q, KTOP, nprobe=np_ivf,
                                       scan_impl=impl_auto), queries,
                    iters=ITERS)
    print(f"section,index,impl,qps")
    print(f"scan_impl,ivfpq,xla,{NQ / t_pq_x:.0f}")
    print(f"scan_impl,ivfpq,{impl_auto},{NQ / t_pq_k:.0f}")
    print(f"scan_impl,ivf,xla,{NQ / t_ivf_x:.0f}")
    print(f"scan_impl,ivf,{impl_auto},{NQ / t_ivf_k:.0f}")
    # recall is equal by the parity assertions above, so the gate is
    # pure throughput; 0.9x guards timer noise when both columns are
    # the same XLA fn (off-TPU)
    assert NQ / t_pq_k >= 0.9 * (NQ / t_pq_x), \
        f"ADC kernel path slower than XLA ({NQ / t_pq_k:.0f} vs " \
        f"{NQ / t_pq_x:.0f} qps)"
    assert NQ / t_ivf_k >= 0.9 * (NQ / t_ivf_x), \
        f"IVF kernel path slower than XLA ({NQ / t_ivf_k:.0f} vs " \
        f"{NQ / t_ivf_x:.0f} qps)"

    # --- engine cache + unified-registry snapshot ------------------------
    # the sections above time index.topk directly; this one goes through
    # the RetrievalEngine so the BENCH payload carries registry-backed
    # cache and memory metrics (check_bench gates cache_hit_rate,
    # check_obs validates the snapshot schema)
    from repro.serve import RetrievalEngine
    eng = RetrievalEngine(ivf, k_top=KTOP, buckets=(NQ,),
                          cache_size=4 * NQ)
    qnp = np.asarray(queries)
    for _ in range(4):          # repeat traffic: rounds 2-4 hit the LRU
        eng.search(qnp)
    est = eng.stats()
    looked = est["cache_hits"] + est["cache_misses"]
    cache_hit_rate = est["cache_hits"] / looked
    print(f"\nengine cache over 4x repeat traffic: {est['cache_hits']} "
          f"hits / {est['cache_misses']} misses "
          f"(hit rate {cache_hit_rate:.2f})")
    assert cache_hit_rate >= 0.5, \
        f"repeat traffic should hit the LRU (rate {cache_hit_rate:.2f})"

    # --- low-rank L: rank sweep ------------------------------------------
    # The paper-scale memory story: a learned metric is effectively
    # low-rank, so a rectangular (d', D) factor shrinks every projected
    # artifact (gallery rows, rerank store, PQ inputs) by D/d'. Model
    # the learned-spectrum regime with a full-rank reference factor
    # whose singular values decay, truncate it by SVD to
    # d' in {D, D/2, D/4}, and measure the QPS / projected-memory /
    # recall frontier. Ground truth is the exact scan under the square
    # factor; the d' = D row is distance-equivalent to it (left-
    # orthogonal factors preserve ||Lx - Ly||), so its recall pins ~1.0
    # and the lower rows show what rank truncation actually costs.
    from repro.obs import index_memory
    u_r, _ = np.linalg.qr(rng.randn(D, D))
    v_r, _ = np.linalg.qr(rng.randn(D, D))
    spec = (0.85 ** np.arange(D)).astype(np.float32)
    L_sq = jnp.asarray((u_r * spec) @ v_r.T, jnp.float32)
    exact_sq = ExactIndex.build(L_sq, gallery)
    _, i_sq = exact_sq.topk(queries, KTOP)

    print("\nsection,d_out,qps,recall_at_10,proj_bytes,mem_reduction")
    rank_rows = []
    for dp in (D, D // 2, D // 4):
        L_r = jnp.asarray(spec[:dp, None] * v_r[:, :dp].T, jnp.float32)
        # deep rerank on purpose: the exact pass runs in the d'-projected
        # space, so it absorbs ADC quantization error (which worsens as
        # more decaying-scale dims share a subspace) and leaves rank
        # truncation as the error the sweep isolates
        idx_r = IVFPQIndex.build(L_r, gallery, n_clusters=C_IVF, nprobe=8,
                                 n_subspaces=min(N_SUB, dp), bits=BITS,
                                 rerank_depth=20 * KTOP, store="device",
                                 iters=10, seed=0, cap_factor=1.5)
        _, i_r = idx_r.topk(queries, KTOP)          # rerank on
        rec = recall_at_k(i_r, i_sq)
        t = _time(lambda q: idx_r.topk(q, KTOP), queries, iters=ITERS)
        mem = index_memory(idx_r)
        # the full-precision projected rows (the rerank store): the
        # component the D/d' claim is about
        proj = mem["host_store"]
        rank_rows.append({"d_out": dp, "qps": NQ / t,
                          "recall_at_10": rec,
                          "projected_gallery_bytes": proj,
                          "memory_by_component": mem})
    sq_proj = rank_rows[0]["projected_gallery_bytes"]
    for row in rank_rows:
        row["memory_reduction_vs_square"] = sq_proj / row[
            "projected_gallery_bytes"]
        print(f"rank,{row['d_out']},{row['qps']:.0f},"
              f"{row['recall_at_10']:.3f},"
              f"{row['projected_gallery_bytes']},"
              f"{row['memory_reduction_vs_square']:.2f}")

    # pinned claim: d' = D/4 keeps recall@10 >= 0.9 (rerank on) while
    # shrinking the projected gallery >= 2x
    low = rank_rows[-1]
    assert low["recall_at_10"] >= 0.9, \
        f"d'=D/4 recall@10 {low['recall_at_10']:.3f} < 0.9"
    assert low["memory_reduction_vs_square"] >= 2.0, \
        f"d'=D/4 projected-gallery reduction " \
        f"{low['memory_reduction_vs_square']:.2f}x < 2x"
    print(f"low-rank claim: d'={low['d_out']} holds recall@10 "
          f"{low['recall_at_10']:.3f} at "
          f"{low['memory_reduction_vs_square']:.2f}x less projected "
          f"gallery  [OK]")

    # --- BENCH json ------------------------------------------------------
    out = out or os.path.join(REPO, "BENCH_retrieval.json")
    payload = {
        "bench": "retrieval_recall", "smoke": smoke,
        "jax_backend": jax.default_backend(),
        "params": {"M": M, "D": D, "k_proj": KPROJ, "n_queries": NQ,
                   "k_top": KTOP, "c_ivf": C_IVF, "c_pq": C_PQ,
                   "n_subspaces": N_SUB, "bits": BITS, "rerank": RERANK},
        "exact": {"qps": NQ / t_exact},
        "ivf_frontier": [
            {"nprobe": n, "qps": q, "recall_at_10": r,
             "speedup_vs_exact": s} for n, q, r, s in frontier],
        "ivfpq_frontier": [
            {"nprobe": n, "qps_raw": qr, "recall_raw": rr,
             "qps_rerank": qq, "recall_rerank": r2}
            for n, qr, rr, qq, r2 in frontier_pq],
        "scan_impl": {
            "resolved_auto": impl_auto,
            "bit_identical": True,
            "ivfpq": {"nprobe": np_pq, "qps_xla": NQ / t_pq_x,
                      "qps_kernel": NQ / t_pq_k},
            "ivf": {"nprobe": np_ivf, "qps_xla": NQ / t_ivf_x,
                    "qps_kernel": NQ / t_ivf_k},
        },
        # low-rank rank sweep: qps keys inside are gated pathwise by
        # check_bench once this file is committed
        "rank_sweep": rank_rows,
        # unified-obs block: gated cache key + the engine's registry
        # snapshot (includes the per-component index memory gauges)
        "obs": {"cache_hit_rate": cache_hit_rate,
                "registry": eng.registry.snapshot()},
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (seconds)")
    ap.add_argument("--out", default=None,
                    help="BENCH json path (default: repo root)")
    a = ap.parse_args()
    main(smoke=a.smoke, out=a.out)
