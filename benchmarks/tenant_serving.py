"""Multi-tenant serving: N metrics over ONE shared gallery, gated.

The tenant router's pitch (serve/tenant.py) is that N learned metrics
can serve off one resident copy of the raw gallery — each tenant pays
only its (d_out-sized) projected view — without giving up per-tenant
answer quality. This benchmark makes that claim falsifiable on a
3-tenant set with deliberately mixed backends:

  t_exact   full-scan ExactIndex view, low-rank L;
  t_ivf     cluster-pruned IVFIndex view, its own L;
  t_pq      IVFPQIndex view (ADC + exact rerank), wider L.

Mixed traffic (round-robin across tenants, unique noisy queries) runs
through the RequestScheduler front end via per-tenant routes — batches
never mix tenants — and per-tenant QPS + recall@10 against that
tenant's own exact-scan oracle are measured and written to
``BENCH_tenant.json`` (gated direction-aware by check_bench.py: qps*
and recall* up, queue_depth* down). The registry snapshot is embedded
for check_obs.py, which also asserts every tenant-scoped series carries
a non-empty ``tenant`` label.

Pinned claims (CI runs ``--smoke`` on every push):

  * recall@10 >= 0.9 for EVERY tenant vs its own exact oracle over the
    shared rows (the ANN views trade work, not correctness);
  * total resident bytes (shared raw store once + all views, via
    ``obs.index_memory``) <= 0.6x three independent stacks (each
    holding its own raw copy + view) — the multi-tenant memory win;
  * shadow promotion is **bit-identical** to a fresh build: after
    ``promote()``, the promoted tenant answers exactly like a second
    router that registered the candidate L directly (same deterministic
    build path a trainer-side ``swap_metric`` rebuild takes);
  * zero silent drops: submitted == completed for every tenant (the
    run is sized inside the admission caps).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _factors(rng, d_in):
    """Three distinct low-rank factors (d_out, d_in), one per tenant."""
    return {
        "t_exact": (0.2 * rng.randn(d_in // 4, d_in)).astype(np.float32),
        "t_ivf": (0.2 * rng.randn(d_in // 4, d_in)).astype(np.float32),
        "t_pq": (0.2 * rng.randn(d_in // 2, d_in)).astype(np.float32),
    }


def _backends(n_clusters, rerank):
    return {
        "t_exact": ("exact", {}),
        "t_ivf": ("ivf", dict(n_clusters=n_clusters, nprobe=n_clusters)),
        "t_pq": ("ivfpq", dict(n_clusters=n_clusters, nprobe=n_clusters,
                               rerank_depth=rerank)),
    }


def main(smoke: bool = False, out: str | None = None):
    from repro.obs import index_memory
    from repro.serve import (ExactIndex, RequestScheduler, RetrievalEngine,
                             TenantRouter)

    k = 10
    if smoke:       # CI-sized: seconds, same code paths + claims
        m, d_in, n_queries, n_clusters = 4000, 48, 240, 16
    else:
        m, d_in, n_queries, n_clusters = 20000, 64, 1200, 32
    rerank = 8 * k
    rng = np.random.RandomState(0)
    n_blobs = 24
    centers = rng.randn(n_blobs, d_in).astype(np.float32) * 2.0
    feats = (centers[rng.randint(0, n_blobs, m)]
             + 0.5 * rng.randn(m, d_in)).astype(np.float32)
    factors = _factors(rng, d_in)
    backends = _backends(n_clusters, rerank)
    names = sorted(factors)

    router = TenantRouter(feats, k_top=k)
    t0 = time.perf_counter()
    for name in names:
        backend, kw = backends[name]
        router.add_tenant(name, factors[name], backend=backend,
                          build_kwargs=kw, deadline_s=30.0)
        router.warm(name)
        router.tenant(name).engine.warmup()
    build_s = time.perf_counter() - t0

    # exact-scan oracle per tenant over the same shared rows
    oracles = {name: RetrievalEngine(
        ExactIndex.build(factors[name], feats), k_top=k)
        for name in names}

    # scheduler front end: default engine is t_exact's (already
    # tenant-scoped, so no unscoped engine_* series leak onto the base
    # registry); degrade off — quality knobs would move recall
    sched = RequestScheduler(router.tenant(names[0]).engine,
                             registry=router.registry, max_batch=32,
                             max_wait_ms=1.0, degrade=False)
    router.attach_scheduler(sched)

    queries = (feats[rng.randint(0, m, n_queries)]
               + 0.1 * rng.randn(n_queries, d_in)).astype(np.float32)
    t0 = time.perf_counter()
    futs = [(names[i % len(names)], i,
             router.submit(names[i % len(names)], queries[i]))
            for i in range(n_queries)]
    per = {name: {"completed": 0, "recall_sum": 0.0} for name in names}
    for name, i, fut in futs:
        _, ids = fut.result(timeout=120)
        _, o_ids = oracles[name].search(queries[i])
        per[name]["completed"] += 1
        per[name]["recall_sum"] += (
            len(set(ids.tolist()) & set(np.asarray(o_ids).tolist())) / k)
    wall = time.perf_counter() - t0
    depth_end = sched.observability()["queue_depth"]

    # memory claim: router (raw once + views) vs independent stacks
    # (each tenant holding its own raw copy + the same view)
    mem = router.memory()
    raw_bytes = mem["gallery"]
    view_bytes = {name: int(sum(
        index_memory(router.tenant(name).engine.index).values()))
        for name in names}
    independent = sum(raw_bytes + v for v in view_bytes.values())
    ratio = mem["total"] / independent

    tenants = {}
    print("tenant,backend,completed,qps,recall_at_10")
    for name in names:
        n_done = per[name]["completed"]
        recall = per[name]["recall_sum"] / max(n_done, 1)
        qps = n_done / wall
        sub = n_queries // len(names) + (n_queries % len(names) > 0)
        tenants[name] = {
            "backend": backends[name][0],
            "completed": n_done,
            "qps": qps,
            "recall_at_10": recall,
            "view_bytes": view_bytes[name],
        }
        print(f"tenant,{backends[name][0]},{n_done},{qps:.0f},"
              f"{recall:.3f}")
        assert n_done >= n_queries // len(names), \
            f"{name}: {n_done} completed of ~{sub} submitted (drops)"
        assert recall >= 0.9, \
            f"{name}: recall@{k} {recall:.3f} < 0.9 vs its exact oracle"
    assert ratio <= 0.6, \
        f"memory ratio {ratio:.3f} > 0.6 (router {mem['total']} B vs " \
        f"independent {independent} B)"

    # shadow promotion == fresh build, bit for bit: promote a candidate
    # L on the IVF tenant, then compare against a second router that
    # registered the candidate directly (same deterministic build)
    L_cand = (0.2 * np.random.RandomState(7)
              .randn(d_in // 4, d_in)).astype(np.float32)
    router.register_shadow("t_ivf", L_cand, sample_rate=1.0)
    for q in queries[:8]:
        router.search("t_ivf", q)       # mirrored: arm gathers evidence
    arm_stats = router.tenant("t_ivf").shadow.stats()
    router.promote("t_ivf")
    fresh = TenantRouter(feats, k_top=k)
    fresh.add_tenant("fresh", L_cand, backend="ivf",
                     build_kwargs=backends["t_ivf"][1])
    probe = queries[:32]
    d_live, i_live = router.search("t_ivf", probe)
    d_fresh, i_fresh = fresh.search("fresh", probe)
    bit_identical = (np.array_equal(i_live, i_fresh)
                     and np.array_equal(d_live, d_fresh))
    assert bit_identical, "promoted view differs from a fresh build"
    print(f"promote: bit-identical to fresh build over {len(probe)} "
          f"probes (shadow overlap {arm_stats['overlap_at_k']:.3f}, "
          f"mirrored {arm_stats['n_mirrored']})")
    print(f"memory: router {mem['total'] / 1e6:.2f} MB vs independent "
          f"{independent / 1e6:.2f} MB ({ratio:.3f}x, gallery "
          f"{raw_bytes / 1e6:.2f} MB resident once)")

    sched.close()
    out = out or os.path.join(REPO, "BENCH_tenant.json")
    payload = {
        "bench": "tenant_serving", "smoke": smoke,
        "params": {"gallery_rows": m, "d_in": d_in,
                   "n_queries": n_queries, "k": k,
                   "n_clusters": n_clusters, "rerank_depth": rerank,
                   "build_s": build_s},
        "tenants": tenants,
        "memory": {"router_bytes": mem["total"],
                   "independent_bytes": independent,
                   "ratio": ratio},
        "promote_bit_identical": bool(bit_identical),
        "shadow": arm_stats,
        # unified-obs block: gated keys + the registry snapshot
        # (schema-validated in CI by benchmarks/check_obs.py, which
        # also asserts tenant labels are never empty)
        "obs": {"queue_depth_end": depth_end,
                "registry": router.registry.snapshot()},
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (same code paths and claims)")
    ap.add_argument("--out", default=None,
                    help="BENCH json path (default: repo root)")
    a = ap.parse_args()
    main(smoke=a.smoke, out=a.out)
