import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""§Perf hillclimb driver: lowers candidate variants of the three selected
(arch x shape) pairs, re-derives the roofline terms, and appends
hypothesis -> change -> before -> after records to
benchmarks/artifacts/perf_hillclimb.json.

Pairs (see EXPERIMENTS.md §Roofline):
  A. zamba2-2.7b x train_4k      — worst memory term (SSD chunk tiles)
  B. dml-imnet63k (paper config) — collective-bound, paper-representative
  C. smollm-135m x prefill_32k   — worst useful-compute (head replication)
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.launch import hlo_analysis, mesh as mesh_lib  # noqa: E402
from repro.launch.dryrun import dryrun_one  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "artifacts")
LOG = os.path.join(ART, "perf_hillclimb.json")


def _load():
    if os.path.exists(LOG):
        with open(LOG) as f:
            return json.load(f)
    return {}


def _store(log):
    os.makedirs(ART, exist_ok=True)
    with open(LOG, "w") as f:
        json.dump(log, f, indent=1, sort_keys=True)


def _summ(rec):
    t = rec["roofline"]
    return {
        "compute_s": t["compute_s"], "memory_s": t["memory_s"],
        "collective_s": t["collective_s"], "dominant": t["dominant"],
        "temp_gib": rec["memory"]["temp_size"] / 2**30,
        "flops_per_chip": rec["flops_per_chip"],
        "hbm_bytes_per_chip": rec["hbm_bytes_per_chip"],
        "collective_bytes_per_chip": rec.get("collectives", {}).get(
            "total_bytes", 0.0),
    }


def run_variant(log, exp: str, name: str, hypothesis: str, arch: str,
                shape: str, overrides: dict, force=False):
    key = f"{exp}:{name}"
    if key in log and not force:
        print(f"[perf] {key}: cached")
        return log[key]
    print(f"[perf] {key}: lowering ({hypothesis[:60]}...)")
    t0 = time.time()
    rec = dryrun_one(arch, shape, multi_pod=False, overrides=overrides or None)
    entry = {"experiment": exp, "variant": name, "hypothesis": hypothesis,
             "overrides": overrides, "elapsed_s": round(time.time() - t0, 1),
             **_summ(rec)}
    log[key] = entry
    _store(log)
    print(f"[perf] {key}: mem={entry['memory_s']:.2f}s "
          f"comp={entry['compute_s']:.2f}s coll={entry['collective_s']:.2f}s "
          f"temp={entry['temp_gib']:.2f}GiB")
    return entry


# ---------------------------------------------------------------------------
# Experiment B: the paper's DML config under communication-efficient
# local-SGD (model-sharded L + per-tau parameter averaging over data).
# ---------------------------------------------------------------------------

def dml_tau_variant(log, tau: int, comm_dtype: str, force=False):
    key = f"B:dml63k_tau{tau}_{comm_dtype}"
    if key in log and not force:
        print(f"[perf] {key}: cached")
        return log[key]
    from repro.configs import dml_paper
    exp = dml_paper.IMNET_63K
    dcfg = exp.dml
    mesh = mesh_lib.make_production_mesh()
    n_data, n_model = mesh.shape["data"], mesh.shape["model"]
    k_loc = dcfg.proj_dim // n_model
    d = dcfg.feat_dim
    B = exp.batch_size            # per-worker pairs per local step
    cdt = jnp.dtype(comm_dtype)

    def dist_loss(L_loc, batch):
        """Eq. 4 with L sharded over 'model' (k/16 rows per rank): the
        squared distance needs one tiny psum of per-pair partials."""
        z = (batch["xs"] - batch["ys"]).astype(jnp.float32)
        proj = z @ L_loc.astype(jnp.float32).T
        d2 = jax.lax.psum(jnp.sum(jnp.square(proj), axis=-1), "model")
        simf = batch["sim"].astype(jnp.float32)
        hinge = jnp.maximum(0.0, dcfg.margin - d2)
        return jnp.mean(simf * d2 + (1 - simf) * dcfg.lam * hinge), {}

    def chunk_fn(L_loc, batches):
        def local_step(Lc, b):
            (loss, _), g = jax.value_and_grad(dist_loss, has_aux=True)(Lc, b)
            return Lc - 0.01 * g, loss

        L_new, losses = jax.lax.scan(local_step, L_loc, batches)
        # server merge once per tau steps, in comm_dtype
        L_new = jax.lax.pmean(L_new.astype(cdt), "data").astype(L_new.dtype)
        return L_new, jnp.mean(losses)

    L_spec = jax.ShapeDtypeStruct((k_loc, d), jnp.float32)
    batches_spec = {
        "xs": jax.ShapeDtypeStruct((tau, B, d), jnp.float32),
        "ys": jax.ShapeDtypeStruct((tau, B, d), jnp.float32),
        "sim": jax.ShapeDtypeStruct((tau, B), jnp.int32),
    }
    from repro.sharding.partition import shard_map
    fn = shard_map(chunk_fn, mesh=mesh,
                   in_specs=(P("model", None), P("data")),
                   out_specs=(P("model", None), P()),
                   check_vma=False)
    # global views for lowering: L (k, d), batches (data*tau, B, ...)
    L_g = jax.ShapeDtypeStruct((dcfg.proj_dim, d), jnp.float32)
    b_g = {
        "xs": jax.ShapeDtypeStruct((n_data * tau, B, d), jnp.float32),
        "ys": jax.ShapeDtypeStruct((n_data * tau, B, d), jnp.float32),
        "sim": jax.ShapeDtypeStruct((n_data * tau, B), jnp.int32),
    }
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn).lower(L_g, b_g)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    csum = hlo_analysis.collective_summary(compiled.as_text())
    mem = compiled.memory_analysis()
    n_chips = 256
    # per-STEP terms (divide the chunk program by tau)
    flops = max(float(cost.get("flops") or 0.0), csum["dot_flops"]) / tau
    obytes = max(float(cost.get("bytes accessed") or 0.0),
                 csum["op_bytes"]) / tau
    cbytes = csum["total_bytes"] / tau
    terms = hlo_analysis.roofline_terms(
        flops, obytes, cbytes, n_chips, mesh_lib.PEAK_FLOPS_BF16,
        mesh_lib.HBM_BW, mesh_lib.ICI_BW)
    entry = {
        "experiment": "B", "variant": f"tau{tau}_{comm_dtype}",
        "hypothesis": (f"local-SGD tau={tau} divides the parameter-average "
                       f"traffic by {tau}; {comm_dtype} comm halves bytes"),
        "per_step": True, "tau": tau, "comm_dtype": comm_dtype,
        "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"], "dominant": terms["dominant"],
        "collective_bytes_per_chip": cbytes,
        "temp_gib": getattr(mem, "temp_size_in_bytes", 0) / 2**30,
        "elapsed_s": round(time.time() - t0, 1),
    }
    log[key] = entry
    _store(log)
    print(f"[perf] {key}: coll={terms['collective_s']*1e6:.1f}us/step "
          f"mem={terms['memory_s']*1e3:.2f}ms comp={terms['compute_s']*1e3:.2f}ms "
          f"dominant={terms['dominant']}")
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", type=str, default="all",
                    choices=["A", "B", "C", "D", "all"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    log = _load()

    if args.exp in ("A", "all"):
        run_variant(log, "A", "it1_chunk128",
                    "halving the SSD chunk halves live (B,Q,Q,H) tile bytes "
                    "(total tile traffic ~ T*Q per layer)",
                    "zamba2-2.7b", "train_4k", {"ssm_chunk": 128},
                    args.force)
        run_variant(log, "A", "it2_tile_bf16",
                    "bf16 decay/G tiles halve intra-chunk HBM traffic; "
                    "f32 accumulation keeps accuracy (validated vs ref)",
                    "zamba2-2.7b", "train_4k", {"ssm_tile_dtype": "bfloat16"},
                    args.force)
        run_variant(log, "A", "it3_chunk128_bf16",
                    "compose it1+it2",
                    "zamba2-2.7b", "train_4k",
                    {"ssm_chunk": 128, "ssm_tile_dtype": "bfloat16"},
                    args.force)
        run_variant(log, "A", "it5_allbf16_chunk128",
                    "end-to-end bf16 tile math (xs/B/C/decays/outputs, f32 "
                    "accumulation) removes the f32 converts that defeated "
                    "it2 and halves every chunk tensor",
                    "zamba2-2.7b", "train_4k",
                    {"ssm_chunk": 128, "ssm_tile_dtype": "bfloat16"},
                    True)
        run_variant(log, "A", "it6_einsum_order",
                    "explicit 2-operand contraction order stops XLA from "
                    "materializing a (B,Q,S,H,p) 5.4GB intermediate per "
                    "chunk einsum; plus group-level remat frees the 9 "
                    "shared-attn residual sets",
                    "zamba2-2.7b", "train_4k",
                    {"ssm_chunk": 128, "ssm_tile_dtype": "bfloat16"},
                    args.force or None is None and False)
        run_variant(log, "A", "it4_chunk64_bf16",
                    "chunk 64: tile bytes keep shrinking but state-passing "
                    "matmuls (T/Q chunks) grow — expect diminishing returns",
                    "zamba2-2.7b", "train_4k",
                    {"ssm_chunk": 64, "ssm_tile_dtype": "bfloat16"},
                    args.force)

    if args.exp in ("B", "all"):
        dml_tau_variant(log, 1, "float32", args.force)    # paper-PS baseline
        dml_tau_variant(log, 4, "float32", args.force)
        dml_tau_variant(log, 16, "float32", args.force)
        dml_tau_variant(log, 16, "bfloat16", args.force)
        dml_tau_variant(log, 64, "bfloat16", args.force)

    if args.exp in ("D", "all"):
        run_variant(log, "D", "qwen3_cap125",
                    "capacity factor 2.0->1.25 shrinks the (E_loc, C, d) "
                    "dispatch buffers ~37% to bring qwen3 train under HBM",
                    "qwen3-moe-30b-a3b", "train_4k",
                    {"moe_capacity_factor": 1.25}, args.force)

    if args.exp in ("C", "all"):
        # seq-parallel attention is auto-applied when heads % model != 0 —
        # this lowers the NEW code; the pre-change artifact is the baseline
        run_variant(log, "C", "it1_seq_parallel",
                    "9 heads don't divide model=16 so every rank repeats the "
                    "full 32k attention; sharding q chunks over 'model' "
                    "divides attention tiles and FLOPs by 16",
                    "smollm-135m", "prefill_32k", {}, args.force)
        run_variant(log, "C", "it2_seqpar_qchunk512",
                    "smaller q chunks shrink live tiles further (512x1024 "
                    "vs 1024x1024) at unchanged FLOPs",
                    "smollm-135m", "prefill_32k",
                    {"attn_q_chunk": 512}, args.force)


if __name__ == "__main__":
    main()
