"""Fig. 3 reproduction: speedup factor vs number of workers.

The paper measures t_1 / t_n where t_n is the wall time for n workers to
reach the objective value p that 1 worker reaches at the end of training.

HARDWARE ADAPTATION (documented in DESIGN.md / EXPERIMENTS.md): this offline
container exposes a SINGLE CPU core, so genuine thread-parallel wall-time
speedup is physically impossible here. The asynchronous *dynamics* (threads,
best-effort queues, stale local copies) are still real; only the clock is
virtualized: worker p's i-th gradient completes at virtual time i * tau,
with tau the measured single-gradient latency — i.e. a perfect-parallel
compute model on top of real staleness. The virtual speedup then measures
the *statistical* efficiency of asynchronous DML: near-P means stale
gradients are (almost) as useful as fresh ones, which is the paper's claim.
On a >= P core host the real wall-clock numbers (also recorded) apply.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import dml_paper
from repro.core import dml
from repro.core.ps import simulator
from repro.data import pairs as pairdata

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def run(workers=(1, 2, 4), steps_per_worker: int = 150, scale: int = 8,
        seed: int = 0):
    exp = dml_paper.scaled_down(dml_paper.MNIST, scale)
    data_cfg = pairdata.PairDatasetConfig(
        n_samples=exp.n_samples, feat_dim=exp.dml.feat_dim,
        n_classes=10, kind="noisy_subspace", seed=seed)
    train_pairs, _ = pairdata.train_eval_split(
        data_cfg, exp.n_similar, exp.n_dissimilar, 1000, 1000)
    L0 = np.asarray(dml.init_params(exp.dml, jax.random.PRNGKey(seed)))

    results = {}
    target = None
    for P in workers:
        cfg = simulator.AsyncPSConfig(
            n_workers=P, lr=1e-2, batch_size=exp.batch_size,
            steps_per_worker=steps_per_worker, seed=seed)
        t0 = time.perf_counter()
        _, trace = simulator.run_async_dml(cfg, train_pairs, L0)
        wall = time.perf_counter() - t0
        # virtual time: worker p's i-th gradient lands at (i+1) * tau, with
        # tau the single-worker per-gradient latency (constant across P —
        # each worker owns a core in the modeled deployment)
        if P == workers[0]:
            tau = wall / len(trace)
        else:
            tau = results[workers[0]]["tau_s"]
        counts = {}
        vts, ls = [], []
        for _, wid, loss in trace:
            counts[wid] = counts.get(wid, 0) + 1
            vts.append(counts[wid] * tau)
            ls.append(loss)
        vts = np.array(vts)
        ls = np.array(ls)
        order = np.argsort(vts, kind="stable")
        smooth = np.convolve(ls[order], np.ones(15) / 15, mode="same")
        if P == workers[0]:
            target = float(ls[-30:].mean())
            t_reach = float(vts.max())
        else:
            hit = np.nonzero(smooth <= target)[0]
            t_reach = float(vts[order][hit[0]]) if len(hit) else float(vts.max())
        results[P] = {"wall_s": wall, "tau_s": tau,
                      "t_reach_target_virtual_s": t_reach}
        print(f"fig3: P={P} wall={wall:.1f}s tau={tau*1e3:.1f}ms "
              f"virtual t_reach={t_reach:.2f}s")

    t1 = results[workers[0]]["t_reach_target_virtual_s"]
    for P in workers:
        results[P]["speedup"] = t1 / max(
            results[P]["t_reach_target_virtual_s"], 1e-9)
        results[P]["ideal"] = float(P)
        print(f"fig3: P={P} speedup={results[P]['speedup']:.2f} (ideal {P})")
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "fig3_speedup.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


def main():
    results = run()
    ps = sorted(results)
    sp = [results[P]["speedup"] for P in ps]
    assert sp[-1] > 1.2, f"no parallel speedup measured: {sp}"
    assert all(b >= a * 0.7 for a, b in zip(sp, sp[1:])), \
        f"speedup not ~monotone: {sp}"


if __name__ == "__main__":
    main()
