"""QPS + recall@10 under sustained gallery churn with periodic compaction.

A production gallery never freezes: rows arrive and expire continuously.
This benchmark drives the identical upsert/delete stream through two
``MutableIndex`` mirrors:

  * the **measured** mirror over an IVF base — pruned probes + exact
    delta scan, auto-compaction thresholds tuned so the run compacts a
    few times (delta folds into segment capacity headroom; only a
    headroom spill pays a k-means rebuild, and never on the query path);
  * an **oracle** mirror over an Exact base — exact by construction, so
    its answers are the ground truth the measured mirror's recall@10 is
    scored against. Sharing the MutableIndex machinery also
    double-exercises the mutation layer itself: both mirrors must mask
    the same tombstones and surface the same upserts.

Per round: upsert a batch of fresh rows (near existing blob centers),
retire a batch of live ids, answer a query batch on both mirrors, and
print ``churn,<round>,<qps>,<recall@10>,<delta>,<tombstones>,
<compactions>,<rebuilds>`` CSV lines. After the last round a snapshot
round-trip asserts the loaded index answers bit-for-bit identically.

Pinned claims (CI runs ``--smoke`` on every push): recall@10 never drops
below 0.9 under churn, compaction triggered at least once, and the
mutation stream itself never forced a rebuild mid-query.
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax.numpy as jnp
import numpy as np


def _time(fn, iters: int):
    fn()                                        # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    np.asarray(out[0])                          # host arrays already
    return (time.perf_counter() - t0) / iters


def main(smoke: bool = False):
    from repro.serve import (MutableIndex, load_index, recall_at_k,
                             save_index)

    if smoke:   # CI-sized: seconds, same code paths
        M, D, KPROJ, C, NPROBE = 2_000, 32, 16, 16, 4
        N_BLOBS, ROUNDS, CHURN, NQ, ITERS = 32, 3, 150, 16, 3
    else:
        M, D, KPROJ, C, NPROBE = 30_000, 64, 32, 64, 8
        N_BLOBS, ROUNDS, CHURN, NQ, ITERS = 128, 8, 900, 64, 5
    KTOP = 10

    rng = np.random.RandomState(0)
    centers = 3.0 * rng.randn(N_BLOBS, D).astype(np.float32)
    gallery = centers[rng.randint(0, N_BLOBS, M)] \
        + 0.3 * rng.randn(M, D).astype(np.float32)
    L = 0.2 * rng.randn(KPROJ, D).astype(np.float32)

    t0 = time.perf_counter()
    measured = MutableIndex.build(
        L, gallery, base="ivf", n_clusters=C, nprobe=NPROBE,
        cap_factor=1.5, auto_compact_delta=0.10, auto_compact_dead=0.10)
    oracle = MutableIndex.build(
        L, gallery, base="exact",
        auto_compact_delta=0.10, auto_compact_dead=0.10)
    print(f"mutable ivf over {M} rows ({C} clusters, cap "
          f"{measured.base.cap}, nprobe {NPROBE}) + exact oracle built in "
          f"{time.perf_counter() - t0:.2f}s")

    print("\nsection,round,qps,recall_at_10,delta_rows,tombstones,"
          "compactions,rebuilds")
    recalls = []
    for r in range(ROUNDS):
        fresh = centers[rng.randint(0, N_BLOBS, CHURN)] \
            + 0.3 * rng.randn(CHURN, D).astype(np.float32)
        ids = measured.upsert(fresh)
        oracle.upsert(fresh, ids=ids)           # identical external ids
        retire = rng.choice(measured.live_ids(), CHURN, replace=False)
        measured.delete(retire)
        oracle.delete(retire)

        q = jnp.asarray(centers[rng.randint(0, N_BLOBS, NQ)]
                        + 0.3 * rng.randn(NQ, D), jnp.float32)
        t = _time(lambda: measured.topk(q, KTOP), iters=ITERS)
        _, ids_a = measured.topk(q, KTOP)
        _, ids_e = oracle.topk(q, KTOP)
        rec = recall_at_k(ids_a, ids_e)
        recalls.append(rec)
        print(f"churn,{r},{NQ / t:.0f},{rec:.3f},{measured.delta_rows},"
              f"{measured.tombstones},{measured.n_compactions},"
              f"{measured.n_rebuilds}")

    # snapshot round-trip on the churned state: identical answers
    q = jnp.asarray(centers[rng.randint(0, N_BLOBS, 8)]
                    + 0.3 * rng.randn(8, D), jnp.float32)
    d_ref, i_ref = measured.topk(q, KTOP)
    with tempfile.TemporaryDirectory() as snap:
        save_index(measured, snap)
        restored = load_index(snap)
        d_new, i_new = restored.topk(q, KTOP)
    assert (np.asarray(i_new) == np.asarray(i_ref)).all() \
        and (np.asarray(d_new) == np.asarray(d_ref)).all(), \
        "snapshot round-trip not bit-for-bit"
    print("snapshot round-trip: top-k bit-for-bit identical  [OK]")

    print(f"min recall@10 over {ROUNDS} churn rounds: {min(recalls):.3f} "
          f"({measured.n_compactions} compactions, "
          f"{measured.n_rebuilds} rebuilds)")
    assert min(recalls) >= 0.9, \
        f"recall@10 dropped to {min(recalls):.3f} under churn"
    assert measured.n_compactions >= 1, \
        "compaction thresholds never triggered — churn not exercised"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (seconds)")
    a = ap.parse_args()
    main(smoke=a.smoke)
