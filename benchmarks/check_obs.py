"""CI gate: schema-validate obs artifacts (metrics snapshots + traces).

Run after a serving run has exported its observability artifacts:

    PYTHONPATH=src python -m repro.launch.serve_retrieval ... \
        --metrics-out metrics.json --trace-out traces.jsonl \
        --trace-sample 1.0
    PYTHONPATH=src python benchmarks/check_obs.py \
        --metrics metrics.json --traces traces.jsonl

With no arguments it validates the ``obs.registry`` snapshot blocks
embedded in the committed ``BENCH_*.json`` payloads, so plain
``python benchmarks/check_obs.py`` is a valid CI step on its own.

What is checked (schema, not values — check_bench.py gates values):

  metrics snapshot   top-level ``{"t", "counters", "gauges",
                     "histograms", "events"}``; every instrument has
                     ``help``/``labels``/``values``; every label key
                     parses back to exactly the declared label names
                     (and any instrument declaring a ``tenant`` label
                     carries a non-empty tenant value in every cell);
                     histogram cells carry ``len(buckets) + 1`` counts
                     whose sum equals ``count``; buckets ascend;
                     events are ``{"t", "event", ...}`` in time order.
  trace JSONL        one JSON object per line with ``trace_id`` and a
                     ``root`` span; spans recursively carry
                     ``name``/``t_start``/``t_end``/``attrs``/
                     ``children`` with ``t_end >= t_start`` and children
                     nested inside the parent's window; at least one
                     trace must cover the end-to-end request path
                     (request -> queue -> engine -> device_topk).

Exit 0 when everything validates, 1 with a findings list otherwise.
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.obs.metrics import parse_label_key      # noqa: E402
from repro.obs.trace import span_names             # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# span names every full request trace must include, in depth-first
# order (other spans may interleave): the ISSUE's acceptance path.
REQUEST_PATH = ("request", "queue", "engine", "device_topk")


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_snapshot(snap: dict, where: str) -> list:
    """Return a list of problem strings (empty = valid)."""
    bad = []

    def err(msg):
        bad.append(f"{where}: {msg}")

    if not isinstance(snap, dict):
        return [f"{where}: snapshot is {type(snap).__name__}, not dict"]
    for key in ("t", "counters", "gauges", "histograms", "events"):
        if key not in snap:
            err(f"missing top-level key {key!r}")
    if bad:
        return bad
    if not _is_num(snap["t"]):
        err(f"t is {snap['t']!r}, not a number")

    def check_instrument(kind, name, m):
        for key in ("help", "labels", "values"):
            if key not in m:
                err(f"{kind}[{name}] missing {key!r}")
                return
        declared = m["labels"]
        if not isinstance(declared, list):
            err(f"{kind}[{name}] labels is not a list")
            return
        for lkey in m["values"]:
            parsed = parse_label_key(lkey)
            if sorted(parsed) != sorted(declared):
                err(f"{kind}[{name}] label key {lkey!r} parses to "
                    f"{sorted(parsed)}, declared {sorted(declared)}")
            elif "tenant" in declared and not parsed.get("tenant"):
                # tenant-scoped series (serve/tenant.py ScopedRegistry
                # binding) must always say WHICH tenant — an empty
                # tenant value means a write bypassed the scoping
                err(f"{kind}[{name}] label key {lkey!r} has an empty "
                    f"tenant label")

    for kind in ("counters", "gauges"):
        for name, m in snap[kind].items():
            check_instrument(kind, name, m)
            for lkey, v in m.get("values", {}).items():
                if not _is_num(v):
                    err(f"{kind}[{name}][{lkey!r}] value {v!r} "
                        f"is not a number")
                elif kind == "counters" and v < 0:
                    err(f"counters[{name}][{lkey!r}] is negative ({v})")

    for name, m in snap["histograms"].items():
        check_instrument("histograms", name, m)
        buckets = m.get("buckets")
        if not isinstance(buckets, list) or not buckets:
            err(f"histograms[{name}] has no buckets")
            continue
        if buckets != sorted(buckets) or len(set(buckets)) != len(buckets):
            err(f"histograms[{name}] buckets not ascending+unique")
        if any(math.isinf(b) for b in buckets):
            err(f"histograms[{name}] buckets contain inf (the overflow "
                f"bucket is implicit)")
        for lkey, cell in m.get("values", {}).items():
            for key in ("counts", "sum", "count"):
                if key not in cell:
                    err(f"histograms[{name}][{lkey!r}] missing {key!r}")
            counts = cell.get("counts", [])
            if len(counts) != len(buckets) + 1:
                err(f"histograms[{name}][{lkey!r}] has {len(counts)} "
                    f"counts for {len(buckets)} buckets "
                    f"(want len(buckets) + 1)")
            if sum(counts) != cell.get("count"):
                err(f"histograms[{name}][{lkey!r}] counts sum "
                    f"{sum(counts)} != count {cell.get('count')}")
            if any((not isinstance(c, int)) or c < 0 for c in counts):
                err(f"histograms[{name}][{lkey!r}] counts must be "
                    f"non-negative ints")

    last_t = -math.inf
    for i, e in enumerate(snap["events"]):
        if not isinstance(e, dict) or "t" not in e or "event" not in e:
            err(f"events[{i}] lacks t/event: {e!r}")
            continue
        if e["t"] < last_t:
            err(f"events[{i}] out of time order "
                f"({e['t']} after {last_t})")
        last_t = e["t"]
    return bad


def check_span(span, where: str, parent_window=None) -> list:
    bad = []
    for key in ("name", "t_start", "t_end", "attrs", "children"):
        if key not in span:
            return [f"{where}: span missing {key!r}: "
                    f"{sorted(span)}"]
    t0, t1 = span["t_start"], span["t_end"]
    if not _is_num(t0) or not _is_num(t1) or t1 < t0:
        bad.append(f"{where}: span {span['name']!r} window "
                   f"[{t0!r}, {t1!r}] is not a valid interval")
    elif parent_window is not None:
        p0, p1 = parent_window
        if t0 < p0 - 1e-9 or t1 > p1 + 1e-9:
            bad.append(f"{where}: span {span['name']!r} "
                       f"[{t0:.6f}, {t1:.6f}] escapes its parent "
                       f"[{p0:.6f}, {p1:.6f}]")
    if not isinstance(span["attrs"], dict):
        bad.append(f"{where}: span {span['name']!r} attrs is not a dict")
    for i, c in enumerate(span["children"]):
        bad.extend(check_span(c, f"{where}.{span['name']}[{i}]",
                              (t0, t1)))
    return bad


def check_traces(path: str) -> list:
    bad = []
    seen_ids = set()
    covered = False
    n = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            n += 1
            where = f"{os.path.basename(path)}:{lineno}"
            try:
                tr = json.loads(line)
            except json.JSONDecodeError as e:
                bad.append(f"{where}: not JSON ({e})")
                continue
            if "trace_id" not in tr or "root" not in tr:
                bad.append(f"{where}: trace lacks trace_id/root")
                continue
            if tr["trace_id"] in seen_ids:
                bad.append(f"{where}: duplicate trace_id "
                           f"{tr['trace_id']!r}")
            seen_ids.add(tr["trace_id"])
            bad.extend(check_span(tr["root"], where))
            names = span_names(tr)
            it = iter(names)
            if all(want in it for want in REQUEST_PATH):
                covered = True
    if n == 0:
        bad.append(f"{path}: no traces (empty file)")
    elif not covered:
        bad.append(f"{path}: no trace covers the request path "
                   f"{' -> '.join(REQUEST_PATH)} "
                   f"(in depth-first order)")
    return bad


def check_embedded() -> list:
    """Validate the obs.registry blocks inside committed BENCH_*.json."""
    bad = []
    found = 0
    for path in sorted(glob.glob(os.path.join(REPO, "BENCH_*.json"))):
        rel = os.path.relpath(path, REPO)
        with open(path) as f:
            payload = json.load(f)
        snap = payload.get("obs", {}).get("registry")
        if snap is None:
            continue
        found += 1
        bad.extend(check_snapshot(snap, f"{rel}[obs.registry]"))
    if found == 0:
        bad.append("no BENCH_*.json carries an obs.registry block — "
                   "rerun the benchmarks")
    return bad


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--metrics", default=None,
                    help="MetricsRegistry snapshot JSON to validate")
    ap.add_argument("--traces", default=None,
                    help="trace JSONL (--trace-out) to validate")
    ap.add_argument("--skip-embedded", action="store_true",
                    help="do not validate BENCH_*.json obs blocks")
    args = ap.parse_args()

    bad = []
    checked = []
    if args.metrics:
        with open(args.metrics) as f:
            bad.extend(check_snapshot(json.load(f), args.metrics))
        checked.append(args.metrics)
    if args.traces:
        bad.extend(check_traces(args.traces))
        checked.append(args.traces)
    if not args.skip_embedded:
        bad.extend(check_embedded())
        checked.append("BENCH_*.json[obs.registry]")

    for msg in bad:
        print(f"FAIL {msg}")
    print(f"checked: {', '.join(checked)} — "
          f"{'OK' if not bad else f'{len(bad)} problem(s)'}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
