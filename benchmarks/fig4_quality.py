"""Fig. 4 reproduction: metric quality — ours (Eq. 4) vs Xing2002 (Eq. 1 PGD
+ eigendecomposition), ITML, KISS and raw Euclidean. Average precision and
precision-recall on held-out pairs, plus single-thread training time.

Paper claims validated:
  * ours reaches the highest AP,
  * Xing2002 is drastically slower per unit of quality (O(d^3) projection),
  * KISS is fast but notably worse,
  * everything learned beats raw Euclidean.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import dml_paper
from repro.core import dml, itml, kiss, xing2002
from repro.core.ps.trainer import train_dml_single
from repro.data import pairs as pairdata

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def evaluate(scores, labels):
    ap = float(dml.average_precision(scores, labels))
    prec, rec = dml.precision_recall_curve(np.asarray(scores),
                                           np.asarray(labels), n_points=25)
    return ap, prec.tolist(), rec.tolist()


def run(scale: int = 8, steps: int = 250, seed: int = 0):
    exp = dml_paper.scaled_down(dml_paper.MNIST, scale)
    d, k = exp.dml.feat_dim, exp.dml.proj_dim
    data_cfg = pairdata.PairDatasetConfig(
        n_samples=exp.n_samples, feat_dim=d, n_classes=10,
        kind="noisy_subspace", seed=seed)
    train_pairs, eval_pairs = pairdata.train_eval_split(
        data_cfg, exp.n_similar, exp.n_dissimilar, 2000, 2000)
    xs = jnp.asarray(eval_pairs["xs"])
    ys = jnp.asarray(eval_pairs["ys"])
    labels = jnp.asarray(eval_pairs["sim"])
    txs = jnp.asarray(train_pairs["xs"])
    tys = jnp.asarray(train_pairs["ys"])
    tsim = jnp.asarray(train_pairs["sim"])
    out = {}

    # ours (Eq. 4, SGD)
    t0 = time.perf_counter()
    L, _ = train_dml_single(exp.dml, train_pairs, steps=steps,
                            batch_size=exp.batch_size, lr=5e-2, seed=seed)
    t_ours = time.perf_counter() - t0
    ap, pr, rc = evaluate(dml.pair_scores(L, xs, ys), labels)
    out["ours"] = {"ap": ap, "train_s": t_ours, "precision": pr, "recall": rc}

    # Xing2002: PGD + eigendecomposition per step
    t0 = time.perf_counter()
    xcfg = xing2002.XingConfig(feat_dim=d, lr=5e-2, steps=steps // 5)
    M_x, _ = xing2002.fit(xcfg, txs, tys, tsim, batch_size=exp.batch_size)
    t_xing = time.perf_counter() - t0
    ap, pr, rc = evaluate(dml.pair_scores_M(M_x, xs, ys), labels)
    out["xing2002"] = {"ap": ap, "train_s": t_xing, "precision": pr,
                       "recall": rc, "steps": steps // 5}

    # ITML
    t0 = time.perf_counter()
    icfg = itml.ITMLConfig(feat_dim=d, gamma=1e-3, sweeps=2)
    n_c = min(4000, txs.shape[0])
    M_i = itml.fit(icfg, txs[:n_c], tys[:n_c], tsim[:n_c])
    t_itml = time.perf_counter() - t0
    ap, pr, rc = evaluate(dml.pair_scores_M(M_i, xs, ys), labels)
    out["itml"] = {"ap": ap, "train_s": t_itml, "precision": pr, "recall": rc}

    # KISS (one-shot)
    t0 = time.perf_counter()
    kcfg = kiss.KISSConfig(feat_dim=d, pca_dim=min(k, d // 2), ridge=1e-4)
    M_k, proj = kiss.fit(kcfg, txs, tys, tsim)
    t_kiss = time.perf_counter() - t0
    exs = xs @ proj if proj is not None else xs
    eys = ys @ proj if proj is not None else ys
    ap, pr, rc = evaluate(dml.pair_scores_M(M_k, exs, eys), labels)
    out["kiss"] = {"ap": ap, "train_s": t_kiss, "precision": pr, "recall": rc}

    # Euclidean baseline
    ap, pr, rc = evaluate(dml.pair_scores_euclidean(xs, ys), labels)
    out["euclidean"] = {"ap": ap, "train_s": 0.0, "precision": pr,
                        "recall": rc}

    for name, r in out.items():
        print(f"fig4: {name:10s} AP={r['ap']:.4f} train={r['train_s']:.1f}s")
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "fig4_quality.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    out = run()
    assert out["ours"]["ap"] >= max(v["ap"] for k, v in out.items()
                                    if k != "ours") - 0.02, \
        "ours should be at or near the best AP (paper Fig. 4)"
    assert out["ours"]["ap"] > out["euclidean"]["ap"]
    assert out["ours"]["train_s"] < out["xing2002"]["train_s"], \
        "Eq.4 must be faster than Eq.1+eigendecomposition per quality"


if __name__ == "__main__":
    main()
