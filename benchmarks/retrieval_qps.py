"""Retrieval serving benchmark: fused metric_topk path vs pure-XLA reference.

Two things, on one default shape (gallery M=16384 x d=128, proj k=64,
query batches of 64, top-10):

  1. **Correctness** — the fused Pallas kernel (kernels/metric_topk,
     interpret mode off-TPU) must match the XLA reference exactly on
     indices and to 1e-4 rtol on distances.
  2. **Throughput** — QPS/latency of the production serving path (gallery
     pre-projected once at index build; factored distances; jitted XLA —
     the Pallas kernel itself is correctness-checked in interpret mode
     and only meaningfully timeable on TPU) vs the pure-XLA per-pair
     reference (metric_topk_naive: apply L to every query-gallery
     difference — the textbook formulation the index amortizes away).
     The serving path must win.

Prints ``retrieval,<name>,<qps>,<ms/batch>`` CSV lines like the other
benchmark sections.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

# default shape (paper §5-style retrieval, scaled to a benchmark budget)
M, D, KPROJ, NQ, KTOP = 16384, 128, 64, 64, 10


def _time(fn, *args, iters: int = 5):
    jax.block_until_ready(fn(*args))            # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main():
    from repro.kernels.metric_topk import (metric_topk, metric_topk_naive,
                                           metric_topk_ref, metric_topk_xla,
                                           project_gallery)

    rng = np.random.RandomState(0)
    L = jnp.asarray(0.2 * rng.randn(KPROJ, D), jnp.float32)
    gallery = jnp.asarray(rng.randn(M, D), jnp.float32)
    queries = jnp.asarray(rng.randn(NQ, D), jnp.float32)

    t0 = time.perf_counter()
    gp, gn = project_gallery(L, gallery)
    gp, gn = jax.block_until_ready((gp, gn))
    print(f"index build (one-time projection of {M} rows): "
          f"{(time.perf_counter() - t0) * 1e3:.1f} ms")

    # --- 1. fused kernel correctness vs the XLA reference ---------------
    qp = queries @ L.T
    d_ref, i_ref = metric_topk_ref(qp, gp, KTOP, gn)
    d_ker, i_ker = metric_topk(L, queries, gp, gn, k_top=KTOP)
    assert (np.asarray(i_ker) == np.asarray(i_ref)).all(), \
        "fused kernel indices != XLA reference"
    np.testing.assert_allclose(np.asarray(d_ker), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-4)
    print(f"fused kernel vs XLA reference on ({NQ}x{M}, d={D}, k={KPROJ}): "
          f"indices exact, distances rtol<=1e-4  [OK]")

    # --- 2. serving throughput: amortized factored path vs per-pair XLA -
    def factored(q):
        return metric_topk_xla(L, q, gp, gn, KTOP)

    def naive(q):
        return metric_topk_naive(L, q, gallery, KTOP)

    t_fused = _time(factored, queries, iters=10)
    t_naive = _time(naive, queries, iters=1)
    rows = [
        ("factored_preprojected", t_fused),
        ("xla_per_pair_reference", t_naive),
    ]
    print("\nsection,name,qps,ms_per_batch64")
    for name, t in rows:
        print(f"retrieval,{name},{NQ / t:.0f},{t * 1e3:.2f}")
    speedup = t_naive / t_fused
    print(f"speedup (factored serving path vs per-pair reference): "
          f"{speedup:.1f}x")
    assert speedup > 1.0, \
        f"serving path did not beat the reference ({speedup})"


if __name__ == "__main__":
    main()
