"""CI gate: fail if a fresh BENCH_*.json regresses QPS vs the committed one.

Run after the benchmark --smoke steps have rewritten the BENCH_*.json
files in the repo root:

    PYTHONPATH=src python benchmarks/check_bench.py [--threshold 0.8]

For every ``BENCH_*.json`` in the working tree, the committed baseline
is read from ``git show HEAD:<file>``; every numeric whose key starts
with ``qps`` is compared *pathwise* (same nested location in both
payloads — list entries pair by index). A fresh value below
``threshold`` x baseline fails the run; new files, new keys, and
structural mismatches (a resized sweep) are reported but never fail —
only a like-for-like throughput drop does. The threshold is loose (20%)
on purpose: CI runners are noisy, and the gate exists to catch
order-of-magnitude faceplants (a kernel silently falling back to a slow
path), not single-digit jitter.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def iter_qps(node, path=""):
    """Yield (json-path, value) for every numeric under a qps* key."""
    if isinstance(node, dict):
        for k in sorted(node):
            sub = f"{path}.{k}" if path else k
            v = node[k]
            if (k.startswith("qps") and isinstance(v, (int, float))
                    and not isinstance(v, bool)):
                yield sub, float(v)
            else:
                yield from iter_qps(v, sub)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from iter_qps(v, f"{path}[{i}]")


def baseline(relpath: str):
    """The committed copy of ``relpath``, or None if HEAD lacks it."""
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{relpath}"], cwd=REPO, check=True,
            capture_output=True).stdout
    except subprocess.CalledProcessError:
        return None
    return json.loads(blob)


def main(threshold: float) -> int:
    failures = []
    checked = 0
    for path in sorted(glob.glob(os.path.join(REPO, "BENCH_*.json"))):
        rel = os.path.relpath(path, REPO)
        old = baseline(rel)
        if old is None:
            print(f"{rel}: no committed baseline (new file), skipping")
            continue
        with open(path) as f:
            new = json.load(f)
        old_qps = dict(iter_qps(old))
        new_qps = dict(iter_qps(new))
        for key, was in sorted(old_qps.items()):
            now = new_qps.get(key)
            if now is None:         # resized sweep / renamed section
                print(f"{rel}: {key} absent in fresh run "
                      f"(was {was:.0f}), skipping")
                continue
            checked += 1
            ratio = now / was if was > 0 else float("inf")
            mark = "FAIL" if ratio < threshold else "ok"
            print(f"{rel}: {key}: {was:.0f} -> {now:.0f} qps "
                  f"({ratio:.2f}x)  [{mark}]")
            if ratio < threshold:
                failures.append((rel, key, was, now))
    print(f"\nchecked {checked} qps figure(s), {len(failures)} below "
          f"{threshold:.0%} of baseline")
    for rel, key, was, now in failures:
        print(f"  REGRESSION {rel}: {key} {was:.0f} -> {now:.0f}")
    return 1 if failures else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=0.8,
                    help="fail below this fraction of the committed "
                         "baseline (default 0.8)")
    a = ap.parse_args()
    sys.exit(main(a.threshold))
