"""CI gate: fail if a fresh BENCH_*.json regresses vs the committed one.

Run after the benchmark --smoke steps have rewritten the BENCH_*.json
files in the repo root:

    PYTHONPATH=src python benchmarks/check_bench.py [--threshold 0.8]

For every ``BENCH_*.json`` in the working tree, the committed baseline
is read from ``git show HEAD:<file>`` and compared *pathwise* (same
nested location in both payloads — list entries pair by index). Three
key families are gated:

  ``qps*``              higher is better: fail below
                        ``threshold`` x baseline;
  ``cache_hit_rate*``   higher is better, same ratio rule (the obs
                        blocks the benchmarks embed from the unified
                        MetricsRegistry) — a cache that silently stops
                        hitting is a serving regression even when raw
                        QPS holds;
  ``queue_depth*``      lower is better: fail above
                        baseline / threshold + 1 (the +1 is absolute
                        slack so a 0 -> 1 blip on a drained queue does
                        not fail);
  ``recall*``           higher is better, ratio rule — the multi-tenant
                        benchmark reports per-tenant recall@k vs an
                        exact oracle, and an ANN view silently losing
                        recall is a quality regression QPS won't show.

New files, new keys, and structural mismatches (a resized sweep) are
reported but never fail — only a like-for-like regression does. The
threshold is loose (20%) on purpose: CI runners are noisy, and the gate
exists to catch order-of-magnitude faceplants (a kernel silently
falling back to a slow path, a cache key that stopped matching), not
single-digit jitter.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# key-prefix -> direction ("up" = higher is better)
GATED = (("qps", "up"), ("cache_hit_rate", "up"), ("queue_depth", "down"),
         ("recall", "up"))


def iter_gated(node, path=""):
    """Yield (json-path, value, direction) for every gated numeric."""
    if isinstance(node, dict):
        for k in sorted(node):
            sub = f"{path}.{k}" if path else k
            v = node[k]
            direction = next((d for p, d in GATED if k.startswith(p)),
                             None)
            if (direction is not None
                    and isinstance(v, (int, float))
                    and not isinstance(v, bool)):
                yield sub, float(v), direction
            else:
                yield from iter_gated(v, sub)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from iter_gated(v, f"{path}[{i}]")


def baseline(relpath: str):
    """The committed copy of ``relpath``, or None if HEAD lacks it."""
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{relpath}"], cwd=REPO, check=True,
            capture_output=True).stdout
    except subprocess.CalledProcessError:
        return None
    return json.loads(blob)


def regressed(was: float, now: float, direction: str,
              threshold: float) -> bool:
    """The gate rule for one pathwise pair."""
    if direction == "up":
        if was <= 0:                    # nothing to hold a ratio against
            return False
        return now / was < threshold
    # "down": lower is better; +1 absolute slack covers 0-baselines
    return now > was / threshold + 1.0


def main(threshold: float) -> int:
    failures = []
    checked = 0
    for path in sorted(glob.glob(os.path.join(REPO, "BENCH_*.json"))):
        rel = os.path.relpath(path, REPO)
        old = baseline(rel)
        if old is None:
            print(f"{rel}: no committed baseline (new file), skipping")
            continue
        with open(path) as f:
            new = json.load(f)
        old_vals = {k: (v, d) for k, v, d in iter_gated(old)}
        new_vals = {k: v for k, v, _ in iter_gated(new)}
        for key, (was, direction) in sorted(old_vals.items()):
            now = new_vals.get(key)
            if now is None:         # resized sweep / renamed section
                print(f"{rel}: {key} absent in fresh run "
                      f"(was {was:.3g}), skipping")
                continue
            checked += 1
            bad = regressed(was, now, direction, threshold)
            arrow = "^" if direction == "up" else "v"
            mark = "FAIL" if bad else "ok"
            print(f"{rel}: {key}: {was:.3g} -> {now:.3g} "
                  f"[{arrow} {mark}]")
            if bad:
                failures.append((rel, key, was, now))
    print(f"\nchecked {checked} gated figure(s), {len(failures)} "
          f"regression(s) at threshold {threshold:.0%}")
    for rel, key, was, now in failures:
        print(f"  REGRESSION {rel}: {key} {was:.3g} -> {now:.3g}")
    return 1 if failures else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=0.8,
                    help="fail below this fraction of the committed "
                         "baseline (default 0.8)")
    a = ap.parse_args()
    sys.exit(main(a.threshold))
