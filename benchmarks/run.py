"""Benchmark suite runner — one entry per paper table/figure + the roofline
report. Prints ``name,status,seconds`` CSV summary lines (machine-parseable)
after each section's own output.

  table1  -> dataset statistics (paper Table 1)
  fig2    -> async-PS convergence vs worker count (paper Fig. 2)
  fig3    -> speedup factors (paper Fig. 3)
  fig4    -> metric quality: ours vs Xing2002/ITML/KISS/Euclidean (Fig. 4)
  roofline-> per (arch x shape x mesh) roofline terms from the dry-run
  retrieval_qps -> serving: fused metric top-k vs per-pair XLA reference
  retrieval_recall -> serving: IVF + IVF-PQ recall@10-vs-QPS frontiers
             vs the exact scan (PQ: uint8 residual codes, ADC tables,
             exact rerank)
  gallery_churn -> serving: QPS + recall@10 under sustained upsert/delete
             churn with periodic compaction (MutableIndex)
  serving_load -> serving: SLO attainment under a calibrated overload
             burst — adaptive degradation vs the non-degrading baseline
             (RequestScheduler; emits BENCH_serving.json)
  mining_convergence -> closed loop: mined+curriculum training matches
             uniform sampling's final kNN accuracy in <= 0.5x the steps
             at equal batch size (HardPairMiner -> MinedPairSource ->
             ClosedLoopTrainer over the serving index)
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    results = []

    def section(name, fn):
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            fn()
            results.append((name, "ok", time.time() - t0))
        except Exception as e:
            traceback.print_exc()
            results.append((name, f"FAIL:{type(e).__name__}",
                            time.time() - t0))

    from benchmarks import (ablation_sync, fig2_convergence, fig3_speedup,
                            fig4_quality, gallery_churn,
                            mining_convergence, retrieval_qps,
                            retrieval_recall, roofline, serving_load,
                            table1_datasets)

    section("table1_datasets", table1_datasets.main)
    section("retrieval_qps", retrieval_qps.main)
    section("retrieval_recall", retrieval_recall.main)
    section("gallery_churn", gallery_churn.main)
    section("serving_load", serving_load.main)
    section("mining_convergence", mining_convergence.main)
    section("fig4_quality", fig4_quality.main)
    section("fig2_convergence", fig2_convergence.main)
    section("fig3_speedup", fig3_speedup.main)
    section("ablation_sync", ablation_sync.main)
    section("roofline", roofline.main)

    print("\nname,status,seconds")
    failed = False
    for name, status, secs in results:
        print(f"{name},{status},{secs:.1f}")
        failed |= status != "ok"
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
