"""Roofline report: reads the dry-run artifacts and prints, per
(arch x shape x mesh): the three terms, the dominant bottleneck,
MODEL_FLOPS / HLO_FLOPs (useful-compute ratio) and a what-would-move-it note.

MODEL_FLOPS conventions (per spec):
  train:   6 * N * D     (N = params w/o embeddings for dense; N_active for MoE)
  prefill: 2 * N * D
  decode:  2 * N * B     (one token per sequence)
"""

from __future__ import annotations

import json
import os
import sys

from repro.configs import get_config, get_shape
from repro.configs.base import ArchConfig

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def _attn_params(cfg: ArchConfig) -> int:
    d, H, K, dh = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.dim_per_head
    return d * H * dh + 2 * d * K * dh + H * dh * d


def _mlp_params(cfg: ArchConfig, f=None) -> int:
    f = f or cfg.d_ff
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return 3 * cfg.d_model * f
    if cfg.mlp_kind == "gelu":
        return 2 * cfg.d_model * f
    if cfg.mlp_kind == "rwkv_channel_mix":
        return 2 * cfg.d_model * f + cfg.d_model * cfg.d_model
    return 3 * cfg.d_model * f


def param_counts(cfg: ArchConfig):
    """(total_params, active_params) excluding embeddings (standard 6ND)."""
    d = cfg.d_model
    L = cfg.n_layers
    if cfg.family == "ssm":       # rwkv6
        tmix = 5 * d * d + 2 * d * max(32, d // 32)
        per_layer = tmix + _mlp_params(cfg)
        return L * per_layer, L * per_layer
    if cfg.family == "hybrid":    # zamba2: mamba2 stack + ONE shared block
        d_in = cfg.ssm_expand * d
        n = cfg.ssm_state
        mamba = (d * d_in + d * (d_in + 2 * n) + d * cfg.ssm_heads
                 + d_in * d)
        shared = _attn_params(cfg) + 2 * d * cfg.d_ff
        total = L * mamba + shared
        # the shared block RUNS L/every times: active compute counts each use
        active = L * mamba + (L // cfg.shared_attn_every) * shared
        return total, active
    per_layer = _attn_params(cfg)
    if cfg.n_experts:
        experts = cfg.n_experts * 3 * d * cfg.d_ff + d * cfg.n_experts
        active = (_attn_params(cfg) + cfg.top_k * 3 * d * cfg.d_ff
                  + d * cfg.n_experts)
        return L * (per_layer + experts), L * active
    per_layer += _mlp_params(cfg)
    return L * per_layer, L * per_layer


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    total, active = param_counts(cfg)
    B, T = shape.global_batch, shape.seq_len
    if shape.mode == "train":
        return 6.0 * active * B * T
    if shape.mode == "prefill":
        return 2.0 * active * B * T
    return 2.0 * active * B          # decode: one token per sequence


def improvement_note(rec: dict) -> str:
    t = rec["roofline"]
    dom = t["dominant"]
    if dom == "memory":
        if rec["mode"] in ("decode",):
            return ("memory: decode reads all weights+cache per token — "
                    "batch more sequences per step or quantize KV to int8")
        return ("memory: attention/scan tiles round-trip HBM — fuse the "
                "streaming softmax into VMEM (Pallas flash kernel) and keep "
                "tiles bf16")
    if dom == "collective":
        return ("collective: gradient/param all-reduce dominates — overlap "
                "reduce-scatter with backward, sync every tau steps "
                "(local-SGD, the paper's async insight), or quantize grads")
    return ("compute: MXU-bound — the causal chunked attention computes "
            "masked tiles; skip fully-masked tiles and align dims to 128")


def load(mesh_name: str) -> dict:
    path = os.path.join(ART, f"dryrun_{mesh_name}.json")
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def report(mesh_name: str = "16x16", out=sys.stdout):
    records = load(mesh_name)
    rows = []
    print(f"\n== Roofline ({mesh_name} mesh) ==", file=out)
    hdr = (f"{'arch':24s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'dominant':>10s} {'useful%':>8s}")
    print(hdr, file=out)
    for key, rec in sorted(records.items()):
        if rec.get("status") != "ok" or rec.get("shape") == "paper_batch":
            continue
        t = rec["roofline"]
        mf = model_flops(rec["arch"], rec["shape"])
        hlo_global = rec["flops_per_chip"] * rec["n_chips"]
        useful = mf / hlo_global if hlo_global else 0.0
        rows.append((rec, useful))
        print(f"{rec['arch']:24s} {rec['shape']:12s} "
              f"{t['compute_s']:9.4f} {t['memory_s']:9.3f} "
              f"{t['collective_s']:9.4f} {t['dominant']:>10s} "
              f"{100*useful:7.1f}%", file=out)
    # paper DML configs
    for key, rec in sorted(records.items()):
        if rec.get("shape") == "paper_batch" and rec.get("status") == "ok":
            t = rec["roofline"]
            print(f"{rec['arch']:24s} {'paper':12s} "
                  f"{t['compute_s']:9.4f} {t['memory_s']:9.3f} "
                  f"{t['collective_s']:9.4f} {t['dominant']:>10s} "
                  f"{'':>8s}", file=out)
    return rows


def main():
    for mesh_name in ("16x16", "pod2x16x16"):
        report(mesh_name)


if __name__ == "__main__":
    main()
