"""Traffic-shaped serving under overload: SLO attainment with and without
adaptive degradation.

The scheduler's pitch is that under a burst the system should *get
cheaper, not slower*. This benchmark makes that claim falsifiable. It
drives the identical open-loop arrival trace — Poisson warm/drain phases
around a burst, Zipf-skewed query popularity, a 70/20/10 interactive /
batch / mining class mix — through two fronts over the same IVF index:

  * **baseline**  — ``RequestScheduler(degrade=False)``: admission control
    and deadlines only, every batch at full build-time quality;
  * **adaptive**  — the same scheduler with the ``LoadController`` stepping
    the nprobe ladder down under sustained queue pressure and back up on
    drain.

The burst rate is **auto-calibrated**, not hard-coded: we measure the
engine's full-quality and fully-degraded batch service times on this
machine and set the burst between the two capacities (2.5x the
full-quality capacity, capped at half the degraded one). The baseline
therefore *cannot* keep up by construction, while the adaptive front has
provable headroom — the pinned claims stay machine-independent.

Per run/class the benchmark prints ``serving,<run>,<class>,<offered>,
<completed>,<expired>,<rejected>,<attainment>,<p50_ms>,<p99_ms>`` CSV
lines, and writes ``BENCH_serving.json`` (calibration + per-run p50/p99/
QPS/attainment) so the serving perf trajectory accrues across commits.

Pinned claims (CI runs ``--smoke`` on every push):

  * effective p99 (expired/rejected count as +inf) of the interactive
    class: adaptive <= its deadline, baseline > it — the SLO the baseline
    misses is held by degradation;
  * adaptive interactive SLO attainment >= 0.9; baseline <= 0.75;
  * the controller both degraded and restored (the ladder round-trips);
  * recall@10 of every served interactive answer vs the exact scan
    >= 0.85 — degraded is cheaper, not wrong;
  * zero silent drops: in both runs every submitted request is accounted
    for as completed, expired, rejected, or failed — by the scheduler's
    own monotone counters.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from concurrent.futures import wait

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MIX = (("interactive", 0.7), ("batch", 0.2), ("mining", 0.1))
RATE_CAP = 3500.0           # open-loop replay ceiling (submits/s)
MISS_S = 60.0               # finite SLO-miss sentinel (percentile-safe)


def _zipf_pool(rng, centers, pool_size, alpha=1.05):
    """Query pool + Zipf popularity over it (hot head, long tail)."""
    n_blobs, d = centers.shape
    pool = (centers[rng.randint(0, n_blobs, pool_size)]
            + 0.3 * rng.randn(pool_size, d)).astype(np.float32)
    w = 1.0 / np.arange(1, pool_size + 1) ** alpha
    return pool, w / w.sum()


def _make_trace(rng, qps_warm, qps_burst, t_warm, t_burst, t_drain, pop):
    """Open-loop arrivals: (t, class, query_id) — Poisson gaps inside each
    phase, the burst phase jumping to the calibrated overload rate."""
    trace, t = [], 0.0
    names = [n for n, _ in MIX]
    probs = [p for _, p in MIX]
    for rate, dur in ((qps_warm, t_warm), (qps_burst, t_burst),
                      (qps_warm, t_drain)):
        end = t + dur
        while True:
            t += rng.exponential(1.0 / rate)
            if t >= end:
                t = end
                break
            trace.append((t, names[rng.choice(len(names), p=probs)],
                          int(rng.choice(len(pop), p=pop))))
    return trace


def _svc_time(cal_eng, batch, knobs, iters=4):
    cal_eng.search(batch, **knobs)              # warm / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        cal_eng.search(batch, **knobs)
    return (time.perf_counter() - t0) / iters


def _replay(sched, trace, pool, deadlines):
    """Submit the trace against the wall clock; returns one record per
    offered request (rejected submits included — nothing is dropped from
    the accounting)."""
    from repro.serve import RejectedError

    records = []
    start = time.perf_counter() + 0.02
    for t_arr, cls_name, qid in trace:
        lag = (start + t_arr) - time.perf_counter()
        if lag > 1e-4:                          # skip sub-0.1ms sleeps
            time.sleep(lag)
        rec = {"cls": cls_name, "qid": qid, "t_sub": time.perf_counter(),
               "fut": None, "t_done": None}
        try:
            fut = sched.submit(pool[qid], priority=cls_name,
                               deadline_s=deadlines[cls_name])
        except RejectedError:
            records.append(rec)
            continue
        rec["fut"] = fut
        fut.add_done_callback(
            lambda f, r=rec: r.__setitem__("t_done", time.perf_counter()))
        records.append(rec)
    return records


def _score(records, deadlines):
    """Per-class outcome counts + latency stats; effective p99 counts
    expired/rejected/failed as a 60s miss sentinel (an SLO miss is a
    miss, and a finite one keeps percentiles well-defined)."""
    from repro.obs import percentile
    from repro.serve import DeadlineExceededError

    out = {}
    for cls_name in (n for n, _ in MIX):
        recs = [r for r in records if r["cls"] == cls_name]
        lat, counts = [], {"offered": len(recs), "completed": 0,
                          "expired": 0, "rejected": 0, "failed": 0}
        eff = []
        for r in recs:
            if r["fut"] is None:
                counts["rejected"] += 1
                eff.append(MISS_S)
                continue
            exc = r["fut"].exception(timeout=0)
            if exc is None:
                counts["completed"] += 1
                lat.append(r["t_done"] - r["t_sub"])
                eff.append(lat[-1])
            elif isinstance(exc, DeadlineExceededError):
                counts["expired"] += 1
                eff.append(MISS_S)
            else:
                counts["failed"] += 1
                eff.append(MISS_S)
        dl = deadlines[cls_name]
        ok = sum(1 for v in eff if v <= dl)
        counts["attainment"] = ok / max(1, len(recs))
        # percentiles via the one obs implementation (NaN when empty)
        counts["p50_ms"] = percentile(lat, 50) * 1e3
        counts["p99_ms"] = percentile(lat, 99) * 1e3
        counts["p99_eff_ms"] = percentile(eff, 99) * 1e3
        out[cls_name] = counts
    return out


def main(smoke: bool = False, out: str | None = None):
    import jax.numpy as jnp

    from repro.serve import (ExactIndex, IVFIndex, RequestScheduler,
                             RetrievalEngine, recall_at_k)

    if smoke:   # CI-sized: tens of seconds, same code paths + claims
        M, D, KPROJ, C, NPROBE = 32_000, 48, 24, 64, 64
        POOL, T_WARM, T_BURST, T_DRAIN = 4096, 0.3, 1.2, 1.0
    else:
        M, D, KPROJ, C, NPROBE = 60_000, 64, 32, 64, 64
        POOL, T_WARM, T_BURST, T_DRAIN = 8192, 0.5, 3.0, 1.5
    KTOP, BATCH, BUCKETS = 10, 32, (8, 32)
    LADDER = ({}, {"nprobe": 8}, {"nprobe": 2})

    rng = np.random.RandomState(0)
    centers = 3.0 * rng.randn(C, D).astype(np.float32)
    gallery = (centers[rng.randint(0, C, M)]
               + 0.3 * rng.randn(M, D)).astype(np.float32)
    L = 0.2 * rng.randn(KPROJ, D).astype(np.float32)

    t0 = time.perf_counter()
    index = IVFIndex.build(L, gallery, n_clusters=C, nprobe=NPROBE,
                           cap_factor=1.25)
    print(f"ivf over {M} rows ({C} clusters, cap {index.cap}, nprobe "
          f"{NPROBE}) built in {time.perf_counter() - t0:.2f}s")
    pool, pop = _zipf_pool(rng, centers, POOL)

    # -- calibrate this machine (cache off: raw device-path service time)
    cal = RetrievalEngine(index, k_top=KTOP, buckets=BUCKETS, cache_size=0)
    qcal = jnp.asarray(pool[rng.randint(0, POOL, BATCH)])
    t_full = _svc_time(cal, qcal, LADDER[0])
    t_deg = _svc_time(cal, qcal, LADDER[-1])
    qps_full, qps_deg = BATCH / t_full, BATCH / t_deg
    headroom = qps_deg / qps_full
    assert headroom >= 3.0, (
        f"ladder headroom {headroom:.1f}x < 3x on this machine — the "
        f"degraded path is not meaningfully cheaper; benchmark invalid")
    qps_burst = min(2.5 * qps_full, 0.5 * qps_deg, RATE_CAP)
    assert qps_burst >= 1.7 * qps_full, (
        f"burst rate {qps_burst:.0f}/s < 1.7x full-quality capacity "
        f"{qps_full:.0f}/s — overload not reachable; benchmark invalid")
    qps_warm = 0.25 * qps_full
    dl_i = max(0.12, min(0.7, 12.0 * t_full))
    deadlines = {"interactive": dl_i, "batch": 4 * dl_i,
                 "mining": 10 * dl_i}
    print(f"calibration: batch svc full {t_full * 1e3:.1f}ms / degraded "
          f"{t_deg * 1e3:.1f}ms -> capacity {qps_full:.0f} vs "
          f"{qps_deg:.0f} q/s ({headroom:.1f}x headroom); burst "
          f"{qps_burst:.0f} q/s, interactive deadline {dl_i * 1e3:.0f}ms")

    trace = _make_trace(rng, qps_warm, qps_burst, T_WARM, T_BURST,
                        T_DRAIN, pop)
    print(f"trace: {len(trace)} arrivals over "
          f"{T_WARM + T_BURST + T_DRAIN:.1f}s")

    def run(label, degrade):
        from repro.serve import PriorityClass
        eng = RetrievalEngine(index, k_top=KTOP, buckets=BUCKETS)
        # generous queue caps: this benchmark's SLO story is deadlines +
        # degradation (admission-control behavior is pinned by the unit
        # and property tests); a tight cap would just convert the ramp
        # backlog into rejections before the controller can react
        classes = tuple(
            PriorityClass(name, prio, deadlines[name], 8192)
            for prio, (name, _) in enumerate(MIX))
        sched = RequestScheduler(
            eng, classes=classes, max_batch=BATCH, max_wait_ms=2.0,
            degrade=degrade, ladder=LADDER if degrade else None,
            high_watermark=BATCH, low_watermark=8,
            degrade_window_s=0.02, restore_window_s=0.25)
        sched.warmup()
        t_run0 = time.perf_counter()
        records = _replay(sched, trace, pool, deadlines)
        futs = [r["fut"] for r in records if r["fut"] is not None]
        wait(futs, timeout=120)
        assert sched.close(timeout=60), f"{label}: workers never exited"
        elapsed = time.perf_counter() - t_run0
        score = _score(records, deadlines)

        # zero silent drops: the scheduler's own counters account for
        # every offered request, and every admitted future resolved
        obs = sched.observability()
        assert all(r["fut"].done() for r in records if r["fut"]), \
            f"{label}: unresolved futures after close"
        for cls_name, s in score.items():
            c = obs["classes"][cls_name]
            assert c["admitted"] == (c["completed"] + c["expired"]
                                     + c["failed"] + c["cancelled"]), \
                f"{label}/{cls_name}: admitted requests unaccounted for"
            assert s["offered"] == c["admitted"] + s["rejected"], \
                f"{label}/{cls_name}: offered != admitted + rejected"
            assert s["failed"] == 0, \
                f"{label}/{cls_name}: {s['failed']} engine failures"
            print(f"serving,{label},{cls_name},{s['offered']},"
                  f"{s['completed']},{s['expired']},{s['rejected']},"
                  f"{s['attainment']:.3f},{s['p50_ms']:.1f},"
                  f"{s['p99_ms']:.1f}")
        done = sum(s["completed"] for s in score.values())
        ctrl = sched.controller
        # the unified-registry view of the same run: cache behavior and
        # the end-of-run queue depth become gated BENCH keys (check_bench
        # regresses cache_hit_rate down / queue_depth up), and the full
        # snapshot block is schema-validated by check_obs
        est = eng.stats()
        looked = est["cache_hits"] + est["cache_misses"]
        return {
            "classes": score,
            "qps_completed": done / elapsed,
            "transitions": ([] if ctrl is None else
                            [(tr.level_from, tr.level_to)
                             for tr in ctrl.transitions]),
            "records": records,
            "cache_hit_rate": (est["cache_hits"] / looked if looked
                               else 0.0),
            "queue_depth_end": obs["queue_depth"],
            "registry": eng.registry.snapshot(),
        }

    def gate():
        """One full baseline-vs-adaptive comparison + the pinned claims;
        raises AssertionError when a claim fails."""
        print("\nserving,run,class,offered,completed,expired,rejected,"
              "attainment,p50_ms,p99_ms")
        base = run("baseline", degrade=False)
        adap = run("adaptive", degrade=True)

        # recall of served interactive answers vs the exact scan
        served = [(r["qid"], r["fut"].result(timeout=0)[1])
                  for r in adap["records"]
                  if r["cls"] == "interactive" and r["fut"] is not None
                  and r["fut"].exception(timeout=0) is None]
        exact = ExactIndex.build(L, gallery)
        qids = sorted({qid for qid, _ in served})
        truth = {}
        for lo in range(0, len(qids), 256):
            chunk = qids[lo:lo + 256]
            _, ids_e = exact.topk(jnp.asarray(pool[chunk]), KTOP)
            truth.update(zip(chunk, np.asarray(ids_e)))
        rec10 = float(recall_at_k(
            np.stack([ids for _, ids in served]),
            np.stack([truth[qid] for qid, _ in served])))

        bi = base["classes"]["interactive"]
        ai = adap["classes"]["interactive"]
        print(f"\ninteractive SLO ({dl_i * 1e3:.0f}ms): baseline "
              f"attainment {bi['attainment']:.3f} (p99_eff "
              f"{bi['p99_eff_ms']:.0f}ms) vs adaptive "
              f"{ai['attainment']:.3f} (p99_eff {ai['p99_eff_ms']:.0f}ms)")
        print(f"adaptive ladder transitions: {adap['transitions']}; "
              f"recall@10 of served interactive answers: {rec10:.3f}")

        assert ai["p99_eff_ms"] <= dl_i * 1e3, \
            "adaptive missed the interactive SLO"
        assert bi["p99_eff_ms"] > dl_i * 1e3, \
            "baseline held the SLO — the burst never overloaded it"
        assert ai["attainment"] >= 0.9, \
            f"adaptive attainment {ai['attainment']:.3f} < 0.9"
        assert bi["attainment"] <= 0.75, \
            f"baseline attainment {bi['attainment']:.3f} > 0.75"
        downs = [t for t in adap["transitions"] if t[1] > t[0]]
        ups = [t for t in adap["transitions"] if t[1] < t[0]]
        assert downs and ups, \
            f"ladder never round-tripped: {adap['transitions']}"
        assert rec10 >= 0.85, f"served recall@10 {rec10:.3f} < 0.85"
        return base, adap, rec10

    # a real-time load test on a shared runner gets one retry: a single
    # scheduling hiccup during the ~100ms degrade ramp can push >1% of a
    # run past the deadline without saying anything about the scheduler
    try:
        base, adap, rec10 = gate()
    except AssertionError as e:
        print(f"SLO gate failed ({e}); retrying once — transient "
              f"machine noise vs real regression")
        base, adap, rec10 = gate()

    out = out or os.path.join(REPO, "BENCH_serving.json")
    payload = {
        "bench": "serving_load", "smoke": smoke,
        "params": {"M": M, "D": D, "k_proj": KPROJ, "n_clusters": C,
                   "nprobe": NPROBE, "k_top": KTOP, "max_batch": BATCH,
                   "ladder": [dict(lv) for lv in LADDER]},
        "calibration": {"t_full_ms": t_full * 1e3, "t_deg_ms": t_deg * 1e3,
                        "qps_full": qps_full, "qps_deg": qps_deg,
                        "headroom": headroom, "qps_burst": qps_burst,
                        "deadline_interactive_ms": dl_i * 1e3},
        "runs": {label: {"qps_completed": r["qps_completed"],
                         "transitions": r["transitions"],
                         "classes": r["classes"]}
                 for label, r in (("baseline", base), ("adaptive", adap))},
        "recall_at_10_served": rec10,
        # unified-obs block: gated keys + the adaptive run's registry
        # snapshot (schema-validated in CI by benchmarks/check_obs.py)
        "obs": {"cache_hit_rate": adap["cache_hit_rate"],
                "queue_depth_end": adap["queue_depth_end"],
                "registry": adap["registry"]},
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (tens of seconds)")
    ap.add_argument("--out", default=None,
                    help="BENCH json path (default: repo root)")
    a = ap.parse_args()
    main(smoke=a.smoke, out=a.out)
