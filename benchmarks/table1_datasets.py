"""Table 1 reproduction: dataset statistics (at the paper's dimensions, and
the CPU-scaled variants actually trained offline)."""

from __future__ import annotations

from repro.configs import dml_paper


def rows():
    out = []
    for name, exp in dml_paper.EXPERIMENTS.items():
        n_params = exp.dml.proj_dim * exp.dml.feat_dim
        out.append({
            "dataset": name,
            "feat_dim": exp.dml.feat_dim,
            "k": exp.dml.proj_dim,
            "params": n_params,
            "samples": exp.n_samples,
            "similar_pairs": exp.n_similar,
            "dissimilar_pairs": exp.n_dissimilar,
            "paper_params": {"dml-mnist": 0.47e6, "dml-imnet63k": 220e6,
                             "dml-imnet1m": 21.5e6}[name],
        })
    return out


def main():
    print("dataset,feat_dim,k,params,paper_params,samples,sim_pairs,dis_pairs")
    for r in rows():
        assert abs(r["params"] - r["paper_params"]) / r["paper_params"] < 0.05, \
            f"param count drifted from paper Table 1: {r}"
        print(f"{r['dataset']},{r['feat_dim']},{r['k']},{r['params']},"
              f"{int(r['paper_params'])},{r['samples']},"
              f"{r['similar_pairs']},{r['dissimilar_pairs']}")


if __name__ == "__main__":
    main()
