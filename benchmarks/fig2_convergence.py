"""Fig. 2 reproduction: objective vs wall-time under different worker counts,
on the threaded asynchronous parameter server (the paper's architecture),
MNIST-scale configuration scaled to the CPU budget.

Claim validated: more workers -> faster convergence in wall time.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import dml_paper
from repro.core import dml
from repro.core.ps import simulator
from repro.data import pairs as pairdata

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def run(workers=(1, 2, 4), steps_total: int = 480, scale: int = 8,
        seed: int = 0):
    exp = dml_paper.scaled_down(dml_paper.MNIST, scale)
    data_cfg = pairdata.PairDatasetConfig(
        n_samples=exp.n_samples, feat_dim=exp.dml.feat_dim,
        n_classes=10, kind="noisy_subspace", seed=seed)
    train_pairs, _ = pairdata.train_eval_split(
        data_cfg, exp.n_similar, exp.n_dissimilar, 1000, 1000)
    L0 = np.asarray(dml.init_params(exp.dml, jax.random.PRNGKey(seed)))

    curves = {}
    for P in workers:
        cfg = simulator.AsyncPSConfig(
            n_workers=P, lr=1e-2, batch_size=exp.batch_size,
            steps_per_worker=steps_total // P, seed=seed)
        t0 = time.perf_counter()
        _, trace = simulator.run_async_dml(cfg, train_pairs, L0)
        wall = time.perf_counter() - t0
        # virtual-parallel time axis (1-core container; see fig3_speedup.py)
        tau = wall / len(trace)
        counts: dict = {}
        ts, ls = [], []
        for _, wid, loss in trace:
            counts[wid] = counts.get(wid, 0) + 1
            ts.append(counts[wid] * tau)
            ls.append(loss)
        ts = np.array(ts)
        ls = np.array(ls)
        nb = 20
        edges = np.linspace(0, ts.max() + 1e-9, nb + 1)
        curve = []
        for i in range(nb):
            m = (ts >= edges[i]) & (ts < edges[i + 1])
            if m.any():
                curve.append((float(edges[i + 1]), float(ls[m].mean())))
        curves[P] = {"wall_s": wall, "curve": curve,
                     "final_loss": float(ls[-40:].mean())}
        print(f"fig2: P={P} wall={wall:.1f}s final_loss="
              f"{curves[P]['final_loss']:.4f}")
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "fig2_convergence.json"), "w") as f:
        json.dump(curves, f, indent=1)
    return curves


def main():
    curves = run()
    # paper claim (Fig. 2): at equal (virtual-parallel) wall time, more
    # workers sit at a lower objective — compare every P against P=1 at the
    # largest time both curves cover
    ps = sorted(curves)
    base = curves[ps[0]]["curve"]
    for P in ps[1:]:
        cur = curves[P]["curve"]
        t_common = min(base[-1][0], cur[-1][0]) * 0.999
        l_base = next(l for t, l in reversed(base) if t <= t_common)
        l_p = next(l for t, l in reversed(cur) if t <= t_common)
        print(f"fig2: at t={t_common:.2f}s  P=1 loss={l_base:.3f}  "
              f"P={P} loss={l_p:.3f}")
        assert l_p < l_base, \
            f"P={P} not ahead of P=1 at equal time ({l_p} vs {l_base})"


if __name__ == "__main__":
    main()
