"""Mined+curriculum vs uniform-sampling convergence (closed-loop mining).

The paper trains on 200M *uniformly sampled* pairs (§5.1). Most uniform
pairs go uninformative fast — similar pairs are already close, dissimilar
pairs already beyond the hinge — and the gradient concentrates on the few
hard constraints (Qian et al. 2013). This benchmark pins what the
closed-loop mining subsystem (src/repro/mining/) buys: on
``noisy_subspace`` data, training whose batches mix in index-mined hard
pairs under a curriculum reaches the uniform run's final kNN accuracy in
**at most half the steps at equal batch size** — the mined run is only
*given* half the steps — and ends within kNN-eval noise of it.

Both runs share every hyperparameter (batch size, lr, optimizer, eval
cadence); only the pair stream differs:

  uniform   pre-sampled balanced S/D pairs through the stock
            ``train_dml_distributed`` path (the full-uniform baseline,
            asserted in the same run);
  mined     ``ClosedLoopTrainer``: a MutableIndex over the train rows is
            refreshed with the current L every ``REFRESH`` steps
            (``swap_metric``), ``HardPairMiner`` sweeps every train row
            for kNN-violating positives + impostor negatives through the
            RetrievalEngine, and ``MinedPairSource`` anneals the mined
            fraction in after a uniform warmup.

Where the speedup comes from: mined *positives* are same-class rows the
current metric keeps outside the anchor's neighborhood — exactly the
pairs the kNN eval scores wrong, with the largest pull gradients — while
uniform similar pairs are mostly already-converged (near-zero loss).
Mined *negatives* are in-neighborhood impostors whose hinge is active.

Pinned claims (CI runs ``--smoke`` on every push; seeded, so the run is
deterministic):
  * the mined run crosses the uniform run's final accuracy within its
    half-step budget (measured: step 80 of 150 vs the uniform run's
    300 — 3.8x fewer steps);
  * the mined run's final accuracy ends no lower than the uniform
    final minus kNN-eval noise (~1600 test rows -> sigma ~0.004; the
    plateaus are statistically identical);
  * the uniform baseline itself converges (final accuracy >= 0.95), so
    the target the mined run chases is a real one;
  * the low-rank factor costs nothing here: the mined rows train a
    rectangular (KPROJ, D) = (16, 64) L through the whole loop
    (``l_rank`` knob -> swap_metric -> mining -> serving), and a
    square-L (64, 64) rerun of the identical closed loop ends within
    0.02 kNN accuracy of it.

``--smoke`` runs exactly the gated comparison; the full run adds an
(ungated) mined-over-IVF row showing the loop riding the ANN index.
"""

from __future__ import annotations

import argparse

import numpy as np

# shared setting: 128 crowded classes in an 8-dim signal subspace of a
# 64-dim feature space — fine class separation is the convergence
# bottleneck, which is exactly the constraint population mining targets
N, D, KPROJ, C, NOISE = 8000, 64, 16, 128, 0.3
LR, BATCH, STEPS, EVAL_EVERY = 3e-3, 128, 300, 10
KNN_K = 5
ACC_TOL = 0.005     # two-sided kNN-eval noise at this test-set size


def _acc_hook(tr_x, tr_y, te_x, te_y):
    from repro.core import eval_tasks

    def hook(t, L):
        return eval_tasks.knn_accuracy(L, tr_x, tr_y, te_x, te_y, k=KNN_K)
    return hook


def main(smoke: bool = False):
    import jax.numpy as jnp  # noqa: F401  (jax init before timing)

    from repro.core import dml
    from repro.core.ps import sync
    from repro.core.ps.trainer import DMLTrainConfig, train_dml_distributed
    from repro.data import pairs as pairdata
    from repro.mining import (ClosedLoopConfig, ClosedLoopTrainer,
                              CurriculumSchedule, MinerConfig)

    cfg = pairdata.PairDatasetConfig(
        n_samples=N, feat_dim=D, n_classes=C, kind="noisy_subspace",
        noise=NOISE, seed=0)
    x, y = pairdata.make_features(cfg)
    n_tr = int(N * 0.8)
    tr_x, tr_y, te_x, te_y = x[:n_tr], y[:n_tr], x[n_tr:], y[n_tr:]
    hook = _acc_hook(tr_x, tr_y, te_x, te_y)

    tcfg = DMLTrainConfig(
        dml=dml.DMLConfig(feat_dim=D, l_rank=KPROJ),
        ps=sync.PSConfig(n_workers=1, seed=0), batch_size=BATCH,
        steps=STEPS, lr=LR, log_every=EVAL_EVERY)

    # --- full-uniform baseline (the paper's sampling) --------------------
    idx = pairdata.sample_pair_indices(tr_y, 20000, 20000, seed=1)
    uni_pairs = {"xs": tr_x[idx["a"]], "ys": tr_x[idx["b"]],
                 "sim": idx["sim"]}
    L_u, hist_u = train_dml_distributed(tcfg, uni_pairs, step_hook=hook)

    print("section,step,knn_acc")
    for h in hist_u:
        print(f"uniform,{h['step']},{h['hook']:.4f}")
    u_accs = [h["hook"] for h in hist_u]
    target = float(np.mean(u_accs[-5:]))
    print(f"uniform final (mean last 5 evals over {STEPS} steps): "
          f"{target:.4f}")

    # --- mined + curriculum, HALF the step budget ------------------------
    def mined_cfg(index: str, index_kwargs=None,
                  dml_cfg=None) -> ClosedLoopConfig:
        return ClosedLoopConfig(
            train=DMLTrainConfig(dml=dml_cfg or tcfg.dml, ps=tcfg.ps,
                                 batch_size=BATCH, steps=STEPS // 2,
                                 lr=LR, log_every=EVAL_EVERY),
            miner=MinerConfig(k_neighbors=20, margin=1.0,
                              max_negatives=1, max_positives=3),
            schedule=CurriculumSchedule(warmup_steps=10, ramp_steps=20,
                                        max_mined_frac=0.7),
            index=index, index_kwargs=index_kwargs,
            refresh_every=15, mine_queries=n_tr)

    clt = ClosedLoopTrainer(mined_cfg("mutable-exact"), tr_x, tr_y)
    L_m, hist_m = clt.run(step_hook=hook)
    for h in hist_m["steps"]:
        print(f"mined,{h['step']},{h['hook']:.4f}")
    maccs = [(h["step"], h["hook"]) for h in hist_m["steps"]]
    cross = next((s for s, a in maccs if a >= target), None)
    m_final = float(np.mean([a for _, a in maccs[-5:]]))
    summ = hist_m["summary"]
    print(f"mined final (mean last 5 evals over {STEPS // 2} steps): "
          f"{m_final:.4f}")
    print(f"mined run: {summ['n_refreshes']} refreshes, mean staleness "
          f"{summ['mean_staleness']:.1f} steps, "
          f"{summ['total_mined_pairs']} pairs mined "
          f"(neg yield {summ['neg_yield']:.2f}/query, pos yield "
          f"{summ['pos_yield']:.2f}/query), engine "
          f"{summ['engine']['qps']:.0f} qps over "
          f"{summ['engine']['n_device_queries']} mining queries")
    if cross is not None:
        print(f"mined crossed the uniform final at step {cross} -> "
              f"{STEPS / cross:.1f}x fewer steps")

    # --- square-L reference: the low-rank knob costs no accuracy ---------
    # the mined rows above train a rectangular (KPROJ, D) factor through
    # the whole loop (low-rank L into swap_metric, mining, serving); this
    # row reruns the identical closed loop with a square (D, D) factor to
    # pin that rank reduction does not cost kNN accuracy on this task
    clt_sq = ClosedLoopTrainer(
        mined_cfg("mutable-exact",
                  dml_cfg=dml.DMLConfig(feat_dim=D, l_rank=D)),
        tr_x, tr_y)
    _, hist_sq = clt_sq.run(step_hook=hook)
    for h in hist_sq["steps"]:
        print(f"mined_square,{h['step']},{h['hook']:.4f}")
    sq_final = float(np.mean([h["hook"] for h in hist_sq["steps"][-5:]]))
    print(f"mined square-L final (d'={D} vs {KPROJ}): {sq_final:.4f}")

    # --- (full mode) the same loop riding the ANN index ------------------
    if not smoke:
        clt_ivf = ClosedLoopTrainer(
            mined_cfg("mutable-ivf",
                      dict(n_clusters=64, nprobe=8, cap_factor=1.5)),
            tr_x, tr_y)
        _, hist_i = clt_ivf.run(step_hook=hook)
        for h in hist_i["steps"]:
            print(f"mined_ivf,{h['step']},{h['hook']:.4f}")
        i_final = float(np.mean([h["hook"]
                                 for h in hist_i["steps"][-5:]]))
        print(f"mined-over-IVF final: {i_final:.4f} (engine "
              f"{hist_i['summary']['engine']['qps']:.0f} qps)")

    # --- gates -----------------------------------------------------------
    assert target >= 0.95, \
        f"uniform baseline failed to converge (final {target:.4f})"
    assert cross is not None and cross <= STEPS // 2, \
        (f"mined run never reached the uniform final {target:.4f} within "
         f"{STEPS // 2} steps (<= 0.5x the uniform run's {STEPS})")
    assert m_final >= target - ACC_TOL, \
        (f"mined final {m_final:.4f} ended below the uniform final "
         f"{target:.4f} by more than eval noise ({ACC_TOL})")
    print(f"claim pinned: mined+curriculum matched the uniform final "
          f"{target:.4f} at step {cross} (<= {STEPS // 2} = 0.5x "
          f"{STEPS}) and ended at {m_final:.4f} "
          f"(>= {target:.4f} - {ACC_TOL})  [OK]")
    assert m_final >= sq_final - 0.02, \
        (f"low-rank (d'={KPROJ}) mined final {m_final:.4f} trails the "
         f"square-L (d'={D}) final {sq_final:.4f} by more than 0.02")
    print(f"claim pinned: low-rank d'={KPROJ} final {m_final:.4f} within "
          f"0.02 of square-L d'={D} final {sq_final:.4f}  [OK]")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: just the pinned uniform-vs-mined "
                         "comparison (~1 min)")
    a = ap.parse_args()
    main(smoke=a.smoke)
