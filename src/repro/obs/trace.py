"""Request-scoped tracing: one trace id from submit to device top-k.

A trace is minted when a request enters the system
(``RequestScheduler.submit`` / ``MicroBatcher.submit``) and its id flows
with the request through every stage — queue wait, batch formation,
micro-batch coalesce, ``engine.search`` (cache lookup / pad / device
top-k, with ``scan_impl`` / ``nprobe`` / ``rerank_depth`` / batch size
as span attributes) — so one sampled trace answers "where did this
request's latency go" without correlating seven subsystems' logs.

Design points:

  clock-driven     every timestamp reads the injected ``clock.now()``
                   (duck-typed; serve/clock.py's ``Clock`` fits), so
                   span durations are asserted *exactly* under
                   ``FakeClock`` — no sleep-based tests;
  sampled          the ``sample_rate`` knob decides at mint time with a
                   deterministic accumulator (rate 0.25 samples exactly
                   every 4th trace — reproducible, not a coin flip). An
                   unsampled trace costs two attribute reads: its spans
                   are a shared no-op ``NullSpan``;
  cross-thread     spans are explicit objects handed across threads
                   (submit thread -> worker -> engine), not
                   thread-locals — the serving stack moves requests
                   between threads as a matter of course;
  bounded + JSONL  finished traces land in a bounded ring; ``drain()``
                   hands them out as plain dicts and ``write_jsonl``
                   appends one JSON object per line (the
                   ``--trace-out`` format benchmarks/check_obs.py
                   validates).

Like obs/metrics.py, this module imports nothing from the serving
stack, so it sits below every subsystem without cycles.
"""

from __future__ import annotations

import json
import threading
from typing import Optional


class NullSpan:
    """No-op span: the unsampled path. All methods return self so call
    sites never branch on sampling."""

    __slots__ = ()
    sampled = False

    def set_attrs(self, **attrs):
        return self

    def child(self, name):
        return self

    def end(self):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = NullSpan()


class Span:
    """One timed stage of a trace. ``end()`` stamps the close time (it
    is idempotent; re-ending keeps the first close). ``child`` opens a
    nested span at the current clock time."""

    __slots__ = ("name", "t_start", "t_end", "attrs", "children", "_clock")
    sampled = True

    def __init__(self, name: str, clock):
        self.name = name
        self._clock = clock
        self.t_start = clock.now()
        self.t_end: Optional[float] = None
        self.attrs: dict = {}
        self.children: list = []

    def set_attrs(self, **attrs):
        self.attrs.update(attrs)
        return self

    def child(self, name: str) -> "Span":
        sp = Span(name, self._clock)
        self.children.append(sp)
        return sp

    def end(self):
        if self.t_end is None:
            self.t_end = self._clock.now()
        return self

    @property
    def duration(self) -> float:
        return (self.t_end if self.t_end is not None
                else self._clock.now()) - self.t_start

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.end()
        return False

    def to_dict(self) -> dict:
        return {"name": self.name, "t_start": self.t_start,
                "t_end": self.t_end, "attrs": dict(self.attrs),
                "children": [c.to_dict() for c in self.children]}


class Trace:
    """One request's span tree. ``sampled=False`` traces carry only the
    id; every span they hand out is the shared NullSpan."""

    __slots__ = ("trace_id", "sampled", "root", "_clock")

    def __init__(self, trace_id: str, sampled: bool, clock,
                 root_name: str = "request"):
        self.trace_id = trace_id
        self.sampled = sampled
        self._clock = clock
        self.root = Span(root_name, clock) if sampled else NULL_SPAN

    def span(self, name: str, parent=None):
        """Open a span under ``parent`` (default: the root)."""
        if not self.sampled:
            return NULL_SPAN
        return (parent if parent is not None else self.root).child(name)

    def to_dict(self) -> dict:
        return {"trace_id": self.trace_id, "root": self.root.to_dict()}


class Tracer:
    """Mints traces, applies sampling, and buffers finished ones.

    ``sample_rate`` in [0, 1]: 0 disables tracing entirely (the default
    for a bare engine — zero overhead on the hot path), 1 records every
    request. Rates in between sample deterministically: an accumulator
    adds ``rate`` per mint and fires each time it crosses 1, so n mints
    yield exactly ``floor(n * rate)`` (±0 — reproducible) samples.
    """

    def __init__(self, clock=None, sample_rate: float = 0.0,
                 max_traces: int = 1024):
        if not 0.0 <= sample_rate <= 1.0:
            raise ValueError(f"sample_rate must be in [0, 1], got "
                             f"{sample_rate}")
        if clock is None:
            from repro.obs.metrics import _MonotonicClock
            clock = _MonotonicClock()
        self.clock = clock
        self.sample_rate = sample_rate
        self.max_traces = max_traces
        self._lock = threading.Lock()
        self._acc = 0.0
        self._n_minted = 0
        self._n_sampled = 0
        self._finished: list = []

    def start_trace(self, root_name: str = "request",
                    force: bool = False) -> Trace:
        """Mint a trace (always returns one; sampling decides whether
        it records). ``force=True`` bypasses sampling — control-plane
        traces (closed-loop refreshes) are rare and always wanted."""
        with self._lock:
            self._n_minted += 1
            tid = f"t{self._n_minted:08x}"
            if force:
                sampled = True
            else:
                self._acc += self.sample_rate
                sampled = self._acc >= 1.0 - 1e-12
                if sampled:
                    self._acc -= 1.0
            if sampled:
                self._n_sampled += 1
        return Trace(tid, sampled, self.clock, root_name)

    def finish(self, trace: Trace) -> None:
        """Close the root span and (for sampled traces) buffer the
        finished tree for export. Unsampled traces are dropped here."""
        if not trace.sampled:
            return
        trace.root.end()
        with self._lock:
            self._finished.append(trace.to_dict())
            if len(self._finished) > self.max_traces:
                del self._finished[:len(self._finished) - self.max_traces]

    @property
    def n_minted(self) -> int:
        with self._lock:
            return self._n_minted

    @property
    def n_sampled(self) -> int:
        with self._lock:
            return self._n_sampled

    def drain(self) -> list:
        """Hand out (and clear) the finished-trace buffer."""
        with self._lock:
            out = self._finished
            self._finished = []
        return out

    def write_jsonl(self, path: str, append: bool = True) -> int:
        """Drain finished traces to ``path`` as JSON-lines; returns how
        many were written."""
        traces = self.drain()
        if traces:
            with open(path, "a" if append else "w") as f:
                for tr in traces:
                    f.write(json.dumps(tr, sort_keys=True) + "\n")
        return len(traces)


def span_names(trace_dict: dict) -> list:
    """Flatten a finished trace dict into depth-first span names —
    the shape assertions in tests and check_obs read."""
    out = []

    def walk(span):
        out.append(span["name"])
        for c in span.get("children", ()):
            walk(c)

    walk(trace_dict["root"])
    return out
