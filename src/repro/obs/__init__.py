"""Unified observability: one metrics registry + request tracing layer.

The measurement subsystem every other layer records into:

  metrics.py  ``MetricsRegistry`` with thread-safe labeled ``Counter`` /
              ``Gauge`` / ``Histogram`` (log-spaced latency buckets),
              mergeable snapshots, Prometheus text exposition, a bounded
              structured-event log, the single ``percentile``
              implementation, and ``index_memory`` byte accounting;
  trace.py    ``Tracer`` / ``Trace`` / ``Span`` — request-scoped span
              trees on an injectable clock, deterministic sampling,
              JSONL export.

Neither module imports jax or the serving stack (clocks are duck-typed),
so obs sits below everything: engine, scheduler, batcher, mutable index,
snapshots, miner, and the closed loop all share one registry/tracer pair
(see docs/observability.md for the metric catalog and span taxonomy).
"""

from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS,  # noqa: F401
                               Counter, Gauge, Histogram, MetricsRegistry,
                               ScopedRegistry, index_memory, log_buckets,
                               merge_snapshots, parse_label_key, percentile)
from repro.obs.trace import (NULL_SPAN, NullSpan, Span,  # noqa: F401
                             Trace, Tracer, span_names)
