"""Unified metrics registry: one place every subsystem's counters live.

Before this module, seven subsystems each grew a private ``stats()``
dict and three of them hand-rolled their own latency percentiles. The
paper's headline claims are *systems* claims (1M points, 200M pairs, 15
hours on 256 cores) — staleness, per-stage throughput, and queue
behavior are quantities that must be measured, not assumed — so the
measurement layer is a subsystem of its own:

  ``MetricsRegistry``   thread-safe, labeled ``Counter`` / ``Gauge`` /
                        ``Histogram`` instruments keyed by stable
                        documented names (docs/observability.md is the
                        catalog), plus a bounded structured-event log
                        for rare lifecycle transitions (compaction,
                        snapshot load, metric swap);
  snapshots             ``registry.snapshot()`` freezes every instrument
                        into a nested plain dict (JSON-safe), and
                        ``merge_snapshots`` combines two — counters and
                        histograms add, gauges take the later value —
                        so per-process registries roll up to one view;
  exposition            ``registry.exposition()`` renders the
                        Prometheus text format for dashboard scrapes;
  ``percentile``        THE latency-percentile implementation. Three
                        ad-hoc copies existed (scheduler.LatencyWindow,
                        serve_retrieval, serving_load) and one of them
                        underflowed to the *minimum* at small n
                        (``lat[int(n * 0.99) - 1]`` is ``lat[0]`` for
                        n=2); everything now routes here.

The registry never imports jax or the serving stack: it accepts any
object with a ``.now() -> float`` method as its clock (serve/clock.py's
``Clock`` satisfies it; the default reads ``time.monotonic``), so the
obs layer sits below every other subsystem without import cycles, and
FakeClock drives event timestamps and histogram tests deterministically.

Thread-safety: one lock per registry serializes every mutation
(``inc``/``set``/``observe``/``event``) and every read, so concurrent
writers never lose an increment — the engine's cache counters used to
be racy read-modify-writes from batcher and scheduler threads; through
the registry they are exact.
"""

from __future__ import annotations

import bisect
import json
import math
import threading
import time
from typing import Dict, Iterable, Optional, Sequence, Tuple


class _MonotonicClock:
    """Default time source (duck-typed ``Clock``): real monotonic time."""

    def now(self) -> float:
        return time.monotonic()


def percentile(values, q):
    """The one percentile implementation (linear interpolation, as
    ``np.percentile``). ``values`` is any sequence of samples; ``q`` a
    scalar or sequence of percentiles in [0, 100]. Empty input returns
    NaN (scalar q) or a list of NaNs.

    Small-n behavior (the class of bug this replaces): n=1 returns that
    sample for every q; n=2 returns the interpolation between the two —
    never the *minimum* for a high percentile, which is what
    ``sorted_values[int(n * 0.99) - 1]`` silently produced.
    """
    import numpy as np

    scalar = np.isscalar(q)
    vals = np.asarray(list(values), np.float64)
    if vals.size == 0:
        return float("nan") if scalar else [float("nan")] * len(q)
    out = np.percentile(vals, q)
    return float(out) if scalar else [float(v) for v in out]


def log_buckets(lo: float = 1e-4, hi: float = 60.0,
                per_decade: int = 3) -> Tuple[float, ...]:
    """Log-spaced histogram bucket upper bounds covering [lo, hi]
    (inclusive), ``per_decade`` bounds per decade. The default spans
    0.1 ms .. 60 s — the serving latency range — in 18 buckets; a
    trailing +inf bucket is implicit in every Histogram.
    """
    if lo <= 0 or hi <= lo:
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    n = int(round(math.log10(hi / lo) * per_decade))
    bounds = [lo * 10.0 ** (i / per_decade) for i in range(n + 1)]
    if bounds[-1] < hi * (1 - 1e-12):
        bounds.append(hi)
    return tuple(round(b, 12) for b in bounds)


DEFAULT_LATENCY_BUCKETS = log_buckets()

_RESERVED = ("le", "quantile")


def _label_key(labelnames: Tuple[str, ...], labels: dict) -> str:
    """Canonical string key for one labelset: "a=x,b=y" (sorted by the
    declared label order), "" when unlabeled. Keys are JSON-object-safe
    so snapshots nest as plain dicts."""
    if set(labels) != set(labelnames):
        raise ValueError(f"labels {sorted(labels)} != declared "
                         f"{sorted(labelnames)}")
    return ",".join(f"{k}={labels[k]}" for k in labelnames)


def parse_label_key(key: str) -> Dict[str, str]:
    """Inverse of the snapshot label key: "a=x,b=y" -> dict."""
    if not key:
        return {}
    return dict(part.split("=", 1) for part in key.split(","))


class _Metric:
    """Shared name/labels plumbing; subclasses own the value shape."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...],
                 lock: threading.RLock):
        if not name or any(c in name for c in " {}\",\n"):
            raise ValueError(f"bad metric name {name!r}")
        for ln in labelnames:
            if ln in _RESERVED:
                raise ValueError(f"label name {ln!r} is reserved")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock
        self._values: dict = {}

    def _key(self, labels: dict) -> str:
        return _label_key(self.labelnames, labels)

    def label_keys(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(self._values)


class Counter(_Metric):
    """Monotone float counter. ``inc`` is atomic under the registry
    lock — concurrent threads never lose an increment."""

    kind = "counter"

    def inc(self, by: float = 1.0, **labels) -> None:
        if by < 0:
            raise ValueError(f"counters only go up (by={by})")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + by

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    def total(self) -> float:
        """Sum across every labelset (e.g. all classes, all outcomes)."""
        with self._lock:
            return sum(self._values.values())


class Gauge(_Metric):
    """Point-in-time value (queue depth, ladder level, resident bytes)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._values[self._key(labels)] = float(value)

    def inc(self, by: float = 1.0, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + by

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)


class Histogram(_Metric):
    """Bucketed distribution with log-spaced default latency buckets.

    Per labelset the histogram keeps ``len(buckets) + 1`` non-cumulative
    bucket counts (the last is the +inf overflow), the sample sum, and
    the sample count. ``observe`` uses ``bisect`` over the upper bounds:
    a value lands in the first bucket whose bound is >= value, exactly —
    tests assert bucket contents with ``==``, not approx.
    """

    kind = "histogram"

    def __init__(self, name, help, labelnames, lock,
                 buckets: Optional[Sequence[float]] = None):
        super().__init__(name, help, labelnames, lock)
        b = tuple(float(x) for x in
                  (DEFAULT_LATENCY_BUCKETS if buckets is None else buckets))
        if not b or list(b) != sorted(set(b)):
            raise ValueError(f"buckets must be ascending+unique, got {b}")
        if math.isinf(b[-1]):
            b = b[:-1]          # +inf bucket is always implicit
        self.buckets = b

    def _cell(self, key):
        cell = self._values.get(key)
        if cell is None:
            cell = self._values[key] = {
                "counts": [0] * (len(self.buckets) + 1),
                "sum": 0.0, "count": 0}
        return cell

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        i = bisect.bisect_left(self.buckets, float(value))
        with self._lock:
            cell = self._cell(key)
            cell["counts"][i] += 1
            cell["sum"] += float(value)
            cell["count"] += 1

    def counts(self, **labels):
        """Non-cumulative per-bucket counts (len(buckets) + 1)."""
        with self._lock:
            cell = self._values.get(self._key(labels))
            return (list(cell["counts"]) if cell
                    else [0] * (len(self.buckets) + 1))

    def count(self, **labels) -> int:
        with self._lock:
            cell = self._values.get(self._key(labels))
            return cell["count"] if cell else 0

    def sum(self, **labels) -> float:
        with self._lock:
            cell = self._values.get(self._key(labels))
            return cell["sum"] if cell else 0.0

    def percentile(self, q: float, **labels) -> float:
        """Upper-bound estimate of the q-th percentile from bucket
        counts (the bound of the bucket holding the q-th sample; inf if
        it landed in the overflow bucket, NaN when empty). This is the
        report-time readout — exact percentiles come from raw windows
        (``obs.percentile``); the histogram trades that for mergeable
        fixed-size state."""
        counts = self.counts(**labels)
        total = int(builtins_sum(counts))
        if total == 0:
            return float("nan")
        rank = q / 100.0 * total
        run = 0
        for i, c in enumerate(counts):
            run += c
            if run >= rank and c:
                return (self.buckets[i] if i < len(self.buckets)
                        else float("inf"))
        return float("inf")


builtins_sum = sum      # Histogram.sum shadows the builtin in-class


class MetricsRegistry:
    """Get-or-create instrument registry + structured-event log.

    One registry spans the whole serving/training stack: the engine
    creates (or receives) one, and every layer that attaches to the
    engine — scheduler, batcher, mutable index, miner, closed loop —
    records into the same instance, so one ``snapshot()`` is the whole
    system's state. ``counter``/``gauge``/``histogram`` are idempotent:
    a second call with the same name returns the same instrument
    (mismatched kind/labels/buckets raise — name collisions are bugs).

    Collectors: ``register_collector(fn)`` adds a zero-arg callable run
    at the top of every ``snapshot()``/``exposition()`` — the hook for
    gauges derived from live state (queue depths, resident bytes) that
    would be stale if only pushed on mutation.
    """

    def __init__(self, clock=None, max_events: int = 1024):
        self._lock = threading.RLock()
        self._metrics: Dict[str, _Metric] = {}
        self._collectors: list = []
        self._events: list = []
        self._max_events = max_events
        self.clock = clock if clock is not None else _MonotonicClock()

    # -- instruments ---------------------------------------------------------

    def _get(self, cls, name, help, labelnames, **kw):
        labelnames = tuple(labelnames)
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, labelnames,
                                              self._lock, **kw)
                return m
        if not isinstance(m, cls):
            raise ValueError(f"{name!r} already registered as {m.kind}")
        if m.labelnames != labelnames:
            raise ValueError(f"{name!r} labelnames {m.labelnames} != "
                             f"{labelnames}")
        if kw.get("buckets") is not None and tuple(
                float(b) for b in kw["buckets"]) != m.buckets:
            raise ValueError(f"{name!r} re-registered with different "
                             f"buckets")
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Iterable[str] = (),
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self._get(Histogram, name, help, labelnames,
                         buckets=buckets)

    def register_collector(self, fn) -> None:
        with self._lock:
            self._collectors.append(fn)

    def scoped(self, **bound) -> "ScopedRegistry":
        """A write view of this registry with label values pre-bound
        (``registry.scoped(tenant="a")``) — see ScopedRegistry below."""
        return ScopedRegistry(self, **bound)

    # -- structured events ---------------------------------------------------

    def event(self, name: str, **attrs) -> None:
        """Append one structured lifecycle event (bounded: oldest events
        drop past ``max_events``). For rare transitions — compactions,
        snapshot save/load, metric swaps — not per-request traffic."""
        rec = {"t": self.clock.now(), "event": name, **attrs}
        with self._lock:
            self._events.append(rec)
            if len(self._events) > self._max_events:
                del self._events[:len(self._events) - self._max_events]

    def events(self, name: Optional[str] = None) -> list:
        with self._lock:
            evs = list(self._events)
        return evs if name is None else [e for e in evs
                                         if e["event"] == name]

    # -- export --------------------------------------------------------------

    def _collect(self):
        for fn in list(self._collectors):
            fn()

    def snapshot(self) -> dict:
        """Freeze every instrument into a nested JSON-safe dict:

        ``{"t", "counters": {name: {"help", "labels", "values":
        {label_key: v}}}, "gauges": {...}, "histograms": {name: {...,
        "buckets", "values": {label_key: {"counts", "sum", "count"}}}},
        "events": [...]}``. Collectors run first, so derived gauges are
        current."""
        self._collect()
        with self._lock:
            out = {"t": self.clock.now(), "counters": {}, "gauges": {},
                   "histograms": {}, "events": [dict(e) for e in
                                                self._events]}
            for name, m in sorted(self._metrics.items()):
                if isinstance(m, Histogram):
                    out["histograms"][name] = {
                        "help": m.help, "labels": list(m.labelnames),
                        "buckets": list(m.buckets),
                        "values": {k: {"counts": list(c["counts"]),
                                       "sum": c["sum"],
                                       "count": c["count"]}
                                   for k, c in m._values.items()}}
                else:
                    kind = "counters" if isinstance(m, Counter) else "gauges"
                    out[kind][name] = {
                        "help": m.help, "labels": list(m.labelnames),
                        "values": dict(m._values)}
        return out

    def exposition(self) -> str:
        """Prometheus text exposition (one scrape). Histograms render
        the standard cumulative ``_bucket{le=...}`` / ``_sum`` /
        ``_count`` triple; events are not part of the format."""
        snap = self.snapshot()
        lines = []

        def fmt_labels(key, extra=None):
            labels = parse_label_key(key)
            if extra:
                labels = {**labels, **extra}
            if not labels:
                return ""
            inner = ",".join(f'{k}="{v}"' for k, v in labels.items())
            return "{" + inner + "}"

        for kind, typ in (("counters", "counter"), ("gauges", "gauge")):
            for name, m in snap[kind].items():
                if m["help"]:
                    lines.append(f"# HELP {name} {m['help']}")
                lines.append(f"# TYPE {name} {typ}")
                for key, v in sorted(m["values"].items()):
                    lines.append(f"{name}{fmt_labels(key)} {v:g}")
        for name, m in snap["histograms"].items():
            if m["help"]:
                lines.append(f"# HELP {name} {m['help']}")
            lines.append(f"# TYPE {name} histogram")
            for key, cell in sorted(m["values"].items()):
                run = 0
                for bound, c in zip(m["buckets"] + [float("inf")],
                                    cell["counts"]):
                    run += c
                    le = "+Inf" if math.isinf(bound) else f"{bound:g}"
                    lines.append(f"{name}_bucket"
                                 f"{fmt_labels(key, {'le': le})} {run}")
                lines.append(f"{name}_sum{fmt_labels(key)} "
                             f"{cell['sum']:g}")
                lines.append(f"{name}_count{fmt_labels(key)} "
                             f"{cell['count']}")
        return "\n".join(lines) + "\n"

    def write_snapshot(self, path: str) -> dict:
        snap = self.snapshot()
        with open(path, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
            f.write("\n")
        return snap


class _BoundInstrument:
    """Instrument facade with some labels pre-bound (e.g. tenant=...).

    Forwards every read/write to the underlying registry instrument with
    the bound labels merged in, so a subsystem written against unlabeled
    instruments (the engine's ``engine_requests_total`` et al.) records
    per-scope series without knowing it is scoped. Explicit labels at the
    call site may not collide with bound ones — that would silently
    reattribute another scope's traffic."""

    __slots__ = ("_inst", "_bound")

    def __init__(self, inst, bound: dict):
        self._inst = inst
        self._bound = dict(bound)

    def _merge(self, labels: dict) -> dict:
        clash = set(labels) & set(self._bound)
        if clash:
            raise ValueError(f"labels {sorted(clash)} are bound by the "
                             f"scope and cannot be overridden")
        return {**self._bound, **labels}

    # Counter / Gauge surface
    def inc(self, by: float = 1.0, **labels):
        return self._inst.inc(by, **self._merge(labels))

    def set(self, value: float, **labels):
        return self._inst.set(value, **self._merge(labels))

    def value(self, **labels):
        return self._inst.value(**self._merge(labels))

    def total(self):
        return self._inst.total()

    # Histogram surface
    def observe(self, value: float, **labels):
        return self._inst.observe(value, **self._merge(labels))

    def counts(self, **labels):
        return self._inst.counts(**self._merge(labels))

    def count(self, **labels):
        return self._inst.count(**self._merge(labels))

    def sum(self, **labels):
        return self._inst.sum(**self._merge(labels))

    def percentile(self, q: float, **labels):
        return self._inst.percentile(q, **self._merge(labels))

    @property
    def name(self):
        return self._inst.name

    @property
    def labelnames(self):
        return self._inst.labelnames

    @property
    def buckets(self):
        return self._inst.buckets


class ScopedRegistry:
    """A MetricsRegistry view with label values bound up front.

    ``registry.scoped(tenant="a")`` returns a facade whose
    ``counter``/``gauge``/``histogram`` calls create the instrument on the
    *base* registry with the bound label names prepended to the declared
    ones, and hand back a ``_BoundInstrument`` that merges the bound
    values into every operation. Two scopes of the same base registry
    therefore share one instrument per name (identical labelnames — no
    get-or-create collision) while their series stay separated by label.
    This is how N per-tenant engines record ``engine_*`` metrics onto one
    router registry as ``engine_requests_total{tenant=...}``.

    Collectors and events forward to the base (events gain the bound
    attrs); ``snapshot``/``exposition``/``write_snapshot`` read the whole
    base registry — a scope is a *write* view, not a filtered read.
    """

    def __init__(self, base: "MetricsRegistry", **bound):
        if not bound:
            raise ValueError("a scope needs at least one bound label")
        while isinstance(base, ScopedRegistry):   # scopes of scopes flatten
            bound = {**base.bound, **bound}
            base = base.base
        for name in bound:
            if name in _RESERVED:
                raise ValueError(f"label name {name!r} is reserved")
        self.base = base
        self.bound = {k: str(v) for k, v in bound.items()}
        self.clock = base.clock

    def scoped(self, **bound) -> "ScopedRegistry":
        return ScopedRegistry(self, **bound)

    def _bound_names(self, labelnames) -> Tuple[str, ...]:
        extra = tuple(labelnames)
        clash = set(extra) & set(self.bound)
        if clash:
            raise ValueError(f"labelnames {sorted(clash)} are already "
                             f"bound by the scope")
        return tuple(self.bound) + extra

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> _BoundInstrument:
        return _BoundInstrument(
            self.base.counter(name, help, self._bound_names(labelnames)),
            self.bound)

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = ()) -> _BoundInstrument:
        return _BoundInstrument(
            self.base.gauge(name, help, self._bound_names(labelnames)),
            self.bound)

    def histogram(self, name: str, help: str = "",
                  labelnames: Iterable[str] = (),
                  buckets: Optional[Sequence[float]] = None
                  ) -> _BoundInstrument:
        return _BoundInstrument(
            self.base.histogram(name, help, self._bound_names(labelnames),
                                buckets=buckets),
            self.bound)

    def register_collector(self, fn) -> None:
        self.base.register_collector(fn)

    def event(self, name: str, **attrs) -> None:
        self.base.event(name, **{**self.bound, **attrs})

    def events(self, name: Optional[str] = None) -> list:
        return self.base.events(name)

    def snapshot(self) -> dict:
        return self.base.snapshot()

    def exposition(self) -> str:
        return self.base.exposition()

    def write_snapshot(self, path: str) -> dict:
        return self.base.write_snapshot(path)


def merge_snapshots(a: dict, b: dict) -> dict:
    """Combine two registry snapshots (e.g. per-worker registries into
    one fleet view): counters and histogram cells add, gauges take the
    later snapshot's value (b wins on conflict), events concatenate in
    time order. Histogram bucket layouts must match."""
    out = {"t": max(a.get("t", 0.0), b.get("t", 0.0)),
           "counters": {}, "gauges": {}, "histograms": {},
           "events": sorted([*a.get("events", []), *b.get("events", [])],
                            key=lambda e: e.get("t", 0.0))}
    for kind in ("counters", "gauges"):
        names = set(a.get(kind, {})) | set(b.get(kind, {}))
        for name in names:
            ma = a.get(kind, {}).get(name)
            mb = b.get(kind, {}).get(name)
            base = mb or ma
            merged = {"help": base["help"], "labels": base["labels"],
                      "values": dict((ma or base)["values"])}
            if ma and mb:
                for key, v in mb["values"].items():
                    if kind == "counters":
                        merged["values"][key] = (
                            merged["values"].get(key, 0.0) + v)
                    else:
                        merged["values"][key] = v      # later value wins
            elif mb:
                merged["values"] = dict(mb["values"])
            out[kind][name] = merged
    names = set(a.get("histograms", {})) | set(b.get("histograms", {}))
    for name in names:
        ma = a.get("histograms", {}).get(name)
        mb = b.get("histograms", {}).get(name)
        base = mb or ma
        merged = {"help": base["help"], "labels": base["labels"],
                  "buckets": list(base["buckets"]),
                  "values": {k: {"counts": list(c["counts"]),
                                 "sum": c["sum"], "count": c["count"]}
                             for k, c in (ma or base)["values"].items()}}
        if ma and mb:
            if list(ma["buckets"]) != list(mb["buckets"]):
                raise ValueError(f"histogram {name!r}: bucket layouts "
                                 f"differ, cannot merge")
            for key, c in mb["values"].items():
                cell = merged["values"].get(key)
                if cell is None:
                    merged["values"][key] = {"counts": list(c["counts"]),
                                             "sum": c["sum"],
                                             "count": c["count"]}
                else:
                    cell["counts"] = [x + y for x, y in
                                      zip(cell["counts"], c["counts"])]
                    cell["sum"] += c["sum"]
                    cell["count"] += c["count"]
        elif mb:
            merged["values"] = {k: {"counts": list(c["counts"]),
                                    "sum": c["sum"], "count": c["count"]}
                                for k, c in mb["values"].items()}
        out["histograms"][name] = merged
    return out


def index_memory(index) -> Dict[str, int]:
    """Resident bytes of a MetricIndex, by component — the ROADMAP's
    memory-budget accounting. Components (absent keys mean the backend
    has no such state):

      gallery     full-precision projected rows + norms on device
                  (ExactIndex gp/gn, IVF gp_pad/gn_pad segments);
      codes       PQ uint8 codes + per-row t term + codebooks;
      centroids   coarse-quantizer centers (IVF/IVFPQ);
      delta       MutableIndex delta buffer (host projected rows, ids,
                  tombstone masks);
      host_store  host-resident full-precision arrays: the IVFPQ rerank
                  store (gp_full/gn_full) and MutableIndex retained raw
                  rows.

    Works on any backend, including a MutableIndex wrapper (wrapper
    components add to the base's).
    """
    out: Dict[str, int] = {}

    def add(key, *arrays):
        n = builtins_sum(a.nbytes for a in arrays if a is not None)
        if n:
            out[key] = out.get(key, 0) + int(n)

    base = getattr(index, "base", None)
    if base is not None and hasattr(index, "delta_gp"):   # MutableIndex
        add("delta", index.delta_gp, index.delta_gn, index.delta_ids,
            index.dead_delta, index.dead_base)
        add("host_store", index.raw_base, index.raw_delta)
        inner = index_memory(base)
        for k, v in inner.items():
            out[k] = out.get(k, 0) + v
        return out
    add("gallery", getattr(index, "gp", None), getattr(index, "gn", None),
        getattr(index, "gp_pad", None), getattr(index, "gn_pad", None))
    add("gallery", getattr(index, "ids_pad", None))
    add("centroids", getattr(index, "centroids", None))
    pq = getattr(index, "pq", None)
    if pq is not None:
        add("codes", getattr(index, "codes_pad", None),
            getattr(index, "t_pad", None),
            getattr(pq, "codebooks", None))
    add("host_store", getattr(index, "gp_full", None),
        getattr(index, "gn_full", None))
    return out
