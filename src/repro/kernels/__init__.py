from repro.kernels import (  # noqa: F401
    dml_pair, flash_attention, metric_topk, pairwise_dist,
)
