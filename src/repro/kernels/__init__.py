from repro.kernels import dml_pair, flash_attention, pairwise_dist  # noqa: F401
