from repro.kernels import (  # noqa: F401
    dml_pair, flash_attention, ivf_scan, metric_topk, pairwise_dist,
    pq_adc,
)
