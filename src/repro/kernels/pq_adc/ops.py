"""Public wrapper for the fused PQ ADC scan: validation, tiling, dispatch.

``pq_adc_topk`` is the one entry point serve/pq.py calls. It owns the
chores the kernel contract forbids inside kernel.py:

  * **validation** — kk must be >= 1 and fit the probed candidate pool
    (the falsy-default bug class: an explicit 0 raises, never silently
    remaps);
  * **XLA fallback** (``use_kernel=False``) — the ref oracle, chunked
    over ``block_q`` query rows with lax.map so the gathered
    (block_q, nprobe, cap, S) intermediate stays cache-sized (the same
    chunking serve/pq.py always used);
  * **kernel dispatch** — flatten segments, lane-pad the LUTs, pick a
    code tile that divides cap, run the fused kernel, then mask
    BIG-sentinel survivors to id -1 and apply the final (distance, id)
    sort so both paths return byte-identical arrays.

Both paths return bit-identical results — tests/test_scan_kernels.py
pins array equality, not allclose.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels._dispatch import (LANE, default_interpret,
                                     map_query_chunks, pad_axis, round_up,
                                     segment_block)
from repro.kernels.metric_topk.kernel import BIG
from repro.kernels.pq_adc.kernel import pq_adc_topk_fused
from repro.kernels.pq_adc.ref import pq_adc_topk_ref


def pq_adc_topk(tables, dc, probes, codes, t, ids, *, kk: int,
                block_q: int = 64, block_m: int = 512,
                use_kernel: bool = True, interpret=None):
    """Top-kk ADC candidates per query from its probed code segments.

    Args:
      tables: (Nq, S*K) flattened per-query LUTs (ProductQuantizer
        ``ip_tables`` reshaped).
      dc: (Nq, nprobe) squared centroid distances of the probed clusters.
      probes: (Nq, nprobe) int32 probed cluster ids.
      codes: (C, cap, S) uint8; t: (C, cap) f32 (+BIG pads);
        ids: (C, cap) int32 (-1 pads) — the IVFPQ segment layout.
      kk: candidates kept per query (1 <= kk <= nprobe * cap).
      block_q: XLA-path query chunk (lax.map granularity).
      block_m: kernel-path code-tile rows (rounded to a divisor of cap).
      use_kernel: False routes to the chunked XLA reference.
      interpret: None compiles on TPU / interprets elsewhere; bool forces.

    Returns (dists (Nq, kk) f32 ascending, ids (Nq, kk) int32), sorted
    lexicographically by (distance, id); -1 ids mark under-filled probes.
    """
    C, cap, S = codes.shape
    nprobe = probes.shape[1]
    if kk < 1:
        raise ValueError(f"kk must be >= 1, got {kk}")
    if kk > nprobe * cap:
        raise ValueError(f"kk={kk} > nprobe*cap={nprobe * cap} scanned "
                         f"rows per query")
    if not use_kernel:
        return map_query_chunks(
            lambda tab, pr, d: pq_adc_topk_ref(tab, d, pr, codes, t, ids,
                                               kk),
            (tables, probes, dc), block_q)

    K = tables.shape[1] // S
    bM = segment_block(cap, block_m)
    tab_pad = pad_axis(tables, round_up(tables.shape[1], LANE), 1)
    d, i = pq_adc_topk_fused(
        probes.astype(jnp.int32), tab_pad, dc,
        codes.reshape(C * cap, S), t.reshape(C * cap),
        ids.reshape(C * cap), n_codes=K, cap=cap, kk=kk, block_m=bM,
        interpret=default_interpret(interpret))
    # entries still at the BIG sentinel are pad slots (real rows cannot
    # reach 1e30) — but the streaming merge may have parked a
    # knocked-out winner's id there; the reference always reports -1
    i = jnp.where(d >= BIG, -1, i)
    return jax.lax.sort((d, i), dimension=-1, num_keys=2)
