"""Fused PQ ADC segment scan: uint8 code gather + LUT accumulate + top-k.

Kernel/ops/ref contract (docs/kernels.md): ``ops.pq_adc_topk`` is the
public dispatcher; ``kernel.pq_adc_topk_fused`` the raw Pallas call;
``ref.pq_adc_topk_ref`` the bit-exact XLA oracle serve/pq.py scans with.
"""

from repro.kernels.pq_adc.kernel import pq_adc_topk_fused
from repro.kernels.pq_adc.ops import pq_adc_topk
from repro.kernels.pq_adc.ref import pq_adc_topk_ref

__all__ = ["pq_adc_topk", "pq_adc_topk_fused", "pq_adc_topk_ref"]
