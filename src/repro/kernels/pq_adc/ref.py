"""Pure-XLA oracle for the fused PQ ADC segment scan.

The semantics both the serving path (serve/pq.py IVFPQIndex) and the
Pallas kernel (kernel.py) must reproduce **bit-for-bit**: gather each
query's probed code segments, accumulate the per-subspace lookup-table
inner products, apply the factored ADC identity

    d = max(d_cent + t - 2 * sum_s LUT[s, code_s], 0)

(d_cent = squared distance to the probed centroid, t = the baked
||r̂||² + 2⟨c, r̂⟩ row term — see serve/pq.py for the derivation), and
keep the kk best (distance, id) candidates.

Two choices here are load-bearing for the bit-identity contract:

  * the subspace sum is a **sequential** unrolled loop, not
    ``.sum(axis=-1)`` — XLA may tree-reduce a sum over an axis, and the
    kernel accumulates its per-subspace one-hot matmul terms in
    subspace order, so the reference fixes the same rounding order;
  * candidates flatten probe-major / slot-minor, the exact order the
    kernel streams tiles in, so position-order tie-breaks agree.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels._dispatch import topk_by_distance


def pq_adc_topk_ref(tables, dc, probes, codes, t, ids, kk: int):
    """ADC-score the probed segments and keep the top kk per query.

    Args:
      tables: (Nq, S*K) flattened per-query inner-product LUTs (entry
        [q, s*K + c] = <qp_q restricted to subspace s, codebook[s, c]>).
      dc: (Nq, nprobe) squared centroid distances of the probed clusters.
      probes: (Nq, nprobe) int32 probed cluster ids.
      codes: (C, cap, S) uint8 segment codes (0 on pad slots).
      t: (C, cap) f32 baked row terms (+BIG on pad slots).
      ids: (C, cap) int32 global row ids (-1 on pad slots).
      kk: candidates kept per query (<= nprobe * cap).

    Returns (dists (Nq, kk) f32 ascending, ids (Nq, kk) int32), sorted
    lexicographically by (distance, id). Pad slots score exactly BIG
    (their t is +BIG, which swallows the small dc/ip terms in f32) and
    surface — with id -1 — only when the probed segments hold fewer
    than kk real rows.
    """
    Nq = tables.shape[0]
    nprobe = probes.shape[1]
    S = codes.shape[2]
    K = tables.shape[1] // S
    cg = jnp.take(codes, probes, axis=0)          # (Nq, np, cap, S) u8
    tg = jnp.take(t, probes, axis=0)              # (Nq, np, cap)
    ig = jnp.take(ids, probes, axis=0)
    # flatten (s, code) -> s*K + code after the segment gather: the
    # gather moves 1-byte codes and the table lookup is one fused
    # take_along_axis over the small gathered block
    offs = jnp.arange(S, dtype=jnp.int32) * K
    fl = cg.astype(jnp.int32) + offs
    picked = jnp.take_along_axis(tables, fl.reshape(Nq, -1), axis=1)
    picked = picked.reshape(Nq, nprobe, cg.shape[2], S)
    ip = picked[..., 0]
    for s in range(1, S):                         # sequential: see module
        ip = ip + picked[..., s]                  # docstring
    d = jnp.maximum(dc[:, :, None] + tg - 2.0 * ip, 0.0)
    return topk_by_distance(d.reshape(Nq, -1), ig.reshape(Nq, -1), kk)
