"""Pallas TPU kernel: fused PQ ADC segment scan + streaming top-k.

The IVFPQ serving hot loop (serve/pq.py): per query, gather the uint8
code blocks of its ``nprobe`` probed segments, accumulate the
per-subspace LUT inner products, apply the ADC identity

    d = max(d_cent + t - 2 * sum_s LUT[s, code_s], 0)

and stream-merge a running top-kk — without ever materializing the
(block_q, nprobe, cap, S) code gather in HBM that the XLA path pays.

Grid: (Nq, nprobe * nsteps) with one query per program row and the
probe/tile stream innermost, so the running (1, kk) best buffers live
in VMEM scratch across a query's whole stream. The probed-segment
gather is the part XLA cannot fuse: the probe list rides in as a
**scalar-prefetch** operand (pltpu.PrefetchScalarGridSpec), so the
code/t/id block index maps read ``probes[q, p]`` before the body runs
and the right (bM, S) code tile is DMA'd per step — codes stream
through VMEM exactly once.

The LUT accumulate is S one-hot matmuls: for subspace s, onehot(codes
column s) is (bM, K) and ``LUT_s @ onehot^T`` picks tab[s*K + code] per
row on the MXU. Each term is **exact** in f32 (one 1.0 * entry product,
all other lanes contribute exact zeros regardless of the reduction
tree), and terms accumulate sequentially in subspace order — the two
properties that make the kernel bit-identical to ref.py, which fixes
the same summation order (ops.py asserts nothing weaker).

Tile order matches the reference's probe-major / slot-minor candidate
flattening, so position-order tie-breaks agree with lax.top_k. The
best-index scratch initializes to -1 (not 0): entries still at the BIG
sentinel when the stream ends must be indistinguishable from real
(BIG, -1) pad-slot candidates — ops.py masks ids at BIG to -1 for the
same reason (the merge can re-surface a knocked-out winner's position
once only BIG candidates remain).

TPU tuning caveat: the (bM, S) uint8 code tile has S lanes (typically
8-16), far below the (32, 128) minimum uint8 tile — compiled-mode
layouts will pad lanes internally. Interpret mode (the CPU test path)
is exact regardless; lane-efficient code packing is hardware-tuning
work for the TPU-validation ROADMAP item.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.metric_topk.kernel import BIG, _merge_topk


def _pq_adc_kernel(probes_ref, tab_ref, dc_ref, codes_ref, t_ref, ids_ref,
                   od_ref, oi_ref, bd_ref, bi_ref,
                   *, n_codes: int, kk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _reset():
        bd_ref[...] = jnp.full(bd_ref.shape, BIG, jnp.float32)
        bi_ref[...] = jnp.full(bi_ref.shape, -1, jnp.int32)

    codes = codes_ref[...].astype(jnp.int32)             # (bM, S)
    tab = tab_ref[...]                                   # (1, SKpad)
    bM, S = codes.shape
    K = n_codes
    code_iota = jax.lax.broadcasted_iota(jnp.int32, (bM, K), 1)
    ip = None
    for s in range(S):          # sequential accumulate: ref.py order
        onehot = (code_iota == codes[:, s][:, None]).astype(jnp.float32)
        term = jax.lax.dot_general(                      # (1, bM)
            tab[:, s * K:(s + 1) * K], onehot,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        ip = term if ip is None else ip + term
    d = jnp.maximum(dc_ref[...] + t_ref[...][None, :] - 2.0 * ip, 0.0)

    bd, bi = _merge_topk(bd_ref[...], bi_ref[...], d,
                         ids_ref[...][None, :], kk)
    bd_ref[...] = bd
    bi_ref[...] = bi

    @pl.when(j == pl.num_programs(1) - 1)
    def _epilogue():
        od_ref[...] = bd_ref[...]
        oi_ref[...] = bi_ref[...]


@functools.partial(jax.jit, static_argnames=("n_codes", "cap", "kk",
                                             "block_m", "interpret"))
def pq_adc_topk_fused(probes, tables, dc, codes, t, ids, *, n_codes: int,
                      cap: int, kk: int, block_m: int,
                      interpret: bool = True):
    """Fused ADC scan + streaming top-k over probed code segments.

    Args:
      probes: (Nq, nprobe) int32 probed cluster ids (scalar-prefetch).
      tables: (Nq, SKpad) flattened LUTs, lane-padded with zeros past
        S * n_codes (the per-subspace slices never read the pad).
      dc: (Nq, nprobe) f32 squared centroid distances of the probes.
      codes: (C*cap, S) uint8 segment codes; t: (C*cap,) f32 row terms
        (+BIG on pads); ids: (C*cap,) int32 row ids (-1 on pads).
      n_codes: codewords per subspace (K = 2**bits).
      cap: rows per segment; block_m: rows per code tile, must divide
        cap evenly (ops.py picks it).

    Returns (dists (Nq, kk) f32, ids (Nq, kk) int32) in streaming-merge
    order (ascending distance); ids at the BIG sentinel may repeat a
    knocked-out winner — ops.py masks them to -1 before the final sort.
    """
    Nq, nprobe = probes.shape
    rows, S = codes.shape
    bM = block_m
    assert cap % bM == 0 and rows % cap == 0, (rows, cap, bM)
    assert kk <= nprobe * cap, (kk, nprobe, cap)
    nsteps = cap // bM          # tiles per probed segment

    def seg_row(q, j, pr):      # flat tile index of stream step j
        return pr[q, j // nsteps] * nsteps + j % nsteps

    kernel = functools.partial(_pq_adc_kernel, n_codes=n_codes, kk=kk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Nq, nprobe * nsteps),
        in_specs=[
            pl.BlockSpec((1, tables.shape[1]),
                         lambda q, j, pr: (q, 0)),            # LUTs
            pl.BlockSpec((1, 1),
                         lambda q, j, pr: (q, j // nsteps)),  # dc
            pl.BlockSpec((bM, S),
                         lambda q, j, pr: (seg_row(q, j, pr), 0)),
            pl.BlockSpec((bM,),
                         lambda q, j, pr: (seg_row(q, j, pr),)),
            pl.BlockSpec((bM,),
                         lambda q, j, pr: (seg_row(q, j, pr),)),
        ],
        out_specs=[
            pl.BlockSpec((1, kk), lambda q, j, pr: (q, 0)),
            pl.BlockSpec((1, kk), lambda q, j, pr: (q, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, kk), jnp.float32),   # running best distances
            pltpu.VMEM((1, kk), jnp.int32),     # running best ids
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Nq, kk), jnp.float32),
            jax.ShapeDtypeStruct((Nq, kk), jnp.int32),
        ],
        interpret=interpret,
    )(probes, tables, dc, codes, t, ids)
