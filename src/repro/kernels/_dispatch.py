"""Shared kernel-dispatch helpers: backend detection, padding, tiling.

Every kernel family (metric_topk, pq_adc, ivf_scan) fronts its Pallas
kernel with the same ops-layer chores: decide compile-vs-interpret from
the runtime backend, round shapes up to tile multiples, pad with zeros
or sentinels, and pick block sizes for inputs smaller than the
configured tile. This module owns those chores — plus the one
tie-breaking contract (``topk_by_distance``) every scan path must agree
on bit-for-bit — so the families stay in lockstep instead of drifting
three private copies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128      # TPU lane width: last-dim tiles round up to this
SUBLANE = 8     # f32 sublane width: second-minor tiles round up to this


def check_metric_factor(L, d_in=None, *, what: str = "L"):
    """Validate the ``(d_out, d_in)`` metric-factor contract up front.

    Every layer that touches a metric factor — projection, index build,
    kernels — agrees that L is 2-D with raw features on the *second*
    axis, and that rectangular ``d_out < d_in`` (a low-rank factor) is
    as legal as square. Checking here, before any jit boundary, turns a
    transposed / 1-D / wrong-dim factor into one clear ValueError
    instead of an opaque dot-dimension error deep inside a traced
    function. Shapes are static at trace time, so the check is also
    safe to reach from inside jit.

    Args:
      L: candidate metric factor.
      d_in: when given, the raw feature dimensionality the factor must
        contract against (``L.shape[1] == d_in``).
      what: name used in error messages.

    Returns L unchanged.
    """
    shape = tuple(jnp.shape(L))
    if len(shape) != 2:
        raise ValueError(
            f"{what} must be a 2-D (d_out, d_in) metric factor, got "
            f"shape {shape}")
    d_out, d = shape
    if d_out < 1 or d < 1:
        raise ValueError(
            f"{what} must have d_out >= 1 and d_in >= 1, got shape "
            f"{shape}")
    if d_in is not None and d != d_in:
        # rows matching the data dim is the transposed-factor signature
        hint = (" — transposed factor? the contract is rows = d_out, "
                "columns = d_in" if d_out == d_in else "")
        raise ValueError(
            f"{what} has d_in={d} but the data is {d_in}-dimensional; "
            f"expected {what}.shape == (d_out, {d_in}){hint}")
    return L


def default_interpret(interpret=None) -> bool:
    """Resolve the ops-layer ``interpret`` knob: ``None`` (the default)
    compiles the kernel on TPU and interprets everywhere else; a bool
    forces that choice."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def round_up(n: int, mult: int) -> int:
    return n + (-n) % mult


def pad_axis(x, target: int, axis: int, value=0.0):
    """Pad ``x`` along ``axis`` up to length ``target`` with ``value``
    (no-op when already there)."""
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def pick_block(n: int, block: int, mult: int) -> int:
    """Row-tile size: the configured ``block`` when ``n`` fills it,
    else all of ``n`` rounded up to ``mult`` (a single tile)."""
    return block if n >= block else round_up(n, mult)


def segment_block(cap: int, block: int) -> int:
    """Segment-scan row tile: ``block`` when it divides the segment
    capacity evenly, else the whole segment. Probed segments cannot be
    padded per probe (the probe list indexes a fixed layout), so the
    tile must divide ``cap`` exactly."""
    return block if cap % block == 0 else cap


def map_query_chunks(fn, arrays, block: int):
    """Run a per-chunk (dists, ids) scan over query-row chunks.

    The XLA fallback shape both segment-scan families share: pad the
    leading (query) axis of every array in ``arrays`` to a multiple of
    ``block``, lax.map ``fn`` over the (block, ...) chunks so the
    gathered per-chunk intermediates stay cache-sized, and slice the
    concatenated results back to the real query count. ``fn`` receives
    one chunk of each array and returns a (dists (B, kk), ids (B, kk))
    pair. Zero query pads are scored but sliced off.
    """
    n = arrays[0].shape[0]
    B = min(block, n)
    Np = round_up(n, B)
    chunked = tuple(pad_axis(a, Np, 0).reshape(Np // B, B, *a.shape[1:])
                    for a in arrays)
    d, i = jax.lax.map(lambda args: fn(*args), chunked)
    kk = d.shape[-1]
    return d.reshape(Np, kk)[:n], i.reshape(Np, kk)[:n]


def topk_by_distance(d, ids, k_top: int):
    """Top-k candidates by distance with a deterministic presentation.

    The one selection contract every scan path (XLA reference, Pallas
    streaming merge, serve/scan.py) must reproduce exactly: lax.top_k
    does the heavy lifting (ties toward the earlier candidate
    *position*), then the k_top survivors re-sort lexicographically by
    (distance, id) so equal-distance neighbors come back
    smallest-id-first regardless of candidate generation order. Ties
    straddling the k_top boundary still resolve by candidate position —
    see serve/scan.py for the serving-level caveats.
    """
    neg, pos = jax.lax.top_k(-d, k_top)
    cd, ci = -neg, jnp.take_along_axis(ids, pos, axis=-1)
    return jax.lax.sort((cd, ci), dimension=-1, num_keys=2)
