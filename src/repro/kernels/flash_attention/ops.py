"""Public wrapper: Pallas flash attention with jnp fallback + ref oracle."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def attend_flash(q, k, v, *, causal: bool = True, window: int = 0,
                 block_q: int = 512, block_k: int = 512,
                 interpret: bool = True):
    """Serving-path attention. Falls back to the oracle when tile shapes
    don't divide (tiny smoke configs)."""
    B, T, H, dh = q.shape
    S = k.shape[1]
    bq = min(block_q, T)
    bk = min(block_k, S)
    if T % bq or S % bk:
        return attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=bq, block_k=bk, interpret=interpret)
