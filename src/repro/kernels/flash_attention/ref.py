"""Pure-jnp oracle for the flash attention kernel (GQA, causal/sliding)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float = None):
    """q (B,T,H,Dh); k,v (B,S,K,Dh) with H % K == 0. Returns (B,T,H,Dh).

    window > 0 limits attention to the last `window` positions (sliding).
    """
    B, T, H, dh = q.shape
    S, K = k.shape[1], k.shape[2]
    scale = scale or 1.0 / np.sqrt(dh)
    qg = q.reshape(B, T, K, H // K, dh)
    s = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(T)
    kpos = jnp.arange(S)
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", w, v.astype(jnp.float32))
    return out.reshape(B, T, H, dh).astype(q.dtype)
