"""Pallas TPU flash attention (forward): GQA, causal and sliding-window.

Grid: (B * H, T/bQ, S/bK) with the KV dimension innermost ("arbitrary"
semantics) so the running max / denominator / accumulator for one q tile
live in VMEM scratch across KV steps — the streaming-softmax algorithm with
no (T, S) materialization. GQA is expressed in the k/v BlockSpec index maps
(q head h reads kv head h // group), so no head replication is stored.

The online-softmax update per KV tile:
    m'   = max(m, rowmax(s))
    p    = exp(s - m')
    corr = exp(m - m')
    l'   = corr * l + rowsum(p)
    acc' = corr * acc + p @ v
with the division by l deferred to the last KV step. Tiles masked fully out
(causal/sliding) are skipped via the index bounds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, nk: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # (bQ, Dh)
    k = k_ref[0].astype(jnp.float32)                    # (bK, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1)
    acc_ref[...] = (corr[:, None] * acc_ref[...]
                    + jax.lax.dot_general(
                        p, v_ref[0].astype(jnp.float32),
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = True):
    """q (B,T,H,Dh); k,v (B,S,K,Dh), H % K == 0. Returns (B,T,H,Dh)."""
    B, T, H, dh = q.shape
    S, K = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    group = H // K
    bQ, bK = min(block_q, T), min(block_k, S)
    assert T % bQ == 0 and S % bK == 0, (T, S, bQ, bK)
    nq, nk = T // bQ, S // bK
    scale = 1.0 / np.sqrt(dh)

    # layout: fold heads into the leading grid dim; block index maps pick the
    # right (batch, head) pane and the GQA kv head = h // group
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, T, dh)
    kt = k.transpose(0, 2, 1, 3).reshape(B * K, S, dh)
    vt = v.transpose(0, 2, 1, 3).reshape(B * K, S, dh)

    def kv_index(bh, qi, kj):
        b = bh // H
        h = (bh % H) // group
        return (b * K + h, kj, 0)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, block_q=bQ, block_k=bK, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bQ, dh), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bK, dh), kv_index),
            pl.BlockSpec((1, bK, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bQ, dh), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bQ,), jnp.float32),
            pltpu.VMEM((bQ,), jnp.float32),
            pltpu.VMEM((bQ, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, T, dh).transpose(0, 2, 1, 3)
