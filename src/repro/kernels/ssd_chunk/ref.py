"""Pure-jnp oracle for the SSD chunk kernel: exact sequential recurrence,
single (batch*head) pane layout matching the kernel's contract."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(xs, Bm, Cm, dt, la):
    """Exact recurrence, pane layout.

    xs (G, T, p)   per-head inputs        (G = B * H panes)
    Bm (G, T, n)   input projections
    Cm (G, T, n)   output projections
    dt (G, T)      softplus'd step sizes
    la (G, T)      log decays (dt * A, negative)

    h_t = exp(la_t) h_{t-1} + dt_t * x_t B_t^T ;  y_t = h_t C_t
    Returns (y (G, T, p), h_final (G, p, n)).
    """
    G, T, p = xs.shape
    n = Bm.shape[-1]

    def pane(x_g, B_g, C_g, dt_g, la_g):
        def step(h, t_in):
            x_t, B_t, C_t, dt_t, la_t = t_in
            h = jnp.exp(la_t) * h + dt_t * jnp.outer(x_t, B_t)
            return h, h @ C_t
        h0 = jnp.zeros((p, n), jnp.float32)
        hf, ys = jax.lax.scan(
            step, h0,
            (x_g.astype(jnp.float32), B_g.astype(jnp.float32),
             C_g.astype(jnp.float32), dt_g.astype(jnp.float32),
             la_g.astype(jnp.float32)))
        return ys, hf

    ys, hf = jax.vmap(pane)(xs, Bm, Cm, dt, la)
    return ys.astype(xs.dtype), hf
