from repro.kernels.ssd_chunk.ops import ssd_core  # noqa: F401
from repro.kernels.ssd_chunk.kernel import ssd_scan  # noqa: F401
from repro.kernels.ssd_chunk.ref import ssd_scan_ref  # noqa: F401
