"""Pallas TPU kernel: Mamba2 SSD chunk scan with VMEM-resident tiles.

The §Perf A analysis showed the pure-JAX chunked SSD is bound by chunk-tile
materialization: every (Q,Q) decay/attention tile and (Q,p) partial takes
an HBM round trip between XLA fusions. This kernel computes a whole chunk
per grid step entirely in VMEM — HBM traffic becomes inputs + outputs only.

Grid: (B*H panes, T/Q chunks), chunk dim sequential ("arbitrary") so the
(p, n) SSM state is carried in VMEM scratch across chunks. Per chunk step
(all on-chip):

    W      = cumsum(la)                       (Q,)   cumulative log decay
    y_int  = (C h^T) * exp(W)[:,None]         inter-chunk term
    G      = C B^T                            (Q,Q)  MXU
    att    = tril(G * exp(W_t - W_s)) * dt_s  (Q,Q)
    y      = y_int + att @ xs                 (Q,p)  MXU
    h'     = exp(W_last) h + ((dt*exp(W_last-W)) * xs)^T B

Per-head layout (p = head_dim, n = state) keeps tiles small: Q=128, p=64,
n=64 -> ~200 KB VMEM per pane, MXU-aligned contractions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xs_ref, B_ref, C_ref, dt_ref, la_ref, y_ref, hout_ref,
                h_ref, *, nc: int, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xs = xs_ref[0].astype(jnp.float32)                  # (Q, p)
    Bm = B_ref[0].astype(jnp.float32)                   # (Q, n)
    Cm = C_ref[0].astype(jnp.float32)                   # (Q, n)
    dt = dt_ref[0].astype(jnp.float32)                  # (Q,)
    la = la_ref[0].astype(jnp.float32)                  # (Q,)

    W = jnp.cumsum(la)                                  # (Q,)
    W_last = W[-1]

    # inter-chunk: y_t += exp(W_t) * (h C_t)
    y_int = jax.lax.dot_general(Cm, h_ref[...],
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (Q,p)
    y_int = y_int * jnp.exp(W)[:, None]

    # intra-chunk: att[t,s] = 1{s<=t} (C_t.B_s) exp(W_t - W_s) dt_s
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)      # (Q,Q)
    Wdiff = W[:, None] - W[None, :]
    tmask = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
             >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    att = jnp.where(tmask, G * jnp.exp(Wdiff), 0.0) * dt[None, :]
    y = y_int + jax.lax.dot_general(att, xs, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    y_ref[0, ...] = y.astype(y_ref.dtype)

    # state update: h' = exp(W_last) h + (xs * src)^T B, src = dt exp(W_last-W)
    src = dt * jnp.exp(W_last - W)                      # (Q,)
    xsrc = xs * src[:, None]                            # (Q, p)
    h_ref[...] = (jnp.exp(W_last) * h_ref[...]
                  + jax.lax.dot_general(xsrc, Bm, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[0, ...] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xs, Bm, Cm, dt, la, *, chunk: int = 128, interpret: bool = True):
    """Pane-parallel SSD scan. Shapes per ref.py: xs (G,T,p), Bm/Cm (G,T,n),
    dt/la (G,T). Returns (y (G,T,p), h_final (G,p,n))."""
    G, T, p = xs.shape
    n = Bm.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk

    kernel = functools.partial(_ssd_kernel, nc=nc, chunk=chunk)
    y, hf = pl.pallas_call(
        kernel,
        grid=(G, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk), lambda g, c: (g, c)),
            pl.BlockSpec((1, chunk), lambda g, c: (g, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, p, n), lambda g, c: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, T, p), xs.dtype),
            jax.ShapeDtypeStruct((G, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xs, Bm, Cm, dt, la)
    return y, hf
