"""Wrapper: run a Mamba2 layer's SSD core through the Pallas kernel.

Used on the inference/prefill path (forward-only; training keeps the
differentiable jnp chunked form in models/mamba2.py — see DESIGN.md §8).
Converts the model's (B, T, H, ...) layout to the kernel's pane layout.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ssd_chunk.kernel import ssd_scan
from repro.kernels.ssd_chunk.ref import ssd_scan_ref


def ssd_core(xs, Bm, Cm, dt, la, *, chunk: int = 128, interpret: bool = True,
             use_kernel: bool = True):
    """xs (B,T,H,p); Bm/Cm (B,T,n) shared across heads (mamba2 ngroups=1);
    dt/la (B,T,H). Returns (y (B,T,H,p), h_final (B,H,p,n))."""
    B, T, H, p = xs.shape
    n = Bm.shape[-1]
    xs_p = xs.transpose(0, 2, 1, 3).reshape(B * H, T, p)
    B_p = jnp.broadcast_to(Bm[:, None], (B, H, T, n)).reshape(B * H, T, n)
    C_p = jnp.broadcast_to(Cm[:, None], (B, H, T, n)).reshape(B * H, T, n)
    dt_p = dt.transpose(0, 2, 1).reshape(B * H, T)
    la_p = la.transpose(0, 2, 1).reshape(B * H, T)
    if use_kernel and T % min(chunk, T) == 0:
        y, hf = ssd_scan(xs_p, B_p, C_p, dt_p, la_p,
                         chunk=chunk, interpret=interpret)
    else:
        y, hf = ssd_scan_ref(xs_p, B_p, C_p, dt_p, la_p)
    y = y.reshape(B, H, T, p).transpose(0, 2, 1, 3)
    return y, hf.reshape(B, H, p, n)
