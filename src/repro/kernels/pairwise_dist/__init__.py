from repro.kernels.pairwise_dist.ops import metric_sqdist_matrix  # noqa: F401
from repro.kernels.pairwise_dist.kernel import pairwise_sqdist  # noqa: F401
from repro.kernels.pairwise_dist.ref import pairwise_sqdist_ref  # noqa: F401
