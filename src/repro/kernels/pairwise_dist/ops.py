"""Public wrapper for the pairwise-distance kernel with padding + fallback."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.pairwise_dist.kernel import pairwise_sqdist
from repro.kernels.pairwise_dist.ref import pairwise_sqdist_ref


def metric_sqdist_matrix(L, x, y, *, interpret: bool = True,
                         use_kernel: bool = True):
    """All-pairs Mahalanobis distances: D[i,j] = ||L(x_i - y_j)||^2.

    Projects through L first (O((N+M) k d)), then runs the tiled kernel on
    the much smaller k-dimensional cross term.
    """
    xp = x.astype(jnp.float32) @ L.astype(jnp.float32).T
    yp = y.astype(jnp.float32) @ L.astype(jnp.float32).T
    N, k = xp.shape
    M = yp.shape[0]
    if not use_kernel or N % 8 or M % 8:
        return pairwise_sqdist_ref(xp, yp)
    bN = 256 if N % 256 == 0 else _largest_tile(N)
    bM = 256 if M % 256 == 0 else _largest_tile(M)
    bC = 512 if k % 512 == 0 else _largest_tile(k)
    return pairwise_sqdist(xp, yp, block_n=bN, block_m=bM, block_c=bC,
                           interpret=interpret)


def _largest_tile(n, cap=512):
    for t in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if t <= cap and n % t == 0:
            return t
    return 1
