"""Pure-jnp oracle for the tiled pairwise-distance kernel."""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_sqdist_ref(xp, yp, yn=None):
    """xp (N,k), yp (M,k) projected points (L @ x). Returns (N,M) f32:
    D[i,j] = ||xp_i - yp_j||^2. ``yn`` optionally supplies precomputed
    ||yp||^2 row norms (the retrieval index amortizes them)."""
    xp = xp.astype(jnp.float32)
    yp = yp.astype(jnp.float32)
    xn = jnp.sum(jnp.square(xp), axis=1)
    if yn is None:
        yn = jnp.sum(jnp.square(yp), axis=1)
    cross = xp @ yp.T
    return jnp.maximum(xn[:, None] + yn[None, :] - 2.0 * cross, 0.0)
