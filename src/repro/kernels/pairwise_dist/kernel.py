"""Pallas TPU kernel: tiled all-pairs squared distances in metric space.

The retrieval/kNN evaluation hot spot (paper §5.4: scoring 200k held-out
pairs, and metric-space retrieval generally): given projected points
``xp = x @ L^T`` (N, k) and ``yp`` (M, k),

    D[i, j] = ||xp_i||^2 + ||yp_j||^2 - 2 xp_i . yp_j

Grid: (N/bN, M/bM, k/bC) — the contraction dim innermost, cross-term
accumulated in VMEM scratch via the MXU; the norm epilogue uses row/col
norms computed in-kernel on the last contraction step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pd_kernel(x_ref, y_ref, o_ref, cross_ref, xn_ref, yn_ref, *, nc: int):
    ci = pl.program_id(2)
    x = x_ref[...].astype(jnp.float32)                  # (bN, bC)
    y = y_ref[...].astype(jnp.float32)                  # (bM, bC)
    part = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)

    @pl.when(ci == 0)
    def _init():
        cross_ref[...] = part
        xn_ref[...] = jnp.sum(jnp.square(x), axis=1)
        yn_ref[...] = jnp.sum(jnp.square(y), axis=1)

    @pl.when(ci > 0)
    def _acc():
        cross_ref[...] += part
        xn_ref[...] += jnp.sum(jnp.square(x), axis=1)
        yn_ref[...] += jnp.sum(jnp.square(y), axis=1)

    @pl.when(ci == nc - 1)
    def _epilogue():
        d = (xn_ref[...][:, None] + yn_ref[...][None, :]
             - 2.0 * cross_ref[...])
        o_ref[...] = jnp.maximum(d, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "block_c",
                                             "interpret"))
def pairwise_sqdist(xp, yp, *, block_n: int = 256, block_m: int = 256,
                    block_c: int = 512, interpret: bool = True):
    """xp (N,k), yp (M,k) -> (N,M) f32 squared distances."""
    N, k = xp.shape
    M = yp.shape[0]
    bN, bM, bC = min(block_n, N), min(block_m, M), min(block_c, k)
    assert N % bN == 0 and M % bM == 0 and k % bC == 0, (N, M, k, bN, bM, bC)
    nc = k // bC

    kernel = functools.partial(_pd_kernel, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(N // bN, M // bM, nc),
        in_specs=[
            pl.BlockSpec((bN, bC), lambda i, j, c: (i, c)),
            pl.BlockSpec((bM, bC), lambda i, j, c: (j, c)),
        ],
        out_specs=pl.BlockSpec((bN, bM), lambda i, j, c: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, M), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bN, bM), jnp.float32),
            pltpu.VMEM((bN,), jnp.float32),
            pltpu.VMEM((bM,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, yp)
