"""Pallas TPU kernel: fused DML pair loss (paper Eq. 4 inner loop).

Computes, in one pass over VMEM tiles of ``L`` (k x d):

    z      = xs - ys                       (fused subtraction, never stored)
    proj   = z @ L^T                       (MXU, accumulated over d tiles)
    d2     = sum(proj^2, axis=k)           (accumulated over k tiles)
    loss   = sim ? d2 : lam * max(0, margin - d2)

Grid: (pairs/bB, k/bK, d/bD) — ``d`` innermost so each (pair, k) tile's
matmul accumulator lives in a VMEM scratch across d steps; ``k`` next so the
per-pair squared-distance accumulator survives across k tiles; the hinge
epilogue fires on the last (k, d) step. TPU-friendly tile defaults are
multiples of the 128-lane MXU; the d-tile (bD) bounds the VMEM working set
(bK x bD weights + bB x bD pair data).

The projection (B, k) is also written out — the backward pass (ops.py) is
two plain matmuls on it, which XLA already schedules optimally.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dml_pair_kernel(sim_ref, xs_ref, ys_ref, L_ref,
                     loss_ref, d2_ref, proj_ref,
                     acc_ref, *, lam: float, margin: float,
                     nk: int, nd: int):
    """One (pair-tile, k-tile, d-tile) grid step."""
    ki = pl.program_id(1)
    di = pl.program_id(2)

    # fused z = xs - ys on the current (bB, bD) tile, f32 accumulate
    z = (xs_ref[...] - ys_ref[...]).astype(jnp.float32)
    part = jax.lax.dot_general(
        z, L_ref[...].astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # (bB, bK)

    @pl.when(di == 0)
    def _init_acc():
        acc_ref[...] = part

    @pl.when(di > 0)
    def _accum():
        acc_ref[...] += part

    @pl.when(di == nd - 1)
    def _k_epilogue():
        proj = acc_ref[...]
        proj_ref[...] = proj.astype(proj_ref.dtype)
        sq = jnp.sum(jnp.square(proj), axis=1)          # (bB,)

        @pl.when(ki == 0)
        def _init_d2():
            d2_ref[...] = sq

        @pl.when(ki > 0)
        def _acc_d2():
            d2_ref[...] += sq

        @pl.when(ki == nk - 1)
        def _loss_epilogue():
            d2 = d2_ref[...]
            simf = sim_ref[...].astype(jnp.float32)
            hinge = jnp.maximum(0.0, margin - d2)
            loss_ref[...] = simf * d2 + (1.0 - simf) * lam * hinge


@functools.partial(jax.jit, static_argnames=("lam", "margin", "block_b",
                                             "block_k", "block_d",
                                             "interpret"))
def dml_pair_fused(L, xs, ys, sim, *, lam: float = 1.0, margin: float = 1.0,
                   block_b: int = 256, block_k: int = 128, block_d: int = 512,
                   interpret: bool = True):
    """Fused forward. Returns (losses (B,), d2 (B,), proj (B,k)).

    Shapes must tile evenly (ops.py pads otherwise): B % block_b == 0,
    k % block_k == 0, d % block_d == 0.
    """
    k, d = L.shape
    B = xs.shape[0]
    bB, bK, bD = min(block_b, B), min(block_k, k), min(block_d, d)
    assert B % bB == 0 and k % bK == 0 and d % bD == 0, (B, k, d, bB, bK, bD)
    nb, nk, nd = B // bB, k // bK, d // bD

    kernel = functools.partial(_dml_pair_kernel, lam=lam, margin=margin,
                               nk=nk, nd=nd)
    return pl.pallas_call(
        kernel,
        grid=(nb, nk, nd),
        in_specs=[
            pl.BlockSpec((bB,), lambda b, ki, di: (b,)),            # sim
            pl.BlockSpec((bB, bD), lambda b, ki, di: (b, di)),      # xs
            pl.BlockSpec((bB, bD), lambda b, ki, di: (b, di)),      # ys
            pl.BlockSpec((bK, bD), lambda b, ki, di: (ki, di)),     # L
        ],
        out_specs=[
            pl.BlockSpec((bB,), lambda b, ki, di: (b,)),            # loss
            pl.BlockSpec((bB,), lambda b, ki, di: (b,)),            # d2
            pl.BlockSpec((bB, bK), lambda b, ki, di: (b, ki)),      # proj
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bB, bK), jnp.float32)],
        interpret=interpret,
    )(sim, xs, ys, L)
