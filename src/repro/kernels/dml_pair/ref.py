"""Pure-jnp oracle for the fused DML pair kernel (paper Eq. 4 hot spot)."""

from __future__ import annotations

import jax.numpy as jnp


def dml_pair_ref(L, xs, ys, sim, lam: float = 1.0, margin: float = 1.0):
    """Returns (losses (B,), sqdists (B,), proj (B, k)).

    losses[b] = sim_b * d2_b + (1-sim_b) * lam * max(0, margin - d2_b)
    where d2_b = ||L (xs_b - ys_b)||^2 computed in f32.
    """
    z = (xs - ys).astype(jnp.float32)
    proj = z @ L.astype(jnp.float32).T                  # (B, k)
    d2 = jnp.sum(jnp.square(proj), axis=-1)             # (B,)
    simf = sim.astype(jnp.float32)
    hinge = jnp.maximum(0.0, margin - d2)
    losses = simf * d2 + (1.0 - simf) * lam * hinge
    return losses, d2, proj
