from repro.kernels.dml_pair.ops import (  # noqa: F401
    dml_pair_loss_fused, dml_pair_loss_reference,
)
from repro.kernels.dml_pair.kernel import dml_pair_fused  # noqa: F401
from repro.kernels.dml_pair.ref import dml_pair_ref  # noqa: F401
