"""Jitted public wrapper for the fused DML pair kernel, with custom VJP.

Forward: the Pallas kernel (fused z / matmul / sumsq / hinge).
Backward: closed-form gradients — two dense matmuls on the saved projection
(XLA-optimal; no kernel needed):

    w_b    = sim_b - lam * (1 - sim_b) * 1{d2_b < margin}   (hinge weight)
    dL     = 2/B * (proj * w)^T @ z * g
    dz     = 2/B * w * (proj @ L) * g ;  dxs = dz, dys = -dz
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dml_pair.kernel import dml_pair_fused
from repro.kernels.dml_pair.ref import dml_pair_ref


def _pad_to(x, mult, axis):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def dml_pair_loss_fused(L, xs, ys, sim, lam: float = 1.0, margin: float = 1.0,
                        interpret: bool = True):
    """Mean Eq. 4 objective via the Pallas kernel. Differentiable w.r.t.
    L, xs, ys (the latter two enable end-to-end deep metric learning)."""
    losses = _forward(L, xs, ys, sim, lam, margin, interpret)[0]
    return jnp.mean(losses)


def _forward(L, xs, ys, sim, lam, margin, interpret):
    k, d = L.shape
    B = xs.shape[0]
    # pad to tile boundaries (sim=1, x=y=0 padding contributes zero loss)
    bB = 256 if B >= 256 else max(8, B)
    bK = 128 if k >= 128 else k
    bD = 512 if d >= 512 else d
    Lp, _ = _pad_to(L, bK, 0)
    Lp, _ = _pad_to(Lp, bD, 1)
    xsp, _ = _pad_to(xs, bD, 1)
    ysp, _ = _pad_to(ys, bD, 1)
    xsp, _ = _pad_to(xsp, bB, 0)
    ysp, _ = _pad_to(ysp, bB, 0)
    simp = jnp.pad(sim, (0, (-B) % bB), constant_values=1)
    losses, d2, proj = dml_pair_fused(
        Lp, xsp, ysp, simp, lam=lam, margin=margin,
        block_b=bB, block_k=bK, block_d=bD, interpret=interpret)
    return losses[:B], d2[:B], proj[:B, :k]


def _fwd(L, xs, ys, sim, lam, margin, interpret):
    losses, d2, proj = _forward(L, xs, ys, sim, lam, margin, interpret)
    return jnp.mean(losses), (L, xs, ys, sim, d2, proj)


def _bwd(lam, margin, interpret, res, g):
    L, xs, ys, sim, d2, proj = res
    B = xs.shape[0]
    simf = sim.astype(jnp.float32)
    active = (d2 < margin).astype(jnp.float32)
    w = simf - lam * (1.0 - simf) * active              # (B,)
    z = (xs - ys).astype(jnp.float32)
    scale = 2.0 * g / B
    pw = proj * w[:, None]                              # (B,k)
    dL = scale * pw.T @ z                               # (k,d)
    dz = scale * (pw @ L.astype(jnp.float32))           # (B,d)
    return (dL.astype(L.dtype), dz.astype(xs.dtype), (-dz).astype(ys.dtype),
            None)


dml_pair_loss_fused.defvjp(_fwd, _bwd)


def dml_pair_loss_reference(L, xs, ys, sim, lam: float = 1.0,
                            margin: float = 1.0):
    """Oracle mean objective (pure jnp) for tests and CPU execution."""
    losses, _, _ = dml_pair_ref(L, xs, ys, sim, lam, margin)
    return jnp.mean(losses)
