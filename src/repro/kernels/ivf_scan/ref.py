"""Pure-XLA oracle for the fused IVF full-precision segment scan.

The semantics serve/ivf.py's probed scan and the Pallas kernel
(kernel.py) both implement: gather each query's probed full-precision
segments, score them with the factored squared distance

    d = max(||qp||² + gn - 2 <qp, gp_row>, 0)

and keep the kk best (distance, id) candidates. Candidates flatten
probe-major / slot-minor — the order the kernel streams tiles in — so
position-order tie-breaks agree. Unlike pq_adc, the contraction over k
is a real reduction (XLA einsum vs MXU dot tree orders can differ), so
the kernel contract here is indices-equal / distances-allclose, not
bitwise (tests/test_scan_kernels.py pins exactly that).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels._dispatch import topk_by_distance


def ivf_scan_topk_ref(qp, probes, g, gn, ids, kk: int):
    """Score the probed segments of each query and keep the top kk.

    Args:
      qp: (Nq, k) projected queries.
      probes: (Nq, nprobe) int32 probed cluster ids (``mode="clip"`` on
        the gather, so an out-of-range sentinel cluster — the sharded
        path's all-pad slot C_loc — reads the last real segment safely
        only when callers append one; in-range ids are unaffected).
      g: (C, cap, k) segment rows (0 on pad slots).
      gn: (C, cap) row norms (+BIG on pad slots).
      ids: (C, cap) int32 global row ids (-1 on pad slots).
      kk: candidates kept per query (<= nprobe * cap).

    Returns (dists (Nq, kk) f32 ascending, ids (Nq, kk) int32), sorted
    lexicographically by (distance, id); -1 ids mark under-filled
    probes.
    """
    gg = jnp.take(g, probes, axis=0, mode="clip")    # (Nq, np, cap, k)
    gng = jnp.take(gn, probes, axis=0, mode="clip")  # (Nq, np, cap)
    idg = jnp.take(ids, probes, axis=0, mode="clip")
    qn = jnp.sum(jnp.square(qp), axis=1)
    cross = jnp.einsum("qpck,qk->qpc", gg, qp)
    d = jnp.maximum(qn[:, None, None] + gng - 2.0 * cross, 0.0)
    Nq = qp.shape[0]
    return topk_by_distance(d.reshape(Nq, -1), idg.reshape(Nq, -1), kk)
