"""Pallas TPU kernel: fused IVF segment gather + factored distance + top-k.

The IVF serving hot loop (serve/ivf.py): per query, gather the
full-precision rows of its ``nprobe`` probed segments, score them with
the factored squared distance, and stream-merge a running top-kk —
without materializing the (block_q, nprobe, cap, k) segment gather the
XLA path pays for in HBM.

Same skeleton as kernels/pq_adc: grid (Nq, nprobe * nsteps), one query
per program row, probe/tile stream innermost, probe list as a
scalar-prefetch operand so the gp/gn/id block index maps DMA the right
(bM, k) segment tile per step, running (1, kk) best buffers in VMEM
scratch, best-index init -1 (BIG-sentinel survivors must look like real
pad candidates; ops.py masks and re-sorts). The only body difference is
the score: an MXU dot of the (1, k) query row against the (bM, k) tile
replaces the one-hot LUT accumulate — which also means the contraction
over k is a genuine reduction, so distances match the XLA reference to
rounding, not bitwise (pq_adc's per-term-exact trick has no analogue
here; metric_topk has the same property).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.metric_topk.kernel import BIG, _merge_topk


def _ivf_scan_kernel(probes_ref, qp_ref, g_ref, gn_ref, ids_ref,
                     od_ref, oi_ref, bd_ref, bi_ref, *, kk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _reset():
        bd_ref[...] = jnp.full(bd_ref.shape, BIG, jnp.float32)
        bi_ref[...] = jnp.full(bi_ref.shape, -1, jnp.int32)

    qp = qp_ref[...]                                     # (1, k)
    qn = jnp.sum(jnp.square(qp), axis=1)                 # (1,)
    cross = jax.lax.dot_general(                         # (1, bM)
        qp, g_ref[...],
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d = jnp.maximum(qn[:, None] + gn_ref[...][None, :] - 2.0 * cross, 0.0)

    bd, bi = _merge_topk(bd_ref[...], bi_ref[...], d,
                         ids_ref[...][None, :], kk)
    bd_ref[...] = bd
    bi_ref[...] = bi

    @pl.when(j == pl.num_programs(1) - 1)
    def _epilogue():
        od_ref[...] = bd_ref[...]
        oi_ref[...] = bi_ref[...]


@functools.partial(jax.jit, static_argnames=("cap", "kk", "block_m",
                                             "interpret"))
def ivf_scan_topk_fused(probes, qp, g, gn, ids, *, cap: int, kk: int,
                        block_m: int, interpret: bool = True):
    """Fused probed-segment scan + streaming top-k.

    Args:
      probes: (Nq, nprobe) int32 probed cluster ids (scalar-prefetch).
      qp: (Nq, k) projected queries, k lane-padded with zeros.
      g: (C*cap, k) segment rows (lane-padded to match qp);
        gn: (C*cap,) row norms (+BIG pads); ids: (C*cap,) int32 ids
        (-1 pads).
      cap: rows per segment; block_m: rows per tile, must divide cap.

    Returns (dists (Nq, kk) f32, ids (Nq, kk) int32) in streaming-merge
    order; ids at the BIG sentinel may repeat a knocked-out winner —
    ops.py masks them to -1 before the final sort.
    """
    Nq, nprobe = probes.shape
    rows, k = g.shape
    bM = block_m
    assert cap % bM == 0 and rows % cap == 0, (rows, cap, bM)
    assert kk <= nprobe * cap, (kk, nprobe, cap)
    nsteps = cap // bM          # tiles per probed segment

    def seg_row(q, j, pr):      # flat tile index of stream step j
        return pr[q, j // nsteps] * nsteps + j % nsteps

    kernel = functools.partial(_ivf_scan_kernel, kk=kk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Nq, nprobe * nsteps),
        in_specs=[
            pl.BlockSpec((1, k), lambda q, j, pr: (q, 0)),   # qp row
            pl.BlockSpec((bM, k),
                         lambda q, j, pr: (seg_row(q, j, pr), 0)),
            pl.BlockSpec((bM,),
                         lambda q, j, pr: (seg_row(q, j, pr),)),
            pl.BlockSpec((bM,),
                         lambda q, j, pr: (seg_row(q, j, pr),)),
        ],
        out_specs=[
            pl.BlockSpec((1, kk), lambda q, j, pr: (q, 0)),
            pl.BlockSpec((1, kk), lambda q, j, pr: (q, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, kk), jnp.float32),   # running best distances
            pltpu.VMEM((1, kk), jnp.int32),     # running best ids
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Nq, kk), jnp.float32),
            jax.ShapeDtypeStruct((Nq, kk), jnp.int32),
        ],
        interpret=interpret,
    )(probes, qp, g, gn, ids)
