"""Fused IVF segment scan: probed gather + factored distance + top-k.

Kernel/ops/ref contract (docs/kernels.md): ``ops.ivf_scan_topk`` is the
public dispatcher; ``kernel.ivf_scan_topk_fused`` the raw Pallas call;
``ref.ivf_scan_topk_ref`` the XLA oracle serve/ivf.py scans with.
"""

from repro.kernels.ivf_scan.kernel import ivf_scan_topk_fused
from repro.kernels.ivf_scan.ops import ivf_scan_topk
from repro.kernels.ivf_scan.ref import ivf_scan_topk_ref

__all__ = ["ivf_scan_topk", "ivf_scan_topk_fused", "ivf_scan_topk_ref"]
