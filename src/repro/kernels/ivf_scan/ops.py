"""Public wrapper for the fused IVF segment scan: validation + dispatch.

``ivf_scan_topk`` is the one entry point serve/ivf.py calls — both for
the single-device query path and (with ``use_kernel=False``) as the
per-shard body inside the sharded shard_map, which is why the XLA
fallback must stay a pure jnp function of its inputs. Chores owned
here, mirroring kernels/pq_adc/ops.py:

  * validation (kk >= 1 and within the probed candidate pool);
  * XLA fallback: the ref oracle chunked over ``block_q`` query rows
    (lax.map keeps the gathered (block_q, nprobe, cap, k) intermediate
    cache-sized — the chunking serve/ivf.py always used);
  * kernel dispatch: lane-pad the projected dim, flatten segments,
    pick a tile dividing cap, run the fused kernel, mask BIG-sentinel
    survivors to id -1, and apply the final (distance, id) sort.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels._dispatch import (LANE, default_interpret,
                                     map_query_chunks, pad_axis, round_up,
                                     segment_block)
from repro.kernels.metric_topk.kernel import BIG
from repro.kernels.ivf_scan.kernel import ivf_scan_topk_fused
from repro.kernels.ivf_scan.ref import ivf_scan_topk_ref


def ivf_scan_topk(qp, probes, g, gn, ids, *, kk: int, block_q: int = 16,
                  block_m: int = 512, use_kernel: bool = True,
                  interpret=None):
    """Top-kk candidates per query from its probed segments.

    Args:
      qp: (Nq, k) projected queries.
      probes: (Nq, nprobe) int32 probed cluster ids.
      g: (C, cap, k) segment rows; gn: (C, cap) norms (+BIG pads);
        ids: (C, cap) int32 row ids (-1 pads) — the IVF segment layout.
      kk: candidates kept per query (1 <= kk <= nprobe * cap).
      block_q: XLA-path query chunk (lax.map granularity).
      block_m: kernel-path tile rows (rounded to a divisor of cap).
      use_kernel: False routes to the chunked XLA reference (also the
        per-shard body of the sharded path).
      interpret: None compiles on TPU / interprets elsewhere; bool
        forces.

    Returns (dists (Nq, kk) f32 ascending, ids (Nq, kk) int32), sorted
    lexicographically by (distance, id); -1 ids mark under-filled
    probes. Kernel and XLA paths agree on ids exactly and on distances
    to f32 rounding (the k-contraction tree differs — see kernel.py).
    """
    C, cap, k = g.shape
    nprobe = probes.shape[1]
    if kk < 1:
        raise ValueError(f"kk must be >= 1, got {kk}")
    if kk > nprobe * cap:
        raise ValueError(f"kk={kk} > nprobe*cap={nprobe * cap} scanned "
                         f"rows per query")
    if not use_kernel:
        return map_query_chunks(
            lambda q, pr: ivf_scan_topk_ref(q, pr, g, gn, ids, kk),
            (qp, probes), block_q)

    kP = round_up(k, LANE)      # zero pad columns are distance-neutral
    qp_pad = pad_axis(qp.astype(jnp.float32), kP, 1)
    g_pad = pad_axis(g.reshape(C * cap, k).astype(jnp.float32), kP, 1)
    bM = segment_block(cap, block_m)
    d, i = ivf_scan_topk_fused(
        probes.astype(jnp.int32), qp_pad, g_pad, gn.reshape(C * cap),
        ids.reshape(C * cap), cap=cap, kk=kk, block_m=bM,
        interpret=default_interpret(interpret))
    # BIG-sentinel survivors are pad slots; the streaming merge may have
    # parked a knocked-out winner's id there — the reference reports -1
    i = jnp.where(d >= BIG, -1, i)
    return jax.lax.sort((d, i), dimension=-1, num_keys=2)
