"""Pallas TPU kernel: fused metric-space top-k retrieval (query side).

The serving hot path: given raw queries ``q`` (Nq, d), the learned metric
factor ``L`` (k, d), and a gallery that was pre-projected **once** at index
build time (``gp = G @ L^T`` (M, k), ``gn = ||gp||^2`` (M,)), compute per
query the k_top nearest gallery rows under the Mahalanobis metric
``M = L^T L`` — in one pass, without ever materializing the (Nq, M)
distance matrix in HBM:

    qp       = q @ L^T                       (MXU, once per query tile,
                                              kept in VMEM scratch)
    D[:, j]  = ||qp||^2 + gn_j - 2 qp . gp_j (per (bQ, bM) gallery tile)
    best     = stream-merge(best, D tile)    (running top-k in VMEM)

Grid: (Nq/bQ, M/bM) — gallery innermost, so the projected-query tile and the
running (bQ, k_top) best-distance/best-index buffers live in VMEM scratch
across the whole gallery sweep; outputs are written on the last gallery
step. The merge is k_top rounds of (min, argmin, one-hot mask) over the
(bQ, k_top + bM) candidate row — pure VPU ops, no sort network — which is
cheap because k_top << bM.

Tie-breaking matches ``jax.lax.top_k``: equal distances resolve to the
smaller gallery index (earlier tiles sit first in the candidate row; within
a tile the index iota ascends; argmin takes the first minimum).

ops.py pads d/k to 128-lane multiples and gallery rows to the tile with
``gn = +BIG`` sentinels, so padded rows can never enter the top-k.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Sentinel "infinite" distance for padded gallery rows / best-buffer init.
# Large enough to lose to any real squared distance, small enough that
# qn + BIG stays finite in float32.
BIG = 1e30


def _merge_topk(bd, bi, d, gidx, k_top: int):
    """Stream-merge a distance tile into the running top-k.

    bd (bQ, k_top) f32 ascending, bi (bQ, k_top) i32, d (bQ, bM) f32,
    gidx (bQ, bM) i32 global gallery indices. Returns new (bd, bi).
    """
    cd = jnp.concatenate([bd, d], axis=1)               # (bQ, k_top + bM)
    ci = jnp.concatenate([bi, gidx], axis=1)
    pos_iota = jax.lax.broadcasted_iota(jnp.int32, cd.shape, 1)
    new_d, new_i = [], []
    for _ in range(k_top):
        m = jnp.min(cd, axis=1)                         # (bQ,)
        pos = jnp.argmin(cd, axis=1).astype(jnp.int32)  # first min = low idx
        hit = pos_iota == pos[:, None]                  # (bQ, k_top + bM)
        new_d.append(m)
        new_i.append(jnp.sum(jnp.where(hit, ci, 0), axis=1))
        cd = jnp.where(hit, BIG, cd)                    # knock out the winner
    return jnp.stack(new_d, axis=1), jnp.stack(new_i, axis=1)


def _metric_topk_kernel(q_ref, L_ref, gp_ref, gn_ref,
                        od_ref, oi_ref,
                        qp_ref, bd_ref, bi_ref,
                        *, k_top: int, nm: int, block_m: int):
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _project_and_reset():
        # query projection fused into the same pass — computed once per
        # query tile, reused for every gallery tile from VMEM
        qp_ref[...] = jax.lax.dot_general(
            q_ref[...].astype(jnp.float32), L_ref[...].astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        bd_ref[...] = jnp.full(bd_ref.shape, BIG, jnp.float32)
        bi_ref[...] = jnp.zeros(bi_ref.shape, jnp.int32)

    qp = qp_ref[...]                                     # (bQ, k)
    qn = jnp.sum(jnp.square(qp), axis=1)                 # (bQ,)
    cross = jax.lax.dot_general(
        qp, gp_ref[...].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d = qn[:, None] + gn_ref[...][None, :] - 2.0 * cross
    d = jnp.maximum(d, 0.0)                              # (bQ, bM)
    gidx = (mi * block_m
            + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1))

    bd, bi = _merge_topk(bd_ref[...], bi_ref[...], d, gidx, k_top)
    bd_ref[...] = bd
    bi_ref[...] = bi

    @pl.when(mi == nm - 1)
    def _epilogue():
        od_ref[...] = bd_ref[...]
        oi_ref[...] = bi_ref[...]


@functools.partial(jax.jit, static_argnames=("k_top", "block_q", "block_m",
                                             "interpret"))
def metric_topk_fused(q, L, gp, gn, *, k_top: int = 10,
                      block_q: int = 128, block_m: int = 512,
                      interpret: bool = True):
    """Fused project + distance + streaming top-k.

    Args:
      q:  (Nq, d) raw queries.
      L:  (k, d) metric factor (held whole in VMEM — serving-sized k*d).
      gp: (M, k) pre-projected gallery rows.
      gn: (M,) squared norms of gp rows (+BIG for padded rows).

    Shapes must tile evenly (ops.py pads otherwise): Nq % block_q == 0 and
    M % block_m == 0. Returns (dists (Nq, k_top) f32 ascending,
    indices (Nq, k_top) int32).
    """
    Nq, d = q.shape
    M, k = gp.shape
    bQ, bM = min(block_q, Nq), min(block_m, M)
    assert Nq % bQ == 0 and M % bM == 0, (Nq, M, bQ, bM)
    assert k_top <= M, (k_top, M)
    nm = M // bM

    kernel = functools.partial(_metric_topk_kernel, k_top=k_top, nm=nm,
                               block_m=bM)
    return pl.pallas_call(
        kernel,
        grid=(Nq // bQ, nm),
        in_specs=[
            pl.BlockSpec((bQ, d), lambda i, j: (i, 0)),     # q
            pl.BlockSpec((k, d), lambda i, j: (0, 0)),      # L (whole)
            pl.BlockSpec((bM, k), lambda i, j: (j, 0)),     # gp
            pl.BlockSpec((bM,), lambda i, j: (j,)),         # gn
        ],
        out_specs=[
            pl.BlockSpec((bQ, k_top), lambda i, j: (i, 0)),
            pl.BlockSpec((bQ, k_top), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Nq, k_top), jnp.float32),
            jax.ShapeDtypeStruct((Nq, k_top), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bQ, k), jnp.float32),       # projected query tile
            pltpu.VMEM((bQ, k_top), jnp.float32),   # running best distances
            pltpu.VMEM((bQ, k_top), jnp.int32),     # running best indices
        ],
        interpret=interpret,
    )(q, L, gp, gn)
