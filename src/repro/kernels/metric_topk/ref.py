"""Pure-jnp oracles for the fused metric top-k retrieval kernel.

Two references at different altitudes:

  * ``metric_topk_ref``   — factored-form distances on *projected* points +
    ``jax.lax.top_k``. Tight oracle for kernel.py (same math, same
    tie-breaking: smaller gallery index wins on equal distance).
  * ``metric_topk_naive`` — the textbook per-pair Mahalanobis retrieval
    baseline: apply ``L`` to every (query - gallery) difference. O(Nq*M*d*k)
    FLOPs vs the index's O((Nq+M)*d*k + Nq*M*k) — this is the cost the
    pre-projected gallery amortizes away (Qian et al. 2015's motivation for
    low-rank L), and the "pure-XLA reference" benchmarks/retrieval_qps.py
    measures against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pairwise_dist.ref import pairwise_sqdist_ref


def metric_sqdist_factored(qp, gp, gn=None):
    """Squared distances between projected queries (Nq,k) and projected
    gallery (M,k): D[i,j] = ||qp_i||^2 + ||gp_j||^2 - 2 qp_i . gp_j >= 0.
    One shared oracle with the pairwise_dist kernel (gn = amortized
    gallery norms)."""
    return pairwise_sqdist_ref(qp, gp, gn)


def metric_topk_ref(qp, gp, k_top: int, gn=None):
    """Top-k nearest gallery rows per projected query.

    Returns (dists (Nq, k_top) f32 ascending, indices (Nq, k_top) int32).
    Ties broken toward the smaller gallery index (lax.top_k semantics).
    """
    d = metric_sqdist_factored(qp, gp, gn)
    neg, idx = jax.lax.top_k(-d, k_top)
    return -neg, idx.astype(jnp.int32)


def metric_topk_naive(L, queries, gallery, k_top: int, chunk: int = 4):
    """Unamortized baseline: project each (query - gallery point) difference
    through L, per pair, chunked over queries to bound the (c, M, d) diff
    tensor. Semantically identical to metric_topk_ref on projected inputs."""
    L = L.astype(jnp.float32)
    queries = queries.astype(jnp.float32)
    gallery = gallery.astype(jnp.float32)
    dists, idxs = [], []
    for s in range(0, queries.shape[0], chunk):
        q = queries[s:s + chunk]                     # (c, d)
        z = q[:, None, :] - gallery[None, :, :]      # (c, M, d)
        proj = jnp.einsum("cmd,kd->cmk", z, L)       # per-pair metric apply
        d = jnp.sum(jnp.square(proj), axis=-1)       # (c, M)
        neg, idx = jax.lax.top_k(-d, k_top)
        dists.append(-neg)
        idxs.append(idx.astype(jnp.int32))
    return jnp.concatenate(dists, axis=0), jnp.concatenate(idxs, axis=0)
