"""Public wrappers for the fused metric top-k kernel: padding + fallback.

The serving contract (serve/index.py builds on this):

  * ``project_gallery``  — the once-per-index amortization: gp = G @ L^T and
    its row norms. Everything at query time is O(k)-dimensional.
  * ``metric_topk``      — padded dispatch into the Pallas kernel
    (kernel.py); ``use_kernel=False`` routes to the factored XLA path
    instead (there is no automatic shape-based fallback — padding makes
    every shape kernel-tileable).
  * ``metric_topk_xla``  — the factored pure-XLA fast path (also the
    per-shard body inside serve/index.py's shard_map).

Padding rules: feature dim d and projection dim k pad with zeros to
128-lane multiples (zero columns change no distance); query rows pad to the
query tile (outputs sliced back); gallery rows pad to the gallery tile with
``gn = +BIG`` sentinels so they can never enter the top-k.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._dispatch import (LANE, SUBLANE, check_metric_factor,
                                     default_interpret, pad_axis,
                                     pick_block, round_up)
from repro.kernels.metric_topk.kernel import BIG, metric_topk_fused
from repro.kernels.metric_topk.ref import metric_topk_ref


def project_gallery(L, gallery):
    """Pre-project the gallery once: returns (gp (M,k) f32, gn (M,) f32).

    This is the index-build step that amortizes the learned metric — after
    it, no query ever touches the d-dimensional space again. ``L`` is
    (d_out, d_in) — square or rectangular — and gp is sized d_out.
    """
    check_metric_factor(L, jnp.shape(gallery)[-1])
    gp = gallery.astype(jnp.float32) @ L.astype(jnp.float32).T
    gn = jnp.sum(jnp.square(gp), axis=1)
    return gp, gn


@functools.partial(jax.jit, static_argnames=("k_top",))
def metric_topk_xla(L, queries, gp, gn, k_top: int):
    """Factored XLA path: project queries, reuse precomputed gallery norms,
    lax.top_k. Production path on hosts without a Pallas backend."""
    qp = queries.astype(jnp.float32) @ L.astype(jnp.float32).T
    return metric_topk_ref(qp, gp, k_top, gn)


def metric_topk(L, queries, gp, gn=None, *, k_top: int = 10,
                block_q: int = 128, block_m: int = 512,
                use_kernel: bool = True, interpret=None):
    """Top-k gallery neighbors of raw queries under the metric L^T L.

    Args:
      L: (d_out, d_in) metric factor — square or rectangular (low rank).
      queries: (Nq, d_in) raw queries.
      gp: (M, d_out) pre-projected gallery (see project_gallery).
      gn: optional (M,) precomputed gp row norms.
      interpret: None (default) compiles the kernel on TPU and interprets
        elsewhere; pass a bool to force.

    Returns (dists (Nq, k_top) f32 ascending, indices (Nq, k_top) int32).
    """
    interpret = default_interpret(interpret)
    Nq, d = queries.shape
    check_metric_factor(L, d)
    M, k = gp.shape
    if k_top > M:
        raise ValueError(f"k_top={k_top} > gallery size M={M}")
    if gn is None:
        gn = jnp.sum(jnp.square(gp.astype(jnp.float32)), axis=1)
    if not use_kernel:
        return metric_topk_xla(L, queries, gp, gn, k_top)

    # lane-align the contracted dims (zero pads are distance-neutral)
    dP, kP = round_up(d, LANE), round_up(k, LANE)
    qpad = pad_axis(queries.astype(jnp.float32), dP, 1)
    Lpad = pad_axis(pad_axis(L.astype(jnp.float32), dP, 1), kP, 0)
    gpad = pad_axis(gp.astype(jnp.float32), kP, 1)

    # row tiles: queries sliced back after, gallery padded with BIG norms
    bQ = pick_block(Nq, block_q, SUBLANE)
    bM = pick_block(M, block_m, LANE)
    qpad = pad_axis(qpad, round_up(Nq, bQ), 0)
    gpad = pad_axis(gpad, round_up(M, bM), 0)
    gnpad = pad_axis(gn.astype(jnp.float32), round_up(M, bM), 0, value=BIG)

    dists, idxs = metric_topk_fused(qpad, Lpad, gpad, gnpad, k_top=k_top,
                                    block_q=bQ, block_m=bM,
                                    interpret=interpret)
    return dists[:Nq], idxs[:Nq]
