from repro.kernels.metric_topk.ops import (  # noqa: F401
    metric_topk, metric_topk_xla, project_gallery,
)
from repro.kernels.metric_topk.kernel import metric_topk_fused  # noqa: F401
from repro.kernels.metric_topk.ref import (  # noqa: F401
    metric_sqdist_factored, metric_topk_naive, metric_topk_ref,
)
