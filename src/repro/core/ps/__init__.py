from repro.core.ps.sync import (  # noqa: F401
    PSConfig, PSState, make_worker_mesh, init_state, make_train_step,
    replicate_for_workers, worker_mean,
)
from repro.core.ps import simulator, trainer  # noqa: F401
