"""Parameter-server synchronization strategies, mapped to TPU/JAX SPMD.

The paper's system (§4): P workers each hold a local copy ``L_p`` of the
metric; a central server aggregates gradient pushes and broadcasts fresh
parameters; threads run best-effort (fully asynchronous). On a TPU mesh there
is no asynchronous message bus — instead we express the *consistency models*
the PS literature compares (paper §2) as deterministic SPMD programs over a
``workers`` mesh axis:

  * ``bsp``   — Bulk-Synchronous Parallel: gradients are all-reduced (pmean)
                every step; all ``L_p`` stay bit-identical. This is the
                Hadoop/Spark strawman the paper argues against.
  * ``local`` — Local SGD: each worker takes ``tau`` local steps between
                parameter all-reduces. tau plays the role of the *average
                staleness* of the paper's asynchronous PS: compute never
                blocks on communication; copies drift and are re-merged.
  * ``ssp``   — Stale Synchronous Parallel (Ho et al. 2013): every step the
                global mean gradient is computed, but each worker applies a
                randomly *delayed* copy of it (delay <= s drawn from a
                deterministic per-worker PRNG), via an s-slot ring buffer;
                every ``s`` steps parameters are forcibly re-averaged so the
                divergence stays bounded — the SSP bound, in SPMD form.

The per-worker parameter copies are materialized as a leading ``(P, ...)``
axis sharded over the worker mesh axis — i.e. worker p's shard *is* its local
copy. The "central server" is the all-reduce epilogue plus an optional
server-side optimizer applied to aggregated updates.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim import Optimizer, apply_updates
from repro.sharding.partition import shard_map


@dataclasses.dataclass(frozen=True)
class PSConfig:
    n_workers: int
    sync: str = "bsp"        # bsp | local | ssp
    tau: int = 1             # local-SGD sync period (sync="local")
    staleness: int = 0       # SSP bound s (sync="ssp")
    axis: str = "workers"    # mesh axis name that indexes workers
    seed: int = 0

    def __post_init__(self):
        if self.sync not in ("bsp", "local", "ssp"):
            raise ValueError(f"unknown sync mode {self.sync!r}")
        if self.sync == "ssp" and self.staleness < 1:
            raise ValueError("ssp requires staleness >= 1")
        if self.sync == "local" and self.tau < 1:
            raise ValueError("local requires tau >= 1")


class PSState(NamedTuple):
    params: Any        # (P, ...) worker-stacked parameter copies
    opt_state: Any     # (P, ...) worker-stacked optimizer states
    step: jax.Array    # scalar, replicated
    grad_ring: Any     # (P, s, ...) delayed-gradient ring buffer (ssp) or None
    rng: jax.Array     # scalar PRNG key, replicated


def make_worker_mesh(n_workers: int, axis: str = "workers") -> Mesh:
    """1-D mesh over the first n_workers local devices (laptop-scale tests).

    Production runs instead pass the pod mesh and use its data axis.
    """
    devs = np.array(jax.devices()[:n_workers])
    return Mesh(devs, (axis,))


def replicate_for_workers(params, n_workers: int):
    """Stack identical copies along a new leading worker axis."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_workers,) + p.shape), params)


def worker_mean(params_stacked):
    """Host-side: collapse worker copies to their mean (final model)."""
    return jax.tree.map(lambda p: jnp.mean(p, axis=0), params_stacked)


def init_state(opt: Optimizer, params, cfg: PSConfig) -> PSState:
    """Build the worker-stacked PS state from single-copy params."""
    opt_state = opt.init(params)
    pstack = replicate_for_workers(params, cfg.n_workers)
    ostack = replicate_for_workers(opt_state, cfg.n_workers)
    if cfg.sync == "ssp":
        ring = jax.tree.map(
            lambda p: jnp.zeros((cfg.n_workers, cfg.staleness) + p.shape, p.dtype),
            params)
    else:
        ring = None
    return PSState(params=pstack, opt_state=ostack,
                   step=jnp.zeros((), jnp.int32), grad_ring=ring,
                   rng=jax.random.PRNGKey(cfg.seed))


def state_sharding(mesh: Mesh, cfg: PSConfig, state: PSState):
    """NamedShardings for a PSState: worker-stacked leaves on the worker axis."""
    ax = cfg.axis

    def spec_like(x, stacked):
        return NamedSharding(mesh, P(ax) if stacked else P())

    return PSState(
        params=jax.tree.map(lambda x: NamedSharding(mesh, P(ax)), state.params),
        opt_state=jax.tree.map(lambda x: NamedSharding(
            mesh, P(ax) if x.ndim >= 1 and x.shape[0] == cfg.n_workers else P()),
            state.opt_state),
        step=NamedSharding(mesh, P()),
        grad_ring=jax.tree.map(lambda x: NamedSharding(mesh, P(ax)),
                               state.grad_ring) if state.grad_ring is not None else None,
        rng=NamedSharding(mesh, P()),
    )


def make_train_step(loss_fn: Callable, opt: Optimizer, cfg: PSConfig,
                    mesh: Mesh) -> Callable:
    """Build the jitted SPMD PS step: (state, batch) -> (state, metrics).

    ``batch`` must have a leading (P, local_batch, ...) worker axis.
    ``loss_fn(params, batch) -> (scalar, aux)``.
    """
    ax = cfg.axis

    def _local(tree):       # strip the size-1 local worker dim
        return jax.tree.map(lambda x: x[0], tree)

    def _stack(tree):       # restore the size-1 local worker dim
        return jax.tree.map(lambda x: x[None], tree)

    def step_fn(state: PSState, batch):
        params = _local(state.params)
        opt_state = _local(state.opt_state)
        batch_l = _local(batch)
        step = state.step

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch_l)

        if cfg.sync == "bsp":
            # server aggregates every step: exact synchronous data-parallel
            grads = jax.lax.pmean(grads, ax)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            ring = None

        elif cfg.sync == "local":
            # worker steps on its own; server merge every tau steps
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            do_sync = (step + 1) % cfg.tau == 0
            synced = jax.lax.pmean(params, ax)
            params = jax.tree.map(
                lambda s, p: jnp.where(do_sync, s, p), synced, params)
            ring = None

        else:  # ssp — bounded-staleness delayed global gradients
            s = cfg.staleness
            gbar = jax.lax.pmean(grads, ax)                   # server aggregate
            ring = _local(state.grad_ring)                    # (s, ...)
            slot = step % s
            ring = jax.tree.map(lambda r, g: r.at[slot].set(g), ring, gbar)
            # worker-specific delay in [0, s-1], deterministic
            widx = jax.lax.axis_index(ax)
            key = jax.random.fold_in(jax.random.fold_in(state.rng, step), widx)
            delay = jax.random.randint(key, (), 0, s)
            delay = jnp.minimum(delay, step)                  # warmup guard
            read = (step - delay) % s
            g_stale = jax.tree.map(lambda r: r[read], ring)
            updates, opt_state = opt.update(g_stale, opt_state, params)
            params = apply_updates(params, updates)
            # SSP bound: force re-average every s steps
            do_sync = (step + 1) % s == 0
            synced = jax.lax.pmean(params, ax)
            params = jax.tree.map(
                lambda sy, p: jnp.where(do_sync, sy, p), synced, params)
            ring = _stack(ring)

        metrics = {
            "loss": jax.lax.pmean(loss, ax),
            **{k: jax.lax.pmean(v, ax) for k, v in aux.items()},
        }
        new_state = PSState(params=_stack(params), opt_state=_stack(opt_state),
                            step=step + 1, grad_ring=ring, rng=state.rng)
        return new_state, metrics

    ring_spec = P(ax) if cfg.sync == "ssp" else None
    state_specs = PSState(params=P(ax), opt_state=P(ax), step=P(),
                          grad_ring=ring_spec, rng=P())
    shmapped = shard_map(
        step_fn, mesh=mesh,
        in_specs=(state_specs, P(ax)),
        out_specs=(state_specs, P()),
        check_vma=False,
    )
    return jax.jit(shmapped)


def make_train_chunk(loss_fn: Callable, opt: Optimizer, cfg: PSConfig,
                     mesh: Mesh) -> Callable:
    """Communication-efficient local-SGD: one call = ``tau`` local steps
    (lax.scan, NO collectives) + a single parameter all-reduce.

    ``make_train_step(sync='local')`` has identical *semantics* (workers
    blend the synced value on sync steps) but its ``where``-based sync still
    issues a pmean every step — same convergence, none of the communication
    saving. This chunked form is what actually divides collective traffic
    by tau, and is what the §Perf local-SGD measurements lower.

    ``batch`` must be shaped (P, tau, local_batch, ...).
    """
    ax = cfg.axis

    def _local(tree):
        return jax.tree.map(lambda x: x[0], tree)

    def _stack(tree):
        return jax.tree.map(lambda x: x[None], tree)

    def chunk_fn(state: PSState, batch):
        params = _local(state.params)
        opt_state = _local(state.opt_state)
        batch_l = _local(batch)                     # (tau, B, ...)

        def local_step(carry, b):
            p, o = carry
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
            updates, o = opt.update(grads, o, p)
            p = apply_updates(p, updates)
            return (p, o), loss

        (params, opt_state), losses = jax.lax.scan(
            local_step, (params, opt_state), batch_l)
        # the single "server" merge for the whole chunk
        params = jax.lax.pmean(params, ax)
        metrics = {"loss": jax.lax.pmean(jnp.mean(losses), ax)}
        new_state = PSState(params=_stack(params), opt_state=_stack(opt_state),
                            step=state.step + cfg.tau, grad_ring=None,
                            rng=state.rng)
        return new_state, metrics

    state_specs = PSState(params=P(ax), opt_state=P(ax), step=P(),
                          grad_ring=None, rng=P())
    shmapped = shard_map(chunk_fn, mesh=mesh,
                         in_specs=(state_specs, P(ax)),
                         out_specs=(state_specs, P()),
                         check_vma=False)
    return jax.jit(shmapped)


def run_steps(train_step, state: PSState, batches, n_steps: int):
    """Host loop helper: returns (state, list-of-metrics)."""
    history = []
    for _ in range(n_steps):
        state, metrics = train_step(state, next(batches))
        history.append(jax.tree.map(float, metrics))
    return state, history
