"""High-level distributed DML training loops built on the PS sync layer.

``train_dml_distributed`` is the production-shaped entry point: it takes a
pair dataset, partitions it over workers (paper §4.1), builds the SPMD PS
step for the requested consistency model and runs it, returning the merged
metric plus the objective trace.

Both loops are shape-agnostic in ``d_out``: the trained factor is whatever
``DMLConfig.proj_dim`` / ``l_rank`` says — square (d, d) or low-rank
rectangular (d', d) — and the PS update path (sync.py) treats L as an
opaque pytree leaf, so rank never appears in the sync logic. A low-rank
L drops straight into ``swap_metric`` / index builds; M = L^T L stays PSD
by construction at any rank (no projection step anywhere).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dml, losses
from repro.core.ps import sync
from repro.data.loader import partition_pairs
from repro.data.pairs import pair_batches
from repro.optim import Optimizer, sgd


@dataclasses.dataclass(frozen=True)
class DMLTrainConfig:
    dml: dml.DMLConfig
    ps: sync.PSConfig
    batch_size: int = 1000        # per-worker pairs per step (paper: 100/1000)
    steps: int = 200
    lr: float = 1e-2
    log_every: int = 10


def stack_worker_streams(streams) -> Iterator[dict]:
    """Zip per-worker batch streams into (P, B, ...) stacked batches."""
    while True:
        bs = [next(s) for s in streams]
        yield {k: jnp.stack([b[k] for b in bs]) for k in bs[0]}


def make_worker_streams(pairs, n_workers: int, batch_size: int, seed: int):
    """Per-worker batch iterators from either pair representation.

    ``pairs`` is pluggable: a pre-sampled pair dict (partitioned over
    workers as in paper §4.1, then streamed with ``pair_batches``) or any
    object with ``worker_streams(n_workers, batch_size, seed)`` — e.g.
    ``mining/stream.MinedPairSource``, whose batches mix uniform and
    index-mined hard pairs under a curriculum.
    """
    if hasattr(pairs, "worker_streams"):
        return pairs.worker_streams(n_workers, batch_size, seed)
    shards = partition_pairs(pairs, n_workers)
    return [pair_batches(s, batch_size, seed=seed + i)
            for i, s in enumerate(shards)]


def _stacked_batches(shards, batch_size, seed) -> Iterator[dict]:
    """Back-compat shim: stream pre-partitioned pair-dict shards."""
    return stack_worker_streams(
        [pair_batches(s, batch_size, seed=seed + i)
         for i, s in enumerate(shards)])


def train_dml_distributed(cfg: DMLTrainConfig, pairs,
                          opt: Optional[Optimizer] = None,
                          mesh=None, rng=None, step_hook=None):
    """Distributed DML training (paper §4) under a chosen sync model.

    ``pairs`` is either a pair dict (the uniform path) or a pluggable
    pair source (see ``make_worker_streams``). ``step_hook(step, L)``,
    if given, is called with the merged metric at every logged step and
    its return value (when not None) lands in that history record under
    ``"hook"`` — e.g. a periodic kNN eval.

    Returns (L_merged, history) — history is a list of per-step metric dicts.
    """
    opt = opt or sgd(cfg.lr)
    mesh = mesh or sync.make_worker_mesh(cfg.ps.n_workers, cfg.ps.axis)
    # seed from the config's explicit seed: dataclass __hash__ varies across
    # Python processes/versions, which silently unseeded distributed runs
    rng = rng if rng is not None else jax.random.PRNGKey(cfg.ps.seed)

    L0 = dml.init_params(cfg.dml, rng)
    state = sync.init_state(opt, L0, cfg.ps)

    def loss_fn(L, batch):
        return losses.dml_pair_loss(L, batch, lam=cfg.dml.lam,
                                    margin=cfg.dml.margin,
                                    compute_dtype=cfg.dml.compute_dtype)

    step_fn = sync.make_train_step(loss_fn, opt, cfg.ps, mesh)
    batches = stack_worker_streams(make_worker_streams(
        pairs, cfg.ps.n_workers, cfg.batch_size, cfg.ps.seed))

    history = []
    for t in range(cfg.steps):
        state, metrics = step_fn(state, next(batches))
        if t % cfg.log_every == 0 or t == cfg.steps - 1:
            rec = {"step": t, **jax.tree.map(float, metrics)}
            if step_hook is not None:
                out = step_hook(t, sync.worker_mean(state.params))
                if out is not None:
                    rec["hook"] = out
            history.append(rec)
    L = sync.worker_mean(state.params)
    return L, history


def train_dml_single(dml_cfg: dml.DMLConfig, pairs: dict, steps: int = 200,
                     batch_size: int = 1000, lr: float = 1e-2, seed: int = 0,
                     opt: Optional[Optimizer] = None, eval_pairs=None,
                     eval_every: int = 0):
    """Single-device reference loop (the t_1 baseline of the speedup curves)."""
    opt = opt or sgd(lr)
    L = dml.init_params(dml_cfg, jax.random.PRNGKey(seed))
    opt_state = opt.init(L)

    def loss_fn(p, b):
        return losses.dml_pair_loss(p, b, lam=dml_cfg.lam, margin=dml_cfg.margin)

    @jax.jit
    def step(L, opt_state, batch):
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(L, batch)
        updates, opt_state = opt.update(g, opt_state, L)
        L = jax.tree.map(lambda p, u: p + u, L, updates)
        return L, opt_state, loss

    batches = pair_batches(pairs, batch_size, seed=seed)
    history = []
    for t in range(steps):
        L, opt_state, loss = step(L, opt_state, next(batches))
        rec = {"step": t, "loss": float(loss)}
        if eval_pairs is not None and eval_every and t % eval_every == 0:
            scores = dml.pair_scores(L, jnp.asarray(eval_pairs["xs"]),
                                     jnp.asarray(eval_pairs["ys"]))
            rec["ap"] = float(dml.average_precision(
                scores, jnp.asarray(eval_pairs["sim"])))
        history.append(rec)
    return L, history
