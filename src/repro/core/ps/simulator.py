"""Faithful in-process reproduction of the paper's asynchronous parameter
server (§4.2): one server + P workers, real threads, real message queues.

  server: update thread + (implicit) communication thread — pops gradient
          messages from the inbound queue, applies them to the global L with
          a server-side optimizer, pushes fresh parameters to every worker's
          inbound queue.
  worker: local computing thread — samples a minibatch from ITS OWN pair
          shard (S_p, D_p), computes a jitted gradient against its local copy
          L_p, pushes the gradient to the server, and opportunistically
          (non-blocking) pulls the freshest parameters the server sent.

Threads run best-effort exactly as described in the paper: nobody blocks on
anybody; coordination is only through the queues. Because jitted JAX
computations release the GIL, worker threads overlap genuinely on multicore
CPU — this is what lets ``benchmarks/fig3_speedup.py`` measure real speedup
curves analogous to the paper's Fig. 3.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dml
from repro.data.loader import partition_pairs
from repro.data.pairs import pair_batches


@dataclasses.dataclass
class AsyncPSConfig:
    n_workers: int
    lr: float = 1e-2
    batch_size: int = 100           # per-worker minibatch of pairs
    lam: float = 1.0
    margin: float = 1.0
    steps_per_worker: int = 200     # local computing iterations per worker
    server_batch: int = 4           # grad messages aggregated per server update
    seed: int = 0


class _Server:
    """Central server: global L + inbound gradient queue + broadcast."""

    def __init__(self, L0: np.ndarray, cfg: AsyncPSConfig,
                 worker_inboxes: List["queue.Queue"]):
        self.L = np.array(L0)
        self.cfg = cfg
        self.inbound: "queue.Queue" = queue.Queue()
        self.worker_inboxes = worker_inboxes
        self.n_updates = 0
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        cfg = self.cfg
        while not self._stop.is_set() or not self.inbound.empty():
            grads = []
            try:
                grads.append(self.inbound.get(timeout=0.05))
            except queue.Empty:
                continue
            # batch whatever else is already queued (paper: update thread
            # "takes a batch of gradient updates from the inbound queue")
            while len(grads) < cfg.server_batch:
                try:
                    grads.append(self.inbound.get_nowait())
                except queue.Empty:
                    break
            g = np.mean(grads, axis=0)
            self.L -= cfg.lr * g
            self.n_updates += 1
            fresh = self.L.copy()
            for inbox in self.worker_inboxes:
                # drop stale broadcast if the worker hasn't consumed it yet —
                # best-effort semantics, the freshest parameter wins
                try:
                    inbox.get_nowait()
                except queue.Empty:
                    pass
                inbox.put(fresh)

    def start(self):
        self.thread.start()

    def stop(self):
        self._stop.set()
        self.thread.join(timeout=30)


def _make_grad_fn(lam: float, margin: float):
    @jax.jit
    def grad_fn(L, xs, ys, sim):
        loss, g = jax.value_and_grad(dml.objective)(L, xs, ys, sim, lam, margin)
        return loss, g
    return grad_fn


class _Worker:
    def __init__(self, wid: int, L0: np.ndarray, shard: dict,
                 cfg: AsyncPSConfig, server: _Server, inbox: "queue.Queue",
                 grad_fn: Callable, loss_trace: list, trace_lock: threading.Lock,
                 t0: float):
        self.wid = wid
        self.L = np.array(L0)
        self.shard = shard
        self.cfg = cfg
        self.server = server
        self.inbox = inbox
        self.grad_fn = grad_fn
        self.loss_trace = loss_trace
        self.trace_lock = trace_lock
        self.t0 = t0
        self.thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        cfg = self.cfg
        batches = pair_batches(self.shard, cfg.batch_size,
                               seed=cfg.seed + 1000 + self.wid)
        for it in range(cfg.steps_per_worker):
            # opportunistic pull of the freshest broadcast (remote update
            # thread in the paper); never blocks
            try:
                self.L = self.inbox.get_nowait()
            except queue.Empty:
                pass
            b = next(batches)
            loss, g = self.grad_fn(jnp.asarray(self.L), b["xs"], b["ys"], b["sim"])
            g = np.asarray(g)
            # local apply (compute thread keeps moving even if server is slow)
            self.L = self.L - cfg.lr * g
            self.server.inbound.put(g)
            with self.trace_lock:
                self.loss_trace.append((time.perf_counter() - self.t0,
                                        self.wid, float(loss)))

    def start(self):
        self.thread.start()

    def join(self):
        self.thread.join(timeout=600)


def run_async_dml(cfg: AsyncPSConfig, pairs: dict, L0: np.ndarray):
    """Run the threaded async PS end to end.

    Returns (final L, trace) where trace is a list of
    (wall_seconds, worker_id, minibatch_loss) tuples ordered by arrival.
    """
    shards = partition_pairs(pairs, cfg.n_workers)
    grad_fn = _make_grad_fn(cfg.lam, cfg.margin)
    # warm the jit cache once so compile time doesn't pollute speedup numbers
    b0 = next(pair_batches(shards[0], cfg.batch_size, seed=cfg.seed))
    grad_fn(jnp.asarray(L0), b0["xs"], b0["ys"], b0["sim"])[0].block_until_ready()

    inboxes = [queue.Queue(maxsize=1) for _ in range(cfg.n_workers)]
    server = _Server(L0, cfg, inboxes)
    trace: list = []
    lock = threading.Lock()
    t0 = time.perf_counter()
    workers = [
        _Worker(w, L0, shards[w], cfg, server, inboxes[w], grad_fn, trace,
                lock, t0)
        for w in range(cfg.n_workers)
    ]
    server.start()
    for w in workers:
        w.start()
    for w in workers:
        w.join()
    server.stop()
    return server.L, trace
