"""Baseline: the original DML formulation of Xing et al. (2002), Eq. 1.

Solved with projected gradient ascent/descent:
  * gradient step on  sum_S (x-y)^T M (x-y)  minus a penalty pushing
    dissimilar pairs beyond the unit margin,
  * projection of M onto the PSD cone via eigendecomposition (the O(d^3)
    step whose removal motivates the paper's reformulation).

This is the comparison method labeled "Xing2002" in Fig. 4. It is kept
single-device on purpose — the paper's point is that this form does not
distribute.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import dml


@dataclasses.dataclass(frozen=True)
class XingConfig:
    feat_dim: int
    lr: float = 1e-2
    margin: float = 1.0
    lam: float = 1.0          # weight on the dissimilarity hinge penalty
    steps: int = 100


def _penalized_objective(M, xs, ys, sim, lam, margin):
    """Eq. 1 with the hard constraint softened to a hinge (for PGD).

    The PSD constraint is handled by projection, not by the objective.
    """
    d2 = dml.mahalanobis_sqdist_M(M, xs, ys)
    sim_f = sim.astype(d2.dtype)
    hinge = jnp.maximum(0.0, margin - d2)
    return jnp.mean(sim_f * d2 + (1.0 - sim_f) * lam * hinge)


@partial(jax.jit, static_argnames=("lam", "margin", "lr"))
def pgd_step(M, xs, ys, sim, *, lam: float, margin: float, lr: float):
    """One projected-gradient step: gradient descent then PSD projection."""
    loss, g = jax.value_and_grad(_penalized_objective)(M, xs, ys, sim, lam, margin)
    M = M - lr * g
    M = dml.psd_project(M)    # O(d^3) eigendecomposition every step
    return M, loss


def fit(cfg: XingConfig, xs, ys, sim, rng=None, batch_size: int = 1000):
    """Full-batch-less PGD training loop over minibatches (host loop)."""
    d = cfg.feat_dim
    M = jnp.eye(d, dtype=jnp.float32)
    n = xs.shape[0]
    key = rng if rng is not None else jax.random.PRNGKey(0)
    losses = []
    for t in range(cfg.steps):
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (min(batch_size, n),), 0, n)
        M, loss = pgd_step(M, xs[idx], ys[idx], sim[idx],
                           lam=cfg.lam, margin=cfg.margin, lr=cfg.lr)
        losses.append(float(loss))
    return M, losses
