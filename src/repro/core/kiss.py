"""Baseline: KISS metric learning (Koestinger et al., CVPR 2012).

"Keep It Simple and Straightforward": a one-shot, likelihood-ratio-test
metric with no iterative optimization —

  M = Sigma_S^{-1} - Sigma_D^{-1}

where Sigma_S / Sigma_D are covariance matrices of pairwise differences over
similar / dissimilar pairs. The result is projected onto the PSD cone to make
it a valid metric (as in the original paper's practical recipe). Optionally a
PCA pre-projection keeps the covariances invertible (the paper reduces MNIST
to 600 dims before KISS).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dml


@dataclasses.dataclass(frozen=True)
class KISSConfig:
    feat_dim: int
    pca_dim: Optional[int] = None   # reduce before covariance estimation
    ridge: float = 1e-6             # diagonal loading for invertibility


def pca_basis(x: jax.Array, dim: int) -> jax.Array:
    """Top-`dim` principal axes of x (n, d) -> (d, dim)."""
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    # economical SVD: eigh on the d x d covariance
    cov = xc.T @ xc / x.shape[0]
    w, V = jnp.linalg.eigh(cov)
    return V[:, -dim:]              # ascending eigenvalues -> take last `dim`


@jax.jit
def _kiss_metric(zs_sim: jax.Array, zs_dis: jax.Array, ridge: float) -> jax.Array:
    d = zs_sim.shape[1]
    eye = jnp.eye(d, dtype=jnp.float32)
    cov_s = zs_sim.T @ zs_sim / zs_sim.shape[0] + ridge * eye
    cov_d = zs_dis.T @ zs_dis / zs_dis.shape[0] + ridge * eye
    M = jnp.linalg.inv(cov_s) - jnp.linalg.inv(cov_d)
    return dml.psd_project(M)


def fit(cfg: KISSConfig, xs, ys, sim):
    """Returns (M, projection) — apply `x @ projection` before using M if not None."""
    proj = None
    if cfg.pca_dim is not None and cfg.pca_dim < cfg.feat_dim:
        allx = jnp.concatenate([xs, ys], axis=0)
        proj = pca_basis(allx, cfg.pca_dim)
        xs, ys = xs @ proj, ys @ proj
    z = xs - ys
    zs_sim = z[sim > 0]
    zs_dis = z[sim <= 0]
    M = _kiss_metric(zs_sim, zs_dis, cfg.ridge)
    return M, proj
