from repro.core import dml, losses  # noqa: F401
