"""Baseline: Information-Theoretic Metric Learning (Davis et al., 2007).

ITML minimizes the LogDet divergence to a prior metric M0 subject to
distance constraints, solved with Bregman projections — one (cheap, rank-one)
projection per constraint visit:

  similar (x,y):      d_M(x,y) <= u
  dissimilar (x,y):   d_M(x,y) >= l

Update (for a visited constraint with z = x - y):
  p     = z^T M z
  alpha = min(lambda_i, gamma/(gamma+1) * (1/p - 1/target))
  beta  = delta * alpha / (1 - delta * alpha * p)       (delta = +1 sim, -1 dis)
  M    <- M + beta * (M z)(M z)^T

This is the paper's Fig. 4 comparison; per-pair cost is O(d^2), vs O(dk)
for the reformulated method — exactly the gap the paper highlights.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ITMLConfig:
    feat_dim: int
    gamma: float = 1e-3       # slack tradeoff (paper §5.4 uses 0.001)
    u: float = 1.0            # upper bound for similar-pair distances
    l: float = 4.0            # lower bound for dissimilar-pair distances
    sweeps: int = 3           # passes over the constraint set


def fit(cfg: ITMLConfig, xs, ys, sim):
    """Run ITML Bregman projections. Host loop with a jitted scan per sweep."""
    n, d = xs.shape
    z_all = (xs - ys).astype(jnp.float32)                  # (n, d)
    delta_all = jnp.where(sim > 0, 1.0, -1.0)              # (n,)
    target_all = jnp.where(sim > 0, cfg.u, cfg.l)          # (n,)
    gamma = cfg.gamma

    def step(carry, inp):
        M, lambdas = carry
        z, delta, target, idx = inp
        Mz = M @ z                                         # (d,)
        p = jnp.maximum(z @ Mz, 1e-12)
        alpha = jnp.minimum(lambdas[idx],
                            delta * (gamma / (gamma + 1.0)) * (1.0 / p - 1.0 / target))
        beta = delta * alpha / (1.0 - delta * alpha * p)
        M = M + beta * jnp.outer(Mz, Mz)
        lambdas = lambdas.at[idx].add(-alpha)
        return (M, lambdas), p

    @jax.jit
    def sweep(M, lambdas):
        idxs = jnp.arange(n)
        (M, lambdas), _ = jax.lax.scan(
            step, (M, lambdas),
            (z_all, delta_all, target_all, idxs))
        return M, lambdas

    M = jnp.eye(d, dtype=jnp.float32)
    lambdas = jnp.zeros((n,), jnp.float32)
    for _ in range(cfg.sweeps):
        M, lambdas = sweep(M, lambdas)
    return M
