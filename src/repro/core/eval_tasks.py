"""Downstream tasks the paper motivates DML with (§1: retrieval, k-means
clustering, kNN classification) — evaluated under a learned metric.

All distances route through the tiled pairwise kernel
(kernels/pairwise_dist). Because the Mahalanobis metric factorizes as
M = LᵀL, every task reduces to Euclidean geometry in the projected space
x -> L x, so k-means stays exact Lloyd iterations there.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pairwise_dist import metric_sqdist_matrix


def knn_classify(L: Optional[jax.Array], train_x, train_y, test_x,
                 k: int = 5):
    """k-nearest-neighbour labels under the metric (L=None -> Euclidean)."""
    train_x = jnp.asarray(train_x)
    test_x = jnp.asarray(test_x)
    if L is None:
        L = jnp.eye(train_x.shape[1], dtype=jnp.float32)
    D = metric_sqdist_matrix(L, test_x, train_x)        # (n_test, n_train)
    # k-selection, not a full sort: lax.top_k on negated distances is
    # O(n_train log k) per row vs argsort's O(n_train log n_train), and
    # keeps the same smallest-index-first tie order argsort used; clamp
    # like argsort's slice did (top_k raises on k > n_train)
    _, nn = jax.lax.top_k(-D, min(k, D.shape[1]))       # (n_test, k)
    votes = jnp.asarray(train_y)[nn]                    # (n_test, k)
    n_classes = int(jnp.max(jnp.asarray(train_y))) + 1
    counts = jax.vmap(lambda v: jnp.bincount(v, length=n_classes))(votes)
    return jnp.argmax(counts, axis=1)


def knn_accuracy(L, train_x, train_y, test_x, test_y, k: int = 5) -> float:
    pred = knn_classify(L, train_x, train_y, test_x, k)
    return float(jnp.mean(pred == jnp.asarray(test_y)))


def metric_kmeans(L: Optional[jax.Array], x, n_clusters: int,
                  n_iter: int = 25, seed: int = 0):
    """Lloyd k-means in the learned metric space. Returns (assignments,
    centers_in_projected_space)."""
    x = jnp.asarray(x, jnp.float32)
    if L is not None:
        xp = x @ jnp.asarray(L, jnp.float32).T
    else:
        xp = x
    n = xp.shape[0]
    rng = np.random.RandomState(seed)
    centers = xp[jnp.asarray(rng.choice(n, n_clusters, replace=False))]

    @jax.jit
    def step(centers):
        d = (jnp.sum(xp**2, 1)[:, None] + jnp.sum(centers**2, 1)[None]
             - 2 * xp @ centers.T)
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, n_clusters, dtype=jnp.float32)
        counts = jnp.maximum(onehot.sum(0), 1.0)
        new_centers = (onehot.T @ xp) / counts[:, None]
        # keep empty clusters where they were
        new_centers = jnp.where((onehot.sum(0) > 0)[:, None],
                                new_centers, centers)
        return new_centers, assign

    assign = None
    for _ in range(n_iter):
        centers, assign = step(centers)
    return assign, centers


def clustering_purity(assignments, labels) -> float:
    """Fraction of points whose cluster's majority label matches theirs."""
    assignments = np.asarray(assignments)
    labels = np.asarray(labels)
    total = 0
    for c in np.unique(assignments):
        member_labels = labels[assignments == c]
        if len(member_labels):
            total += np.bincount(member_labels).max()
    return total / len(labels)
