"""Loss registry — DML objectives and LM loss as first-class, composable losses.

Every loss has signature ``loss_fn(params, batch) -> (scalar, aux_dict)`` so
the PS trainer, the backbone trainer and the benchmarks can swap them freely.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import dml

LossFn = Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]
_REGISTRY: Dict[str, LossFn] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get(name: str) -> LossFn:
    if name not in _REGISTRY:
        raise KeyError(f"unknown loss '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


@register("dml_pair")
def dml_pair_loss(L, batch, *, lam: float = 1.0, margin: float = 1.0,
                  compute_dtype=None):
    """Paper Eq. 4 over a pair minibatch {xs, ys, sim}."""
    loss = dml.objective(L, batch["xs"], batch["ys"], batch["sim"],
                         lam=lam, margin=margin, compute_dtype=compute_dtype)
    d2 = dml.mahalanobis_sqdist(L, batch["xs"], batch["ys"])
    sim = batch["sim"].astype(jnp.float32)
    aux = {
        "loss": loss,
        "mean_sim_dist": jnp.sum(d2 * sim) / jnp.maximum(jnp.sum(sim), 1.0),
        "mean_dis_dist": jnp.sum(d2 * (1 - sim)) / jnp.maximum(jnp.sum(1 - sim), 1.0),
        "hinge_active_frac": jnp.mean((d2 < margin) * (1 - sim)),
    }
    return loss, aux


@register("dml_triplet")
def dml_triplet_loss(L, batch, *, margin: float = 1.0, compute_dtype=None):
    """Triple-wise constraint extension (paper §4)."""
    loss = dml.triplet_objective(L, batch["anchor"], batch["pos"], batch["neg"],
                                 margin=margin, compute_dtype=compute_dtype)
    return loss, {"loss": loss}


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask=None) -> jax.Array:
    """Token-level mean CE. logits (..., V), labels (...) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


@register("lm")
def lm_loss(logits, batch):
    """Next-token LM loss given precomputed logits and {labels, mask?}."""
    loss = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"loss": loss}
