"""Core distance-metric-learning objectives from Xie & Xing (2014).

Implements both the original constrained SDP form (Eq. 1, used by the
``xing2002`` baseline) and the paper's parallelizable reformulation (Eq. 4):

    min_L  sum_{(x,y) in S} ||L(x-y)||^2
         + lambda * sum_{(x,y) in D} max(0, 1 - ||L(x-y)||^2)

where ``M = L^T L`` is the implied Mahalanobis matrix, ``L`` is
``(d_out, d_in)`` with ``d_out <= d_in``. Everything is pure JAX and
jit/pjit friendly.

Low-rank training (Qian et al. 2015, "Towards Making High Dimensional
Distance Metric Learning Practical") falls out of the same objective:
optimizing a *rectangular* L with ``d_out = l_rank << d_in`` directly on
the pairwise hinge loss keeps ``M = L^T L`` PSD by construction (rank at
most ``l_rank``) — no PSD projection, no square factor, and every
downstream consumer (projected galleries, PQ codes, kernel tiles,
snapshots) shrinks by ``d_in / l_rank``. Set ``DMLConfig(l_rank=...)``
to pick the rank; the trainer and PS update path are shape-agnostic in
``d_out``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DMLConfig:
    """Hyper-parameters of the reformulated DML objective (paper §3/§5.2).

    ``proj_dim`` and ``l_rank`` are two names for the same quantity —
    the number of rows ``d_out`` of the learned (d_out, d_in) factor.
    ``l_rank`` is the low-rank knob: set it below ``feat_dim`` and the
    trained L is rectangular, which bounds rank(M) = rank(L^T L) and
    shrinks every projected artifact downstream by feat_dim / l_rank.
    Setting neither trains a square factor; setting both to different
    values is an error.
    """

    feat_dim: int                     # d_in — feature dimensionality
    proj_dim: Optional[int] = None    # d_out — rows of L (<= d_in)
    lam: float = 1.0        # lambda — dissimilar-pair tradeoff (paper: 1)
    margin: float = 1.0     # c — dissimilarity margin (paper: 1)
    dtype: jnp.dtype = jnp.float32
    # Compute policy: matmuls may run in bf16 on TPU while params stay fp32.
    compute_dtype: Optional[jnp.dtype] = None
    # low-rank knob: alias for proj_dim (d_out of the rectangular factor)
    l_rank: Optional[int] = None

    def __post_init__(self):
        if (self.proj_dim is not None and self.l_rank is not None
                and self.proj_dim != self.l_rank):
            raise ValueError(
                f"proj_dim={self.proj_dim} and l_rank={self.l_rank} "
                f"disagree; they name the same d_out — set one")
        d_out = self.proj_dim if self.proj_dim is not None else self.l_rank
        if d_out is None:
            d_out = self.feat_dim           # square factor by default
        object.__setattr__(self, "proj_dim", int(d_out))
        if not 1 <= self.proj_dim <= self.feat_dim:
            raise ValueError(
                f"proj_dim d_out={self.proj_dim} must be in "
                f"1..feat_dim d_in={self.feat_dim}")


def init_params(cfg: DMLConfig, rng: jax.Array) -> jax.Array:
    """Initialize L (d_out, d_in). Scaled Gaussian so initial distances
    are O(1)."""
    scale = 1.0 / np.sqrt(cfg.feat_dim)
    return scale * jax.random.normal(rng, (cfg.proj_dim, cfg.feat_dim), cfg.dtype)


def mahalanobis_sqdist(L: jax.Array, x: jax.Array, y: jax.Array,
                       compute_dtype=None) -> jax.Array:
    """||L(x - y)||^2 for batched x, y of shape (..., d). Returns (...,)."""
    z = x - y
    if compute_dtype is not None:
        z = z.astype(compute_dtype)
        L = L.astype(compute_dtype)
    proj = z @ L.T                      # (..., k)
    return jnp.sum(jnp.square(proj.astype(jnp.float32)), axis=-1)


def pair_losses(L: jax.Array, xs: jax.Array, ys: jax.Array, sim: jax.Array,
                lam: float = 1.0, margin: float = 1.0,
                compute_dtype=None) -> jax.Array:
    """Per-pair Eq. 4 loss.

    Args:
      L: (k, d) metric factor.
      xs, ys: (B, d) pair members.
      sim: (B,) bool/int — 1 for similar pairs (set S), 0 for dissimilar (D).

    Returns (B,) per-pair losses:
      similar:    ||L(x-y)||^2
      dissimilar: lam * max(0, margin - ||L(x-y)||^2)
    """
    d2 = mahalanobis_sqdist(L, xs, ys, compute_dtype)
    sim = sim.astype(d2.dtype)
    hinge = jnp.maximum(0.0, margin - d2)
    return sim * d2 + (1.0 - sim) * lam * hinge


def objective(L: jax.Array, xs: jax.Array, ys: jax.Array, sim: jax.Array,
              lam: float = 1.0, margin: float = 1.0,
              compute_dtype=None) -> jax.Array:
    """Mean Eq. 4 objective over a minibatch of pairs (scalar)."""
    return jnp.mean(pair_losses(L, xs, ys, sim, lam, margin, compute_dtype))


# Value-and-grad of the reformulated objective. Gradient is what each PS
# worker computes from its local pair shard (paper §4.1).
objective_value_and_grad = jax.value_and_grad(objective)


def objective_full(L: jax.Array, xs: jax.Array, ys: jax.Array,
                   sim: jax.Array, lam: float = 1.0, margin: float = 1.0) -> jax.Array:
    """Sum-form objective as written in Eq. 4 (not mean-normalized).

    Used when matching the paper's reported objective-value curves.
    """
    return jnp.sum(pair_losses(L, xs, ys, sim, lam, margin))


def analytic_grad(L: jax.Array, xs: jax.Array, ys: jax.Array, sim: jax.Array,
                  lam: float = 1.0, margin: float = 1.0) -> jax.Array:
    """Closed-form minibatch-mean gradient of Eq. 4 w.r.t. L.

    dL ||Lz||^2 = 2 L z z^T. For dissimilar pairs inside the hinge the sign
    flips and picks up lambda. Used as an independent oracle in tests (checked
    against jax.grad) and by the Pallas kernel's backward pass.
    """
    z = xs - ys                                   # (B, d)
    d2 = mahalanobis_sqdist(L, xs, ys)            # (B,)
    sim_f = sim.astype(L.dtype)
    active = (d2 < margin).astype(L.dtype)        # hinge active mask
    # weight per pair: +1 for similar, -lam * 1{d2 < margin} for dissimilar
    w = sim_f - lam * (1.0 - sim_f) * active      # (B,)
    Lz = z @ L.T                                  # (B, k)
    # grad = mean_B 2 * w_b * (L z_b) z_b^T  -> (k, d)
    g = 2.0 * (Lz * w[:, None]).T @ z / xs.shape[0]
    return g.astype(L.dtype)


# ---------------------------------------------------------------------------
# Original formulation (Eq. 1) pieces — used by the xing2002 baseline.
# ---------------------------------------------------------------------------

def mahalanobis_sqdist_M(M: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    """(x-y)^T M (x-y) for batched inputs."""
    z = x - y
    return jnp.einsum("...d,de,...e->...", z, M, z)


def psd_project(M: jax.Array) -> jax.Array:
    """Project a symmetric matrix onto the PSD cone via eigendecomposition.

    This is the O(d^3) step the paper's reformulation removes.
    """
    M = 0.5 * (M + M.T)
    w, V = jnp.linalg.eigh(M)
    w = jnp.maximum(w, 0.0)
    return (V * w[None, :]) @ V.T


def M_from_L(L: jax.Array) -> jax.Array:
    """Recover the Mahalanobis matrix M = L^T L (guaranteed PSD)."""
    return L.T @ L


# ---------------------------------------------------------------------------
# Triplet extension (paper §4: "can be easily extended to support
# triple-wise constraints" a la Weinberger et al. 2005).
# ---------------------------------------------------------------------------

def triplet_losses(L: jax.Array, anchor: jax.Array, pos: jax.Array,
                   neg: jax.Array, margin: float = 1.0,
                   compute_dtype=None) -> jax.Array:
    """max(0, margin + ||L(a-p)||^2 - ||L(a-n)||^2) per triplet."""
    d_pos = mahalanobis_sqdist(L, anchor, pos, compute_dtype)
    d_neg = mahalanobis_sqdist(L, anchor, neg, compute_dtype)
    return jnp.maximum(0.0, margin + d_pos - d_neg)


def triplet_objective(L, anchor, pos, neg, margin: float = 1.0,
                      compute_dtype=None) -> jax.Array:
    return jnp.mean(triplet_losses(L, anchor, pos, neg, margin, compute_dtype))


# ---------------------------------------------------------------------------
# Evaluation (paper §5.4): threshold distances to classify pairs as
# similar/dissimilar; report average precision and precision-recall curves.
# ---------------------------------------------------------------------------

def pair_scores(L: jax.Array, xs: jax.Array, ys: jax.Array) -> jax.Array:
    """Similarity score = negative Mahalanobis distance (higher = more similar)."""
    return -mahalanobis_sqdist(L, xs, ys)


def pair_scores_euclidean(xs: jax.Array, ys: jax.Array) -> jax.Array:
    return -jnp.sum(jnp.square(xs - ys), axis=-1)


def pair_scores_M(M: jax.Array, xs: jax.Array, ys: jax.Array) -> jax.Array:
    return -mahalanobis_sqdist_M(M, xs, ys)


def average_precision(scores: jax.Array, labels: jax.Array) -> jax.Array:
    """AP of ranking similar pairs (labels==1) above dissimilar (labels==0).

    Pure-jnp implementation (no sklearn): AP = sum_k P(k) * rel(k) / n_pos
    over the score-descending ranking.
    """
    order = jnp.argsort(-scores)
    rel = labels.astype(jnp.float32)[order]
    cum_pos = jnp.cumsum(rel)
    ranks = jnp.arange(1, rel.shape[0] + 1, dtype=jnp.float32)
    precision_at_k = cum_pos / ranks
    n_pos = jnp.maximum(jnp.sum(rel), 1.0)
    return jnp.sum(precision_at_k * rel) / n_pos


def precision_recall_curve(scores: np.ndarray, labels: np.ndarray,
                           n_points: int = 100):
    """(precision, recall) arrays swept over score thresholds (numpy, eval-only)."""
    scores = np.asarray(scores)
    labels = np.asarray(labels).astype(np.float64)
    order = np.argsort(-scores)
    rel = labels[order]
    tp = np.cumsum(rel)
    fp = np.cumsum(1.0 - rel)
    n_pos = max(rel.sum(), 1.0)
    precision = tp / np.maximum(tp + fp, 1e-12)
    recall = tp / n_pos
    # subsample to n_points for compact reporting
    idx = np.linspace(0, len(rel) - 1, min(n_points, len(rel))).astype(int)
    return precision[idx], recall[idx]
