"""MLP variants: SwiGLU / GeGLU / plain GELU, and the RWKV channel-mix."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.sharding import constrain


def init_mlp(cfg: ArchConfig, rng) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "w_gate": common.he_init(ks[0], (d, f), d),
            "w_up": common.he_init(ks[1], (d, f), d),
            "w_down": common.he_init(ks[2], (f, d), f),
        }
    if cfg.mlp_kind == "gelu":
        return {
            "w_up": common.he_init(ks[0], (d, f), d),
            "b_up": jnp.zeros((f,), jnp.float32),
            "w_down": common.he_init(ks[1], (f, d), f),
            "b_down": jnp.zeros((d,), jnp.float32),
        }
    if cfg.mlp_kind == "rwkv_channel_mix":
        return {
            "mix_k": 0.5 * jnp.ones((d,), jnp.float32),
            "w_k": common.he_init(ks[0], (d, f), d),
            "w_v": common.he_init(ks[1], (f, d), f),
            "mix_r": 0.5 * jnp.ones((d,), jnp.float32),
            "w_r": common.he_init(ks[2], (d, d), d),
        }
    raise ValueError(cfg.mlp_kind)


def logical_axes(cfg: ArchConfig) -> dict:
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {"w_gate": ("embed", "ffn"), "w_up": ("embed", "ffn"),
                "w_down": ("ffn", "embed")}
    if cfg.mlp_kind == "gelu":
        return {"w_up": ("embed", "ffn"), "b_up": ("ffn",),
                "w_down": ("ffn", "embed"), "b_down": ("embed",)}
    return {"mix_k": (None,), "w_k": ("embed", "ffn"), "w_v": ("ffn", "embed"),
            "mix_r": (None,), "w_r": ("embed", "embed2")}


def apply_mlp(p, x, cfg: ArchConfig, x_prev=None):
    """x (B,T,d). ``x_prev`` is the token-shifted input (rwkv channel mix)."""
    dt = x.dtype
    if cfg.mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else partial_gelu
        g = act(x @ p["w_gate"].astype(dt))
        u = x @ p["w_up"].astype(dt)
        h = constrain(g * u, ("batch", "seq", "ffn"))
        return h @ p["w_down"].astype(dt)
    if cfg.mlp_kind == "gelu":
        h = jax.nn.gelu(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt))
        h = constrain(h, ("batch", "seq", "ffn"))
        return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)
    if cfg.mlp_kind == "rwkv_channel_mix":
        assert x_prev is not None, "rwkv channel mix needs token shift"
        xk = x + (x_prev - x) * p["mix_k"].astype(dt)
        xr = x + (x_prev - x) * p["mix_r"].astype(dt)
        k = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(dt)))
        k = constrain(k, ("batch", "seq", "ffn"))
        r = jax.nn.sigmoid(xr @ p["w_r"].astype(dt))
        return r * (k @ p["w_v"].astype(dt))
    raise ValueError(cfg.mlp_kind)


def partial_gelu(x):
    return jax.nn.gelu(x, approximate=True)
