"""GQA/MQA attention with RoPE, optional QK-norm, sliding window, KV cache.

Three execution paths:
  * ``attend_naive``   — materializes (T, S) scores; short sequences/smoke.
  * ``attend_chunked`` — flash-style streaming softmax over KV chunks inside
                         a q-chunk ``lax.map``; O(chunk^2) live memory. This
                         is the default for long-sequence prefill/training —
                         mandatory at 32k+ where naive scores would be TBs.
  * ``decode_attend``  — single-token query against a (ring-buffered) cache.

Sliding-window caches are ring buffers of length ``window`` so long_500k
decode holds O(window), not O(seq), state per layer.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common
from repro.sharding import constrain


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_cache, K, Dh)
    v: jax.Array          # (B, S_cache, K, Dh)


def init_attention(cfg: ArchConfig, rng) -> dict:
    d, H, K, dh = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.dim_per_head
    ks = jax.random.split(rng, 6)
    p = {
        "wq": common.he_init(ks[0], (d, H, dh), d),
        "wk": common.he_init(ks[1], (d, K, dh), d),
        "wv": common.he_init(ks[2], (d, K, dh), d),
        "wo": common.he_init(ks[3], (H, dh, d), H * dh),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H, dh), jnp.float32)
        p["bk"] = jnp.zeros((K, dh), jnp.float32)
        p["bv"] = jnp.zeros((K, dh), jnp.float32)
        p["bo"] = jnp.zeros((d,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def logical_axes(cfg: ArchConfig) -> dict:
    lg = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.attn_bias:
        lg.update({"bq": ("heads", "head_dim"), "bk": ("kv_heads", "head_dim"),
                   "bv": ("kv_heads", "head_dim"), "bo": ("embed",)})
    if cfg.qk_norm:
        lg.update({"q_norm": (None,), "k_norm": (None,)})
    return lg


def _rms(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def qkv_proj(p, x, positions, cfg: ArchConfig):
    """x (B,T,d) -> q (B,T,H,Dh), k/v (B,T,K,Dh), RoPE applied."""
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(dt))
    if cfg.attn_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = _rms(q, p["q_norm"])
        k = _rms(k, p["k_norm"])
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    v = constrain(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def out_proj(p, ctx, cfg: ArchConfig):
    """ctx (B,T,H,Dh) -> (B,T,d)."""
    y = jnp.einsum("bthk,hkd->btd", ctx, p["wo"].astype(ctx.dtype))
    if cfg.attn_bias:
        y = y + p["bo"].astype(ctx.dtype)
    return constrain(y, ("batch", "seq", None))


def _group_q(q, n_kv):
    """(B,T,H,Dh) -> (B,T,K,G,Dh) for GQA."""
    B, T, H, dh = q.shape
    return q.reshape(B, T, n_kv, H // n_kv, dh)


def attend_naive(q, k, v, cfg: ArchConfig, q_offset: int = 0):
    """Materialized-scores attention. q (B,T,H,Dh); k,v (B,S,K,Dh)."""
    B, T, H, dh = q.shape
    S = k.shape[1]
    K = k.shape[2]
    qg = _group_q(q, K)                                 # (B,T,K,G,Dh)
    scale = 1.0 / np.sqrt(dh)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k) * scale
    scores = scores.astype(jnp.float32)
    qpos = jnp.arange(T) + q_offset
    kpos = jnp.arange(S)
    mask = jnp.ones((T, S), bool)
    if cfg.causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if cfg.attention == "sliding":
        mask &= kpos[None, :] > qpos[:, None] - cfg.window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgts,bskd->btkgd", w, v)
    return ctx.reshape(B, T, H, dh)


def _seq_parallel_wanted(n_heads: int) -> bool:
    """Context parallelism fallback: when the head count doesn't divide the
    model axis, head-parallel attention replicates the full O(T^2) work on
    every model rank (e.g. smollm's 9 heads on model=16). Sharding the
    q-chunk/sequence dim instead splits the tiles across ranks."""
    from repro.sharding.partition import _current_mesh
    mesh = _current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return False
    return n_heads % mesh.shape["model"] != 0


def attend_chunked(q, k, v, cfg: ArchConfig, q_chunk: int = 1024,
                   kv_chunk: int = 1024):
    """Flash-style streaming attention (self-attention over full sequence).

    q (B,T,H,Dh), k/v (B,T,K,Dh). Causal and/or sliding-window masks applied
    per (q-chunk, kv-chunk) tile; running max/denominator carried across kv
    chunks so no (T, T) tensor is ever materialized.
    """
    B, T, H, dh = q.shape
    K = k.shape[2]
    q_chunk = min(q_chunk, T)
    kv_chunk = min(kv_chunk, T)
    nq, nk = T // q_chunk, T // kv_chunk
    assert T % q_chunk == 0 and T % kv_chunk == 0, (T, q_chunk, kv_chunk)
    scale = 1.0 / np.sqrt(dh)

    qg = _group_q(q, K).reshape(B, nq, q_chunk, K, H // K, dh)
    kc = k.reshape(B, nk, kv_chunk, K, dh)
    vc = v.reshape(B, nk, kv_chunk, K, dh)
    seq_par = _seq_parallel_wanted(H)

    def one_q_chunk(qi):
        qblk = qg[:, qi]                                 # (B,qc,K,G,Dh)
        if seq_par:
            # context parallelism: split each q chunk over the model axis
            qblk = constrain(qblk, ("batch", "seq_sp", None, None, None))
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kj):
            m, l, acc = carry
            kblk = jax.lax.dynamic_index_in_dim(kc, kj, 1, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vc, kj, 1, keepdims=False)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk) * scale
            s = s.astype(jnp.float32)                    # (B,K,G,qc,kc)
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if cfg.causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if cfg.attention == "sliding":
                mask &= kpos[None, :] > qpos[:, None] - cfg.window
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = corr * l + jnp.sum(p, axis=-1)
            acc_new = corr[..., None] * acc + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(q.dtype), vblk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, H // K, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, K, H // K, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, K, H // K, q_chunk, dh), jnp.float32)
        # checkpoint: backward recomputes the (qc, kc) score/prob tiles from
        # the tiny running stats instead of saving them for every tile —
        # this is what makes 32k-token training fit in HBM
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step),
                                      (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B,K,G,qc,Dh) -> (B,qc,H,Dh)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, dh)
        out = out.astype(q.dtype)
        if seq_par:
            out = constrain(out, ("batch", "seq_sp", None, None))
        return out

    out = jax.lax.map(one_q_chunk, jnp.arange(nq))       # (nq,B,qc,H,Dh)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, T, H, dh)


def attend(q, k, v, cfg: ArchConfig, chunked_threshold: int = 2048):
    if q.shape[1] <= chunked_threshold:
        return attend_naive(q, k, v, cfg)
    return attend_chunked(q, k, v, cfg, q_chunk=cfg.attn_q_chunk,
                          kv_chunk=cfg.attn_kv_chunk)


# --------------------------------------------------------------------------
# Decode path
# --------------------------------------------------------------------------

def cache_len(cfg: ArchConfig, max_seq: int) -> int:
    return min(cfg.window, max_seq) if cfg.attention == "sliding" else max_seq


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> KVCache:
    S = cache_len(cfg, max_seq)
    K, dh = cfg.kv_heads, cfg.dim_per_head
    return KVCache(k=jnp.zeros((batch, S, K, dh), dtype),
                   v=jnp.zeros((batch, S, K, dh), dtype))


def cache_update(cache: KVCache, k_new, v_new, pos, cfg: ArchConfig) -> KVCache:
    """Insert one step's K/V (B,1,K,Dh) at position ``pos`` (ring-buffered
    modulo the cache length for sliding windows)."""
    S = cache.k.shape[1]
    slot = pos % S
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype),
                                            slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype),
                                            slot, axis=1)
    return KVCache(k=k, v=v)


def decode_attend(p, x, cache: KVCache, pos, cfg: ArchConfig):
    """One-token attention. x (B,1,d); pos scalar int (position of the new
    token). Returns (out (B,1,d), updated cache)."""
    B = x.shape[0]
    dt = x.dtype
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    k_new = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(dt))
    v_new = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(dt))
    if cfg.attn_bias:
        q = q + p["bq"].astype(dt)
        k_new = k_new + p["bk"].astype(dt)
        v_new = v_new + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = _rms(q, p["q_norm"])
        k_new = _rms(k_new, p["k_norm"])
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k_new = common.apply_rope(k_new, positions, cfg.rope_theta)

    cache = cache_update(cache, k_new, v_new, pos, cfg)
    S = cache.k.shape[1]
    K = cache.k.shape[2]
    H, dh = q.shape[2], q.shape[3]

    # position held by each ring slot: largest p <= pos with p % S == slot
    slots = jnp.arange(S)
    slot_pos = pos - ((pos - slots) % S)
    valid = slot_pos >= 0
    if cfg.attention == "sliding":
        valid &= slot_pos > pos - cfg.window
    # (for full attention S == max_seq so slot_pos == slots <= pos check)
    valid &= slot_pos <= pos

    qg = q.reshape(B, 1, K, H // K, dh)
    scale = 1.0 / np.sqrt(dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, cache.k.astype(dt)) * scale
    scores = scores.astype(jnp.float32)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", w, cache.v.astype(dt))
    ctx = ctx.reshape(B, 1, H, dh)
    out = out_proj(p, ctx, cfg)
    return out, cache
