"""Mixture-of-Experts FFN: top-k router + expert-parallel execution.

Production path (``apply_moe`` with a mesh): the layer runs inside
``shard_map``. Expert weights are sharded over the ``model`` mesh axis;
activations arrive batch-sharded over (``pod``, ``data``) and replicated over
``model``. Each device routes its *local* tokens, gathers the ones assigned
to its *local* experts into a capacity-bounded (E_loc, C, d) group buffer,
runs the expert FFNs as dense MXU matmuls, scatter-adds weighted outputs to
a local partial, and a single ``psum`` over ``model`` combines expert
contributions — the same one collective a Megatron-sharded dense FFN needs.
No all-to-all and no (B,T,E,C) dispatch tensor is ever materialized.

Reference path (``apply_moe_dense``): the naive every-expert-sees-every-token
einsum. Exact, O(E/k) more FLOPs — used as the oracle in tests and for tiny
smoke configs only.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import common
from repro.sharding.partition import shard_map


def init_moe(cfg: ArchConfig, rng) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 4)
    return {
        "router": common.normal_init(ks[0], (d, E), 0.02),
        "w_gate": common.he_init(ks[1], (E, d, f), d),
        "w_up": common.he_init(ks[2], (E, d, f), d),
        "w_down": common.he_init(ks[3], (E, f, d), f),
    }


def logical_axes(cfg: ArchConfig) -> dict:
    return {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "expert_ffn"),
        "w_up": ("experts", "embed", "expert_ffn"),
        "w_down": ("experts", "expert_ffn", "embed"),
    }


def _route(router_w, x, cfg: ArchConfig):
    """x (N,d) -> (topv (N,k) f32 renormalized, topi (N,k) i32, aux scalar)."""
    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    # Switch-style load-balance loss over the local token set
    frac_tokens = jnp.mean(
        jax.nn.one_hot(topi, cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
    return topv, topi, aux


def _expert_ffn(p, xe, cfg: ArchConfig, e_slice=None):
    """xe (E?, C, d) against expert weight stacks (E?, d, f)."""
    dt = xe.dtype
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    if e_slice is not None:
        wg, wu, wd = wg[e_slice], wu[e_slice], wd[e_slice]
    g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd.astype(dt))


def _capacity(n_tokens: int, cfg: ArchConfig, n_local_experts: int,
              factor: float = None) -> int:
    factor = factor if factor is not None else cfg.moe_capacity_factor
    expect = n_tokens * cfg.top_k / cfg.n_experts
    c = int(factor * expect) + 8
    return max(8, (c + 7) // 8 * 8)


def _moe_local(p_local, x, cfg: ArchConfig, e_offset, n_local_experts: int,
               capacity: int):
    """Grouped dispatch over the device-local token set and expert shard.

    p_local: expert weights already sliced to the local shard (E_loc, ...).
    x: (N, d) local tokens. e_offset: global id of first local expert.
    Returns (y_partial (N, d) — contributions of LOCAL experts only, aux).
    """
    N, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    topv, topi, aux = _route(p_local["router"], x, cfg)

    # map global expert ids to local slots; non-local -> capacity overflow bin
    local_e = topi - e_offset                                   # (N,k)
    is_local = (local_e >= 0) & (local_e < n_local_experts)
    flat_e = jnp.where(is_local, local_e, n_local_experts).reshape(-1)  # (N*k,)

    # position of each (token, slot) in its expert queue (stable order)
    onehot = jax.nn.one_hot(flat_e, n_local_experts + 1, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.sum(pos_in_e * onehot, axis=1)                   # (N*k,)
    keep = (slot < capacity) & (flat_e < n_local_experts)
    dest = jnp.where(keep, flat_e * capacity + slot,
                     n_local_experts * capacity)

    # Dispatch/combine unrolled over the k routing slots: a single fused
    # gather would materialize an (N*k, d) tensor — measured 4 GiB (+4 GiB
    # f32 cotangent) per layer at qwen3 scale (§Perf D). Per-slot scatters
    # touch only (N, d) at a time.
    dest2 = dest.reshape(N, k)
    buf = jnp.zeros((n_local_experts * capacity + 1, d), dt)
    for j in range(k):
        buf = buf.at[dest2[:, j]].set(x, mode="drop")
    xe = buf[:-1].reshape(n_local_experts, capacity, d)

    ye = _expert_ffn(p_local, xe, cfg)                          # (E_loc,C,d)

    yf = ye.reshape(n_local_experts * capacity, d)
    w2 = (topv * keep.reshape(N, k)).astype(dt)                 # (N,k)
    src2 = jnp.minimum(dest2, n_local_experts * capacity - 1)
    y = jnp.zeros((N, d), dt)
    for j in range(k):
        y = y + yf[src2[:, j]] * w2[:, j, None]
    return y, aux


def apply_moe(p, x, cfg: ArchConfig, mesh: Optional[Mesh] = None,
              expert_axis: str = "model"):
    """x (B,T,d) -> (y (B,T,d), aux). Expert-parallel when a mesh with the
    expert axis is provided; single-device grouped dispatch otherwise."""
    B, T, d = x.shape

    if mesh is None or expert_axis not in mesh.shape:
        xf = x.reshape(B * T, d)
        cap = _capacity(B * T, cfg, cfg.n_experts)
        y, aux = _moe_local(p, xf, cfg, 0, cfg.n_experts, cap)
        return y.reshape(B, T, d), aux

    n_shards = mesh.shape[expert_axis]
    assert cfg.n_experts % n_shards == 0, (cfg.n_experts, n_shards)
    e_loc = cfg.n_experts // n_shards
    # shard the batch over whichever data-like axes divide it (B=1 decode
    # shapes leave the data axes idle)
    batch_axes = []
    prod = 1
    for a in ("pod", "data"):
        if a in mesh.shape and B % (prod * mesh.shape[a]) == 0:
            batch_axes.append(a)
            prod *= mesh.shape[a]
    batch_axes = tuple(batch_axes)

    # FSDP composition: expert weights stay sharded over `data` on their
    # embed/ffn dims in the in_specs and are all-gathered INSIDE the body —
    # when this layer runs under scan-over-layers that keeps the gather
    # per-layer-per-step. Replicated in_specs instead would force XLA to
    # materialize the full 48-layer expert stack before the scan
    # (measured: +10 GiB temp on qwen3-moe train_4k; §Perf D).
    fsdp = ("data" in mesh.shape and cfg.d_model % mesh.shape["data"] == 0
            and cfg.d_ff % 1 == 0)
    fsdp_axis = "data" if fsdp else None

    def shard_fn(p_sh, x_sh):
        # x_sh: (B_loc, T, d) — replicated over the expert axis
        if fsdp_axis is not None:
            p_sh = dict(
                p_sh,
                w_gate=jax.lax.all_gather(p_sh["w_gate"], fsdp_axis,
                                          axis=1, tiled=True),
                w_up=jax.lax.all_gather(p_sh["w_up"], fsdp_axis,
                                        axis=1, tiled=True),
                w_down=jax.lax.all_gather(p_sh["w_down"], fsdp_axis,
                                          axis=2, tiled=True),
            )
        Bl, Tl, dl = x_sh.shape
        eid = jax.lax.axis_index(expert_axis)
        cap = _capacity(Bl * Tl, cfg, e_loc)
        y, aux = _moe_local(p_sh, x_sh.reshape(Bl * Tl, dl), cfg,
                            eid * e_loc, e_loc, cap)
        y = jax.lax.psum(y, expert_axis)          # combine expert partials
        aux = jax.lax.pmean(aux, expert_axis)
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return y.reshape(Bl, Tl, dl), aux

    if fsdp_axis is not None:
        wspec = {"w_gate": P(expert_axis, fsdp_axis, None),
                 "w_up": P(expert_axis, fsdp_axis, None),
                 "w_down": P(expert_axis, None, fsdp_axis)}
    else:
        wspec = {"w_gate": P(expert_axis), "w_up": P(expert_axis),
                 "w_down": P(expert_axis)}
    pspec = {"router": P(), **wspec}
    xspec = P(batch_axes if batch_axes else None)
    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(pspec, xspec),
                   out_specs=(xspec, P()),
                   check_vma=False)
    return fn(p, x)


def apply_moe_dense(p, x, cfg: ArchConfig):
    """Oracle: every expert computes every token; combine by router weights."""
    B, T, d = x.shape
    E = cfg.n_experts
    dt = x.dtype
    topv, topi, aux = _route(p["router"], x.reshape(B * T, d), cfg)
    combine = jnp.sum(jax.nn.one_hot(topi, E, dtype=jnp.float32)
                      * topv[..., None], axis=1)                # (N,E)
    xf = x.reshape(1, B * T, d) * jnp.ones((E, 1, 1), dt)
    ye = _expert_ffn(p, xf, cfg)                                # (E,N,d)
    y = jnp.einsum("end,ne->nd", ye.astype(jnp.float32),
                   combine).astype(dt)
    return y.reshape(B, T, d), aux
