"""Shared model building blocks: norms, RoPE, initializers, embeddings.

All modules are functional: ``init_*`` returns a param pytree, ``apply``-style
functions are pure. Activation sharding uses logical-axis constraints from
repro.sharding (no-ops outside a mesh context).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def normal_init(rng, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.normal(rng, shape, dtype)


def he_init(rng, shape, fan_in, dtype=jnp.float32):
    return jax.random.normal(rng, shape, dtype) / np.sqrt(fan_in)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, d: int):
    if cfg.norm_kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(p, x, cfg: ArchConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, Dh) — rotate pairs. positions: (..., T) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., T, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def init_embedding(cfg: ArchConfig, rng) -> dict:
    p = {"tok": normal_init(rng, (cfg.vocab_size, cfg.d_model), 0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = normal_init(jax.random.fold_in(rng, 1),
                                   (cfg.d_model, cfg.vocab_size), 0.02)
    if cfg.input_kind == "embeddings":
        # projector from the (stubbed) modality frontend's embedding space
        p["frontend_proj"] = he_init(jax.random.fold_in(rng, 2),
                                     (cfg.d_model, cfg.d_model), cfg.d_model)
    return p


def embed_tokens(p, tokens, cfg: ArchConfig, dtype):
    x = jnp.take(p["tok"], tokens, axis=0).astype(dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    return x


def embed_frontend(p, embeddings, cfg: ArchConfig, dtype):
    """Modality carve-out: precomputed frame/patch embeddings -> d_model."""
    return (embeddings.astype(dtype) @ p["frontend_proj"].astype(dtype))


def unembed(p, x, cfg: ArchConfig):
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    return x @ w.astype(x.dtype)


def logical_axes_embedding(cfg: ArchConfig) -> dict:
    lg = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        lg["unembed"] = ("embed", "vocab")
    if cfg.input_kind == "embeddings":
        lg["frontend_proj"] = ("embed", "embed2")
    return lg
