"""Mamba2 (SSD — state-space duality) block, chunked for the TPU MXU.

Recurrence per head h (head_dim p, state n):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t B_t^T        (h: (p, n))
    y_t = h_t C_t + D * x_t

Chunked evaluation (Dao & Gu 2024), scan over chunks of length Q:
  intra-chunk: attention-like lower-triangular term with cumulative decays,
  inter-chunk: carried state h updated once per chunk.
Both terms are dense einsums -> MXU-friendly; the scan carries only the
(heads, p, n) state. Decode is the exact single-step recurrence.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common
from repro.sharding import constrain


class MambaCache(NamedTuple):
    h: jax.Array        # (B, H, p, n) SSM state
    conv: jax.Array     # (B, W-1, conv_channels) causal-conv history


def _dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads
    p = d_in // H
    n = cfg.ssm_state
    conv_ch = d_in + 2 * n
    return d_in, H, p, n, conv_ch


def init_mamba2(cfg: ArchConfig, rng) -> dict:
    d = cfg.d_model
    d_in, H, p, n, conv_ch = _dims(cfg)
    ks = jax.random.split(rng, 8)
    dt = jnp.exp(jax.random.uniform(ks[5], (H,), jnp.float32,
                                    np.log(1e-3), np.log(1e-1)))
    return {
        "w_z": common.he_init(ks[0], (d, d_in), d),
        "w_xbc": common.he_init(ks[1], (d, conv_ch), d),
        "w_dt": common.he_init(ks[2], (d, H), d),
        "conv_w": 0.1 * jax.random.normal(ks[3], (cfg.conv_width, conv_ch)),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)),                  # softplus inverse
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "w_out": common.he_init(ks[4], (d_in, d), d_in),
    }


def logical_axes(cfg: ArchConfig) -> dict:
    return {
        "w_z": ("embed", "ffn"), "w_xbc": ("embed", "ffn"),
        "w_dt": ("embed", None), "conv_w": ("conv", None),
        "conv_b": (None,), "dt_bias": (None,), "A_log": (None,),
        "D": (None,), "norm_scale": (None,), "w_out": ("ffn", "embed"),
    }


def _causal_conv(x, w, b, history=None):
    """Depthwise causal conv. x (B,T,C), w (W,C). history (B,W-1,C) or None."""
    W = w.shape[0]
    if history is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = history.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (B, T+W-1, C)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    return out + b.astype(x.dtype)


def _proj_split(p, x, cfg: ArchConfig):
    d_in, H, _, n, conv_ch = _dims(cfg)
    dt_ = x.dtype
    z = x @ p["w_z"].astype(dt_)                        # (B,T,d_in)
    xbc = x @ p["w_xbc"].astype(dt_)                    # (B,T,conv_ch)
    dt_raw = x @ p["w_dt"].astype(dt_)                  # (B,T,H)
    return z, xbc, dt_raw


def _post(p, y, z, cfg: ArchConfig):
    """Gated RMSNorm + output projection. y,z (B,T,d_in)."""
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"]).astype(y.dtype)
    return y @ p["w_out"].astype(y.dtype)


def apply_mamba2(p, x, cfg: ArchConfig, chunk: int = None):
    """Training/prefill forward. x (B,T,d) -> (B,T,d)."""
    B, T, d = x.shape
    d_in, H, ph, n, conv_ch = _dims(cfg)
    dtype = x.dtype
    tile_dt = jnp.dtype(cfg.ssm_tile_dtype)
    chunk = min(chunk or cfg.ssm_chunk, T)
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk

    z, xbc, dt_raw = _proj_split(p, x, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_in].reshape(B, T, H, ph)
    Bm = xbc[..., d_in:d_in + n]                        # (B,T,n)
    Cm = xbc[..., d_in + n:]                            # (B,T,n)

    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32)
                           + p["dt_bias"])              # (B,T,H)
    A = -jnp.exp(p["A_log"])                            # (H,) negative
    la = dt_v * A[None, None, :]                        # log decay, (B,T,H)

    # chunked views
    xs_c = xs.reshape(B, nc, chunk, H, ph)
    B_c = Bm.reshape(B, nc, chunk, n)
    C_c = Cm.reshape(B, nc, chunk, n)
    dt_c = dt_v.reshape(B, nc, chunk, H)
    la_c = la.reshape(B, nc, chunk, H)

    def chunk_step(h, inputs):
        xs_k, B_k, C_k, dt_k, la_k = inputs
        # cumulative decays within the chunk (inclusive), always f32
        W = jnp.cumsum(la_k, axis=1)                    # (B,Q,H)
        W_last = W[:, -1]                               # (B,H)
        # All O(Q^2) / O(Q*H*p) tiles are held in cfg.ssm_tile_dtype (bf16
        # for the production configs); every einsum accumulates in f32 via
        # preferred_element_type. Only the scalar-ish decay math is f32.
        C_t = C_k.astype(tile_dt)
        B_t = B_k.astype(tile_dt)
        x_t = xs_k.astype(tile_dt)
        # NOTE: every contraction below is written as explicit two-operand
        # steps — a single 3/4-operand einsum lets XLA pick a contraction
        # order that materializes a (B,Q,S,H,p) 5-D intermediate (measured:
        # 5.4 GB per dot at the full config; §Perf A it6).
        # ---- inter-chunk: y_t += C_t (exp(W_t) h_prev); W_t includes la_t
        # because h_t = exp(la_t) h_{t-1} + ... applies decay at every step
        decay_to_t = jnp.exp(W).astype(tile_dt)         # (B,Q,H)
        ch = jnp.einsum("bqn,bhpn->bqhp", C_t, h.astype(tile_dt),
                        preferred_element_type=jnp.float32)
        y_inter = ch * decay_to_t[..., None]            # (B,Q,H,p) f32
        # ---- intra-chunk: attention-like with decay kernel
        # contribution of s<=t: dt_s * exp(sum_{i=s+1..t} la_i) * (C_t.B_s) x_s
        G = jnp.einsum("bqn,bsn->bqs", C_t, B_t,
                       preferred_element_type=jnp.float32)  # (B,Q,S)
        Wdiff = W[:, :, None, :] - W[:, None, :, :]     # (B,Q,S,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        Ldec = jnp.where(mask[None, :, :, None],
                         jnp.exp(Wdiff), 0.0).astype(tile_dt)
        att = (G[..., None].astype(tile_dt) * Ldec
               * dt_k[:, None].astype(tile_dt))         # (B,Q,S,H)
        y_intra = jnp.einsum("bqsh,bshp->bqhp", att, x_t,
                             preferred_element_type=jnp.float32)
        # ---- state update: h_new = exp(W_last) h + sum_s exp(W_last-W_s) dt_s x_s B_s^T
        carry_decay = jnp.exp(W_last)                   # (B,H)
        src = (jnp.exp(W_last[:, None, :] - W) * dt_k).astype(tile_dt)
        xsrc = x_t * src[..., None]                     # (B,Q,H,p)
        h_new = (carry_decay[:, :, None, None] * h
                 + jnp.einsum("bqhp,bqn->bhpn", xsrc, B_t,
                              preferred_element_type=jnp.float32))
        y = (y_inter + y_intra).astype(tile_dt)         # (B,Q,H,p)
        return h_new, y

    h0 = jnp.zeros((B, H, ph, n), jnp.float32)
    inputs = (xs_c.transpose(1, 0, 2, 3, 4), B_c.transpose(1, 0, 2, 3),
              C_c.transpose(1, 0, 2, 3), dt_c.transpose(1, 0, 2, 3),
              la_c.transpose(1, 0, 2, 3))
    # checkpoint: the (B,Q,Q,H) decay/attention tiles are recomputed in the
    # backward pass instead of being stored per chunk
    _, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, inputs)  # (nc,B,Q,H,p)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, ph)
    y = y + (p["D"].astype(tile_dt)[None, None, :, None]
             * xs.astype(tile_dt))
    y = y.reshape(B, T, d_in).astype(dtype)
    return _post(p, y, z, cfg)


def init_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> MambaCache:
    d_in, H, p, n, conv_ch = _dims(cfg)
    return MambaCache(
        h=jnp.zeros((batch, H, p, n), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype))


def decode_step(p, x, cache: MambaCache, cfg: ArchConfig):
    """x (B,1,d) -> (y (B,1,d), cache). Exact recurrence."""
    B = x.shape[0]
    d_in, H, ph, n, conv_ch = _dims(cfg)
    dtype = x.dtype

    z, xbc, dt_raw = _proj_split(p, x, cfg)
    conv_hist = jnp.concatenate([cache.conv, xbc.astype(cache.conv.dtype)],
                                axis=1)                 # (B,W,C)
    xbc_t = jnp.einsum("bwc,wc->bc", conv_hist.astype(dtype),
                       p["conv_w"].astype(dtype)) + p["conv_b"].astype(dtype)
    xbc_t = jax.nn.silu(xbc_t)                          # (B,C)
    new_conv = conv_hist[:, 1:]

    xs = xbc_t[:, :d_in].reshape(B, H, ph)
    Bm = xbc_t[:, d_in:d_in + n]                        # (B,n)
    Cm = xbc_t[:, d_in + n:]                            # (B,n)
    dt_v = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_v * A[None, :])                  # (B,H)

    h = (decay[:, :, None, None] * cache.h
         + jnp.einsum("bh,bhp,bn->bhpn", dt_v, xs.astype(jnp.float32),
                      Bm.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, d_in).astype(dtype)
    out = _post(p, y, z, cfg)
    return out, MambaCache(h=h, conv=new_conv)


def apply_mamba2_kernel(p, x, cfg: ArchConfig, chunk: int = 128,
                        interpret: bool = True):
    """Inference/prefill forward through the Pallas SSD kernel
    (kernels/ssd_chunk): chunk tiles stay in VMEM, HBM traffic is inputs +
    outputs only. Forward-only (training uses apply_mamba2)."""
    from repro.kernels.ssd_chunk import ssd_core
    B, T, d = x.shape
    d_in, H, ph, n, conv_ch = _dims(cfg)
    dtype = x.dtype

    z, xbc, dt_raw = _proj_split(p, x, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_in].reshape(B, T, H, ph)
    Bm = xbc[..., d_in:d_in + n]
    Cm = xbc[..., d_in + n:]
    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    la = dt_v * A[None, None, :]

    y, _ = ssd_core(xs, Bm, Cm, dt_v, la, chunk=min(chunk, T),
                    interpret=interpret)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, T, d_in).astype(dtype)
    return _post(p, y, z, cfg)


# ---------------------------------------------------------------------------
# Reference (exact sequential scan) — oracle for tests.
# ---------------------------------------------------------------------------

def apply_mamba2_ref(p, x, cfg: ArchConfig):
    """Token-by-token recurrence; numerically exact, O(T) sequential."""
    B, T, d = x.shape
    cache = init_cache(cfg, B, dtype=x.dtype)
    # run the shared pre-compute once to keep conv semantics identical
    z, xbc, dt_raw = _proj_split(p, x, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    d_in, H, ph, n, conv_ch = _dims(cfg)
    xs = xbc[..., :d_in].reshape(B, T, H, ph)
    Bm = xbc[..., d_in:d_in + n]
    Cm = xbc[..., d_in + n:]
    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    def step(h, t_in):
        xs_t, B_t, C_t, dt_t = t_in
        decay = jnp.exp(dt_t * A[None, :])
        h = (decay[:, :, None, None] * h
             + jnp.einsum("bh,bhp,bn->bhpn", dt_t, xs_t.astype(jnp.float32),
                          B_t.astype(jnp.float32)))
        y = jnp.einsum("bhpn,bn->bhp", h, C_t.astype(jnp.float32))
        return h, y

    h0 = jnp.zeros((B, H, ph, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (xs.transpose(1, 0, 2, 3),
                                    Bm.transpose(1, 0, 2),
                                    Cm.transpose(1, 0, 2),
                                    dt_v.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3)                        # (B,T,H,p)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, T, d_in).astype(x.dtype)
    return _post(p, y, z, cfg)
