"""RWKV-6 "Finch" time-mix block — attention-free, data-dependent decay.

Per head (key/value dims p), with receptance r, key k, value v, per-channel
data-dependent decay w_t (the Finch contribution) and bonus u:

    y_t = r_t^T (diag(u) k_t v_t^T + S_{t-1})
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Training/prefill uses a chunked evaluation (scan over chunks; intra-chunk
attention-like einsum with cumulative log decays, inter-chunk state carry) —
the TPU-idiomatic form. Decode is the exact recurrence.

Simplifications vs the released model (noted in DESIGN.md): static token-shift
mix vectors (full ddlerp omitted); decay LoRA retained since data-dependent
decay is the paper's headline feature. Channel-mix lives in models/mlp.py.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common


class RWKVCache(NamedTuple):
    S: jax.Array          # (B, H, pk, pv) wkv state
    x_att: jax.Array      # (B, d) previous token (time-mix shift)
    x_ffn: jax.Array      # (B, d) previous token (channel-mix shift)


def _dims(cfg: ArchConfig):
    H = cfg.n_heads
    p = cfg.dim_per_head
    return H, p


def init_rwkv6(cfg: ArchConfig, rng) -> dict:
    d = cfg.d_model
    H, p = _dims(cfg)
    lora = max(32, d // 32)
    ks = jax.random.split(rng, 10)
    return {
        "mix_r": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_k": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_v": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_w": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_g": 0.5 * jnp.ones((d,), jnp.float32),
        "w_r": common.he_init(ks[0], (d, d), d),
        "w_k": common.he_init(ks[1], (d, d), d),
        "w_v": common.he_init(ks[2], (d, d), d),
        "w_g": common.he_init(ks[3], (d, d), d),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
        "w0": -6.0 + 0.5 * jax.random.normal(ks[4], (d,), jnp.float32),
        "w_lora_a": common.he_init(ks[5], (d, lora), d),
        "w_lora_b": 0.01 * jax.random.normal(ks[6], (lora, d), jnp.float32),
        "u": 0.5 * jax.random.normal(ks[7], (H, p), jnp.float32),
        "ln_scale": jnp.ones((d,), jnp.float32),   # per-head group norm scale
        "w_o": common.he_init(ks[8], (d, d), d),
    }


def logical_axes(cfg: ArchConfig) -> dict:
    return {
        "mix_r": (None,), "mix_k": (None,), "mix_v": (None,), "mix_w": (None,),
        "mix_g": (None,),
        "w_r": ("embed", "heads_flat"), "w_k": ("embed", "heads_flat"),
        "w_v": ("embed", "heads_flat"), "w_g": ("embed", "heads_flat"),
        "w0": (None,), "w_lora_a": ("embed", None), "w_lora_b": (None, None),
        "u": ("heads", None), "ln_scale": (None,), "w_o": ("heads_flat", "embed"),
    }


def _shift(x, x_prev):
    """Token shift: x_{t-1} with x_prev filling t=0. x (B,T,d), x_prev (B,d)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1]], axis=1)


def _mix_heads(p, x, x_prev, cfg: ArchConfig):
    """Compute r,k,v,g,(log)w from token-shifted mixes. Returns heads layout."""
    B, T, d = x.shape
    H, ph = _dims(cfg)
    dt = x.dtype
    xs = _shift(x, x_prev)

    def mix(m):
        return x + (xs - x) * p[m].astype(dt)

    r = (mix("mix_r") @ p["w_r"].astype(dt)).reshape(B, T, H, ph)
    k = (mix("mix_k") @ p["w_k"].astype(dt)).reshape(B, T, H, ph)
    v = (mix("mix_v") @ p["w_v"].astype(dt)).reshape(B, T, H, ph)
    g = jax.nn.silu(mix("mix_g") @ p["w_g"].astype(dt))          # (B,T,d)
    xw = mix("mix_w").astype(jnp.float32)
    lw = p["w0"] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]  # (B,T,d)
    # clamp so per-token log-decay is in [-5, 0): w <= e^-5 is already ~fully
    # forgotten after 2 tokens, and the bound keeps the chunked form's
    # exp(+/-W) factors inside f32 range (see apply_rwkv6)
    logw = -jnp.exp(jnp.clip(lw, -20.0, 1.609))                  # log decay < 0
    logw = logw.reshape(B, T, H, ph)
    return r, k, v, g, logw


def _group_norm(y, scale, cfg: ArchConfig, eps=64e-5):
    """Per-head LayerNorm (RWKV 'ln_x'). y (B,T,H,p)."""
    yf = y.astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + eps)
    B, T, H, p = y.shape
    return (yn.reshape(B, T, H * p) * scale).astype(y.dtype)


def apply_rwkv6(p, x, cfg: ArchConfig, x_prev=None, chunk: int = 32):
    """Training/prefill forward. x (B,T,d) -> (B,T,d).

    x_prev (B,d): last token of the previous segment (zeros at sequence start).
    """
    B, T, d = x.shape
    H, ph = _dims(cfg)
    dtype = x.dtype
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    if x_prev is None:
        x_prev = jnp.zeros((B, d), dtype)

    r, k, v, g, logw = _mix_heads(p, x, x_prev, cfg)
    u = p["u"]                                                   # (H,p)

    rc = r.reshape(B, nc, chunk, H, ph).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nc, chunk, H, ph).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, H, ph).transpose(1, 0, 2, 3, 4)
    wc = logw.reshape(B, nc, chunk, H, ph).transpose(1, 0, 2, 3, 4)

    def chunk_step(S, inp):
        r_k, k_k, v_k, lw_k = inp                    # (B,Q,H,p*)
        r_f = r_k.astype(jnp.float32)
        k_f = k_k.astype(jnp.float32)
        v_f = v_k.astype(jnp.float32)
        W = jnp.cumsum(lw_k, axis=1)                 # (B,Q,H,pk) inclusive
        Wm1 = W - lw_k                               # exclusive (up to t-1)
        # inter-chunk: y_t += (r_t * exp(Wm1_t))^T S_prev  (Wm1 <= 0, safe)
        y_inter = jnp.einsum("bqhk,bhkv->bqhv", r_f * jnp.exp(Wm1), S)
        # intra-chunk (s < t): A[t,s] = sum_k r_t,k k_s,k exp(Wm1_t - W_s)
        #   = sum_k (r_t,k e^{Wm1_t-c}) (k_s,c e^{c-W_s}); centering by
        #   c = W_last/2 keeps both factors inside f32 range for chunk<=32
        c = 0.5 * W[:, -1]                           # (B,H,pk)
        rdec = r_f * jnp.exp(Wm1 - c[:, None])
        kdec = k_f * jnp.exp(c[:, None] - W)
        att = jnp.einsum("bqhk,bshk->bhqs", rdec, kdec)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        y_intra = jnp.einsum("bhqs,bshv->bqhv", att, v_f)
        # current-token bonus: (r_t . (u * k_t)) v_t
        bonus = jnp.einsum("bqhk,hk,bqhk->bqh", r_f, u, k_f)
        y_bonus = bonus[..., None] * v_f
        # state: S_new = diag(exp(W_last)) S + sum_s e^{W_last - W_s} k_s v_s^T
        W_last = W[:, -1]                            # (B,H,pk)
        ksrc = k_f * jnp.exp(W_last[:, None] - W)
        S_new = (jnp.exp(W_last)[..., None] * S
                 + jnp.einsum("bshk,bshv->bhkv", ksrc, v_f))
        return S_new, y_inter + y_intra + y_bonus

    S0 = jnp.zeros((B, H, ph, ph), jnp.float32)
    # checkpoint: recompute intra-chunk tiles in backward (see mamba2.py)
    S_fin, ys = jax.lax.scan(jax.checkpoint(chunk_step), S0, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, ph)
    y = _group_norm(y, p["ln_scale"], cfg)                       # (B,T,d)
    y = (y * g).astype(dtype)
    return y @ p["w_o"].astype(dtype)


def init_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> RWKVCache:
    H, ph = _dims(cfg)
    return RWKVCache(
        S=jnp.zeros((batch, H, ph, ph), jnp.float32),
        x_att=jnp.zeros((batch, cfg.d_model), dtype),
        x_ffn=jnp.zeros((batch, cfg.d_model), dtype))


def decode_step(p, x, cache: RWKVCache, cfg: ArchConfig):
    """Exact single-token recurrence. x (B,1,d)."""
    B, _, d = x.shape
    H, ph = _dims(cfg)
    dtype = x.dtype
    r, k, v, g, logw = _mix_heads(p, x, cache.x_att.astype(dtype), cfg)
    r_f = r[:, 0].astype(jnp.float32)                 # (B,H,p)
    k_f = k[:, 0].astype(jnp.float32)
    v_f = v[:, 0].astype(jnp.float32)
    w_f = jnp.exp(logw[:, 0])                         # (B,H,p) decay
    u = p["u"]

    kv = jnp.einsum("bhk,bhv->bhkv", k_f, v_f)
    y = jnp.einsum("bhk,bhkv->bhv", r_f, u[None, :, :, None] * kv + cache.S)
    S_new = w_f[..., None] * cache.S + kv

    y = y[:, None]                                    # (B,1,H,p)
    y = _group_norm(y.reshape(B, 1, H, ph), p["ln_scale"], cfg)
    y = (y * g).astype(dtype)
    out = y @ p["w_o"].astype(dtype)
    return out, RWKVCache(S=S_new, x_att=x[:, 0], x_ffn=cache.x_ffn)


# ---------------------------------------------------------------------------
# Reference: exact token-by-token recurrence (oracle for the chunked form).
# ---------------------------------------------------------------------------

def apply_rwkv6_ref(p, x, cfg: ArchConfig, x_prev=None):
    B, T, d = x.shape
    H, ph = _dims(cfg)
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    r, k, v, g, logw = _mix_heads(p, x, x_prev, cfg)
    u = p["u"]

    def step(S, t_in):
        r_t, k_t, v_t, lw_t = t_in                    # (B,H,p)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t,
                       u[None, :, :, None] * kv + S)
        S = jnp.exp(lw_t)[..., None] * S + kv
        return S, y

    S0 = jnp.zeros((B, H, ph, ph), jnp.float32)
    seq = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
           k.transpose(1, 0, 2, 3).astype(jnp.float32),
           v.transpose(1, 0, 2, 3).astype(jnp.float32),
           logw.transpose(1, 0, 2, 3))
    _, ys = jax.lax.scan(step, S0, seq)
    y = ys.transpose(1, 0, 2, 3).reshape(B, T, H, ph)
    y = _group_norm(y.reshape(B, T, H, ph), p["ln_scale"], cfg)
    y = (y * g).astype(x.dtype)
    return y @ p["w_o"].astype(x.dtype)
