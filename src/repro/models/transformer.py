"""Backbone assembly: scan-over-layers decoder/encoder for every family.

Families map to per-layer block kinds:
  dense / moe / vlm / audio -> attention block (+ MLP or MoE)
  ssm (rwkv6)               -> rwkv6 time-mix + channel-mix
  hybrid (zamba2)           -> mamba2 blocks with a *shared* attention block
                               applied every ``shared_attn_every`` layers

Layer parameters are stacked along a leading ``layers`` axis and executed
with ``lax.scan`` (bounded HLO size and compile time for the 40+ dry-run
configs). ``remat=True`` checkpoints each layer.

Public surface (functional):
    model = build_model(cfg)
    params = model.init(rng)
    logits, aux = model.apply(params, batch, mesh=..., remat=...)
    cache = model.init_decode_cache(batch_size, max_seq)
    logits, cache = model.decode_step(params, cache, tokens, pos, mesh=...)
    emb = model.embed_pool(params, batch)   # pooled embeddings for DML
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention, common, mamba2, mlp, moe, rwkv6
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# Per-layer blocks
# ---------------------------------------------------------------------------

def _init_attn_block(cfg: ArchConfig, rng) -> dict:
    ks = jax.random.split(rng, 4)
    p = {"norm1": common.init_norm(cfg, cfg.d_model),
         "attn": attention.init_attention(cfg, ks[0])}
    if not cfg.parallel_block:
        p["norm2"] = common.init_norm(cfg, cfg.d_model)
    if cfg.n_experts and cfg.family == "moe":
        p["moe"] = moe.init_moe(cfg, ks[1])
    else:
        p["mlp"] = mlp.init_mlp(cfg, ks[1])
    return p


def _init_rwkv_block(cfg: ArchConfig, rng) -> dict:
    ks = jax.random.split(rng, 2)
    return {"norm1": common.init_norm(cfg, cfg.d_model),
            "tmix": rwkv6.init_rwkv6(cfg, ks[0]),
            "norm2": common.init_norm(cfg, cfg.d_model),
            "cmix": mlp.init_mlp(cfg, ks[1])}


def _init_mamba_block(cfg: ArchConfig, rng) -> dict:
    return {"norm1": common.init_norm(cfg, cfg.d_model),
            "mamba": mamba2.init_mamba2(cfg, rng)}


def _apply_attn_block(p, x, cfg: ArchConfig, mesh=None, positions=None):
    """Full-sequence attention block. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = common.apply_norm(p["norm1"], x, cfg)
    q, k, v = attention.qkv_proj(p["attn"], h, positions, cfg)
    ctx = attention.attend(q, k, v, cfg)
    att_out = attention.out_proj(p["attn"], ctx, cfg)
    if cfg.parallel_block:
        mlp_out = mlp.apply_mlp(p["mlp"], h, cfg)
        return x + att_out + mlp_out, aux
    x = x + att_out
    h2 = common.apply_norm(p["norm2"], x, cfg)
    if "moe" in p:
        y, aux = moe.apply_moe(p["moe"], h2, cfg, mesh=mesh)
    else:
        y = mlp.apply_mlp(p["mlp"], h2, cfg)
    return x + y, aux


def _decode_attn_block(p, x, cache, pos, cfg: ArchConfig, mesh=None):
    aux = jnp.zeros((), jnp.float32)
    h = common.apply_norm(p["norm1"], x, cfg)
    att_out, cache = attention.decode_attend(p["attn"], h, cache, pos, cfg)
    if cfg.parallel_block:
        mlp_out = mlp.apply_mlp(p["mlp"], h, cfg)
        return x + att_out + mlp_out, cache, aux
    x = x + att_out
    h2 = common.apply_norm(p["norm2"], x, cfg)
    if "moe" in p:
        y, aux = moe.apply_moe(p["moe"], h2, cfg, mesh=mesh)
    else:
        y = mlp.apply_mlp(p["mlp"], h2, cfg)
    return x + y, cache, aux


def _apply_rwkv_block(p, x, cfg: ArchConfig):
    h = common.apply_norm(p["norm1"], x, cfg)
    x = x + rwkv6.apply_rwkv6(p["tmix"], h, cfg)
    h2 = common.apply_norm(p["norm2"], x, cfg)
    h2_prev = jnp.concatenate([jnp.zeros_like(h2[:, :1]), h2[:, :-1]], axis=1)
    x = x + mlp.apply_mlp(p["cmix"], h2, cfg, x_prev=h2_prev)
    return x


def _decode_rwkv_block(p, x, cache: rwkv6.RWKVCache, cfg: ArchConfig):
    h = common.apply_norm(p["norm1"], x, cfg)
    y, cache = rwkv6.decode_step(p["tmix"], h, cache, cfg)
    x = x + y
    h2 = common.apply_norm(p["norm2"], x, cfg)
    x = x + mlp.apply_mlp(p["cmix"], h2, cfg,
                          x_prev=cache.x_ffn[:, None].astype(x.dtype))
    cache = cache._replace(x_ffn=h2[:, 0])
    return x, cache


def _apply_mamba_block(p, x, cfg: ArchConfig):
    h = common.apply_norm(p["norm1"], x, cfg)
    return x + mamba2.apply_mamba2(p["mamba"], h, cfg)


def _decode_mamba_block(p, x, cache, cfg: ArchConfig):
    h = common.apply_norm(p["norm1"], x, cfg)
    y, cache = mamba2.decode_step(p["mamba"], h, cache, cfg)
    return x + y, cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ----- init -----

    def init(self, rng) -> dict:
        cfg = self.cfg
        k_emb, k_blocks, k_shared, k_final = jax.random.split(rng, 4)
        block_init = {
            "rwkv6": _init_rwkv_block,
            "mamba2": _init_mamba_block,
            "attn": _init_attn_block,
        }[cfg.block_kind if cfg.family in ("ssm", "hybrid") else "attn"]
        layer_keys = jax.random.split(k_blocks, cfg.n_layers)
        blocks = jax.vmap(lambda k: block_init(cfg, k))(layer_keys)
        params = {
            "embedding": common.init_embedding(cfg, k_emb),
            "blocks": blocks,
            "final_norm": common.init_norm(cfg, cfg.d_model),
        }
        if cfg.shared_attn_every:
            shared_cfg = self._shared_cfg()
            params["shared"] = _init_attn_block(shared_cfg, k_shared)
        return params

    def _shared_cfg(self) -> ArchConfig:
        """Config view for zamba2's shared attention block (windowed full
        attention + gelu MLP at d_model)."""
        cfg = self.cfg
        return cfg.replace(block_kind="attn", n_experts=0,
                           attention="sliding",
                           window=cfg.shared_attn_window,
                           mlp_kind="gelu", family="dense")

    # ----- full-sequence forward (train / prefill) -----

    def apply(self, params, batch: Dict[str, Any], mesh=None,
              remat: bool = False):
        """Returns (logits (B,T,V), aux dict)."""
        h, aux = self.hidden(params, batch, mesh=mesh, remat=remat)
        logits = common.unembed(params["embedding"], h, self.cfg)
        logits = constrain(logits, ("batch", "seq", "vocab"))
        return logits, aux

    def hidden(self, params, batch: Dict[str, Any], mesh=None,
               remat: bool = False):
        """Final normed hidden states (B,T,d) + aux — callers that want
        memory-bounded losses unembed in sequence chunks themselves."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = self._embed_inputs(params, batch, dtype)
        B, T, _ = x.shape
        positions = jnp.arange(T, dtype=jnp.int32)[None, :].repeat(B, 0)
        x = constrain(x, ("batch", "seq_sp", None))

        h, aux = self._run_blocks(params, x, cfg, mesh, remat, positions)
        h = common.apply_norm(params["final_norm"], h, cfg)
        return h, {"moe_aux": aux}

    def _embed_inputs(self, params, batch, dtype):
        cfg = self.cfg
        if cfg.input_kind == "embeddings" and "embeddings" in batch:
            return common.embed_frontend(params["embedding"],
                                         batch["embeddings"], cfg, dtype)
        return common.embed_tokens(params["embedding"], batch["tokens"],
                                   cfg, dtype)

    def _run_blocks(self, params, x, cfg, mesh, remat, positions):
        if cfg.family == "ssm":
            def body(carry, p_l):
                y = _apply_rwkv_block(p_l, carry, cfg)
                return constrain(y, ("batch", "seq_sp", None)), None
        elif cfg.family == "hybrid":
            return self._run_hybrid(params, x, cfg, mesh, remat, positions)
        else:
            def body(carry, p_l):
                y, aux = _apply_attn_block(p_l, carry, cfg, mesh, positions)
                return constrain(y, ("batch", "seq_sp", None)), aux

        if remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, params["blocks"])
        aux = jnp.zeros((), jnp.float32) if auxs is None else jnp.sum(auxs)
        return x, aux

    def _run_hybrid(self, params, x, cfg, mesh, remat, positions):
        """Zamba2: groups of mamba layers + the shared attention block."""
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        shared_cfg = self._shared_cfg()
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]),
            params["blocks"])

        def inner(carry, p_l):
            return _apply_mamba_block(p_l, carry, cfg), None

        if remat:
            inner = jax.checkpoint(inner)

        def group_body(carry, p_g):
            h, _ = jax.lax.scan(inner, carry, p_g)
            h2, _ = _apply_attn_block(params["shared"], h, shared_cfg,
                                      mesh, positions)
            return constrain(h2, ("batch", "seq_sp", None)), None

        if remat:
            # checkpoint the whole group too: without this the 9 shared-
            # attention invocations keep their flash carries/residuals live
            # for the entire backward pass
            group_body = jax.checkpoint(group_body)
        x, _ = jax.lax.scan(group_body, x, grouped)
        return x, jnp.zeros((), jnp.float32)

    # ----- decode -----

    def init_decode_cache(self, batch: int, max_seq: int,
                          dtype=None) -> dict:
        cfg = self.cfg
        if dtype is None:
            dtype = jnp.dtype(cfg.dtype)
        if not cfg.has_decode:
            raise ValueError(f"{cfg.name} is encoder-only: no decode step")
        L = cfg.n_layers
        stack = lambda c: jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), c)
        if cfg.family == "ssm":
            cache = {"blocks": stack(rwkv6.init_cache(cfg, batch, dtype))}
        elif cfg.family == "hybrid":
            every = cfg.shared_attn_every
            n_groups = cfg.n_layers // every
            shared_cfg = self._shared_cfg()
            mcache = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (L,) + a.shape),
                mamba2.init_cache(cfg, batch, dtype))
            scache = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape),
                attention.init_cache(shared_cfg, batch, max_seq, dtype))
            cache = {"blocks": mcache, "shared": scache}
        else:
            cache = {"blocks": stack(
                attention.init_cache(cfg, batch, max_seq, dtype))}
        return cache

    def decode_step(self, params, cache: dict, tokens, pos, mesh=None):
        """tokens (B,) or (B,1) int32; pos scalar int32 (current position).
        Returns (logits (B,V), new cache)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        if tokens.ndim == 1:
            tokens = tokens[:, None]
        x = common.embed_tokens(params["embedding"], tokens, cfg, dtype)

        if cfg.family == "ssm":
            def body(carry, pc):
                p_l, c_l = pc
                y, c_new = _decode_rwkv_block(p_l, carry, c_l, cfg)
                return y, c_new
            x, new_blocks = jax.lax.scan(body, x,
                                         (params["blocks"], cache["blocks"]))
            new_cache = {"blocks": new_blocks}
        elif cfg.family == "hybrid":
            x, new_cache = self._decode_hybrid(params, cache, x, pos, cfg, mesh)
        else:
            def body(carry, pc):
                p_l, c_l = pc
                y, c_new, _ = _decode_attn_block(p_l, carry, c_l, pos, cfg, mesh)
                return y, c_new
            x, new_blocks = jax.lax.scan(body, x,
                                         (params["blocks"], cache["blocks"]))
            new_cache = {"blocks": new_blocks}

        h = common.apply_norm(params["final_norm"], x, cfg)
        logits = common.unembed(params["embedding"], h, cfg)
        return logits[:, 0], new_cache

    def _decode_hybrid(self, params, cache, x, pos, cfg, mesh):
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        shared_cfg = self._shared_cfg()
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]),
            params["blocks"])
        gcache = jax.tree.map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]),
            cache["blocks"])

        def inner(carry, pc):
            p_l, c_l = pc
            y, c_new = _decode_mamba_block(p_l, carry, c_l, cfg)
            return y, c_new

        def group_body(carry, pcs):
            p_g, c_g, sc = pcs
            h, c_new = jax.lax.scan(inner, carry, (p_g, c_g))
            h2, sc_new, _ = _decode_attn_block(params["shared"], h, sc, pos,
                                               shared_cfg, mesh)
            return h2, (c_new, sc_new)

        x, (new_blocks, new_shared) = jax.lax.scan(
            group_body, x, (grouped, gcache, cache["shared"]))
        new_blocks = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_blocks)
        return x, {"blocks": new_blocks, "shared": new_shared}

    # ----- pooled embeddings (DML integration) -----

    def embed_pool(self, params, batch, mesh=None):
        """Mean-pooled final hidden state (B, d_model) — the embedding the
        DML metric head consumes (DESIGN.md §4, modes 2/3)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = self._embed_inputs(params, batch, dtype)
        B, T, _ = x.shape
        positions = jnp.arange(T, dtype=jnp.int32)[None, :].repeat(B, 0)
        h, _ = self._run_blocks(params, x, cfg, mesh, False, positions)
        h = common.apply_norm(params["final_norm"], h, cfg)
        return jnp.mean(h.astype(jnp.float32), axis=1)

    # ----- logical sharding axes -----

    def logical_axes(self, params) -> Any:
        """Pytree matching params with logical-axis tuples at each leaf.
        Stacked block leaves get a leading 'layers' axis."""
        cfg = self.cfg

        def block_axes(shared: bool):
            bcfg = self._shared_cfg() if shared else cfg
            if not shared and cfg.family == "ssm":
                ax = {"norm1": {"scale": (None,)},
                      "tmix": rwkv6.logical_axes(cfg),
                      "norm2": {"scale": (None,)},
                      "cmix": mlp.logical_axes(cfg)}
                if cfg.norm_kind == "layernorm":
                    ax["norm1"]["bias"] = (None,)
                    ax["norm2"]["bias"] = (None,)
                return ax
            if not shared and cfg.family == "hybrid":
                return {"norm1": _norm_axes(cfg),
                        "mamba": mamba2.logical_axes(cfg)}
            ax = {"norm1": _norm_axes(bcfg),
                  "attn": attention.logical_axes(bcfg)}
            if not bcfg.parallel_block:
                ax["norm2"] = _norm_axes(bcfg)
            if bcfg.n_experts and bcfg.family == "moe":
                ax["moe"] = moe.logical_axes(bcfg)
            else:
                ax["mlp"] = mlp.logical_axes(bcfg)
            return ax

        def add_layers(tree):
            return jax.tree.map(lambda lg: ("layers",) + tuple(lg), tree,
                                is_leaf=lambda x: isinstance(x, tuple))

        axes = {
            "embedding": common.logical_axes_embedding(cfg),
            "blocks": add_layers(block_axes(False)),
            "final_norm": _norm_axes(cfg),
        }
        if cfg.shared_attn_every:
            axes["shared"] = block_axes(True)
        return axes


def _norm_axes(cfg: ArchConfig) -> dict:
    if cfg.norm_kind == "rmsnorm":
        return {"scale": (None,)}
    return {"scale": (None,), "bias": (None,)}


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg=cfg)
