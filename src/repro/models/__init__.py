from repro.models.transformer import Model, build_model  # noqa: F401
from repro.models import (  # noqa: F401
    attention, common, mamba2, mlp, moe, rwkv6,
)
