"""Architecture / run configuration dataclasses and the reduction rule used
by smoke tests (2 layers, d_model <= 512, <= 4 experts)."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One backbone architecture. Field defaults follow llama conventions;
    every assigned config overrides explicitly and cites its source."""

    name: str
    family: str                  # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    n_kv_heads: Optional[int] = None     # None -> MHA
    head_dim: Optional[int] = None       # None -> d_model // n_heads

    # block structure
    mlp_kind: str = "swiglu"             # swiglu | geglu | gelu
    norm_kind: str = "rmsnorm"           # rmsnorm | layernorm
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    parallel_block: bool = False         # command-r: attn & mlp in parallel
    embed_scale: bool = False            # gemma: embeddings * sqrt(d_model)
    qk_norm: bool = False
    attn_bias: bool = False

    # attention
    attention: str = "full"              # full | sliding | none
    window: int = 4096                   # sliding-window width
    causal: bool = True                  # False for encoder-only
    attn_q_chunk: int = 1024             # flash-chunk sizes (perf knobs)
    attn_kv_chunk: int = 1024

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_aux_weight: float = 0.01
    moe_capacity_factor: float = 2.0     # expert queue slack (perf knob)

    # SSM (mamba2-style) / rwkv6
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256                 # SSD chunk length (perf knob)
    ssm_tile_dtype: str = "float32"      # intra-chunk decay-tile dtype
    block_kind: str = "attn"             # attn | mamba2 | rwkv6 (per-layer default)

    # hybrid (zamba2): a shared attention block is interleaved every N layers
    shared_attn_every: int = 0
    shared_attn_window: int = 4096

    # modality frontend (audio/vlm carve-out): model consumes embeddings
    input_kind: str = "tokens"           # tokens | embeddings

    dtype: str = "bfloat16"              # activation/compute dtype
    param_dtype: str = "float32"
    source: str = ""                     # citation for the config

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads if self.n_kv_heads is not None else self.n_heads

    @property
    def dim_per_head(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def has_decode(self) -> bool:
        """Encoder-only architectures have no autoregressive decode step."""
        return self.causal

    @property
    def subquadratic(self) -> bool:
        """Whether long_500k decode is admissible (see DESIGN.md §5)."""
        return (self.family in ("ssm", "hybrid")
                or self.attention in ("sliding", "none"))

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant: same family/block structure, tiny dimensions."""
    d_model = min(cfg.d_model, 256)
    n_heads = max(2, min(cfg.n_heads, 4))
    kv = cfg.kv_heads
    n_kv = max(1, min(kv, n_heads if kv >= cfg.n_heads else 2))
    head_dim = max(16, d_model // n_heads)
    kw = dict(
        n_layers=2,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim if cfg.head_dim is not None else None,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        window=min(cfg.window, 64),
        shared_attn_window=min(cfg.shared_attn_window, 64),
    )
    if cfg.n_experts:
        kw["n_experts"] = min(cfg.n_experts, 4)
        kw["top_k"] = min(cfg.top_k, 2)
    if cfg.ssm_state:
        kw["ssm_state"] = min(cfg.ssm_state, 16)
    if cfg.ssm_heads:
        kw["ssm_heads"] = max(1, min(cfg.ssm_heads, 4))
    if cfg.shared_attn_every:
        kw["shared_attn_every"] = 2
    return cfg.replace(**kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One benchmark input shape (assigned set of 4)."""
    name: str
    seq_len: int
    global_batch: int
    mode: str                    # train | prefill | decode


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Launcher-level knobs: optimization, distribution, logging."""
    arch: str = "smollm-135m"
    shape: str = "train_4k"
    lr: float = 3e-4
    opt: str = "adamw"
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup: int = 100
    total_steps: int = 1000
    seed: int = 0
    # distribution
    multi_pod: bool = False
    sync: str = "bsp"            # PS consistency model for data-parallel sync
    tau: int = 1
    # memory / perf
    remat: bool = True           # activation checkpointing across layers
    scan_layers: bool = True
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
