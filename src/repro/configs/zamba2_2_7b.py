"""Zamba2-2.7B — Mamba2 backbone with interleaved *shared* attention blocks
[arXiv:2411.15242]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    mlp_kind="gelu",
    norm_kind="rmsnorm",
    attention="full",          # the shared block uses full attention
    block_kind="mamba2",
    ssm_state=64,
    ssm_heads=80,              # mamba head_dim 64: 2*2560/64 = 80 heads
    ssm_expand=2,
    conv_width=4,
    shared_attn_every=6,       # one shared attn+mlp block every 6 mamba layers
    # §Perf A winners: chunk 128 + bf16 tiles + ordered contractions
    # (memory term 264s -> 66.6s, per-chip temp 62 GiB -> 5.7 GiB)
    ssm_chunk=128,
    ssm_tile_dtype="bfloat16",
    source="arXiv:2411.15242 (Zamba2 technical report)",
)
