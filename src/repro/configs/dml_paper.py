"""The paper's own experiment configs (Table 1 / §5.2).

These drive the reproduction benchmarks. Feature data is generated
synthetically at matching dimensionality (see repro.data.pairs); the paper's
raw datasets (MNIST pixels, ImageNet LLC codes) are not shipped offline.
"""

import dataclasses

from repro.core.dml import DMLConfig


@dataclasses.dataclass(frozen=True)
class DMLExperiment:
    name: str
    dml: DMLConfig
    n_samples: int
    n_classes: int
    n_similar: int
    n_dissimilar: int
    batch_size: int          # paper §5.2 minibatch (pairs per step)
    data_kind: str
    source: str = "Xie & Xing 2014, Table 1 / §5.2"


# MNIST: d=780, k=600, minibatch 1000 (500 S + 500 D), 100K+100K pairs
MNIST = DMLExperiment(
    name="dml-mnist",
    dml=DMLConfig(feat_dim=780, proj_dim=600, lam=1.0, margin=1.0),
    n_samples=60_000, n_classes=10,
    n_similar=100_000, n_dissimilar=100_000,
    batch_size=1000,
    data_kind="mnist_like",
)

# ImageNet-63K: d=21504, k=10000 -> 220M params, minibatch 100
IMNET_63K = DMLExperiment(
    name="dml-imnet63k",
    dml=DMLConfig(feat_dim=21504, proj_dim=10000, lam=1.0, margin=1.0),
    n_samples=63_000, n_classes=1000,
    n_similar=100_000, n_dissimilar=100_000,
    batch_size=100,
    data_kind="llc_like",
)

# ImageNet-1M: d=21504, k=1000 -> 21.5M params, minibatch 1000, 100M+100M pairs
IMNET_1M = DMLExperiment(
    name="dml-imnet1m",
    dml=DMLConfig(feat_dim=21504, proj_dim=1000, lam=1.0, margin=1.0),
    n_samples=1_000_000, n_classes=1000,
    n_similar=100_000_000, n_dissimilar=100_000_000,
    batch_size=1000,
    data_kind="llc_like",
)

EXPERIMENTS = {e.name: e for e in (MNIST, IMNET_63K, IMNET_1M)}


def scaled_down(exp: DMLExperiment, factor: int = 10) -> DMLExperiment:
    """CPU-tractable variant preserving d/k aspect and pair balance."""
    return dataclasses.replace(
        exp,
        name=exp.name + f"-small{factor}",
        dml=dataclasses.replace(exp.dml,
                                feat_dim=max(32, exp.dml.feat_dim // factor),
                                proj_dim=max(16, exp.dml.proj_dim // factor)),
        n_samples=max(500, exp.n_samples // factor),
        n_similar=max(2000, exp.n_similar // (factor * factor)),
        n_dissimilar=max(2000, exp.n_dissimilar // (factor * factor)),
        batch_size=min(exp.batch_size, 256),
    )
