from repro.configs.base import ArchConfig, InputShape, RunConfig, reduced  # noqa: F401
from repro.configs.registry import get_config, list_configs  # noqa: F401
from repro.configs.shapes import SHAPES, get_shape  # noqa: F401
