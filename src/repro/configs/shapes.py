"""The four assigned input shapes (see repo spec)."""

from repro.configs.base import InputShape

SHAPES = {
    "train_4k": InputShape("train_4k", seq_len=4096, global_batch=256,
                           mode="train"),
    "prefill_32k": InputShape("prefill_32k", seq_len=32768, global_batch=32,
                              mode="prefill"),
    "decode_32k": InputShape("decode_32k", seq_len=32768, global_batch=128,
                             mode="decode"),
    "long_500k": InputShape("long_500k", seq_len=524288, global_batch=1,
                            mode="decode"),
}


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name}; have {sorted(SHAPES)}")
    return SHAPES[name]
