"""Gemma-7B — GeGLU MLP, head_dim=256, embedding scaling [arXiv:2403.08295]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp_kind="geglu",
    norm_kind="rmsnorm",
    embed_scale=True,
    tie_embeddings=True,
    attention="full",
    source="arXiv:2403.08295 (Gemma)",
)
