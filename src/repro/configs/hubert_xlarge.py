"""HuBERT-XLarge — encoder-only audio transformer (wav2vec2 architecture)
[arXiv:2106.07447].

``input_kind="embeddings"``: the mel/conv feature extractor is the sanctioned
stub; input_specs() provides precomputed frame embeddings (B, T, d_model).
Encoder-only: no causal mask and NO decode step (decode shapes skipped —
see DESIGN.md §5). vocab_size=504 is the masked-unit prediction codebook.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    mlp_kind="gelu",
    norm_kind="layernorm",
    attn_bias=True,
    causal=False,
    attention="full",
    input_kind="embeddings",
    source="arXiv:2106.07447 (HuBERT)",
)
