"""RWKV-6 "Finch" 1.6B — attention-free RNN with data-dependent decay
[arXiv:2404.05892]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # wkv heads: head_size 64 -> 2048/64
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    mlp_kind="rwkv_channel_mix",
    norm_kind="layernorm",
    attention="none",
    block_kind="rwkv6",
    source="arXiv:2404.05892 (Eagle and Finch: RWKV-5/6)",
)
