"""SmolLM-135M — small llama-architecture dense model
[hf:HuggingFaceTB/SmolLM-135M]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    tie_embeddings=True,
    attention="full",
    source="hf:HuggingFaceTB/SmolLM-135M",
)
