"""Architecture registry — ``--arch <id>`` resolution."""

from __future__ import annotations

from repro.configs.base import ArchConfig, reduced
from repro.configs import (
    yi_6b, rwkv6_1_6b, zamba2_2_7b, command_r_35b, pixtral_12b,
    granite_moe_1b, qwen3_moe_30b, smollm_135m, hubert_xlarge, gemma_7b,
)

_ARCHS = {}
for _mod in (yi_6b, rwkv6_1_6b, zamba2_2_7b, command_r_35b, pixtral_12b,
             granite_moe_1b, qwen3_moe_30b, smollm_135m, hubert_xlarge,
             gemma_7b):
    _ARCHS[_mod.CONFIG.name] = _mod.CONFIG


def get_config(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return reduced(get_config(name[: -len("-reduced")]))
    if name not in _ARCHS:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_ARCHS)}")
    return _ARCHS[name]


def list_configs():
    return sorted(_ARCHS)
