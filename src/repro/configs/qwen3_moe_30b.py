"""Qwen3-30B-A3B — 128-expert top-8 MoE with QK-norm
[hf:Qwen/Qwen3-30B-A3B]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,                 # per-expert FFN width
    vocab_size=151936,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    qk_norm=True,
    n_experts=128,
    top_k=8,
    rope_theta=1_000_000.0,
    attention="full",
    source="hf:Qwen/Qwen3-30B-A3B",
)
