"""Yi-6B — llama-architecture dense decoder with GQA [arXiv:2403.04652]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=5_000_000.0,
    attention="full",
    source="arXiv:2403.04652 (Yi: Open Foundation Models)",
)
