"""Pixtral-12B — Pixtral-ViT vision encoder (stubbed per the modality
carve-out) feeding a Mistral-Nemo decoder [hf:mistralai/Pixtral-12B-2409].

``input_kind="embeddings"``: input_specs() provides precomputed patch
embeddings of shape (B, T, d_model); the vision tower + projector are the one
sanctioned stub. The language decoder below is fully implemented.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,              # nemo: explicit head_dim (32*128 != 5120)
    d_ff=14336,
    vocab_size=131072,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000_000.0,
    attention="full",
    input_kind="embeddings",
    source="hf:mistralai/Pixtral-12B-2409 (decoder = Mistral-Nemo-12B)",
)
