"""Command-R 35B — Cohere dense decoder: parallel attn/FFN block, LayerNorm,
no biases, GQA kv=8 [hf:CohereForAI/c4ai-command-r-v01]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    mlp_kind="swiglu",
    norm_kind="layernorm",
    parallel_block=True,
    attn_bias=False,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    attention="full",
    source="hf:CohereForAI/c4ai-command-r-v01",
)
