"""Sharding-aware pytree checkpointing (npz payload + msgpack manifest).

Arrays are gathered to host (fully replicated view) before writing; restore
optionally re-places leaves onto a target sharding tree. No orbax offline —
this is a small, dependency-free implementation with the same surface.
"""

from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves, treedef = flat
    out = {}
    for path, leaf in leaves:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3):
    """Write tree to <ckpt_dir>/step_<step>.npz + .manifest.msgpack."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "keys": [], "scalars": {}}
    for k, v in flat.items():
        if isinstance(v, (int, float, bool, str)) or v is None:
            manifest["scalars"][k] = v
            continue
        arr = np.asarray(jax.device_get(v))
        arrays[k] = arr
        manifest["keys"].append(k)
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    np.savez(path + ".npz", **arrays)
    with open(path + ".manifest.msgpack", "wb") as f:
        f.write(msgpack.packb(manifest))
    _gc(ckpt_dir, keep)
    return path + ".npz"


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        for suffix in (".npz", ".manifest.msgpack"):
            p = os.path.join(ckpt_dir, f"step_{s:08d}{suffix}")
            if os.path.exists(p):
                os.remove(p)


def latest_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for fn in os.listdir(ckpt_dir):
        m = re.match(r"step_(\d+)\.npz$", fn)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = latest_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, target: Any, step: Optional[int] = None,
                       shardings: Any = None):
    """Restore into the structure of ``target``. ``shardings`` (optional)
    is a matching pytree of NamedShardings for device placement."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(path + ".npz")
    with open(path + ".manifest.msgpack", "rb") as f:
        manifest = msgpack.unpackb(f.read())

    flat_target, treedef = _flatten_with_paths(target)
    restored = {}
    for k, v in flat_target.items():
        if k in manifest["scalars"]:
            restored[k] = manifest["scalars"][k]
        elif k in data:
            arr = data[k]
            if hasattr(v, "dtype"):
                arr = arr.astype(v.dtype)
            restored[k] = jnp.asarray(arr)
        else:
            raise KeyError(f"checkpoint {path} missing leaf {k}")

    leaves_in_order = [restored[k] for k in flat_target.keys()]
    tree = jax.tree_util.tree_unflatten(treedef, leaves_in_order)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            tree, shardings)
    return tree, step
