"""Hand-rolled optimizers (no optax offline) with an optax-like interface:

    opt = adam(1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

All transforms are pure pytree functions — jit/pjit/shard_map friendly. The
PS server-side optimizer (paper §4.2 "update thread") is just one of these
applied to aggregated gradients.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, step: jax.Array) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Optional[Any]], Any]  # (grads, state, params)


class ScaleState(NamedTuple):
    step: jax.Array


def sgd(lr: Schedule) -> Optimizer:
    def init(params):
        return ScaleState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        updates = jax.tree.map(lambda g: -lr_t * g, grads)
        return updates, ScaleState(step=step)

    return Optimizer(init, update)


class MomentumState(NamedTuple):
    step: jax.Array
    mu: Any


def momentum(lr: Schedule, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return MomentumState(step=jnp.zeros((), jnp.int32),
                             mu=jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params=None):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        mu = jax.tree.map(lambda m, g: beta * m + g, state.mu, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -lr_t * (beta * m + g), mu, grads)
        else:
            upd = jax.tree.map(lambda m: -lr_t * m, mu)
        return upd, MomentumState(step=step, mu=mu)

    return Optimizer(init, update)


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    return _adam_impl(lr, b1, b2, eps, weight_decay=0.0)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    return _adam_impl(lr, b1, b2, eps, weight_decay=weight_decay)


def _adam_impl(lr, b1, b2, eps, weight_decay) -> Optimizer:
    def init(params):
        return AdamState(step=jnp.zeros((), jnp.int32),
                         m=jax.tree.map(jnp.zeros_like, params),
                         v=jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params=None):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                         state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def u(m_, v_, p):
            upd = -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and p is not None:
                upd = upd - lr_t * weight_decay * p
            return upd

        if weight_decay and params is not None:
            updates = jax.tree.map(u, m, v, params)
        else:
            updates = jax.tree.map(lambda m_, v_: u(m_, v_, None), m, v)
        return updates, AdamState(step=step, m=m, v=v)

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
