"""Learning-rate schedules as step -> lr callables (jit-traceable)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def inverse_time(lr0: float, decay: float = 1e-3):
    """lr0 / (1 + decay * step) — the classic asynchronous-SGD schedule."""
    return lambda step: lr0 / (1.0 + decay * step.astype(jnp.float32))


def cosine(lr0: float, total_steps: int, warmup: int = 0, floor: float = 0.0):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0) if warmup else 1.0
        prog = jnp.clip((s - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.asarray(lr0, jnp.float32) * warm * cos
    return fn


def linear_warmup(lr0: float, warmup: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return lr0 * jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
    return fn
