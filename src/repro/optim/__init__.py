from repro.optim.optimizers import (  # noqa: F401
    Optimizer, sgd, momentum, adam, adamw, clip_by_global_norm, apply_updates,
)
from repro.optim import schedules  # noqa: F401
