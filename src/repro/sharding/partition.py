"""Logical-axis sharding rules with divisibility-checked fallback.

Model code annotates parameters and activations with *logical* axis names
("embed", "heads", "ffn", "vocab", "experts", "batch", "seq", ...). A rule
table maps logical axes to physical mesh axes; ``logical_to_physical`` drops
any mapping whose dimension size does not divide the mesh axis size (e.g.
yi-6b's 4 KV heads on a model=16 axis -> replicated), so every config lowers
on every mesh without hand-tuning.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default rule table for the production meshes (data, model) / (pod, data, model).
# Batch-like axes shard over data(+pod); weight axes shard over model.
DEFAULT_RULES: Dict[str, Union[str, Tuple[str, ...], None]] = {
    "batch": ("pod", "data"),
    "pairs": ("pod", "data"),
    "workers": ("pod", "data"),
    "seq": None,
    # sequence-parallel residual: the inter-layer activation is sharded over
    # the model axis between blocks (Megatron-SP style) so deep stacks don't
    # hold O(layers * B * T * d) replicated residuals under remat
    "seq_sp": "model",
    # decode KV caches: shard the cache sequence dim over model when KV heads
    # don't divide the model axis (flash-decoding style partial softmax)
    "cache_seq": "model",
    # FSDP: weight embed dims shard over the data axis (ZeRO-3 style); XLA
    # all-gathers per layer and reduce-scatters gradients
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ffn": None,
    "heads_flat": "model",  # fused (H*Dh) output dims (rwkv r/k/v/g mats)
    "embed2": None,
    "proj": "model",        # DML: k rows of L
    "feat": None,           # DML: d columns of L
    "gallery": ("pod", "data"),  # serve: pre-projected gallery rows
    "neighbors": None,      # serve: per-query top-k result dim
    "state": None,          # SSM state dim
    "conv": None,
    "layers": None,         # scan-over-layers leading axis
}


def _mesh_axis_size(mesh: Mesh, axis: Union[str, Tuple[str, ...]]) -> int:
    if isinstance(axis, str):
        return mesh.shape[axis]
    n = 1
    for a in axis:
        n *= mesh.shape[a]
    return n


def logical_to_physical(logical: Sequence[Optional[str]], mesh: Mesh,
                        rules: Optional[dict] = None,
                        shape: Optional[Sequence[int]] = None) -> P:
    """Map logical axis names to a PartitionSpec, dropping non-dividing axes.

    Args:
      logical: one logical name (or None) per tensor dimension.
      mesh: target mesh; mappings to axes absent from the mesh are dropped.
      rules: overrides of DEFAULT_RULES.
      shape: if given, a mapping is kept only when shape[i] divides the mesh
        axis size (replicate otherwise).
    """
    table = dict(DEFAULT_RULES)
    if rules:
        table.update(rules)
    used = set()
    spec = []
    for i, name in enumerate(logical):
        phys = table.get(name) if name is not None else None
        if phys is None:
            spec.append(None)
            continue
        axes = (phys,) if isinstance(phys, str) else tuple(phys)
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        if not axes:
            spec.append(None)
            continue
        if shape is not None:
            size = _mesh_axis_size(mesh, axes)
            if shape[i] % size != 0:
                # try single-axis fallback before replicating entirely
                axes = tuple(a for a in axes if shape[i] % mesh.shape[a] == 0)
                axes = axes[:1]
                if not axes:
                    spec.append(None)
                    continue
        used.update(axes)
        spec.append(axes[0] if len(axes) == 1 else axes)
    return P(*spec)


def shardable(x: jax.Array, logical: Sequence[Optional[str]]):
    """Tag helper used by model code: returns (x, logical) pairs for tables."""
    return x, tuple(logical)


def make_param_shardings(logical_tree, mesh: Mesh, shapes_tree=None,
                         rules: Optional[dict] = None):
    """Map a pytree of logical-axis tuples (+ optional matching shapes) to
    NamedShardings."""
    if shapes_tree is None:
        return jax.tree.map(
            lambda lg: NamedSharding(mesh, logical_to_physical(lg, mesh, rules)),
            logical_tree, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(
        lambda lg, shp: NamedSharding(
            mesh, logical_to_physical(lg, mesh, rules, shape=shp)),
        logical_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple))


def constrain(x: jax.Array, logical: Sequence[Optional[str]],
              mesh: Optional[Mesh] = None, rules: Optional[dict] = None):
    """with_sharding_constraint by logical names. No-op outside a mesh ctx."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_physical(logical, mesh, rules, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_map(f, mesh: Mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable jax.shard_map.

    jax >= 0.5 exports jax.shard_map (replication check kwarg: check_vma);
    jax 0.4.x only has jax.experimental.shard_map.shard_map (check_rep).
    All repo call sites go through here.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None
