from repro.sharding.partition import (  # noqa: F401
    shardable, logical_to_physical, make_param_shardings, constrain,
)
