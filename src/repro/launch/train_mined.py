"""Closed-loop mined-pair training launcher.

Run:  PYTHONPATH=src python -m repro.launch.train_mined \
          [--steps 300] [--refresh-every 15] [--max-mined-frac 0.7] ...

Stands up the full closed loop on synthetic noisy_subspace data: builds a
MutableIndex over the train rows, wraps it in a RetrievalEngine (warmed
for the miner's k, like ``serve_retrieval --warmup-ks`` does for serving
clients), and runs ``ClosedLoopTrainer`` — training epochs alternating
with ``swap_metric`` index refreshes and ``HardPairMiner`` sweeps, the
mined pairs feeding back into the worker batch streams under a
curriculum. Reports the kNN-accuracy trace, per-refresh mining yield,
and the engine's serving stats (QPS over the mining queries rides the
same bucketed-jit path as retrieval traffic).

``--baseline`` also runs the stock uniform-sampling trainer at the same
batch size for the full step budget, for a side-by-side trace.
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-samples", type=int, default=8000)
    ap.add_argument("--feat-dim", type=int, default=64)
    ap.add_argument("--proj-dim", type=int, default=16)
    ap.add_argument("--l-rank", type=int, default=None,
                    help="low-rank d_out of the trained rectangular L; "
                         "overrides --proj-dim")
    ap.add_argument("--n-classes", type=int, default=128)
    ap.add_argument("--noise", type=float, default=0.3)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--sync", choices=["bsp", "local", "ssp"],
                    default="bsp")
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    # mining knobs
    ap.add_argument("--index", choices=["mutable-exact", "mutable-ivf",
                                        "exact", "ivf"],
                    default="mutable-exact",
                    help="serving backend the miner queries (mutable-* "
                         "refresh via swap_metric; frozen kinds rebuild)")
    ap.add_argument("--n-clusters", type=int, default=64,
                    help="ivf backends: gallery segments")
    ap.add_argument("--nprobe", type=int, default=8,
                    help="ivf backends: clusters scanned per query")
    ap.add_argument("--k-neighbors", type=int, default=20)
    ap.add_argument("--margin", type=float, default=1.0)
    ap.add_argument("--max-negatives", type=int, default=1)
    ap.add_argument("--max-positives", type=int, default=3)
    ap.add_argument("--refresh-every", type=int, default=15,
                    help="index refresh + re-mine period (steps)")
    ap.add_argument("--plateau-window", type=int, default=0,
                    help=">0: also refresh when the loss plateaus over "
                         "this many trailing steps")
    ap.add_argument("--mine-queries", type=int, default=0,
                    help="anchors per refresh (0 = every train row)")
    ap.add_argument("--warmup-steps", type=int, default=10)
    ap.add_argument("--ramp-steps", type=int, default=20)
    ap.add_argument("--max-mined-frac", type=float, default=0.7)
    ap.add_argument("--baseline", action="store_true",
                    help="also run the uniform-sampling trainer for "
                         "comparison")
    args = ap.parse_args()

    from repro.core import dml, eval_tasks
    from repro.core.ps import sync
    from repro.core.ps.trainer import (DMLTrainConfig,
                                       train_dml_distributed)
    from repro.data import pairs as pairdata
    from repro.mining import (ClosedLoopConfig, ClosedLoopTrainer,
                              CurriculumSchedule, MinerConfig)

    cfg = pairdata.PairDatasetConfig(
        n_samples=args.n_samples, feat_dim=args.feat_dim,
        n_classes=args.n_classes, kind="noisy_subspace",
        noise=args.noise, seed=args.seed)
    x, y = pairdata.make_features(cfg)
    n_tr = int(args.n_samples * 0.8)
    tr_x, tr_y, te_x, te_y = x[:n_tr], y[:n_tr], x[n_tr:], y[n_tr:]

    def hook(t, L):
        return eval_tasks.knn_accuracy(L, tr_x, tr_y, te_x, te_y, k=5)

    tcfg = DMLTrainConfig(
        dml=dml.DMLConfig(
            feat_dim=args.feat_dim,
            l_rank=(args.l_rank if args.l_rank is not None
                    else args.proj_dim)),
        ps=sync.PSConfig(n_workers=args.workers, sync=args.sync,
                         seed=args.seed),
        batch_size=args.batch, steps=args.steps, lr=args.lr,
        log_every=args.eval_every)
    ikw = (dict(n_clusters=args.n_clusters, nprobe=args.nprobe)
           if "ivf" in args.index else None)
    ccfg = ClosedLoopConfig(
        train=tcfg,
        miner=MinerConfig(k_neighbors=args.k_neighbors,
                          margin=args.margin,
                          max_negatives=args.max_negatives,
                          max_positives=args.max_positives),
        schedule=CurriculumSchedule(warmup_steps=args.warmup_steps,
                                    ramp_steps=args.ramp_steps,
                                    max_mined_frac=args.max_mined_frac),
        index=args.index, index_kwargs=ikw,
        refresh_every=args.refresh_every,
        plateau_window=args.plateau_window,
        mine_queries=args.mine_queries or n_tr)

    trainer = ClosedLoopTrainer(ccfg, tr_x, tr_y)
    print(f"closed loop: {args.index} index over {n_tr} rows, "
          f"refresh every {args.refresh_every} steps, "
          f"mine {ccfg.mine_queries} anchors/refresh, "
          f"curriculum {args.warmup_steps}+{args.ramp_steps} -> "
          f"{args.max_mined_frac:.0%} mined")
    L, hist = trainer.run(step_hook=hook)

    print("\nstep,loss,knn_acc,staleness,mined_frac")
    for h in hist["steps"]:
        print(f"{h['step']},{h['loss']:.4f},{h['hook']:.4f},"
              f"{h['staleness']},{h['mined_frac']:.2f}")
    print("\nrefresh,step,n_pairs,neg_yield,pos_yield,engine_qps")
    for r in hist["refreshes"]:
        print(f"{r['refresh']},{r['step']},{r['n_pairs']},"
              f"{r['neg_yield']:.2f},{r['pos_yield']:.2f},"
              f"{r['engine_qps']:.0f}")
    s = hist["summary"]
    est = s["engine"]
    print(f"\n{s['n_refreshes']} refreshes, mean staleness "
          f"{s['mean_staleness']:.1f} steps, {s['total_mined_pairs']} "
          f"pairs mined")
    print(f"engine[{est['index']}]: {est['qps']:.0f} qps over "
          f"{est['n_device_queries']} mining queries "
          f"({est['cache_hits']} cache hits), gallery "
          f"{est['gallery_size']} rows")
    print(f"final kNN accuracy (mined, {args.steps} steps): "
          f"{hist['steps'][-1]['hook']:.4f}")

    if args.baseline:
        idx = pairdata.sample_pair_indices(tr_y, 20000, 20000,
                                           seed=args.seed + 1)
        uni = {"xs": tr_x[idx["a"]], "ys": tr_x[idx["b"]],
               "sim": idx["sim"]}
        _, hist_u = train_dml_distributed(tcfg, uni, step_hook=hook)
        print(f"final kNN accuracy (uniform, {args.steps} steps): "
              f"{hist_u[-1]['hook']:.4f}")


if __name__ == "__main__":
    main()
