"""Production mesh construction (TPU v5e pods; CPU host devices in dry-run).

Kept as functions (never module-level constants) so importing this module
never touches JAX device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int = None):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = jax.device_count()
    data = data or max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
