import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x input-shape) combination
on the production meshes, with zero real allocation (ShapeDtypeStruct inputs).

For each combination this script:
  1. builds the model + step function (train / prefill / serve per shape),
  2. jit-lowers with explicit in/out shardings on the requested mesh,
  3. compiles, records memory_analysis() (proves fit) and cost_analysis()
     (FLOPs / bytes for the roofline),
  4. parses the optimized HLO for collective traffic,
  5. appends the record to an incremental JSON artifact
     (benchmarks/artifacts/dryrun_<mesh>.json).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
  python -m repro.launch.dryrun --dml            # the paper's own configs
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from functools import partial  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config, list_configs, get_shape, SHAPES  # noqa: E402
from repro.configs.base import RunConfig  # noqa: E402
from repro.launch import hlo_analysis, mesh as mesh_lib, steps  # noqa: E402
from repro.models.transformer import build_model  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts")


def _artifact_path(multi_pod: bool) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    name = "dryrun_pod2x16x16.json" if multi_pod else "dryrun_16x16.json"
    return os.path.join(ARTIFACT_DIR, name)


def _load(path):
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def _store(path, records):
    with open(path, "w") as f:
        json.dump(records, f, indent=1, sort_keys=True)


def _cost_number(cost, key):
    try:
        v = cost.get(key)
        return float(v) if v is not None else 0.0
    except Exception:
        return 0.0


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               collect_hlo: bool = True, loss_chunks: int = 8,
               overrides: dict = None):
    """Lower+compile one combination; returns the result record.

    ``overrides``: ArchConfig.replace(**overrides) knobs — used by the §Perf
    hillclimb to lower candidate variants (chunk sizes, tile dtypes, ...).
    """
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    shape = get_shape(shape_name)
    base_cfg = get_config(arch)
    skip = steps.skip_reason(base_cfg, shape)
    if skip:
        return {"status": "skipped", "reason": skip, "arch": arch,
                "shape": shape_name, "mesh": str(dict(mesh.shape))}
    cfg = steps.effective_config(base_cfg, shape)
    if overrides:
        cfg = cfg.replace(**overrides)
    model = build_model(cfg)
    run = RunConfig(arch=arch, shape=shape_name)

    t0 = time.time()
    rng = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(model.init, rng)
    pshard = steps.param_shardings(model, params_shape, mesh)
    specs = steps.input_specs(cfg, shape)
    in_shard = steps.input_shardings(specs, mesh)

    with mesh:
        if shape.mode == "train":
            opt = steps.make_optimizer(run)
            state_shape = jax.eval_shape(
                lambda p: steps.TrainState(p, opt.init(p),
                                           jnp.zeros((), jnp.int32)),
                params_shape)
            sshard = steps.make_state_shardings(state_shape, params_shape,
                                                pshard, mesh)
            step_fn = steps.make_train_step(model, opt, run, mesh=mesh,
                                            loss_chunks=loss_chunks)
            jitted = jax.jit(step_fn,
                             in_shardings=(sshard, in_shard),
                             out_shardings=(sshard, None))
            lowered = jitted.lower(state_shape, specs)
        elif shape.mode == "prefill":
            step_fn = steps.make_prefill_step(model, run, mesh=mesh)
            jitted = jax.jit(step_fn, in_shardings=(pshard, in_shard),
                             out_shardings=None)
            lowered = jitted.lower(params_shape, specs)
        else:  # decode
            cache_shape = steps.cache_shape_structs(model, shape)
            cshard = steps.cache_shardings(model, cfg, shape, mesh)
            step_fn = steps.make_serve_step(model, run, mesh=mesh)
            jitted = jax.jit(step_fn,
                             in_shardings=(pshard, cshard, in_shard),
                             out_shardings=(None, cshard))
            lowered = jitted.lower(params_shape, cache_shape, specs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    record = {
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mode": shape.mode,
        "mesh": dict(mesh.shape),
        "n_chips": n_chips,
        "attn_variant": cfg.attention,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # raw cost_analysis (NOTE: while bodies counted once — see
        # hlo_analysis; the loop-corrected parse below is authoritative)
        "cost_analysis_flops": _cost_number(cost, "flops"),
        "cost_analysis_bytes": _cost_number(cost, "bytes accessed"),
        "memory": {
            "argument_size": getattr(mem, "argument_size_in_bytes", 0),
            "output_size": getattr(mem, "output_size_in_bytes", 0),
            "temp_size": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size": getattr(mem, "generated_code_size_in_bytes", 0),
        },
    }
    flops_per_chip = record["cost_analysis_flops"]
    bytes_per_chip = record["cost_analysis_bytes"]
    if collect_hlo:
        try:
            hlo = compiled.as_text()
            csum = hlo_analysis.collective_summary(hlo)
            record["collectives"] = {
                "bytes": csum["bytes"], "counts": csum["counts"],
                "total_bytes": csum["total_bytes"],
            }
            # loop-corrected per-chip FLOPs / HBM bytes from the HLO parse
            record["hlo_dot_flops_per_chip"] = csum["dot_flops"]
            record["hlo_op_bytes_per_chip"] = csum["op_bytes"]
            flops_per_chip = max(flops_per_chip, csum["dot_flops"])
            bytes_per_chip = max(bytes_per_chip, csum["op_bytes"])
        except Exception as e:  # pragma: no cover
            record["collectives"] = {"error": str(e)}
    record["flops_per_chip"] = flops_per_chip
    record["hbm_bytes_per_chip"] = bytes_per_chip
    # the SPMD module is per-partition, so parsed collective bytes are
    # already per-chip traffic — no further division by n_chips
    terms = hlo_analysis.roofline_terms(
        flops_per_chip, bytes_per_chip,
        record.get("collectives", {}).get("total_bytes", 0.0),
        n_chips, mesh_lib.PEAK_FLOPS_BF16, mesh_lib.HBM_BW, mesh_lib.ICI_BW)
    record["roofline"] = terms
    return record


def dryrun_dml(multi_pod: bool):
    """Dry-run the paper's own DML configs (train step over pair batches)."""
    from repro.configs import dml_paper
    from repro.core import dml as dml_core, losses as losses_mod
    from repro.optim import sgd
    from repro.sharding.partition import logical_to_physical

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    out = {}
    for name, exp in dml_paper.EXPERIMENTS.items():
        t0 = time.time()
        dcfg = exp.dml
        L_shape = jax.ShapeDtypeStruct((dcfg.proj_dim, dcfg.feat_dim),
                                       jnp.float32)
        # pairs per global step: paper minibatch per worker x data-parallel
        B = exp.batch_size * mesh.shape["data"] * mesh.shape.get("pod", 1)
        batch = {
            "xs": jax.ShapeDtypeStruct((B, dcfg.feat_dim), jnp.float32),
            "ys": jax.ShapeDtypeStruct((B, dcfg.feat_dim), jnp.float32),
            "sim": jax.ShapeDtypeStruct((B,), jnp.int32),
        }
        Lsh = NamedSharding(mesh, logical_to_physical(
            ("proj", "feat"), mesh, shape=(dcfg.proj_dim, dcfg.feat_dim)))
        bsh = {
            "xs": NamedSharding(mesh, logical_to_physical(
                ("pairs", None), mesh, shape=(B, dcfg.feat_dim))),
            "ys": NamedSharding(mesh, logical_to_physical(
                ("pairs", None), mesh, shape=(B, dcfg.feat_dim))),
            "sim": NamedSharding(mesh, logical_to_physical(
                ("pairs",), mesh, shape=(B,))),
        }

        def train_step(L, b):
            (loss, aux), g = jax.value_and_grad(
                lambda p, bb: losses_mod.dml_pair_loss(
                    p, bb, lam=dcfg.lam, margin=dcfg.margin),
                has_aux=True)(L, b)
            return L - 0.01 * g, loss

        with mesh:
            jitted = jax.jit(train_step, in_shardings=(Lsh, bsh),
                             out_shardings=(Lsh, None))
            lowered = jitted.lower(L_shape, batch)
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        csum = hlo_analysis.collective_summary(compiled.as_text())
        mem = compiled.memory_analysis()
        terms = hlo_analysis.roofline_terms(
            max(_cost_number(cost, "flops"), csum["dot_flops"]),
            max(_cost_number(cost, "bytes accessed"), csum["op_bytes"]),
            csum["total_bytes"],
            n_chips, mesh_lib.PEAK_FLOPS_BF16, mesh_lib.HBM_BW,
            mesh_lib.ICI_BW)
        out[name] = {
            "status": "ok", "arch": name, "shape": "paper_batch",
            "mesh": dict(mesh.shape), "n_chips": n_chips,
            "global_pair_batch": B,
            "compile_s": round(time.time() - t0, 1),
            "flops_per_chip": _cost_number(cost, "flops"),
            "hbm_bytes_per_chip": _cost_number(cost, "bytes accessed"),
            "collectives": {"bytes": csum["bytes"],
                            "total_bytes": csum["total_bytes"]},
            "memory": {"temp_size": getattr(mem, "temp_size_in_bytes", 0),
                       "argument_size": getattr(mem, "argument_size_in_bytes", 0)},
            "roofline": terms,
        }
        print(f"[dml dryrun] {name}: ok compile={out[name]['compile_s']}s "
              f"dominant={terms['dominant']}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--dml", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()

    path = _artifact_path(args.multi_pod)
    records = _load(path)

    if args.dml:
        dml_records = dryrun_dml(args.multi_pod)
        for k, v in dml_records.items():
            records[f"{k}|paper_batch"] = v
        _store(path, records)
        return

    combos = []
    if args.all:
        for arch in list_configs():
            for shape in SHAPES:
                combos.append((arch, shape))
    else:
        combos.append((args.arch, args.shape))

    for arch, shape in combos:
        key = f"{arch}|{shape}"
        if args.skip_done and records.get(key, {}).get("status") in ("ok", "skipped"):
            print(f"[dryrun] {key}: cached, skipping", flush=True)
            continue
        print(f"[dryrun] {key}: lowering...", flush=True)
        try:
            rec = dryrun_one(arch, shape, args.multi_pod,
                             collect_hlo=not args.no_hlo)
        except Exception as e:
            rec = {"status": "error", "arch": arch, "shape": shape,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-3000:]}
        records[key] = rec
        _store(path, records)
        if rec["status"] == "ok":
            t = rec["roofline"]
            print(f"[dryrun] {key}: OK compile={rec['compile_s']}s "
                  f"temp={rec['memory']['temp_size']/2**30:.2f}GiB "
                  f"compute={t['compute_s']*1e3:.2f}ms "
                  f"memory={t['memory_s']*1e3:.2f}ms "
                  f"coll={t['collective_s']*1e3:.2f}ms "
                  f"dominant={t['dominant']}", flush=True)
        elif rec["status"] == "skipped":
            print(f"[dryrun] {key}: SKIPPED ({rec['reason']})", flush=True)
        else:
            print(f"[dryrun] {key}: ERROR {rec['error']}", flush=True)


if __name__ == "__main__":
    main()
