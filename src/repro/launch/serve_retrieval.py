"""Metric-retrieval serving launcher.

Run:  PYTHONPATH=src python -m repro.launch.serve_retrieval \
          [--gallery-size 20000] [--train-steps 200] [--requests 500]

Builds a class-structured gallery (data.pairs), optionally trains the
metric L on pair constraints, stands up the serving stack
(index -> RetrievalEngine -> MicroBatcher), fires single-query
traffic through the batcher, and reports QPS + latency percentiles +
neighbor class purity (fraction of returned neighbors sharing the query's
class — the quality the learned metric buys at serve time).

``--index exact`` scans the whole gallery (ExactIndex); ``--index ivf``
builds the cluster-pruned ANN index (IVFIndex) and scans only the
``--nprobe`` nearest of ``--n-clusters`` gallery segments per query.
``--cache-size`` bounds the engine's hot-query LRU (0 disables).

With --data > 1 the gallery shards over a forced-host-device mesh
(dry-run style) to exercise the sharded query path (both index kinds).
"""

from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gallery-size", type=int, default=20000)
    ap.add_argument("--feat-dim", type=int, default=64)
    ap.add_argument("--proj-dim", type=int, default=32)
    ap.add_argument("--n-classes", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--train-steps", type=int, default=200,
                    help="0 = random L (no learned metric)")
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--backend", choices=["xla", "pallas"], default="xla")
    ap.add_argument("--index", choices=["exact", "ivf"], default="exact")
    ap.add_argument("--n-clusters", type=int, default=64,
                    help="ivf: gallery segments (rounds up to a multiple "
                         "of the shard count)")
    ap.add_argument("--nprobe", type=int, default=8,
                    help="ivf: clusters scanned per query")
    ap.add_argument("--cache-size", type=int, default=1024,
                    help="engine hot-query LRU entries (0 disables)")
    ap.add_argument("--data", type=int, default=1,
                    help=">1 forces that many host devices and shards "
                         "the gallery over the data axis")
    args = ap.parse_args()
    if args.index == "ivf" and args.backend == "pallas":
        ap.error("--index ivf only supports --backend xla (the fused "
                 "pallas kernel serves the exact full-scan path)")

    if args.data > 1:   # must precede first jax import
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.data} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import dml
    from repro.core.ps.trainer import train_dml_single
    from repro.data import pairs as pairdata
    from repro.launch.mesh import make_local_mesh
    from repro.serve import (ExactIndex, IVFIndex, MicroBatcher,
                             RetrievalEngine)

    # --- data + metric ---------------------------------------------------
    cfg = pairdata.PairDatasetConfig(
        n_samples=args.gallery_size, feat_dim=args.feat_dim,
        n_classes=args.n_classes, kind="noisy_subspace", noise=0.5, seed=0)
    feats, labels = pairdata.make_features(cfg)
    dcfg = dml.DMLConfig(feat_dim=args.feat_dim, proj_dim=args.proj_dim)
    if args.train_steps > 0:
        train_pairs, _ = pairdata.train_eval_split(
            cfg, n_train_sim=4000, n_train_dis=4000,
            n_eval_sim=100, n_eval_dis=100)
        L, hist = train_dml_single(dcfg, train_pairs, steps=args.train_steps,
                                   batch_size=512, lr=2e-2, seed=0)
        print(f"trained L: objective {hist[0]['loss']:.3f} -> "
              f"{hist[-1]['loss']:.3f}")
    else:
        L = dml.init_params(dcfg, jax.random.PRNGKey(0))

    # --- serving stack ---------------------------------------------------
    mesh = make_local_mesh(data=args.data) if args.data > 1 else None
    t0 = time.perf_counter()
    if args.index == "ivf":
        index = IVFIndex.build(L, jnp.asarray(feats), mesh=mesh,
                               n_clusters=args.n_clusters,
                               nprobe=args.nprobe)
    else:
        index = ExactIndex.build(L, jnp.asarray(feats), mesh=mesh)
    build_s = time.perf_counter() - t0
    engine = RetrievalEngine(index, k_top=args.k, backend=args.backend,
                             cache_size=args.cache_size)
    engine.warmup()
    print(f"index[{args.index}]: {index.size} x {args.proj_dim} "
          f"({index.n_shards} shard(s)), built+projected in {build_s:.2f}s")
    if args.index == "ivf":
        scanned = index.nprobe * index.cap
        print(f"  ivf: {index.n_clusters} clusters, cap {index.cap}, "
              f"nprobe {index.nprobe} -> <= {scanned} of {index.size} rows "
              f"scanned per query ({scanned / index.size:.1%})")

    batcher = MicroBatcher(engine, max_batch=args.max_batch,
                           max_wait_ms=args.max_wait_ms)

    # --- traffic ---------------------------------------------------------
    rng = np.random.RandomState(1)
    qids = rng.randint(0, len(feats), args.requests)
    noisy = feats[qids] + 0.1 * rng.randn(args.requests, args.feat_dim) \
        .astype(np.float32)
    t0 = time.perf_counter()
    pending = [(qid, time.perf_counter(), batcher.submit(noisy[i]))
               for i, qid in enumerate(qids)]
    lat, purity = [], []
    for qid, t_sub, fut in pending:
        _, nbr = fut.result(timeout=60)
        lat.append(time.perf_counter() - t_sub)
        purity.append(float(np.mean(labels[nbr] == labels[qid])))
    wall = time.perf_counter() - t0
    batcher.close()

    lat_ms = np.sort(np.asarray(lat)) * 1e3
    st = engine.stats()
    print(f"requests={args.requests} wall={wall:.2f}s "
          f"qps={args.requests / wall:.0f} "
          f"(device-side qps={st['qps']:.0f})")
    print(f"latency ms: p50={lat_ms[len(lat_ms) // 2]:.2f} "
          f"p99={lat_ms[int(len(lat_ms) * 0.99) - 1]:.2f} "
          f"max={lat_ms[-1]:.2f}")
    print(f"batches={batcher.n_batches} "
          f"mean batch={np.mean(batcher.batch_sizes):.1f}")
    print(f"cache: {st['cache_hits']} hits / {st['cache_misses']} misses "
          f"({st['cache_entries']} entries)")
    print(f"neighbor class purity@{args.k}: {np.mean(purity):.3f} "
          f"(chance {1.0 / args.n_classes:.3f})")


if __name__ == "__main__":
    main()
