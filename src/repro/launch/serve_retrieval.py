"""Metric-retrieval serving launcher.

Run:  PYTHONPATH=src python -m repro.launch.serve_retrieval \
          [--gallery-size 20000] [--train-steps 200] [--requests 500]

Builds a class-structured gallery (data.pairs), optionally trains the
metric L on pair constraints, stands up the serving stack
(index -> RetrievalEngine -> MicroBatcher), fires single-query
traffic through the batcher, and reports QPS + latency percentiles +
neighbor class purity (fraction of returned neighbors sharing the query's
class — the quality the learned metric buys at serve time).

``--index exact`` scans the whole gallery (ExactIndex); ``--index ivf``
builds the cluster-pruned ANN index (IVFIndex) and scans only the
``--nprobe`` nearest of ``--n-clusters`` gallery segments per query;
``--index ivfpq`` additionally compresses the scanned segments to uint8
product-quantization codes (``--n-subspaces`` codes of ``--bits`` bits
per row, trained on residuals to the cluster centroids) scored by
ADC lookup tables, with the top ``--rerank-depth`` candidates re-scored
exactly against the full-precision store (``--pq-store host`` keeps that
store in RAM instead of device memory). ``--scan-impl`` picks the
segment-scan implementation for both ANN indexes — "auto" serves the
fused Pallas kernels (kernels/pq_adc, kernels/ivf_scan) on TPU and the
XLA scan elsewhere. ``--cache-size`` bounds the engine's hot-query LRU
(0 disables).

``--mutable`` wraps the index in a MutableIndex (streaming upserts /
deletes / compaction / metric hot-swap); ``--churn N`` then exercises N
upserts + N deletes after the traffic run and reports the lifecycle
counters. ``--snapshot-dir`` restarts without re-projecting: if the
directory holds a snapshot it is loaded (the manifest's L fingerprint is
checked against this run's metric), otherwise the freshly built index is
saved there. ``--warmup-ks`` pre-compiles extra k values so non-default
``k_top`` requests don't pay first-request jit. ``--mine N`` runs a
``HardPairMiner`` sweep for N anchors against the live engine after the
traffic run — mining shares the engine's jit cache/warmup and its QPS
shows up in the same ``stats()`` counters as serving traffic.

``--scheduler`` swaps the MicroBatcher front door for the traffic-shaped
``RequestScheduler``: traffic is submitted under a 70/20/10 interactive /
batch / mining class mix with per-class deadlines (``--deadline-ms``
overrides), bounded admission queues, and (unless ``--no-degrade``) the
adaptive quality ladder derived from the index's own knobs —
``--high/--low-watermark`` and ``--degrade/--restore-window-ms`` tune the
load controller's hysteresis. The run then reports per-class
counters/latency percentiles and the degradation transitions alongside
the usual engine stats.

With --data > 1 the gallery shards over a forced-host-device mesh
(dry-run style) to exercise the sharded query path (both index kinds;
incompatible with --mutable / --snapshot-dir, which are single-shard).

Observability: ``--metrics-out FILE`` writes the run's final
MetricsRegistry snapshot (render it with ``launch/metrics_report.py``),
``--trace-sample R`` samples request traces at rate R (deterministic),
and ``--trace-out FILE`` exports the sampled span trees as JSONL —
``benchmarks/check_obs.py`` schema-validates both files.
"""

from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gallery-size", type=int, default=20000)
    ap.add_argument("--feat-dim", type=int, default=64)
    ap.add_argument("--proj-dim", type=int, default=32)
    ap.add_argument("--l-rank", type=int, default=None,
                    help="train a low-rank rectangular L with this many "
                         "rows (d_out); overrides --proj-dim. The whole "
                         "serving stack (projected gallery, PQ codes, "
                         "snapshots) shrinks by feat_dim/l_rank")
    ap.add_argument("--n-classes", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--train-steps", type=int, default=200,
                    help="0 = random L (no learned metric)")
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--backend", choices=["xla", "pallas"], default="xla")
    ap.add_argument("--index", choices=["exact", "ivf", "ivfpq"],
                    default="exact")
    ap.add_argument("--n-clusters", type=int, default=64,
                    help="ivf/ivfpq: gallery segments (ivf rounds up to "
                         "a multiple of the shard count)")
    ap.add_argument("--nprobe", type=int, default=8,
                    help="ivf/ivfpq: clusters scanned per query")
    ap.add_argument("--n-subspaces", type=int, default=8,
                    help="ivfpq: uint8 codes per row (code bytes/row)")
    ap.add_argument("--bits", type=int, default=8,
                    help="ivfpq: log2 codewords per subspace (1..8)")
    ap.add_argument("--rerank-depth", type=int, default=50,
                    help="ivfpq: ADC candidates re-scored exactly per "
                         "query (0 serves raw ADC distances)")
    ap.add_argument("--pq-store", choices=["device", "host"],
                    default="device",
                    help="ivfpq: where the full-precision rerank rows "
                         "live (host = RAM only, saves device memory)")
    ap.add_argument("--scan-impl", choices=["auto", "xla", "pallas"],
                    default="auto",
                    help="ivf/ivfpq: segment-scan implementation — auto "
                         "picks the fused Pallas kernel on TPU and XLA "
                         "elsewhere; pallas forces the kernel (interpret "
                         "mode off TPU, correctness only)")
    ap.add_argument("--cache-size", type=int, default=1024,
                    help="engine hot-query LRU entries (0 disables)")
    ap.add_argument("--mutable", action="store_true",
                    help="wrap the index in a MutableIndex (retains raw "
                         "features for metric hot-swap)")
    ap.add_argument("--churn", type=int, default=0,
                    help="with --mutable: upsert+delete this many rows "
                         "after the traffic run")
    ap.add_argument("--snapshot-dir", default=None,
                    help="load the index from this snapshot if present, "
                         "else save the built index there")
    ap.add_argument("--warmup-ks", default=None,
                    help="comma-separated extra k values to pre-compile "
                         "(e.g. 5,20); --k is always included")
    ap.add_argument("--mine", type=int, default=0,
                    help="after the traffic run, mine hard pairs for "
                         "this many anchors against the live serving "
                         "engine (shares its jit cache and stats) and "
                         "report yield + mining QPS")
    ap.add_argument("--data", type=int, default=1,
                    help=">1 forces that many host devices and shards "
                         "the gallery over the data axis")
    ap.add_argument("--scheduler", action="store_true",
                    help="serve through the traffic-shaped "
                         "RequestScheduler (priority classes, deadlines, "
                         "adaptive degradation) instead of the plain "
                         "MicroBatcher")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="scheduler: per-request deadline override in ms "
                         "(default: each class's own deadline)")
    ap.add_argument("--no-degrade", action="store_true",
                    help="scheduler: disable the adaptive quality ladder "
                         "(admission control + deadlines only)")
    ap.add_argument("--high-watermark", type=int, default=32,
                    help="scheduler: queue depth that starts the "
                         "degrade window")
    ap.add_argument("--low-watermark", type=int, default=4,
                    help="scheduler: queue depth that starts the "
                         "restore window")
    ap.add_argument("--degrade-window-ms", type=float, default=50.0,
                    help="scheduler: sustained pressure before stepping "
                         "the ladder down")
    ap.add_argument("--restore-window-ms", type=float, default=500.0,
                    help="scheduler: sustained drain before stepping "
                         "back up")
    ap.add_argument("--tenants", type=int, default=0,
                    help="after the main run, stand up a TenantRouter "
                         "serving this many metrics over ONE shared raw "
                         "gallery (tenant 0 serves this run's L; the "
                         "rest get seeded low-rank factors) and report "
                         "per-tenant purity + the shared-gallery memory "
                         "ratio vs independent stacks")
    ap.add_argument("--shadow", action="store_true",
                    help="with --tenants: register this run's L as a "
                         "shadow arm behind tenant 1, mirror the tenant "
                         "traffic through it, report overlap/latency "
                         "deltas, and promote it live")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final MetricsRegistry snapshot (JSON) "
                         "here — launch/metrics_report.py renders it")
    ap.add_argument("--trace-out", default=None,
                    help="write sampled request traces here as JSONL "
                         "(one span tree per line)")
    ap.add_argument("--trace-sample", type=float, default=0.0,
                    help="trace sampling rate in [0, 1] (deterministic: "
                         "rate 0.25 samples every 4th request)")
    args = ap.parse_args()
    if not 0.0 <= args.trace_sample <= 1.0:
        ap.error(f"--trace-sample must be in [0, 1], got "
                 f"{args.trace_sample}")
    if args.shadow and args.tenants < 2:
        ap.error("--shadow needs --tenants >= 2 (tenant 1 hosts the arm)")
    if args.tenants and args.data > 1:
        ap.error("--tenants is single-shard (incompatible with "
                 "--data > 1)")
    if args.index in ("ivf", "ivfpq") and args.backend == "pallas":
        ap.error(f"--index {args.index} only supports --backend xla (the "
                 "fused pallas kernel serves the exact full-scan path)")
    if args.data > 1 and (args.mutable or args.snapshot_dir):
        ap.error("--mutable / --snapshot-dir are single-shard "
                 "(incompatible with --data > 1)")
    if args.data > 1 and args.index == "ivfpq":
        ap.error("--index ivfpq is single-shard (incompatible with "
                 "--data > 1)")
    if args.data > 1 and args.scan_impl == "pallas":
        ap.error("--scan-impl pallas is single-shard (incompatible with "
                 "--data > 1)")
    if args.churn and not args.mutable:
        ap.error("--churn requires --mutable")

    if args.data > 1:   # must precede first jax import
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.data} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import dml
    from repro.core.ps.trainer import train_dml_single
    from repro.data import pairs as pairdata
    from repro.launch.mesh import make_local_mesh
    from repro.serve import (ExactIndex, IVFIndex, IVFPQIndex,
                             MicroBatcher, MutableIndex, RequestScheduler,
                             RetrievalEngine, SchedulerError, has_snapshot,
                             load_index, save_index)

    # --- data + metric ---------------------------------------------------
    cfg = pairdata.PairDatasetConfig(
        n_samples=args.gallery_size, feat_dim=args.feat_dim,
        n_classes=args.n_classes, kind="noisy_subspace", noise=0.5, seed=0)
    feats, labels = pairdata.make_features(cfg)
    if args.l_rank is not None:         # low-rank knob wins over proj-dim
        args.proj_dim = args.l_rank
    dcfg = dml.DMLConfig(feat_dim=args.feat_dim, l_rank=args.proj_dim)
    if args.train_steps > 0:
        train_pairs, _ = pairdata.train_eval_split(
            cfg, n_train_sim=4000, n_train_dis=4000,
            n_eval_sim=100, n_eval_dis=100)
        L, hist = train_dml_single(dcfg, train_pairs, steps=args.train_steps,
                                   batch_size=512, lr=2e-2, seed=0)
        print(f"trained L: objective {hist[0]['loss']:.3f} -> "
              f"{hist[-1]['loss']:.3f}")
    else:
        L = dml.init_params(dcfg, jax.random.PRNGKey(0))

    # --- serving stack ---------------------------------------------------
    mesh = make_local_mesh(data=args.data) if args.data > 1 else None
    ivf_kw = dict(n_clusters=args.n_clusters, nprobe=args.nprobe,
                  scan_impl=args.scan_impl)
    ivfpq_kw = dict(ivf_kw, n_subspaces=args.n_subspaces, bits=args.bits,
                    rerank_depth=args.rerank_depth, store=args.pq_store)
    base_kw = {"exact": {}, "ivf": ivf_kw, "ivfpq": ivfpq_kw}[args.index]
    t0 = time.perf_counter()
    loaded = bool(args.snapshot_dir) and has_snapshot(args.snapshot_dir)
    if loaded:
        index = load_index(args.snapshot_dir, expect_L=L)
        if args.mutable and not isinstance(index, MutableIndex):
            ap.error(f"--mutable requested but {args.snapshot_dir} holds "
                     f"a frozen {type(index).__name__} snapshot; point "
                     f"--snapshot-dir elsewhere or drop --mutable")
    elif args.mutable:
        index = MutableIndex.build(
            L, feats, base=args.index, retain_raw=True, **base_kw)
    elif args.index == "ivfpq":
        index = IVFPQIndex.build(L, jnp.asarray(feats), mesh=mesh,
                                 **ivfpq_kw)
    elif args.index == "ivf":
        index = IVFIndex.build(L, jnp.asarray(feats), mesh=mesh, **ivf_kw)
    else:
        index = ExactIndex.build(L, jnp.asarray(feats), mesh=mesh)
    build_s = time.perf_counter() - t0
    if args.snapshot_dir and not loaded:
        save_index(index, args.snapshot_dir)
        print(f"snapshot saved to {args.snapshot_dir}")
    engine = RetrievalEngine(index, k_top=args.k, backend=args.backend,
                             cache_size=args.cache_size)
    engine.tracer.sample_rate = args.trace_sample
    warm_ks = [args.k]
    if args.warmup_ks:
        warm_ks += [int(x) for x in args.warmup_ks.split(",")]
    engine.warmup(ks=sorted(set(warm_ks)))
    verb = "loaded from snapshot" if loaded else "built+projected"
    print(f"index[{type(index).__name__}]: {index.size} x {args.proj_dim} "
          f"({index.n_shards} shard(s)), {verb} in {build_s:.2f}s")
    ivf = index.base if isinstance(index, MutableIndex) else index
    if isinstance(ivf, (IVFIndex, IVFPQIndex)):
        from repro.serve import scan as scanmod
        scanned = ivf.nprobe * ivf.cap
        resolved = scanmod.resolve_scan_impl(ivf.scan_impl)
        print(f"  {type(ivf).__name__}: {ivf.n_clusters} clusters, cap "
              f"{ivf.cap}, nprobe {ivf.nprobe} -> <= {scanned} of "
              f"{ivf.size} rows scanned per query "
              f"({scanned / max(ivf.size, 1):.1%}); "
              f"scan_impl={ivf.scan_impl} (resolves to {resolved})")
    if isinstance(ivf, IVFPQIndex):
        print(f"  pq: {ivf.pq.n_subspaces} x {ivf.pq.bits}-bit codes "
              f"({ivf.code_bytes_per_row} B/row scanned vs "
              f"{4 * args.proj_dim + 4} full precision, "
              f"{ivf.compression_ratio:.1f}x), rerank depth "
              f"{ivf.rerank_depth}, store={ivf.store}")

    if args.scheduler:
        front = RequestScheduler(
            engine, max_batch=args.max_batch,
            max_wait_ms=args.max_wait_ms, degrade=not args.no_degrade,
            high_watermark=args.high_watermark,
            low_watermark=args.low_watermark,
            degrade_window_s=args.degrade_window_ms / 1e3,
            restore_window_s=args.restore_window_ms / 1e3)
        front.warmup(ks=sorted(set(warm_ks)))   # ladder levels too
        if front.controller is not None:
            print(f"  scheduler ladder: "
                  f"{[dict(lv) for lv in front.controller.ladder]}")
    else:
        front = MicroBatcher(engine, max_batch=args.max_batch,
                             max_wait_ms=args.max_wait_ms)

    # --- traffic ---------------------------------------------------------
    rng = np.random.RandomState(1)
    qids = rng.randint(0, len(feats), args.requests)
    noisy = feats[qids] + 0.1 * rng.randn(args.requests, args.feat_dim) \
        .astype(np.float32)
    mix = rng.choice(["interactive", "batch", "mining"],
                     size=args.requests, p=[0.7, 0.2, 0.1])
    t0 = time.perf_counter()
    pending, n_rejected = [], 0
    for i, qid in enumerate(qids):
        t_sub = time.perf_counter()
        try:
            if args.scheduler:
                fut = front.submit(
                    noisy[i], priority=str(mix[i]),
                    deadline_s=(args.deadline_ms / 1e3
                                if args.deadline_ms else None))
            else:
                fut = front.submit(noisy[i])
            pending.append((qid, t_sub, fut))
        except SchedulerError:                  # typed backpressure
            n_rejected += 1
    lat, purity, n_expired = [], [], 0
    for qid, t_sub, fut in pending:
        try:
            _, nbr = fut.result(timeout=60)
        except SchedulerError:                  # deadline expired in queue
            n_expired += 1
            continue
        lat.append(time.perf_counter() - t_sub)
        # a loaded post-churn snapshot can serve rows upserted after this
        # run's synthetic label table was made; score only known ids
        nbr = np.asarray(nbr)
        known = nbr[(nbr >= 0) & (nbr < len(labels))]
        if len(known):
            purity.append(float(np.mean(labels[known] == labels[qid])))
    wall = time.perf_counter() - t0

    # --- hard-pair mining against the live engine ------------------------
    # before front.close(): under --scheduler the miner rides the front
    # end's ``mining`` priority class (admission + deadlines shape the
    # mining load exactly like third-tier traffic), so the front door
    # must still be open. k_neighbors is sized so the mined k equals
    # --k — the scheduler rejects k above the engine's k_top.
    mine_stats = None
    if args.mine > 0:
        from repro.mining import HardPairMiner, MinerConfig
        use_front = args.scheduler and args.k >= 3
        miner = HardPairMiner(
            engine, feats, labels,
            MinerConfig(k_neighbors=(args.k - 1 if use_front
                                     else max(args.k, 5))),
            frontend=front if use_front else None)
        mine_stats = miner.mine(n_queries=args.mine, seed=2).stats
        mine_stats["via_scheduler"] = use_front
    front.close()

    from repro.obs import percentile

    lat_ms = np.sort(np.asarray(lat)) * 1e3
    st = engine.stats()
    print(f"requests={args.requests} wall={wall:.2f}s "
          f"qps={args.requests / wall:.0f} "
          f"(device-side qps={st['qps']:.0f})")
    if lat_ms.size:
        # obs.percentile interpolates — the old index math
        # (lat[int(n * 0.99) - 1]) underflowed to the *minimum* at small n
        p50, p99 = percentile(lat_ms, (50.0, 99.0))
        print(f"latency ms: p50={p50:.2f} p99={p99:.2f} "
              f"max={lat_ms[-1]:.2f}")
    print(f"batches={front.n_batches} "
          f"mean batch={np.mean(front.batch_sizes):.1f}")
    print(f"cache: {st['cache_hits']} hits / {st['cache_misses']} misses "
          f"({st['cache_entries']} entries)")
    print(f"neighbor class purity@{args.k}: {np.mean(purity):.3f} "
          f"(chance {1.0 / args.n_classes:.3f})")
    if args.scheduler:
        obs = st["frontend"]
        for name, c in obs["classes"].items():
            print(f"  class {name}: admitted {c['admitted']} "
                  f"completed {c['completed']} expired {c['expired']} "
                  f"rejected {c['rejected']} queue_depth "
                  f"{c['queue_depth']} p50={c['p50_ms']:.2f}ms "
                  f"p99={c['p99_ms']:.2f}ms")
        # end-of-run gauges: depths should have drained to 0 and the
        # ladder recovered toward level 0 — nonzero values here mean the
        # run ended under pressure
        print(f"  gauges: total queue_depth {obs['queue_depth']}, "
              f"ladder level {obs['degradation_level']}")
        print(f"  degradation: level {obs['degradation_level']} "
              f"knobs {obs['degradation_knobs']} "
              f"({obs['n_transitions']} transition(s)); "
              f"{n_rejected} rejected at admission, "
              f"{n_expired} expired in queue")

    if mine_stats is not None:
        ms = mine_stats
        via = ("scheduler mining class" if ms["via_scheduler"]
               else "direct engine path")
        print(f"mining ({via}): {ms['n_pairs']} hard pairs from "
              f"{ms['n_queries']} anchors (neg yield "
              f"{ms['neg_yield']:.2f}/q, pos yield "
              f"{ms['pos_yield']:.2f}/q, {ms['n_semi_hard']} semi-hard, "
              f"{ms['n_fallback_neg']} fallback, {ms['n_dropped']} shed "
              f"by the front end) in "
              f"{ms['mine_busy_s']:.2f}s device time — engine now at "
              f"{ms['engine_qps']:.0f} qps over "
              f"{engine.stats()['n_device_queries']} device queries")

    # --- mutation lifecycle demo -----------------------------------------
    if args.mutable and args.churn > 0 and isinstance(index, MutableIndex):
        n = min(args.churn, index.size // 2)
        fresh = feats[rng.randint(0, len(feats), n)] \
            + 0.1 * rng.randn(n, args.feat_dim).astype(np.float32)
        new_ids = index.upsert(fresh)
        retire = index.live_ids()[:n]
        retire = retire[~np.isin(retire, new_ids)]
        index.delete(retire)
        d_m, i_m = engine.search(noisy[:8])
        st = engine.stats()
        print(f"churn: +{n} upserts / -{len(retire)} deletes -> "
              f"size {index.size}, delta_rows {st['delta_rows']}, "
              f"tombstones {st['tombstones']}, "
              f"compactions {st['compactions']} "
              f"(version {index.version}); new ids reachable: "
              f"{bool(np.isin(i_m, new_ids).any())}")
        if args.snapshot_dir:
            save_index(index, args.snapshot_dir)
            print(f"post-churn snapshot saved to {args.snapshot_dir}")

    # --- multi-tenant serving over the shared gallery --------------------
    if args.tenants > 0:
        from repro.serve import TenantRouter
        # fresh registry: the main engine's series are unscoped, tenant
        # engines label everything with tenant=... — one registry cannot
        # carry both shapes of the same metric name
        router = TenantRouter(feats, k_top=args.k)
        backends = {"exact": {}, "ivf": ivf_kw, "ivfpq": ivfpq_kw}
        for i in range(args.tenants):
            if i == 0:
                ti_L = np.asarray(L, np.float32)
            else:       # seeded low-rank factors standing in for other
                        # surfaces' trained metrics
                t_rng = np.random.RandomState(100 + i)
                ti_L = t_rng.randn(
                    max(args.proj_dim // 2, 2),
                    args.feat_dim).astype(np.float32) * 0.1
            router.add_tenant(f"t{i}", ti_L, backend=args.index,
                              build_kwargs=backends[args.index])
        if args.shadow:
            router.register_shadow("t1", np.asarray(L, np.float32),
                                   sample_rate=0.5)
        t_qids = rng.randint(0, len(feats), 64)
        for i, qid in enumerate(t_qids):
            name = f"t{i % args.tenants}"
            _, nbr = router.search(name, noisy[qid % args.requests]
                                   if args.requests else feats[qid])
        tob = router.observability()
        mem = tob["memory"]
        # the multi-tenant win: raw rows resident once, not per tenant
        per_tenant = mem["gallery"] + max(mem["tenants"].values())
        ratio = mem["total"] / max(per_tenant * args.tenants, 1)
        print(f"tenants: {args.tenants} metrics over one "
              f"{tob['gallery_rows']}-row gallery; resident "
              f"{mem['total'] / 1e6:.1f} MB vs ~"
              f"{per_tenant * args.tenants / 1e6:.1f} MB for "
              f"independent stacks ({ratio:.2f}x)")
        for name in sorted(tob["tenants"]):
            tb = tob["tenants"][name]
            print(f"  {name}: backend={tb['backend']} "
                  f"l_shape={tb['l_shape']} requests={tb['n_requests']} "
                  f"warm={tb['warm']}")
        if args.shadow:
            arm = router.tenant("t1").shadow
            st_sh = arm.stats()
            print(f"  shadow@t1: mirrored {st_sh['n_mirrored']} "
                  f"(rate {st_sh['sample_rate']}), overlap@{args.k} "
                  f"{st_sh['overlap_at_k']:.3f}, latency ratio "
                  f"{st_sh['latency_ratio']:.2f}")
            router.promote("t1")
            print(f"  promoted shadow -> t1 live "
                  f"(fingerprint {router.tenant('t1').fingerprint})")

    # --- obs export ------------------------------------------------------
    if args.metrics_out:
        engine.registry.write_snapshot(args.metrics_out)
        print(f"metrics snapshot -> {args.metrics_out}")
    if args.trace_out:
        n_tr = engine.tracer.write_jsonl(args.trace_out, append=False)
        print(f"traces -> {args.trace_out} ({n_tr} sampled of "
              f"{engine.tracer.n_minted} minted)")


if __name__ == "__main__":
    main()
