"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs prefill on a prompt batch then a jitted decode loop with the
arch-appropriate cache (KV / SSM state / hybrid). Reduced configs run real
tokens on CPU; full configs are exercised via the dry-run (launch.dryrun).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg).replace(dtype="float32")
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only — nothing to decode")
    from repro.models import build_model
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    prompts = jnp.asarray(rng.randint(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32))
    max_seq = args.prompt_len + args.gen_len

    # prefill = teacher-forced decode over the prompt (state-carrying for
    # ssm/hybrid; cache-filling for attention)
    cache = model.init_decode_cache(args.batch, max_seq)
    decode = jax.jit(model.decode_step)
    t0 = time.time()
    for t in range(args.prompt_len):
        logits, cache = decode(params, cache, prompts[:, t], jnp.int32(t))
    prefill_s = time.time() - t0

    toks = jnp.argmax(logits, axis=-1)
    out = [toks]
    t0 = time.time()
    for t in range(args.prompt_len, max_seq - 1):
        logits, cache = decode(params, cache, toks, jnp.int32(t))
        toks = jnp.argmax(logits, axis=-1)
        out.append(toks)
    jax.block_until_ready(toks)
    decode_s = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prefill={prefill_s*1e3:.0f}ms "
          f"decode={decode_s/max(len(out)-1,1)*1e3:.1f} ms/token")
    print(f"generated shape: {gen.shape}; sample: {np.asarray(gen[0, :12])}")
    assert np.isfinite(np.asarray(logits)).all()


if __name__ == "__main__":
    main()
