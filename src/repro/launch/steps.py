"""Step builders + ShapeDtypeStruct input specs for train / prefill / decode.

Everything here is shape-level: ``input_specs`` returns ShapeDtypeStructs
(weak-type-correct, shardable, zero allocation), and the ``make_*_step``
functions return plain python callables ready for ``jax.jit(...,
in_shardings=..., out_shardings=...)`` — used identically by the real
launcher and the multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape, RunConfig
from repro.core import losses
from repro.models import common
from repro.models.transformer import Model, build_model
from repro.optim import (Optimizer, adamw, adam, sgd, momentum,
                         clip_by_global_norm, apply_updates, schedules)
from repro.sharding.partition import logical_to_physical, DEFAULT_RULES


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def make_optimizer(run: RunConfig) -> Optimizer:
    lr = schedules.cosine(run.lr, run.total_steps, warmup=run.warmup)
    if run.opt == "adamw":
        return adamw(lr, weight_decay=run.weight_decay)
    if run.opt == "adam":
        return adam(lr)
    if run.opt == "sgd":
        return sgd(lr)
    if run.opt == "momentum":
        return momentum(lr)
    raise ValueError(run.opt)


# ---------------------------------------------------------------------------
# Effective config per (arch, shape): long-context needs sub-quadratic attn.
# ---------------------------------------------------------------------------

def effective_config(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Dense/MoE/VLM archs switch to the sliding-window variant for the
    524k-token decode shape (DESIGN.md §5); SSM/hybrid run natively."""
    if (shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid")
            and cfg.attention == "full"):
        return cfg.replace(attention="sliding", window=4096)
    return cfg


def skip_reason(cfg: ArchConfig, shape: InputShape) -> Optional[str]:
    if shape.mode == "decode" and not cfg.has_decode:
        return "encoder-only architecture: no autoregressive decode step"
    return None


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    """Model inputs for one step, as ShapeDtypeStructs."""
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.mode == "train":
        if cfg.input_kind == "embeddings":
            return {
                "embeddings": jax.ShapeDtypeStruct((B, T, cfg.d_model),
                                                   jnp.dtype(cfg.dtype)),
                "labels": jax.ShapeDtypeStruct((B, T), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, T), i32),
                "labels": jax.ShapeDtypeStruct((B, T), i32)}
    if shape.mode == "prefill":
        if cfg.input_kind == "embeddings":
            return {"embeddings": jax.ShapeDtypeStruct(
                (B, T, cfg.d_model), jnp.dtype(cfg.dtype))}
        return {"tokens": jax.ShapeDtypeStruct((B, T), i32)}
    if shape.mode == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B,), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}
    raise ValueError(shape.mode)


def batch_pspec(name: str, mesh: Mesh, shape_struct) -> P:
    """PartitionSpec for one input leaf: batch dim over (pod, data)."""
    logical = {
        "tokens": ("batch",) if len(shape_struct.shape) == 1 else ("batch", "seq"),
        "labels": ("batch", "seq"),
        "embeddings": ("batch", "seq", None),
        "pos": (),
    }[name]
    return logical_to_physical(logical, mesh, shape=shape_struct.shape)


def input_shardings(specs, mesh: Mesh):
    return {k: NamedSharding(mesh, batch_pspec(k, mesh, v))
            for k, v in specs.items()}


# ---------------------------------------------------------------------------
# Parameter / state shardings
# ---------------------------------------------------------------------------

def param_shardings(model: Model, params_shape, mesh: Mesh):
    """NamedShardings for the param tree from the model's logical axes."""
    axes = model.logical_axes(
        jax.tree.map(lambda x: None, params_shape))
    return jax.tree.map(
        lambda lg, shp: NamedSharding(
            mesh, logical_to_physical(lg, mesh, shape=shp.shape)),
        axes, params_shape, is_leaf=lambda x: isinstance(x, tuple))


def make_state_shardings(state_shape: TrainState, params_shape, pshard,
                         mesh: Mesh) -> TrainState:
    """Shard TrainState: params as given; opt moment buffers mirror params
    by shape; scalars replicated."""
    index = [(s.shape, sh) for s, sh in
             zip(jax.tree.leaves(params_shape), jax.tree.leaves(pshard))]

    def match(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        for shp, sh in index:
            if shp == leaf.shape:
                return sh
        return NamedSharding(mesh, P())

    return TrainState(
        params=pshard,
        opt_state=jax.tree.map(match, state_shape.opt_state),
        step=NamedSharding(mesh, P()),
    )


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def chunked_ce_loss(model: Model, params, h, labels, n_chunks: int = 8):
    """Cross-entropy with seq-chunked unembedding (bounds live logits to
    (B, T/n_chunks, V)); rematerialized in backward."""
    cfg = model.cfg
    B, T, d = h.shape
    while T % n_chunks != 0:
        n_chunks -= 1
    hc = h.reshape(B, n_chunks, T // n_chunks, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, T // n_chunks).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(h_k, l_k):
        logits = common.unembed(params["embedding"], h_k, cfg)
        return losses.softmax_cross_entropy(logits, l_k)

    def body(acc, inp):
        h_k, l_k = inp
        return acc + chunk_loss(h_k, l_k), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / n_chunks


def make_train_step(model: Model, opt: Optimizer, run: RunConfig,
                    mesh: Optional[Mesh] = None, loss_chunks: int = 8):
    cfg = model.cfg

    def loss_fn(params, batch):
        h, aux = model.hidden(params, batch, mesh=mesh, remat=run.remat)
        ce = chunked_ce_loss(model, params, h, batch["labels"], loss_chunks)
        total = ce + cfg.moe_aux_weight * aux["moe_aux"]
        return total, {"ce": ce, "moe_aux": aux["moe_aux"]}

    def train_step(state: TrainState, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch)
        if run.grad_clip:
            grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
        else:
            gnorm = jnp.zeros(())
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, **aux}
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


def make_prefill_step(model: Model, run: RunConfig, mesh=None):
    def prefill_step(params, batch):
        logits, aux = model.apply(params, batch, mesh=mesh, remat=False)
        return logits

    return prefill_step


def make_serve_step(model: Model, run: RunConfig, mesh=None):
    def serve_step(params, cache, batch):
        logits, cache = model.decode_step(params, cache, batch["tokens"],
                                          batch["pos"], mesh=mesh)
        return logits, cache

    return serve_step


# ---------------------------------------------------------------------------
# Cache specs/shardings for decode shapes
# ---------------------------------------------------------------------------

def cache_shape_structs(model: Model, shape: InputShape):
    """ShapeDtypeStructs of the decode cache (no allocation)."""
    return jax.eval_shape(
        lambda: model.init_decode_cache(shape.global_batch, shape.seq_len))


def cache_logical_axes(cfg: ArchConfig, mesh: Mesh):
    """Logical axes for cache leaves, chosen per divisibility:
    KV caches (B, S, K, Dh): shard K over model if divisible, else shard S
    (flash-decoding); SSM states shard heads over model."""

    def kv_axes(leaf_shape):
        B, S, K, dh = leaf_shape
        if K % mesh.shape["model"] == 0:
            return ("batch", None, "kv_heads", None)
        return ("batch", "cache_seq", None, None)

    return kv_axes


def cache_shardings(model: Model, cfg: ArchConfig, shape: InputShape,
                    mesh: Mesh):
    structs = cache_shape_structs(model, shape)
    kv_axes = cache_logical_axes(cfg, mesh)

    def leaf_sharding(path_leaf):
        shp = path_leaf.shape
        if len(shp) == 4 and shp[1] > 1 and shp[3] == cfg.dim_per_head:
            lg = kv_axes(shp)
        elif len(shp) == 5:
            # stacked (L, B, S, K, Dh) KV caches / (L,B,H,p,n) ssm states
            if shp[4] == cfg.dim_per_head and shp[2] > 8:
                lg = (None,) + kv_axes(shp[1:])
            else:
                lg = (None, "batch", "heads", None, None)
        elif len(shp) == 4:
            lg = ("batch", "heads", None, None)      # ssm state (B,H,p,n)
        elif len(shp) == 3:
            lg = ("batch", None, None)               # conv history (B,W,C)
        elif len(shp) == 2:
            lg = ("batch", None)                     # rwkv x_prev (B,d)
        else:
            lg = tuple(None for _ in shp)
        return NamedSharding(mesh, logical_to_physical(lg, mesh, shape=shp))

    return jax.tree.map(leaf_sharding, structs)
