"""Post-compile HLO analysis: collective bytes, dot FLOPs, roofline terms.

Why parse HLO text instead of trusting ``compiled.cost_analysis()``:
  1. cost_analysis has no collective-traffic entry at all;
  2. cost_analysis counts a ``while`` body ONCE — with scan-over-layers that
     undercounts FLOPs/bytes by a factor of n_layers.

So we walk the optimized HLO call graph ourselves: per computation we
accumulate (a) collective output bytes, (b) matmul FLOPs from ``dot`` ops
(2 x output-numel x contraction-size, operand shapes are in the text),
(c) operand+output bytes of top-level ops (fusion bodies excluded — their
internals don't touch HBM). ``while`` bodies are multiplied by the loop trip
count, recovered from the largest integer constant in the loop's condition
computation (exact for lax.scan loops).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_CALL_RE = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES[dtype]


def _line_output_bytes(line: str) -> int:
    """Sum buffer sizes on the LHS of `lhs = <shapes> op-name(...)`."""
    eq = line.find(" = ")
    if eq < 0:
        return 0
    rhs = line[eq + 3:]
    # shapes before the op name; op name terminates the shape prefix
    m = re.match(r"\(?((?:\w+\[[\d,]*\](?:\{[\d,]*\})?,?\s*)+)\)?\s*[\w\-]+\(",
                 rhs)
    if not m:
        return 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(m.group(1)):
        total += _shape_bytes(dt, dims)
    return total


_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OPNAME_RE = re.compile(r"^\(?[\w\[\],\{\}\s]*?\)?\s*([\w\-]+)\(")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_TRIP_COUNT_RE = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"?(\d+)"?')


@dataclasses.dataclass
class Computation:
    name: str
    collective_bytes: Dict[str, int]
    collective_counts: Dict[str, int]
    while_calls: List[Tuple[str, str]]        # (cond_name, body_name)
    other_calls: List[str]
    max_constant: int = 0
    dot_flops: float = 0.0
    op_bytes: float = 0.0
    is_fusion_body: bool = False


def _shape_prefix_bytes(rhs: str) -> int:
    """Buffer bytes of the shape prefix of an op definition RHS (possibly a
    tuple), i.e. everything before the op name."""
    m = _OPNAME_RE.match(rhs)
    prefix = rhs[:m.start(1)] if m else rhs
    total = 0
    for dt, dims in _SHAPE_RE.findall(prefix):
        total += _shape_bytes(dt, dims)
    return total


def _shape_prefix_dims(rhs: str) -> List[List[int]]:
    m = _OPNAME_RE.match(rhs)
    prefix = rhs[:m.start(1)] if m else rhs
    out = []
    for dt, dims in _SHAPE_RE.findall(prefix):
        out.append([int(d) for d in dims.split(",")] if dims else [])
    return out


# ops that are pure aliasing / control structure: no HBM traffic of their own
_NO_TRAFFIC_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
                   "constant", "while", "conditional", "call", "custom-call",
                   "after-all", "partition-id", "replica-id"}
# ops whose traffic is proportional to the (small) output, not the operand
_OUTPUT_TRAFFIC_OPS = {"dynamic-slice", "slice", "gather", "iota",
                       "broadcast", "reshape", "transpose", "copy"}


def _op_traffic_bytes(opname: str, out_name: str, rhs: str, opm,
                      sym_bytes: Dict[str, int]) -> int:
    """Approximate HBM traffic of one top-level op.

    dynamic-slice reads only the slice (not the whole stacked operand —
    critical inside scan-over-layers); dynamic-update-slice writes only the
    update; aliasing ops are free; everything else reads operands and writes
    its output.
    """
    out_b = sym_bytes.get(out_name, 0)
    if opname in _NO_TRAFFIC_OPS:
        return 0
    if opname in _OUTPUT_TRAFFIC_OPS:
        return 2 * out_b
    args = rhs[opm.end(1):] if opm else ""
    args = args.split("), ")[0]
    operands = _OPERAND_RE.findall(args)
    if opname in ("dynamic-update-slice", "scatter"):
        upd = sym_bytes.get(operands[1], 0) if len(operands) > 1 else out_b
        return 2 * upd
    if opname == "fusion":
        # inputs + output of the fused region (its internals are on-chip)
        return out_b + sum(sym_bytes.get(o, 0) for o in operands
                           if "fused" not in o)
    return out_b + sum(sym_bytes.get(o, 0) for o in operands)


def _parse_computations(hlo_text: str) -> Dict[str, Computation]:
    # pass 1: symbol table  op-name -> (output bytes, first shape dims)
    sym_bytes: Dict[str, int] = {}
    sym_dims: Dict[str, List[int]] = {}
    for raw in hlo_text.splitlines():
        line = raw.strip()
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rhs = d.group(1), d.group(2)
        sym_bytes[name] = _shape_prefix_bytes(rhs)
        dims = _shape_prefix_dims(rhs)
        if dims:
            sym_dims[name] = dims[0]
    # parameters in computation headers also define names; ignore (their
    # bytes only matter as operands of ops that read them, which resolve
    # through get-tuple-element/parameter def lines inside the body).

    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if _HEADER_RE.match(raw) and not raw.startswith(" "):
            h = _HEADER_RE.match(raw)
            cur = Computation(h.group(2), defaultdict(int),
                              defaultdict(int), [], [])
            cur.is_fusion_body = "fused" in cur.name
            comps[cur.name] = cur
            if h.group(1):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        for c in _CONST_RE.findall(line):
            cur.max_constant = max(cur.max_constant, int(c))
        d = _DEF_RE.match(line)
        if not d:
            continue
        rhs = _COMMENT_RE.sub("", d.group(2))
        opm = _OPNAME_RE.match(rhs)
        opname = opm.group(1) if opm else ""

        if opname == "dot":
            out_numel = 1
            for dim in sym_dims.get(d.group(1), []):
                out_numel *= dim
            args = rhs[opm.end(1):]
            operands = _OPERAND_RE.findall(args.split("),")[0] + ")")
            csize = 1
            cm = _LHS_CONTRACT_RE.search(rhs)
            if operands and cm is not None:
                lhs_dims = sym_dims.get(operands[0], [])
                for ci in (cm.group(1).split(",") if cm.group(1) else []):
                    if int(ci) < len(lhs_dims):
                        csize *= lhs_dims[int(ci)]
            cur.dot_flops += 2.0 * out_numel * csize

        if not cur.is_fusion_body:
            cur.op_bytes += _op_traffic_bytes(opname, d.group(1), rhs, opm,
                                              sym_bytes)

        if opname == "while":
            body = cond = None
            for m2 in re.finditer(r"(condition|body)=%?([\w\.\-]+)", rhs):
                if m2.group(1) == "condition":
                    cond = m2.group(2)
                else:
                    body = m2.group(2)
            tm = _TRIP_COUNT_RE.search(d.group(2))
            trips = int(tm.group(1)) if tm else None
            if body:
                cur.while_calls.append((cond, body, trips))
            continue
        for cname in _CALL_RE.findall(rhs):
            cur.other_calls.append(cname)
        if opname.replace("-start", "") in COLLECTIVE_KINDS:
            kind = opname.replace("-start", "")
            b = sym_bytes.get(d.group(1), 0)
            cur.collective_bytes[kind] += b
            cur.collective_counts[kind] += 1
    return comps


def collective_summary(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Loop-corrected totals: collective bytes/counts, dot FLOPs, op bytes."""
    comps = _parse_computations(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:
        return {"bytes": {}, "counts": {}, "total_bytes": 0,
                "dot_flops": 0.0, "op_bytes": 0.0}

    memo: Dict[str, Tuple] = {}

    def walk(name: str, depth=0):
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 60:
            return {}, {}, 0.0, 0.0
        memo[name] = ({}, {}, 0.0, 0.0)       # cycle guard
        bytes_ = dict(comp.collective_bytes)
        counts = dict(comp.collective_counts)
        flops = comp.dot_flops
        obytes = comp.op_bytes
        for cname in comp.other_calls:
            if cname == name:
                continue
            # other_calls has one entry per call SITE — a fusion invoked from
            # three sites executes three times, so count each occurrence
            cb, cc, cf, cby = walk(cname, depth + 1)
            for k, v in cb.items():
                bytes_[k] = bytes_.get(k, 0) + v
            for k, v in cc.items():
                counts[k] = counts.get(k, 0) + v
            flops += cf
            obytes += cby
        for cond, body, known_trips in comp.while_calls:
            if known_trips:
                trips = known_trips
            elif cond in comps and comps[cond].max_constant > 0:
                trips = comps[cond].max_constant
            else:
                trips = 1
            cb, cc, cf, cby = walk(body, depth + 1)
            for k, v in cb.items():
                bytes_[k] = bytes_.get(k, 0) + v * trips
            for k, v in cc.items():
                counts[k] = counts.get(k, 0) + v * trips
            flops += cf * trips
            obytes += cby * trips
        memo[name] = (bytes_, counts, flops, obytes)
        return memo[name]

    b, c, f, ob = walk(entry.name)
    return {"bytes": b, "counts": c, "total_bytes": float(sum(b.values())),
            "dot_flops": float(f), "op_bytes": float(ob)}


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   n_chips: int, peak_flops: float, hbm_bw: float,
                   ici_bw: float) -> Dict[str, float]:
    """The three roofline terms in seconds (global work over global capacity).

    FLOPs/bytes from cost_analysis are per-partition program totals under
    SPMD, so multiply by n_chips for globals — or equivalently treat
    cost_analysis as per-chip and divide by per-chip capability. We use the
    per-chip interpretation directly.
    """
    compute_s = flops / peak_flops
    memory_s = hbm_bytes / hbm_bw
    collective_s = collective_bytes / ici_bw
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
    }
