"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real TPU pods this builds the production mesh and trains the full config;
on the offline CPU container use ``--reduced`` (smoke-scale) which runs a
genuine end-to-end loop: sharded data pipeline -> scan-over-layers model ->
chunked CE loss -> optimizer -> checkpointing.

The ``--loss dml`` mode trains the backbone + metric head jointly with the
paper's Eq. 4 objective over pooled embeddings (DESIGN.md §4 mode 3).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config, reduced as reduce_cfg
from repro.configs.base import RunConfig
from repro.data.tokens import token_stream
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="build the (data=16, model=16) pod mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt", type=str, default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg).replace(dtype="float32")
    from repro.models import build_model
    model = build_model(cfg)
    run = RunConfig(arch=args.arch, lr=args.lr, total_steps=args.steps,
                    warmup=min(20, args.steps // 5), remat=args.remat)

    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_local_mesh())
    opt = steps_lib.make_optimizer(run)
    params = model.init(jax.random.PRNGKey(run.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={dict(mesh.shape)}")

    state = steps_lib.TrainState(params, opt.init(params),
                                 jnp.zeros((), jnp.int32))
    train_step = jax.jit(steps_lib.make_train_step(model, opt, run,
                                                   mesh=None, loss_chunks=2))

    if cfg.input_kind == "embeddings":
        rng = np.random.RandomState(0)

        def batches():
            while True:
                yield {
                    "embeddings": jnp.asarray(rng.randn(
                        args.batch, args.seq, cfg.d_model).astype(np.float32)),
                    "labels": jnp.asarray(rng.randint(
                        0, cfg.vocab_size, (args.batch, args.seq)).astype(np.int32)),
                }
        stream = batches()
    else:
        stream = token_stream(cfg.vocab_size, args.batch, args.seq)

    t0 = time.time()
    first = None
    for t in range(args.steps):
        state, metrics = train_step(state, next(stream))
        loss = float(metrics["loss"])
        first = loss if first is None else first
        if t % args.log_every == 0 or t == args.steps - 1:
            print(f"step {t:5d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(t+1)*1e3:.0f} ms/step)", flush=True)
    print(f"loss {first:.4f} -> {loss:.4f}")
    if args.ckpt:
        path = save_checkpoint(args.ckpt, args.steps,
                               {"params": state.params})
        print(f"checkpoint: {path}")


if __name__ == "__main__":
    main()
