"""Text dashboard over a MetricsRegistry snapshot file.

Run:  PYTHONPATH=src python -m repro.launch.metrics_report metrics.json

Renders the snapshot a serving run exported with
``serve_retrieval --metrics-out metrics.json`` (or any
``MetricsRegistry.write_snapshot`` output) as a terminal dashboard:
serving traffic counters, per-class latency percentiles (estimated from
the ``frontend_latency_seconds`` histogram buckets), queue depths and
the degradation-ladder level, engine cache behavior, per-index memory
gauges, and the most recent lifecycle events. ``--merge`` folds
additional snapshot files in first (counters/histograms add, gauges
take the later file's value) — the per-worker roll-up path.

docs/observability.md is the catalog of every metric name rendered
here; benchmarks/check_obs.py validates the snapshot schema in CI.
"""

from __future__ import annotations

import argparse
import json

from repro.obs.metrics import merge_snapshots, parse_label_key


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} GiB"


def _hist_percentile(hist: dict, key: str, q: float) -> float:
    """Upper-bound percentile estimate from one histogram cell (same
    rule as obs.Histogram.percentile): the bound of the bucket holding
    the q-th sample; inf in the overflow bucket, NaN when empty."""
    cell = hist["values"].get(key)
    if cell is None or cell["count"] == 0:
        return float("nan")
    rank = q / 100.0 * cell["count"]
    run = 0
    bounds = list(hist["buckets"]) + [float("inf")]
    for bound, c in zip(bounds, cell["counts"]):
        run += c
        if run >= rank and c:
            return bound
    return float("inf")


def _counter_values(snap: dict, name: str) -> dict:
    return snap.get("counters", {}).get(name, {}).get("values", {})


def _gauge_values(snap: dict, name: str) -> dict:
    return snap.get("gauges", {}).get(name, {}).get("values", {})


def render(snap: dict, n_events: int = 8) -> str:
    """The dashboard text for one (possibly merged) snapshot dict."""
    lines = []
    w = lines.append

    w("== serving ==")
    eng = {k: v.get("", 0.0) for k, v in
           ((n, _counter_values(snap, f"engine_{n}_total"))
            for n in ("requests", "queries", "device_queries",
                      "busy_seconds", "cache_hits", "cache_misses"))}
    dev, busy = eng["device_queries"], eng["busy_seconds"]
    qps = dev / busy if busy > 0 else 0.0
    w(f"engine: {eng['requests']:.0f} requests / {eng['queries']:.0f} "
      f"queries ({dev:.0f} on device, {busy:.3f}s busy, {qps:.0f} qps)")
    looked = eng["cache_hits"] + eng["cache_misses"]
    rate = eng["cache_hits"] / looked if looked else 0.0
    entries = _gauge_values(snap, "engine_cache_entries").get("", 0.0)
    w(f"cache:  {eng['cache_hits']:.0f} hits / "
      f"{eng['cache_misses']:.0f} misses ({rate:.1%} hit rate, "
      f"{entries:.0f} entries resident)")
    for name in ("batcher_batches_total", "frontend_batches_total"):
        vals = _counter_values(snap, name)
        if vals:
            w(f"{name.split('_')[0]}: {vals.get('', 0.0):.0f} batches")

    depths = _gauge_values(snap, "frontend_queue_depth")
    level = _gauge_values(snap, "frontend_degradation_level").get("")
    if depths or level is not None:
        w("")
        w("== front end ==")
        if depths:
            parts = [f"{parse_label_key(k).get('cls', '?')}="
                     f"{v:.0f}" for k, v in sorted(depths.items())]
            w(f"queue depth: {' '.join(parts)} "
              f"(total {sum(depths.values()):.0f})")
        if level is not None:
            w(f"ladder level: {level:.0f} (0 = full quality)")
        reqs = _counter_values(snap, "frontend_requests_total")
        per_class: dict = {}
        for key, v in reqs.items():
            lab = parse_label_key(key)
            per_class.setdefault(lab.get("cls", "?"), {})[
                lab.get("outcome", "?")] = v
        lat = snap.get("histograms", {}).get("frontend_latency_seconds")
        for cls in sorted(per_class):
            c = per_class[cls]
            row = (f"  {cls:<12} admitted {c.get('admitted', 0):.0f} "
                   f"completed {c.get('completed', 0):.0f} "
                   f"expired {c.get('expired', 0):.0f} "
                   f"rejected {c.get('rejected', 0):.0f}")
            if lat is not None:
                p50 = _hist_percentile(lat, f"cls={cls}", 50.0)
                p99 = _hist_percentile(lat, f"cls={cls}", 99.0)
                row += (f"  p50<={p50 * 1e3:.1f}ms p99<={p99 * 1e3:.1f}ms")
            w(row)

    mem = _gauge_values(snap, "index_memory_bytes")
    if mem:
        w("")
        w("== index memory ==")
        rows = _gauge_values(snap, "index_gallery_rows").get("", 0.0)
        w(f"gallery rows: {rows:.0f}")
        total = 0.0
        for key, v in sorted(mem.items()):
            comp = parse_label_key(key).get("component", key)
            total += v
            if v:
                w(f"  {comp:<12} {_fmt_bytes(v)}")
        w(f"  {'total':<12} {_fmt_bytes(total)}")

    loop_gauges = {n: _gauge_values(snap, f"loop_{n}").get("")
                   for n in ("staleness_steps", "mined_frac", "pool_size",
                             "neg_yield", "pos_yield")}
    if any(v is not None for v in loop_gauges.values()):
        w("")
        w("== closed loop ==")
        refreshes = _counter_values(
            snap, "loop_refreshes_total").get("", 0.0)
        w(f"refreshes: {refreshes:.0f}")
        for n, v in loop_gauges.items():
            if v is not None:
                w(f"  {n:<16} {v:g}")
        mined = _counter_values(snap, "miner_pairs_total")
        if mined:
            parts = [f"{parse_label_key(k).get('kind', '?')}={v:.0f}"
                     for k, v in sorted(mined.items())]
            w(f"  mined pairs: {' '.join(parts)}")

    events = snap.get("events", [])
    if events:
        w("")
        w(f"== events (last {min(n_events, len(events))} of "
          f"{len(events)}) ==")
        for e in events[-n_events:]:
            attrs = {k: v for k, v in e.items()
                     if k not in ("t", "event")}
            w(f"  t={e.get('t', 0.0):.3f} {e.get('event', '?'):<22} "
              + " ".join(f"{k}={v}" for k, v in attrs.items()))
    return "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("snapshot", help="MetricsRegistry snapshot JSON "
                                     "(serve_retrieval --metrics-out)")
    ap.add_argument("--merge", nargs="*", default=[],
                    help="additional snapshot files to merge in "
                         "(counters/histograms add, later gauges win)")
    ap.add_argument("--events", type=int, default=8,
                    help="recent lifecycle events to show")
    args = ap.parse_args()
    with open(args.snapshot) as f:
        snap = json.load(f)
    for path in args.merge:
        with open(path) as f:
            snap = merge_snapshots(snap, json.load(f))
    print(render(snap, n_events=args.events), end="")


if __name__ == "__main__":
    main()
