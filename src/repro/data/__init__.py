from repro.data import pairs, tokens, loader  # noqa: F401
