"""Synthetic LM token streams for backbone training/smoke/bench runs.

Deterministic Markov-ish structure (not pure uniform noise) so a trained LM
loss actually decreases, which the end-to-end driver asserts.
"""

from __future__ import annotations

from typing import Iterator

import numpy as np
import jax.numpy as jnp


def token_stream(vocab_size: int, batch_size: int, seq_len: int,
                 seed: int = 0) -> Iterator[dict]:
    """Yields {tokens (B, T) int32, labels (B, T) int32} batches forever.

    Sequences follow x_{t+1} = (a * x_t + b + noise) mod V with per-sequence
    (a, b) so there is learnable next-token structure.
    """
    rng = np.random.RandomState(seed)
    # a FIXED set of transition modes (drawn once): the stream is stationary,
    # so a trained LM's loss actually decreases
    n_modes = 4
    mode_a = rng.randint(1, 5, size=n_modes)
    mode_b = rng.randint(0, vocab_size, size=n_modes)
    while True:
        m = rng.randint(0, n_modes, size=(batch_size, 1))
        a, b = mode_a[m], mode_b[m]
        x0 = rng.randint(0, vocab_size, size=(batch_size, 1))
        toks = np.empty((batch_size, seq_len + 1), np.int64)
        toks[:, :1] = x0
        for t in range(seq_len):
            noise = rng.randint(0, 3, size=(batch_size, 1))
            toks[:, t + 1:t + 2] = (a * toks[:, t:t + 1] + b + noise) % vocab_size
        yield {
            "tokens": jnp.asarray(toks[:, :-1].astype(np.int32)),
            "labels": jnp.asarray(toks[:, 1:].astype(np.int32)),
        }


def embedding_stream(embed_dim: int, batch_size: int, seq_len: int,
                     n_classes: int = 16, seed: int = 0) -> Iterator[dict]:
    """Precomputed frame/patch embedding batches for the audio/VLM frontends
    (the one sanctioned stub): {embeddings (B, T, D), labels (B,) int32}."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(n_classes, embed_dim).astype(np.float32)
    while True:
        cls = rng.randint(0, n_classes, size=batch_size)
        e = centers[cls][:, None, :] + 0.5 * rng.randn(
            batch_size, seq_len, embed_dim).astype(np.float32)
        yield {"embeddings": jnp.asarray(e), "labels": jnp.asarray(cls.astype(np.int32))}
