"""Synthetic pair-constraint datasets mirroring the paper's setup (§5.1).

The paper samples similar pairs (same class) and dissimilar pairs (different
class) from labeled image features (MNIST pixels / ImageNet LLC). Offline we
generate class-structured feature clouds of matching dimensionality:

  * ``class_blobs``     — Gaussian blobs around random class centers (fast,
                          used by unit/integration tests).
  * ``mnist_like``      — 780-dim, 10-class cloud with pixel-like sparsity and
                          [0,1] range so the MNIST-scale experiments are
                          shape/scale faithful.
  * ``llc_like``        — high-dim sparse nonnegative features mimicking LLC
                          codes (ImageNet-63K / ImageNet-1M configs).

Pair sampling matches the paper: uniform over same-class pairs for S, over
different-class pairs for D.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PairDatasetConfig:
    n_samples: int
    feat_dim: int
    n_classes: int
    kind: str = "class_blobs"       # class_blobs | mnist_like | llc_like
    noise: float = 0.3
    sparsity: float = 0.9           # fraction of zero dims (llc_like)
    seed: int = 0


def make_features(cfg: PairDatasetConfig) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (features (n, d) float32, labels (n,) int32)."""
    rng = np.random.RandomState(cfg.seed)
    labels = rng.randint(0, cfg.n_classes, size=cfg.n_samples).astype(np.int32)
    centers = rng.randn(cfg.n_classes, cfg.feat_dim).astype(np.float32)
    if cfg.kind == "class_blobs":
        x = centers[labels] + cfg.noise * rng.randn(
            cfg.n_samples, cfg.feat_dim).astype(np.float32)
    elif cfg.kind == "mnist_like":
        # pixel-ish: nonnegative, bounded, with class-dependent active masks
        masks = (rng.rand(cfg.n_classes, cfg.feat_dim) < 0.25)
        base = np.abs(centers)
        x = (base[labels] * masks[labels]).astype(np.float32)
        x += 0.1 * np.abs(rng.randn(cfg.n_samples, cfg.feat_dim)).astype(np.float32)
        x = np.clip(x / (x.max() + 1e-6), 0.0, 1.0)
    elif cfg.kind == "noisy_subspace":
        # class signal lives in a small subspace; the remaining dims carry
        # high-variance noise that dominates Euclidean distance — the
        # canonical case where a learned Mahalanobis metric matters
        s = max(4, cfg.feat_dim // 8)
        sig_centers = rng.randn(cfg.n_classes, s).astype(np.float32)
        x = np.empty((cfg.n_samples, cfg.feat_dim), np.float32)
        x[:, :s] = sig_centers[labels] + cfg.noise * rng.randn(
            cfg.n_samples, s).astype(np.float32)
        x[:, s:] = 3.0 * rng.randn(
            cfg.n_samples, cfg.feat_dim - s).astype(np.float32)
    elif cfg.kind == "llc_like":
        # sparse nonnegative codes: class-specific support + magnitude noise
        masks = (rng.rand(cfg.n_classes, cfg.feat_dim) < (1.0 - cfg.sparsity))
        mags = np.abs(centers)
        x = (mags[labels] * masks[labels]).astype(np.float32)
        x += cfg.noise * np.abs(
            rng.randn(cfg.n_samples, cfg.feat_dim)).astype(np.float32) * masks[labels]
    else:
        raise ValueError(f"unknown kind {cfg.kind}")
    return x, labels


def _draw_pair_indices(rng, labels: np.ndarray, n_pairs: int,
                       want_same: bool, dedup: bool = True):
    """Rejection-sample (a, b) index pairs of the requested kind.

    Self-pairs (a == b) are always masked — they carry zero gradient for
    similar constraints and are label-inconsistent for dissimilar ones.
    With ``dedup`` (default), duplicate constraints within the draw are
    dropped too, treating (a, b) and (b, a) as the same unordered
    constraint, so every returned pair is distinct.
    """
    n = labels.shape[0]
    a = np.empty(n_pairs, np.int64)
    b = np.empty(n_pairs, np.int64)
    # canonical min*n+max keys taken so far, kept SORTED: membership is
    # then a searchsorted per round instead of np.isin's full re-sort of
    # the accumulated set (which goes quadratic-ish at the paper's
    # 200M-pair scale), and the merge below is a linear memcpy
    seen = np.empty(0, np.int64)
    filled = 0
    stalled = 0
    grow = 1        # oversample factor; doubles when a round finds nothing
                    # fresh (coupon-collector tail near pool exhaustion)
    while filled < n_pairs:
        m = min(max(2 * (n_pairs - filled) * grow, 64), 1 << 22)
        ca = rng.randint(0, n, size=m)
        cb = rng.randint(0, n, size=m)
        same = labels[ca] == labels[cb]
        keep = (same if want_same else ~same) & (ca != cb)
        ca, cb = ca[keep], cb[keep]
        if dedup and len(ca):
            key = np.minimum(ca, cb) * n + np.maximum(ca, cb)
            _, first = np.unique(key, return_index=True)
            first.sort()               # keep draw order (determinism)
            ca, cb, key = ca[first], cb[first], key[first]
            pos = np.searchsorted(seen, key)
            found = np.zeros(len(key), bool)
            inside = pos < len(seen)
            found[inside] = seen[pos[inside]] == key[inside]
            ca, cb, key = ca[~found], cb[~found], key[~found]
            take = min(len(ca), n_pairs - filled)
            new = np.sort(key[:take])
            seen = np.insert(seen, np.searchsorted(seen, new), new)
        k = min(len(ca), n_pairs - filled)
        a[filled:filled + k] = ca[:k]
        b[filled:filled + k] = cb[:k]
        filled += k
        if k == 0:
            stalled += 1
            grow = min(grow * 2, 1 << 16)
        else:
            stalled = 0
        if stalled >= 64:
            raise ValueError(
                f"could not draw {n_pairs} distinct "
                f"{'similar' if want_same else 'dissimilar'} pairs from "
                f"{n} rows (only {filled} exist under the labeling)")
    return a, b


def sample_pairs(features: np.ndarray, labels: np.ndarray, n_similar: int,
                 n_dissimilar: int, seed: int = 0, dedup: bool = True):
    """Sample S and D as in the paper: same class -> similar, else dissimilar.

    Returns dict(xs, ys, sim) with xs/ys (n_s+n_d, d), sim in {1, 0}.
    Self-pairs are masked and (with ``dedup``) each unordered constraint
    appears at most once per set.
    """
    rng = np.random.RandomState(seed)
    sa, sb = _draw_pair_indices(rng, labels, n_similar, True, dedup)
    da, db = _draw_pair_indices(rng, labels, n_dissimilar, False, dedup)
    xs = np.concatenate([features[sa], features[da]], axis=0)
    ys = np.concatenate([features[sb], features[db]], axis=0)
    sim = np.concatenate([np.ones(n_similar, np.int32),
                          np.zeros(n_dissimilar, np.int32)])
    perm = rng.permutation(xs.shape[0])
    return {"xs": xs[perm], "ys": ys[perm], "sim": sim[perm]}


def sample_pair_indices(labels: np.ndarray, n_similar: int,
                        n_dissimilar: int, seed: int = 0,
                        dedup: bool = True):
    """Index-only pair sampling: returns dict(a, b, sim) of int arrays.

    O(n_pairs) memory instead of O(n_pairs * d) — at web scale (the paper's
    200M pairs) pairs are always stored as indices into the feature store.
    Self-pairs are masked and (with ``dedup``) each unordered constraint
    appears at most once per set.
    """
    rng = np.random.RandomState(seed)
    sa, sb = _draw_pair_indices(rng, labels, n_similar, True, dedup)
    da, db = _draw_pair_indices(rng, labels, n_dissimilar, False, dedup)
    a = np.concatenate([sa, da])
    b = np.concatenate([sb, db])
    sim = np.concatenate([np.ones(n_similar, np.int32),
                          np.zeros(n_dissimilar, np.int32)])
    perm = rng.permutation(a.shape[0])
    return {"a": a[perm], "b": b[perm], "sim": sim[perm]}


def distinct_draws(rng, n_pool: int, size: int) -> np.ndarray:
    """``size`` distinct uniform draws from range(n_pool), O(size) expected
    when size << n_pool (rng.choice(replace=False) permutes the whole pool,
    which at the paper's 200M-pair scale is O(pool) per batch). Falls back
    to replacement draws only when the pool is smaller than the batch."""
    if size > n_pool:
        return rng.randint(0, n_pool, size)
    if 4 * size >= n_pool:              # dense: permutation is cheapest
        return rng.permutation(n_pool)[:size]
    out = np.unique(rng.randint(0, n_pool, size))
    while len(out) < size:
        out = np.union1d(out, rng.randint(0, n_pool, 2 * (size - len(out))))
    return out[rng.permutation(len(out))[:size]]


def pair_batches_from_indices(features: np.ndarray, idx_pairs: dict,
                              batch_size: int, seed: int = 0,
                              balanced: bool = True) -> Iterator[dict]:
    """Minibatch stream gathering features on the fly (memory-bounded).
    Constraints within a batch are distinct (no duplicated pair rows)."""
    rng = np.random.RandomState(seed)
    sim_idx = np.nonzero(idx_pairs["sim"] == 1)[0]
    dis_idx = np.nonzero(idx_pairs["sim"] == 0)[0]
    n = idx_pairs["sim"].shape[0]
    while True:
        if balanced and len(sim_idx) and len(dis_idx):
            h = batch_size // 2
            sel = np.concatenate([
                sim_idx[distinct_draws(rng, len(sim_idx), h)],
                dis_idx[distinct_draws(rng, len(dis_idx),
                                        batch_size - h)]])
        else:
            sel = distinct_draws(rng, n, batch_size)
        yield {
            "xs": jnp.asarray(features[idx_pairs["a"][sel]]),
            "ys": jnp.asarray(features[idx_pairs["b"][sel]]),
            "sim": jnp.asarray(idx_pairs["sim"][sel]),
        }


def pair_batches(pairs: dict, batch_size: int, seed: int = 0,
                 balanced: bool = True) -> Iterator[dict]:
    """Infinite minibatch stream. ``balanced`` draws half S / half D per batch
    as in the paper's experimental setup (§5.2). Constraints within a batch
    are distinct (no duplicated pair rows)."""
    rng = np.random.RandomState(seed)
    sim_idx = np.nonzero(pairs["sim"] == 1)[0]
    dis_idx = np.nonzero(pairs["sim"] == 0)[0]
    n = pairs["sim"].shape[0]
    while True:
        if balanced and len(sim_idx) and len(dis_idx):
            h = batch_size // 2
            idx = np.concatenate([
                sim_idx[distinct_draws(rng, len(sim_idx), h)],
                dis_idx[distinct_draws(rng, len(dis_idx),
                                        batch_size - h)]])
        else:
            idx = distinct_draws(rng, n, batch_size)
        yield {k: jnp.asarray(v[idx]) for k, v in pairs.items()}


def train_eval_split(cfg: PairDatasetConfig, n_train_sim: int, n_train_dis: int,
                     n_eval_sim: int, n_eval_dis: int):
    """Features + disjoint train/eval pair sets (paper's held-out pair eval)."""
    x, y = make_features(cfg)
    n_hold = max(cfg.n_samples // 5, 2 * cfg.n_classes)
    train_x, train_y = x[:-n_hold], y[:-n_hold]
    hold_x, hold_y = x[-n_hold:], y[-n_hold:]
    train_pairs = sample_pairs(train_x, train_y, n_train_sim, n_train_dis,
                               seed=cfg.seed + 1)
    eval_pairs = sample_pairs(hold_x, hold_y, n_eval_sim, n_eval_dis,
                              seed=cfg.seed + 2)
    return train_pairs, eval_pairs


def sample_triplet_indices(labels: np.ndarray, n_triplets: int,
                           seed: int = 0):
    """(anchor, positive, negative) index triples — the paper's §4
    triple-wise constraint extension ("i is more similar to j than to k")."""
    rng = np.random.RandomState(seed)
    n = labels.shape[0]
    a = np.empty(n_triplets, np.int64)
    p = np.empty(n_triplets, np.int64)
    ng = np.empty(n_triplets, np.int64)
    filled = 0
    while filled < n_triplets:
        ca = rng.randint(0, n, size=2 * (n_triplets - filled))
        cp = rng.randint(0, n, size=2 * (n_triplets - filled))
        cn = rng.randint(0, n, size=2 * (n_triplets - filled))
        keep = ((labels[ca] == labels[cp]) & (labels[ca] != labels[cn])
                & (ca != cp))
        k = min(keep.sum(), n_triplets - filled)
        a[filled:filled + k] = ca[keep][:k]
        p[filled:filled + k] = cp[keep][:k]
        ng[filled:filled + k] = cn[keep][:k]
        filled += k
    return {"a": a, "p": p, "n": ng}


def triplet_batches_from_indices(features: np.ndarray, idx: dict,
                                 batch_size: int, seed: int = 0):
    """Minibatch stream of {anchor, pos, neg} gathered on the fly."""
    rng = np.random.RandomState(seed)
    n = idx["a"].shape[0]
    while True:
        sel = rng.randint(0, n, batch_size)
        yield {
            "anchor": jnp.asarray(features[idx["a"][sel]]),
            "pos": jnp.asarray(features[idx["p"][sel]]),
            "neg": jnp.asarray(features[idx["n"][sel]]),
        }
