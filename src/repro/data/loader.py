"""Sharded batch pipeline: host-side iterator -> device arrays laid out for a
mesh. Handles per-worker partitioning of the pair sets (paper §4.1: "we
partition the similar pairs and dissimilar pairs onto different machines").
"""

from __future__ import annotations

import itertools
import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


def partition_pairs(pairs: dict, n_workers: int):
    """Split a pair dict into n_workers shards (S_p, D_p as in the paper)."""
    n = pairs["sim"].shape[0]
    idx = np.arange(n)
    shards = np.array_split(idx, n_workers)
    return [{k: v[s] for k, v in pairs.items()} for s in shards]


def shard_batch(batch: dict, sharding) -> dict:
    """Place a host batch onto devices with the given NamedSharding."""
    return jax.tree.map(lambda x: jax.device_put(x, sharding), batch)


class Prefetcher:
    """Background-thread prefetch of an iterator (depth-bounded queue)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def take(it: Iterator, n: int):
    return itertools.islice(it, n)
