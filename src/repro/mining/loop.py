"""The closed loop: train -> refresh the serving index -> mine -> train.

``ClosedLoopTrainer`` alternates PS training steps with serving-index
refreshes. The index always serves neighborhoods under a *recent* metric:
every refresh pushes the current merged L into the index
(``MutableIndex.swap_metric`` for mutable bases — the PR-3 trainer->server
hot swap — or a from-scratch rebuild for frozen bases), then re-mines the
hard-pair pool with ``HardPairMiner`` and swaps it into the
``MinedPairSource`` feeding the workers. This is the first subsystem that
exercises training and serving in one process: the same index answering
retrieval traffic is the constraint producer for the trainer.

Refresh is governed by an explicit staleness policy: every
``refresh_every`` steps, and/or when the objective plateaus (relative
improvement of the recent loss window below ``plateau_tol``). Mining
against a stale metric is not wrong — it is the *asynchronous PS
tradeoff from the paper applied to data*: bounded staleness buys
throughput (no rebuild per step), and the history records exactly how
stale each training step's pairs were (``staleness`` = steps since the
pool's metric was current).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from repro.core import dml, losses
from repro.core.ps import sync
from repro.core.ps.trainer import DMLTrainConfig, stack_worker_streams
from repro.mining.miner import HardPairMiner, MinerConfig
from repro.mining.stream import CurriculumSchedule, MinedPairSource
from repro.optim import Optimizer, sgd
from repro.serve import (ExactIndex, IVFIndex, MutableIndex,
                         RetrievalEngine)


@dataclasses.dataclass(frozen=True)
class ClosedLoopConfig:
    """Everything above the per-step training math.

    train: the inner DMLTrainConfig (steps, batch, lr, sync model).
    miner / schedule: hard-pair filter knobs + curriculum.
    index: which serving backend mines — "mutable-exact" / "mutable-ivf"
      (refreshed via swap_metric) or "exact" / "ivf" (frozen: refresh
      rebuilds from scratch — correct but pays projection + clustering
      every time; the mutable path is why PR 3 exists).
    index_kwargs: forwarded to the base build (n_clusters, nprobe, ...).
    refresh_every: refresh the index + pool every R steps (0 disables
      periodic refresh — then only plateau triggers fire).
    plateau_window: trailing loss steps inspected for a plateau (0
      disables plateau-triggered refresh).
    plateau_tol: relative improvement of the window's older half over
      its newer half below which the objective counts as plateaued.
    min_refresh_gap: floor between refreshes, so a flat stretch does not
      refresh every step.
    mine_queries: anchors mined per refresh.
    """

    train: DMLTrainConfig
    miner: MinerConfig = MinerConfig()
    schedule: CurriculumSchedule = CurriculumSchedule()
    index: str = "mutable-exact"
    index_kwargs: Optional[dict] = None
    refresh_every: int = 100
    plateau_window: int = 0
    plateau_tol: float = 1e-3
    min_refresh_gap: int = 10
    mine_queries: int = 1024

    def __post_init__(self):
        if self.index not in ("mutable-exact", "mutable-ivf", "exact",
                              "ivf"):
            raise ValueError(f"unknown index kind {self.index!r}")
        if self.refresh_every == 0 and self.plateau_window == 0:
            raise ValueError("no staleness policy: set refresh_every > 0 "
                             "and/or plateau_window > 0")
        if self.mine_queries < 1:
            raise ValueError(f"mine_queries must be >= 1, got "
                             f"{self.mine_queries}")


class ClosedLoopTrainer:
    """Alternates PS training with serving-index refresh + re-mining."""

    def __init__(self, cfg: ClosedLoopConfig, features, labels, *,
                 opt: Optional[Optimizer] = None, mesh=None,
                 engine: Optional[RetrievalEngine] = None,
                 router=None, tenant: Optional[str] = None,
                 shadow_probe: int = 8):
        """Build the serving stack and the mined source (no training yet).

        ``engine`` lets a caller share an existing serving engine (its
        index must be over ``features`` with row ids 0..n-1); by default
        the trainer stands up its own index of ``cfg.index`` kind under
        the *initial* L — the first refresh replaces that metric.

        ``router`` + ``tenant`` close the loop through the multi-tenant
        front end (serve/tenant.py): each metric-swapping refresh also
        registers the fresh L as the tenant's *shadow arm*, mirrors
        ``shadow_probe`` seeded anchor queries through it (so the arm
        carries overlap/latency evidence, visible in the registry and
        the refresh record), then promotes it live — the serving
        tenant's metric tracks training without ever serving a view the
        shadow machinery didn't build.
        """
        self.cfg = cfg
        if (router is None) != (tenant is None):
            raise ValueError("pass router and tenant together (or "
                             "neither)")
        self.router = router
        self.tenant = tenant
        self.shadow_probe = shadow_probe
        if router is not None:
            router.tenant(tenant)   # unknown tenant fails here, not at
            if router.d_in != np.asarray(features).shape[1]:   # refresh
                raise ValueError(
                    f"router gallery d_in={router.d_in} != feature "
                    f"dim {np.asarray(features).shape[1]}")
        self.features = np.asarray(features, np.float32)
        self.labels = np.asarray(labels)
        self.opt = opt or sgd(cfg.train.lr)
        self.mesh = mesh or sync.make_worker_mesh(cfg.train.ps.n_workers,
                                                  cfg.train.ps.axis)
        self.rng = jax.random.PRNGKey(cfg.train.ps.seed)
        self.L0 = dml.init_params(cfg.train.dml, self.rng)
        if engine is None:
            index = self._build_index(np.asarray(self.L0))
            engine = RetrievalEngine(index,
                                     k_top=cfg.miner.k_neighbors + 1)
        self.engine = engine
        self.miner = HardPairMiner(engine, self.features, self.labels,
                                   cfg.miner)
        self.source = MinedPairSource(self.features, self.labels,
                                      cfg.schedule)
        self.n_refreshes = 0
        self.refreshes = []          # per-refresh mining stats records
        # obs: the loop records into the engine's registry/tracer so the
        # closed loop and the serving path share one snapshot; refreshes
        # are rare control-plane transitions, so their traces bypass
        # sampling (force=True)
        self.registry = getattr(engine, "registry", None)
        self.tracer = getattr(engine, "tracer", None)
        if self.registry is not None:
            self._c_refresh = self.registry.counter(
                "loop_refreshes_total", "index refresh + re-mine cycles")
            self._g_staleness = self.registry.gauge(
                "loop_staleness_steps",
                "training steps since the pair pool's metric was current")
            self._g_mined_frac = self.registry.gauge(
                "loop_mined_frac",
                "curriculum fraction of mined pairs in the current batch")
            self._g_pool = self.registry.gauge(
                "loop_pool_size", "pairs in the live mined pool")
            self._g_neg_yield = self.registry.gauge(
                "loop_neg_yield", "hard-negative yield of the last mine")
            self._g_pos_yield = self.registry.gauge(
                "loop_pos_yield", "hard-positive yield of the last mine")

    def _build_index(self, L):
        kw = dict(self.cfg.index_kwargs or {})
        if self.cfg.index.startswith("mutable"):
            return MutableIndex.build(L, self.features,
                                      base=self.cfg.index.split("-")[1],
                                      retain_raw=True, **kw)
        if self.cfg.index == "ivf":
            return IVFIndex.build(L, np.asarray(self.features), **kw)
        return ExactIndex.build(L, np.asarray(self.features), **kw)

    # -- refresh -------------------------------------------------------------

    def refresh(self, L, step: int, swap: bool = True) -> dict:
        """Push L into the index, re-mine, swap the pool. Returns stats.
        ``swap=False`` only re-mines (used for the initial pool, whose
        metric the index was just built with)."""
        trace = (self.tracer.start_trace("refresh", force=True)
                 if self.tracer is not None else None)
        if trace is not None:
            trace.root.set_attrs(step=step, swap=swap)
        if swap:
            L = np.asarray(L, np.float32)
            index = self.engine.index
            if isinstance(index, MutableIndex):
                sp = (trace.span("swap_metric") if trace is not None
                      else None)
                index.swap_metric(L)  # version bump -> engine cache flush
                if sp is not None:
                    sp.set_attrs(rows=index.size).end()
            else:
                # frozen base: rebuild off to the side and repoint the
                # engine (the engine's LRU flushes on the identity change)
                sp = trace.span("rebuild") if trace is not None else None
                self.engine.index = self._build_index(L)
                if sp is not None:
                    sp.set_attrs(kind=self.cfg.index,
                                 rows=self.engine.index.size).end()
        shadow_stats = None
        if swap and self.router is not None:
            # A/B the fresh metric through the tenant's shadow arm:
            # mirror a few seeded anchors for overlap/latency evidence,
            # then promote — the router's deterministic build makes the
            # promoted view identical to a fresh rebuild under L
            p_sp = trace.span("promote") if trace is not None else None
            arm = self.router.register_shadow(self.tenant,
                                              np.asarray(L, np.float32),
                                              sample_rate=1.0)
            probe_rng = np.random.RandomState(
                self.cfg.train.ps.seed + self.n_refreshes)
            probes = probe_rng.randint(
                0, len(self.features),
                size=min(self.shadow_probe, len(self.features)))
            for qid in probes:
                self.router.search(self.tenant, self.features[qid])
            shadow_stats = arm.stats()
            self.router.promote(self.tenant)
            if p_sp is not None:
                p_sp.set_attrs(tenant=self.tenant,
                               n_mirrored=shadow_stats["n_mirrored"],
                               overlap_at_k=shadow_stats["overlap_at_k"]
                               ).end()
        m_sp = trace.span("mine") if trace is not None else None
        result = self.miner.mine(n_queries=self.cfg.mine_queries,
                                 seed=self.cfg.train.ps.seed
                                 + self.n_refreshes)
        if m_sp is not None:
            m_sp.set_attrs(n_queries=self.cfg.mine_queries,
                           n_pairs=result.stats["n_pairs"],
                           neg_yield=result.stats["neg_yield"]).end()
        self.source.set_pool(result)
        self.n_refreshes += 1
        if self.registry is not None:
            self._c_refresh.inc()
            self._g_pool.set(self.source.pool_size)
            self._g_neg_yield.set(result.stats["neg_yield"])
            self._g_pos_yield.set(result.stats["pos_yield"])
            self.registry.event("loop_refresh", step=step,
                                refresh=self.n_refreshes,
                                n_pairs=result.stats["n_pairs"],
                                index_version=result.stats["index_version"])
        if trace is not None:
            self.tracer.finish(trace)
        rec = {"step": step, "refresh": self.n_refreshes, **result.stats}
        if shadow_stats is not None:
            rec["shadow"] = shadow_stats
            rec["promoted_tenant"] = self.tenant
        self.refreshes.append(rec)
        return rec

    def _plateaued(self, trace) -> bool:
        w = self.cfg.plateau_window
        if w == 0 or len(trace) < w:
            return False
        recent = np.asarray(trace[-w:], np.float64)
        old = recent[:w // 2].mean()
        new = recent[w // 2:].mean()
        return (old - new) < self.cfg.plateau_tol * max(abs(old), 1e-12)

    # -- training ------------------------------------------------------------

    def run(self, step_hook=None):
        """Train for ``cfg.train.steps`` with interleaved refreshes.

        Returns (L_merged, history): history["steps"] mirrors
        ``train_dml_distributed`` records plus ``staleness`` (steps since
        the pairs' metric was current) and ``mined_frac``;
        history["refreshes"] holds one mining-stats record per refresh
        (hard-pair yield, engine QPS, index version); history["summary"]
        has the run-level roll-up (refresh count, mean staleness at use,
        total mined pairs). ``step_hook(step, L)`` behaves as in
        ``train_dml_distributed``.
        """
        tcfg = self.cfg.train
        state = sync.init_state(self.opt, self.L0, tcfg.ps)

        def loss_fn(L, batch):
            return losses.dml_pair_loss(L, batch, lam=tcfg.dml.lam,
                                        margin=tcfg.dml.margin,
                                        compute_dtype=tcfg.dml.compute_dtype)

        step_fn = sync.make_train_step(loss_fn, self.opt, tcfg.ps,
                                       self.mesh)
        batches = stack_worker_streams(self.source.worker_streams(
            tcfg.ps.n_workers, tcfg.batch_size, tcfg.ps.seed))

        # initial pool under L0: the curriculum starts uniform, but the
        # pool must exist before the ramp's first mined batch (no metric
        # swap — the index was just built with L0)
        self.refresh(sync.worker_mean(state.params), step=0, swap=False)
        last_refresh = 0
        staleness_sum = 0
        trace = []
        history = []
        for t in range(tcfg.steps):
            if t > 0 and self._due(t, last_refresh, trace):
                self.refresh(sync.worker_mean(state.params), step=t)
                last_refresh = t
                trace = []           # plateau window restarts post-refresh
            state, metrics = step_fn(state, next(batches))
            loss = float(metrics["loss"])
            trace.append(loss)
            staleness_sum += t - last_refresh
            if self.registry is not None:   # per-step staleness gauges
                self._g_staleness.set(t - last_refresh)
                self._g_mined_frac.set(self.cfg.schedule.mined_frac(t))
                self._g_pool.set(self.source.pool_size)
            if t % tcfg.log_every == 0 or t == tcfg.steps - 1:
                rec = {"step": t,
                       **{k: float(v) for k, v in metrics.items()},
                       "staleness": t - last_refresh,
                       "mined_frac": self.cfg.schedule.mined_frac(t),
                       "pool_size": self.source.pool_size}
                if step_hook is not None:
                    out = step_hook(t, sync.worker_mean(state.params))
                    if out is not None:
                        rec["hook"] = out
                history.append(rec)
        L = sync.worker_mean(state.params)
        summary = {
            "n_refreshes": self.n_refreshes,
            "mean_staleness": staleness_sum / max(tcfg.steps, 1),
            "total_mined_pairs": int(sum(r["n_pairs"]
                                         for r in self.refreshes)),
            "neg_yield": float(np.mean([r["neg_yield"]
                                        for r in self.refreshes])),
            "pos_yield": float(np.mean([r["pos_yield"]
                                        for r in self.refreshes])),
            "engine": self.engine.stats(),
        }
        return L, {"steps": history, "refreshes": self.refreshes,
                   "summary": summary}

    def _due(self, t: int, last_refresh: int, trace) -> bool:
        gap = t - last_refresh
        if self.cfg.refresh_every and gap >= self.cfg.refresh_every:
            return True
        return gap >= self.cfg.min_refresh_gap and self._plateaued(trace)
