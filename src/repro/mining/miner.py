"""Hard-pair mining over the serving index: the retrieval stack as a
constraint *producer* for the trainer.

The paper trains on uniformly sampled pairs (§5.1); most of those go
uninformative within a few epochs — similar pairs are already close,
dissimilar pairs already sit outside the hinge margin, and the gradient
signal concentrates on the few *hard* constraints (Qian et al. 2013).
``HardPairMiner`` finds those constraints at retrieval speed: it runs
batched k-NN queries against any ``MetricIndex`` (through the
``RetrievalEngine``, so mining throughput rides the same bucketed-jit /
IVF / PQ work the serving path has), then label-filters each
neighborhood under the *current* metric L:

  hard negative   the nearest different-class neighbors — *impostors* in
                  LMNN terms: rows inside the anchor's neighborhood that
                  kNN would vote with incorrectly, and the dissimilar
                  pairs whose hinge is active;
  hard positive   a same-class row the current metric keeps *outside*
                  the anchor's k-NN neighborhood — a present kNN
                  violation, and the similar pair with a large
                  pull-together gradient (same-class rows *inside* the
                  neighborhood are the easy positives: near-zero loss);
  semi-hard band  negatives farther than the farthest in-neighborhood
                  same-class row but within ``margin`` of it (Schroff et
                  al.'s FaceNet band) — informative without being
                  label-noise dominated; the ``band_pct`` knob
                  additionally clips the band at a distance percentile
                  of the neighborhood.

Mined output is index pairs (dict(a, b, sim), the contract of
``data/pairs.sample_pair_indices``), so it drops into the existing batch
streams. ``mining/stream.MinedPairSource`` mixes them with uniform pairs
under a curriculum; ``mining/loop.ClosedLoopTrainer`` refreshes the
index's metric between epochs — closing the train -> serve -> train loop.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.data.pairs import distinct_draws
from repro.serve.engine import RetrievalEngine


@dataclasses.dataclass(frozen=True)
class MinerConfig:
    """Knobs of the neighborhood -> hard-pair label filter.

    k_neighbors: neighborhood size per query (the engine is asked for
      k_neighbors + 1 so the query's own row can be dropped).
    margin: the training hinge margin c — the semi-hard band is
      [d(hard positive), d(hard positive) + margin).
    semi_hard: restrict negatives to the band. When a query has no
      in-band negative (every different-class row pushed out of margin)
      and ``fallback_nearest`` is set, the plain nearest negative is
      used instead so late-training yield never hits zero.
    band_pct: clip the band at this distance percentile of the
      neighborhood (100 = no clip) — guards against a degenerate L
      whose "band" spans the whole gallery.
    max_negatives / max_positives: pairs kept per query.
    pos_candidates: same-class rows sampled per anchor and tested for
      neighborhood membership; the ones *outside* the neighborhood
      (present kNN violations) become hard positives, up to
      max_positives.
    """

    k_neighbors: int = 20
    margin: float = 1.0
    semi_hard: bool = True
    fallback_nearest: bool = True
    band_pct: float = 100.0
    max_negatives: int = 2
    max_positives: int = 1
    pos_candidates: int = 8

    def __post_init__(self):
        if self.k_neighbors < 2:
            raise ValueError("k_neighbors must be >= 2 (need room for a "
                             "positive and a negative)")
        if not 0.0 < self.band_pct <= 100.0:
            raise ValueError(f"band_pct must be in (0, 100], got "
                             f"{self.band_pct}")
        if self.max_negatives < 0 or self.max_positives < 0:
            raise ValueError("max_negatives / max_positives must be >= 0")


@dataclasses.dataclass
class MiningResult:
    """Mined constraints + where they came from.

    ``pairs`` is dict(a, b, sim) of index arrays (a = anchor row, b =
    neighbor row, sim in {1, 0}) — the same shape
    ``data/pairs.sample_pair_indices`` returns, so every existing batch
    stream accepts it. ``stats`` records the yield per category and the
    engine's QPS during the mining queries.
    """

    pairs: dict
    stats: dict

    @property
    def n_pairs(self) -> int:
        return int(self.pairs["sim"].shape[0])


class HardPairMiner:
    """Batched k-NN mining against a MetricIndex through the engine path.

    The miner owns no index state: it holds the feature/label table the
    anchors are drawn from and a ``RetrievalEngine`` whose index the
    closed loop refreshes underneath it (``MutableIndex.swap_metric`` /
    an engine index swap both bump the version the engine's cache keys
    on, so mined neighborhoods always reflect the metric the index
    currently serves).
    """

    def __init__(self, engine, features, labels,
                 cfg: Optional[MinerConfig] = None, *,
                 query_batch: int = 512, warmup: bool = True,
                 frontend=None):
        """Args:
          engine: a RetrievalEngine, or any MetricIndex (wrapped in a
            fresh engine here — pass an engine to share its cache/stats
            with serving traffic).
          features / labels: (n, d) anchor rows + (n,) int labels. Index
            row ids must index this table (build the index over the same
            rows, external ids 0..n-1).
          cfg: filter knobs (MinerConfig defaults).
          query_batch: anchors per engine.search call — batched through
            the engine's bucketed jit path.
          warmup: pre-compile the (bucket, k_neighbors + 1) query fns up
            front (the engine-warmup reuse serve_retrieval's
            --warmup-ks flag provides for serving clients).
          frontend: optional RequestScheduler over the same engine —
            mining queries then ride its ``mining`` priority class
            instead of calling the engine directly, so serving traffic
            shapes (and can shed) the mining load. Anchors the front end
            rejects or expires mine nothing this sweep (counted in
            stats["n_dropped"]; the loop retries them next epoch).
        """
        self.cfg = cfg or MinerConfig()
        if not isinstance(engine, RetrievalEngine):
            engine = RetrievalEngine(engine, k_top=self.cfg.k_neighbors + 1)
        self.engine = engine
        self.frontend = frontend
        if frontend is not None and self.cfg.k_neighbors + 1 > engine.k_top:
            raise ValueError(
                f"k_neighbors + 1 = {self.cfg.k_neighbors + 1} exceeds "
                f"the front end's engine k_top={engine.k_top}; the "
                f"scheduler rejects oversized k (size the engine or "
                f"shrink the neighborhood)")
        self.features = np.asarray(features, np.float32)
        self.labels = np.asarray(labels)
        if self.labels.shape[0] != self.features.shape[0]:
            raise ValueError(
                f"labels ({self.labels.shape[0]}) != features "
                f"({self.features.shape[0]}) rows")
        self.query_batch = int(query_batch)
        self.n_mines = 0
        # obs: mining volume lands on the shared engine registry, labeled
        # by pair kind, so one snapshot covers serving AND the closed loop
        self.registry = getattr(self.engine, "registry", None)
        if self.registry is not None:
            self._c_mines = self.registry.counter(
                "miner_mines_total", "mine() sweeps")
            self._c_queries = self.registry.counter(
                "miner_queries_total", "anchor queries mined")
            self._c_pairs = self.registry.counter(
                "miner_pairs_total", "mined training pairs by kind",
                labelnames=("kind",))
            self._c_starved = self.registry.counter(
                "miner_starved_total",
                "anchors that yielded no pair at all")
            self._c_dropped = self.registry.counter(
                "miner_dropped_total",
                "anchors shed by the traffic front end (rejected or "
                "deadline-expired under the mining class)")
        # class -> row ids, for hard-positive candidate sampling
        order = np.argsort(self.labels, kind="stable")
        classes, starts = np.unique(self.labels[order], return_index=True)
        bounds = np.append(starts, len(order))
        self._class_rows = {int(c): order[bounds[i]:bounds[i + 1]]
                            for i, c in enumerate(classes)}
        if warmup:
            # same clamp mine() applies: a gallery smaller than the
            # neighborhood still mines (and must still warm up)
            self.engine.warmup(ks=[min(self.cfg.k_neighbors + 1,
                                       self.engine.index.size)])

    # -- mining --------------------------------------------------------------

    def _neighborhoods(self, qid, k):
        """(dists (n,k), ids (n,k), served (n,) bool) for one anchor
        chunk. Direct engine path by default; with a front end attached,
        per-anchor futures through its ``mining`` priority class —
        anchors the scheduler sheds (queue full, deadline expired, or a
        failed batch) come back unserved and are skipped this sweep."""
        feats = self.features[qid]
        if self.frontend is None:
            d, i = self.engine.search(feats, k_top=k)
            return (np.asarray(d), np.asarray(i),
                    np.ones(len(qid), bool))
        futs = []
        for row in feats:
            try:
                futs.append(self.frontend.submit(row, k_top=k,
                                                 priority="mining"))
            except Exception:       # RejectedError: admission shed it
                futs.append(None)
        dists = np.full((len(qid), k), np.inf, np.float32)
        ids = np.full((len(qid), k), -1, np.int64)
        served = np.zeros(len(qid), bool)
        for row, fut in enumerate(futs):
            if fut is None:
                continue
            try:
                dists[row], ids[row] = fut.result()
                served[row] = True
            except Exception:       # expired / cancelled / batch failed
                pass
        return dists, ids, served

    def mine(self, query_ids=None, n_queries: Optional[int] = None,
             seed: int = 0) -> MiningResult:
        """Mine hard pairs for a set of anchor rows.

        Either pass explicit ``query_ids`` (row indices into the feature
        table) or ``n_queries`` anchors drawn uniformly (seeded).
        Returns a MiningResult; ``pairs`` may be empty if every
        neighborhood is single-class (stats say which filter starved).
        """
        rng = np.random.RandomState(seed)
        if query_ids is None:
            if n_queries is None:
                raise ValueError("pass query_ids or n_queries")
            if n_queries < 1:
                raise ValueError(f"n_queries must be >= 1, got "
                                 f"{n_queries}")
            # distinct draws without permuting the whole table
            # (rng.choice(replace=False) is O(table) per mine call)
            query_ids = distinct_draws(
                rng, len(self.labels),
                min(n_queries, len(self.labels)))
        query_ids = np.asarray(query_ids, np.int64)
        if len(query_ids) == 0:
            raise ValueError("query_ids is empty")
        k = min(self.cfg.k_neighbors + 1, self.engine.index.size)

        a_out, b_out, sim_out = [], [], []
        n_hard_neg = n_semi = n_fallback = n_hard_pos = n_starved = 0
        n_dropped = 0
        t_busy0 = self.engine.busy_s
        n_dev0 = self.engine.n_device_queries
        for s in range(0, len(query_ids), self.query_batch):
            qid = query_ids[s:s + self.query_batch]
            dists, ids, served = self._neighborhoods(qid, k)
            n_dropped += int((~served).sum())
            if not served.all():    # shed anchors mine nothing (a row
                qid = qid[served]   # of -1s would fake hard positives)
                dists, ids = dists[served], ids[served]
            if len(qid) == 0:
                continue
            a, b, sim, st = self._filter(qid, np.asarray(dists),
                                         np.asarray(ids), rng)
            a_out.append(a)
            b_out.append(b)
            sim_out.append(sim)
            n_hard_neg += st["hard_neg"]
            n_semi += st["semi"]
            n_fallback += st["fallback"]
            n_hard_pos += st["hard_pos"]
            n_starved += st["starved"]
        self.n_mines += 1

        pairs = {
            "a": (np.concatenate(a_out) if a_out
                  else np.zeros(0, np.int64)),
            "b": (np.concatenate(b_out) if b_out
                  else np.zeros(0, np.int64)),
            "sim": (np.concatenate(sim_out).astype(np.int32) if sim_out
                    else np.zeros(0, np.int32))}
        nq = max(len(query_ids), 1)
        est = self.engine.stats()
        # QPS over *this mine's* device queries, not the engine's
        # lifetime average (the engine may have served unrelated
        # retrieval traffic before)
        busy = est["busy_s"] - t_busy0
        dev = est["n_device_queries"] - n_dev0
        stats = {
            "n_queries": int(len(query_ids)),
            "n_pairs": int(pairs["sim"].shape[0]),
            "n_hard_neg": int(n_hard_neg),
            "n_semi_hard": int(n_semi),
            "n_fallback_neg": int(n_fallback),
            "n_hard_pos": int(n_hard_pos),
            "n_starved": int(n_starved),
            "n_dropped": int(n_dropped),
            "neg_yield": n_hard_neg / nq,
            "pos_yield": n_hard_pos / nq,
            "mine_busy_s": busy,
            "engine_qps": dev / busy if busy > 0 else 0.0,
            "index_version": self.engine.index.version,
        }
        if self.registry is not None:
            self._c_mines.inc()
            self._c_queries.inc(stats["n_queries"])
            self._c_starved.inc(stats["n_starved"])
            self._c_dropped.inc(stats["n_dropped"])
            for kind, key in (("hard_neg", "n_hard_neg"),
                              ("semi_hard", "n_semi_hard"),
                              ("fallback_neg", "n_fallback_neg"),
                              ("hard_pos", "n_hard_pos")):
                self._c_pairs.inc(stats[key], kind=kind)
        return MiningResult(pairs=pairs, stats=stats)

    # -- label filter --------------------------------------------------------

    def _filter(self, qid, dists, ids, rng):
        """Neighborhoods (Nq, k) -> hard pairs. Vectorized on the host:
        selection is argsort/broadcast tricks over boolean masks, never a
        Python loop over queries."""
        cfg = self.cfg
        # drop the anchor's own row, unservable slots (-1 from
        # under-filled IVF probes), and ids beyond the label table (a
        # mutable index can serve rows upserted after the table was
        # made); columns arrive distance-ascending
        valid = ((ids >= 0) & (ids < len(self.labels))
                 & (ids != qid[:, None]))
        same = np.zeros_like(valid)
        safe = np.where(valid, ids, 0)
        same[valid] = (self.labels[safe] == self.labels[qid][:, None])[valid]
        diff = valid & ~same
        dists = np.where(valid, dists, np.inf)

        # the farthest in-neighborhood same-class row bounds the
        # territory the anchor currently "wins"; it anchors the
        # semi-hard band below
        kcols = ids.shape[1]
        rev_pos = np.argsort(~same[:, ::-1], axis=1, kind="stable")
        far_col = (kcols - 1) - rev_pos[:, 0]
        has_same = same.any(axis=1)
        d_hard_pos = np.where(
            has_same,
            np.take_along_axis(dists, far_col[:, None], axis=1)[:, 0], 0.0)

        # negative band: nearest different-class columns, optionally
        # clipped to the semi-hard band [d_hard_pos, d_hard_pos + margin)
        # and the band_pct distance percentile of the neighborhood
        cand = diff
        if cfg.semi_hard:
            # the band is only defined for anchors with a same-class
            # neighbor to anchor it on; others go to the fallback (a
            # d_hard_pos of 0 would degenerate the band into a plain
            # dist < margin cutoff and misreport those rows as
            # semi-hard)
            band = cand & has_same[:, None] \
                & (dists >= d_hard_pos[:, None]) \
                & (dists < (d_hard_pos + cfg.margin)[:, None])
            if cfg.band_pct < 100.0:
                lim = np.nanpercentile(
                    np.where(valid, dists, np.nan), cfg.band_pct, axis=1)
                band &= dists <= lim[:, None]
            n_semi_rows = band.any(axis=1)
            if cfg.fallback_nearest:
                cand = np.where(n_semi_rows[:, None], band, diff)
            else:
                cand = band
        else:
            n_semi_rows = np.zeros(len(qid), bool)
        neg_cols = np.argsort(~cand, axis=1,
                              kind="stable")[:, :max(cfg.max_negatives, 1)]
        neg_ok = np.take_along_axis(cand, neg_cols, axis=1)

        a, b, sim = [], [], []
        n_neg = n_pos = 0
        if cfg.max_negatives > 0:
            an = np.broadcast_to(qid[:, None], neg_ok.shape)[neg_ok]
            bn = np.take_along_axis(safe, neg_cols, axis=1)[neg_ok]
            n_neg = len(an)
            a.append(an)
            b.append(bn)
            sim.append(np.zeros(len(an), np.int32))
        has_pos = np.zeros(len(qid), bool)
        if cfg.max_positives > 0:
            ap, bp = self._violating_positives(qid, ids, valid, rng)
            n_pos = len(ap)
            has_pos = np.isin(qid, ap)
            a.append(ap)
            b.append(bp)
            sim.append(np.ones(len(ap), np.int32))

        has_neg = neg_ok[:, 0] if cfg.max_negatives > 0 \
            else np.zeros(len(qid), bool)
        from_band = n_semi_rows & has_neg
        stats = {
            "hard_neg": n_neg,
            "semi": int(from_band.sum()),
            "fallback": int((has_neg & ~n_semi_rows).sum())
            if cfg.semi_hard else 0,
            "hard_pos": n_pos,
            "starved": int((~has_neg & ~has_pos).sum()),
        }
        return (np.concatenate(a) if a else np.zeros(0, np.int64),
                np.concatenate(b) if b else np.zeros(0, np.int64),
                np.concatenate(sim) if sim else np.zeros(0, np.int32),
                stats)

    def _violating_positives(self, qid, ids, valid, rng):
        """Hard positives: same-class rows the current metric keeps
        *outside* the anchor's neighborhood (the pairs a kNN eval is
        getting wrong right now — LMNN's "pull" step). Samples
        ``pos_candidates`` same-class rows per anchor and keeps up to
        ``max_positives`` that are not among the returned neighbors."""
        cfg = self.cfg
        nq, nc = len(qid), cfg.pos_candidates
        cand = np.empty((nq, nc), np.int64)
        qlab = self.labels[qid]
        for c in np.unique(qlab):               # grouped draw per class
            rows = self._class_rows[int(c)]
            m = qlab == c
            cand[m] = rows[rng.randint(0, len(rows), (int(m.sum()), nc))]
        # violating iff not the anchor itself and not a returned neighbor
        nbr = np.where(valid, ids, -1)
        ok = ~(cand[:, :, None] == nbr[:, None, :]).any(axis=2)
        ok &= cand != qid[:, None]
        order = np.argsort(~ok, axis=1, kind="stable")[:, :cfg.max_positives]
        sel_ok = np.take_along_axis(ok, order, axis=1)
        sel = np.take_along_axis(cand, order, axis=1)
        for j in range(1, sel.shape[1]):        # dedupe repeated draws
            sel_ok[:, j] &= (sel[:, j:j + 1] != sel[:, :j]).all(axis=1)
        return (np.broadcast_to(qid[:, None], sel.shape)[sel_ok],
                sel[sel_ok])
