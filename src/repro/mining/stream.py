"""Mined pair source: the miner's output as a trainer-ready batch stream.

``MinedPairSource`` satisfies the pluggable pair-source contract
``core/ps/trainer.train_dml_distributed`` accepts (an object with
``worker_streams(n_workers, batch_size, seed)``): each worker gets an
infinite iterator of ``{"xs", "ys", "sim"}`` batches — the exact shape
``data/pairs.pair_batches`` yields — so mined training drops into
``_stacked_batches`` unchanged.

Each batch mixes two origins under a ratio schedule:

  uniform  pairs freshly rejection-sampled from the label table
           (``data/pairs.sample_pair_indices`` semantics: balanced S/D,
           self-pairs masked, no duplicates within the draw);
  mined    pairs drawn from the miner's latest *pool* (index pairs
           produced by ``HardPairMiner.mine``; ``set_pool`` swaps it in
           after every closed-loop refresh).

The schedule is the curriculum: warm up on pure uniform pairs (hard
negatives under a random L are mostly label noise), then anneal linearly
toward ``max_mined_frac``. Streams are per-worker sharded: worker w owns
pool rows ``w::n_workers`` (disjoint mined shards, mirroring the
``data/loader.partition_pairs`` split of the uniform path, paper §4.1)
and a distinct uniform seed; within a batch both shares are
duplicate-free.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.data.pairs import distinct_draws, sample_pair_indices


@dataclasses.dataclass(frozen=True)
class CurriculumSchedule:
    """Mined-pair fraction as a function of the (per-worker) step.

    warmup_steps of pure uniform, then a linear ramp over ramp_steps up
    to max_mined_frac, constant after. max_mined_frac=0 degenerates to
    the uniform stream (handy as an ablation baseline).
    """

    warmup_steps: int = 50
    ramp_steps: int = 100
    max_mined_frac: float = 0.5

    def __post_init__(self):
        if not 0.0 <= self.max_mined_frac <= 1.0:
            raise ValueError(f"max_mined_frac must be in [0, 1], got "
                             f"{self.max_mined_frac}")
        if self.warmup_steps < 0 or self.ramp_steps < 0:
            raise ValueError("warmup_steps / ramp_steps must be >= 0")

    def mined_frac(self, step: int) -> float:
        if step < self.warmup_steps:
            return 0.0
        if self.ramp_steps == 0:
            return self.max_mined_frac
        ramp = (step - self.warmup_steps) / self.ramp_steps
        return self.max_mined_frac * min(ramp, 1.0)


class MinedPairSource:
    """Curriculum mix of uniform and mined pair batches, per-worker
    sharded. Satisfies the trainer's pluggable pair-source contract."""

    def __init__(self, features, labels,
                 schedule: Optional[CurriculumSchedule] = None, *,
                 balanced_uniform: bool = True):
        """Args:
          features / labels: the (n, d) feature table and (n,) labels
            every pair (mined or uniform) indexes into.
          schedule: curriculum (CurriculumSchedule defaults).
          balanced_uniform: draw the uniform share half-S / half-D (the
            paper's §5.2 setup); mined pairs keep whatever S/D mix the
            miner produced.
        """
        self.features = np.asarray(features, np.float32)
        self.labels = np.asarray(labels)
        self.schedule = schedule or CurriculumSchedule()
        self.balanced_uniform = balanced_uniform
        self._pool = {"a": np.zeros(0, np.int64),
                      "b": np.zeros(0, np.int64),
                      "sim": np.zeros(0, np.int32)}
        self.pool_version = 0

    # -- pool lifecycle ------------------------------------------------------

    @property
    def pool_size(self) -> int:
        return int(self._pool["sim"].shape[0])

    def set_pool(self, pairs: dict) -> None:
        """Swap in a freshly mined pool (dict(a, b, sim) index pairs, or
        a MiningResult's ``.pairs``). Streams pick it up on their next
        batch — no stream restart needed."""
        pairs = getattr(pairs, "pairs", pairs)
        a = np.asarray(pairs["a"], np.int64)
        b = np.asarray(pairs["b"], np.int64)
        sim = np.asarray(pairs["sim"], np.int32)
        if not (a.shape == b.shape == sim.shape):
            raise ValueError("pool arrays must be same-shape 1-D")
        n = self.features.shape[0]
        if len(a) and (max(a.max(), b.max()) >= n or min(a.min(),
                                                         b.min()) < 0):
            raise ValueError("pool indices out of range of the feature "
                             "table")
        self._pool = {"a": a, "b": b, "sim": sim}
        self.pool_version += 1

    # -- the trainer contract ------------------------------------------------

    def worker_streams(self, n_workers: int, batch_size: int,
                       seed: int = 0) -> List[Iterator[dict]]:
        """One infinite batch iterator per worker (disjoint shards)."""
        return [self._stream(w, n_workers, batch_size, seed + w)
                for w in range(n_workers)]

    def _stream(self, worker: int, n_workers: int, batch_size: int,
                seed: int) -> Iterator[dict]:
        rng = np.random.RandomState(seed)
        step = 0
        while True:
            frac = self.schedule.mined_frac(step)
            # worker's shard of the current pool (recomputed per batch:
            # set_pool may have swapped it since the last one)
            pa = self._pool["a"][worker::n_workers]
            pb = self._pool["b"][worker::n_workers]
            ps = self._pool["sim"][worker::n_workers]
            n_mined = min(int(round(frac * batch_size)), len(pa))
            n_uni = batch_size - n_mined
            parts_a, parts_b, parts_s = [], [], []
            if n_mined:
                # distinct rows per batch, matching the dedup contract
                # the uniform share gets from sample_pair_indices
                sel = distinct_draws(rng, len(pa), n_mined)
                parts_a.append(pa[sel])
                parts_b.append(pb[sel])
                parts_s.append(ps[sel])
            if n_uni:
                if self.balanced_uniform:
                    n_sim = n_uni // 2
                    n_dis = n_uni - n_sim
                else:
                    n_sim = int(rng.binomial(n_uni, 0.5))
                    n_dis = n_uni - n_sim
                uni = sample_pair_indices(
                    self.labels, n_sim, n_dis,
                    seed=int(rng.randint(0, 2 ** 31 - 1)))
                parts_a.append(uni["a"])
                parts_b.append(uni["b"])
                parts_s.append(uni["sim"])
            a = np.concatenate(parts_a)
            b = np.concatenate(parts_b)
            sim = np.concatenate(parts_s)
            perm = rng.permutation(batch_size)
            yield {
                "xs": jnp.asarray(self.features[a[perm]]),
                "ys": jnp.asarray(self.features[b[perm]]),
                "sim": jnp.asarray(sim[perm]),
            }
            step += 1
