"""Closed-loop hard-pair mining: the serving index feeds the trainer.

miner.py   HardPairMiner — batched k-NN through the RetrievalEngine,
           label-filtered into hard negatives / hard positives / a
           semi-hard band under the current metric L.
stream.py  MinedPairSource — trainer-contract batch streams mixing
           uniform and mined pairs under a CurriculumSchedule, per-worker
           sharded.
loop.py    ClosedLoopTrainer — alternates PS training with index refresh
           (MutableIndex.swap_metric or rebuild) + re-mining, under an
           explicit staleness policy (every R steps / on plateau).
"""

from repro.mining.loop import ClosedLoopConfig, ClosedLoopTrainer  # noqa: F401
from repro.mining.miner import (HardPairMiner, MinerConfig,  # noqa: F401
                                MiningResult)
from repro.mining.stream import (CurriculumSchedule,  # noqa: F401
                                 MinedPairSource)
