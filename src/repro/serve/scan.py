"""Shared gallery-scan machinery: projection, row sharding, top-k merge.

Every index backend (serve/index.py ExactIndex, serve/ivf.py IVFIndex)
answers a query the same way at the bottom: project the query once into the
k-dim metric space, score some set of pre-projected gallery rows with the
factored squared distance, and keep the k_top best with ties broken toward
the smaller global row id. This module owns that shared substrate so the
backends only differ in *which rows they score*:

  * ``project_queries``      — q @ L^T, the once-per-query projection;
  * ``gallery_axes`` / ``put_row_sharded`` / ``put_replicated`` — mapping
    the logical "gallery" axis onto physical mesh axes and placing arrays;
  * ``local_topk`` / ``topk_by_distance`` — candidate selection.
    ``topk_by_distance`` is the deterministic (distance, id) lexicographic
    merge: ties go to the smaller global row id regardless of the order
    candidates were generated in (IVF visits rows cluster-permuted);
  * ``build_sharded_topk``   — the shard_map local-topk/global-merge
    skeleton: each shard turns its local rows into at most ``kk``
    globally-id'd candidates, the per-shard candidates concatenate along
    the neighbor axis, and one final merge makes the result exact.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels import _dispatch
from repro.sharding import partition


def project_queries(L, queries):
    """Project raw (Nq, d_in) queries into the d_out-dim metric space (f32).

    ``L`` is the (d_out, d_in) metric factor — square or rectangular.
    Validates the factor contract up front (shapes are static at trace
    time, so this also fires with a clear error from inside jit instead
    of an opaque dot-dimension failure)."""
    check_metric_factor(L, jnp.shape(queries)[-1])
    return queries.astype(jnp.float32) @ L.astype(jnp.float32).T


def check_metric_factor(L, d_in=None, *, what: str = "L"):
    """Validate L against the (d_out, d_in) contract — see
    kernels/_dispatch.check_metric_factor (the one copy every layer
    shares); re-exported here because serve-side callers (index builds,
    engine, CLI) reach it through the scan substrate."""
    return _dispatch.check_metric_factor(L, d_in, what=what)


SCAN_IMPLS = ("auto", "xla", "pallas")


def resolve_scan_impl(default: str, override=None) -> str:
    """Resolve a segment-scan implementation knob to "xla" or "pallas".

    ``default`` is the index's build-time setting; ``override`` a
    per-call value (None defers to the default — ``is None``, never
    truthiness, so an explicit empty/0 value raises instead of silently
    remapping, the k_top=0 bug class). "auto" picks the fused Pallas
    kernel when the runtime backend is a TPU and the XLA path elsewhere
    (interpret-mode Pallas is a correctness tool, not a serving path).
    """
    impl = default if override is None else override
    if impl not in SCAN_IMPLS:
        raise ValueError(f"unknown scan_impl {impl!r} "
                         f"({'|'.join(SCAN_IMPLS)})")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def recall_at_k(approx_ids, exact_ids) -> float:
    """Mean per-query overlap |approx ∩ exact| / k between two (Nq, k)
    neighbor-id arrays — the ANN quality metric the IVF frontier sweeps.
    Host-side numpy helper shared by benchmarks, examples, and tests.
    -1 sentinel ids (under-filled probes) never match a real id."""
    a = np.asarray(approx_ids)
    e = np.asarray(exact_ids)
    k = e.shape[1]
    return float(np.mean([len(set(ar[ar >= 0]) & set(er)) / k
                          for ar, er in zip(a, e)]))


def gallery_axes(mesh: Mesh, n_rows: Optional[int] = None,
                 rules=None) -> Tuple[str, ...]:
    """Physical mesh axes the gallery rows shard over (possibly empty).

    ``n_rows=None`` skips the divisibility check — for backends (IVF) that
    pick their padded row count *after* learning the shard count.
    """
    shape = None if n_rows is None else (n_rows, 1)
    spec = partition.logical_to_physical(("gallery", None), mesh, rules,
                                         shape=shape)
    ax = spec[0]
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(ax)


def row_axis(axes: Tuple[str, ...]):
    """PartitionSpec entry for the row dimension (one axis or a tuple)."""
    return axes if len(axes) > 1 else axes[0]


def n_shards(mesh: Optional[Mesh], axes: Tuple[str, ...]) -> int:
    if not axes:
        return 1
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def put_row_sharded(mesh: Mesh, axes: Tuple[str, ...], arr):
    """device_put with the leading dim split over the gallery mesh axes."""
    spec = P(row_axis(axes), *([None] * (arr.ndim - 1)))
    return jax.device_put(arr, NamedSharding(mesh, spec))


def put_replicated(mesh: Mesh, arr):
    return jax.device_put(jnp.asarray(arr), NamedSharding(mesh, P()))


def shard_index(mesh: Mesh, axes: Tuple[str, ...]):
    """Spec-major linear shard id (traced; only valid inside shard_map)."""
    s = jnp.int32(0)
    for a in axes:
        s = s * mesh.shape[a] + jax.lax.axis_index(a)
    return s


def local_topk(d, ids, kk: int):
    """Cheapest local selection: lax.top_k on -d, ties toward the earlier
    candidate position. Correct merge input whenever candidate position
    order equals global-id order (the contiguous row scan)."""
    neg, pos = jax.lax.top_k(-d, kk)
    return -neg, jnp.take_along_axis(ids, pos, axis=-1)


def topk_by_distance(d, ids, k_top: int):
    """Top-k candidates by distance with a deterministic presentation.

    lax.top_k does the heavy selection (O(n log k); a full lexicographic
    lax.sort is ~50x slower on CPU), then the k_top survivors re-sort
    lexicographically by (distance, id) so equal-distance neighbors always
    come back smallest-id-first regardless of the order candidates were
    generated in (IVF visits rows cluster-permuted). Caveat: ties
    *straddling* the k_top boundary still resolve by candidate position,
    so on galleries with exactly duplicated rows the returned member of a
    tied tail may differ between backends (distances are still correct;
    distinct real-valued distances are unaffected).

    Delegates to kernels/_dispatch.py — the one copy of the contract the
    Pallas segment-scan kernels and their XLA references must reproduce
    bit-for-bit.
    """
    return _dispatch.topk_by_distance(d, ids, k_top)


def build_sharded_topk(mesh: Mesh, axes: Tuple[str, ...],
                       sharded_arrays: Sequence[jax.Array],
                       local_candidates: Callable, k_top: int,
                       n_extras: int = 0):
    """Build the shard_map local-topk/global-merge query skeleton.

    ``local_candidates(shard, qp, extras, locals_) -> (d, ids)`` runs per
    shard: ``shard`` is this shard's spec-major id, ``qp`` the replicated
    projected queries, ``extras`` replicated per-call inputs (e.g. IVF
    probe lists), ``locals_`` this shard's slices of ``sharded_arrays``.
    It must return (Nq, kk) candidates with *global* row ids and
    kk >= min(k_top, candidates available on the shard) — then the final
    (distance, id) merge over the concatenated (Nq, kk * n_shards)
    candidates is exact.

    Returns ``run(qp, *extras) -> (dists, ids)`` (not jitted; callers wrap
    it together with query projection).
    """
    row_ax = row_axis(axes)
    specs = tuple(P(row_ax, *([None] * (a.ndim - 1))) for a in sharded_arrays)
    in_specs = (P(),) * (1 + n_extras) + specs
    out_specs = (P(None, row_ax), P(None, row_ax))

    def body(qp, *rest):
        extras, locals_ = rest[:n_extras], rest[n_extras:]
        return local_candidates(shard_index(mesh, axes), qp, extras, locals_)

    inner = partition.shard_map(body, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs)

    def run(qp, *extras):
        cand_d, cand_i = inner(qp, *extras, *sharded_arrays)
        return topk_by_distance(cand_d, cand_i, k_top)

    return run
