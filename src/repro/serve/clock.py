"""Injectable time source for the serving front end.

Every time-dependent serving behavior — micro-batch deadlines, request
expiry, degradation windows — reads time and performs timed waits through
a ``Clock`` so tests can drive the whole front end deterministically with
``FakeClock``: no ``time.sleep``, no flaky "waited long enough?" asserts.

The contract is deliberately tiny:

  * ``now()``            — monotonic seconds (origin arbitrary);
  * ``wait_on(cond, t)`` — park on an already-held ``threading.Condition``
                           until notified or ``t`` seconds pass
                           (``t=None`` = wait for a notify only).

Producers wake consumers with plain ``cond.notify_all()`` — the clock only
mediates how *timeouts* elapse. Under ``SystemClock`` a timed wait is just
``Condition.wait(timeout)``. Under ``FakeClock`` virtual time is frozen
until the test calls ``advance(dt)``, which wakes exactly the waiters
whose deadlines have come due; ``wait_for_waiters(n)`` lets the test rank
with a worker thread (block until it is parked) before advancing, so the
interleaving is pinned, not raced. ``wait_for_waiters`` is the one place
real time appears — as a guard against a deadlocked test, never as an
assertion.

Timed-wait call sites must loop: a wait can return early (a producer
notify meant for another consumer, or an advance() that only partially
covers the timeout), so correctness always comes from re-checking the
predicate and the remaining budget against ``now()``, exactly like a
plain condition variable.
"""

from __future__ import annotations

import threading
import time


class Clock:
    """Time-source protocol (see module docstring)."""

    def now(self) -> float:
        raise NotImplementedError

    def wait_on(self, cond: "threading.Condition",
                timeout: float | None) -> None:
        """Park on ``cond`` (held by the caller) until notified or
        ``timeout`` virtual seconds elapse. May return early — callers
        re-check their predicate against ``now()``."""
        raise NotImplementedError

    def sleep(self, dt: float) -> None:
        """Block the calling thread for ``dt`` (virtual) seconds."""
        cond = threading.Condition()
        deadline = self.now() + dt
        with cond:
            while True:
                remaining = deadline - self.now()
                if remaining <= 0:
                    return
                self.wait_on(cond, remaining)


class SystemClock(Clock):
    """Real wall-clock time — the production default."""

    def now(self) -> float:
        return time.monotonic()

    def wait_on(self, cond, timeout):
        cond.wait(timeout=None if timeout is None else max(timeout, 0.0))

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class FakeClock(Clock):
    """Virtual time for deterministic tests.

    ``now()`` is frozen until ``advance(dt)`` moves it; timed waiters
    park for real (their thread blocks) but their timeout elapses only
    in virtual time. The test choreography is always:

        fake.wait_for_waiters(1)   # worker is parked on its timeout
        fake.advance(wait_s)       # its deadline comes due -> it wakes

    Waiters with ``timeout=None`` park untimed (woken only by producer
    notifies) and do **not** count toward ``wait_for_waiters`` — they are
    idle consumers, not pending timeouts.
    """

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)
        self._lock = threading.Lock()
        # parked timed waiters: id -> (cond, virtual deadline)
        self._waiters: dict[int, tuple[threading.Condition, float]] = {}
        self._next_id = 0
        self._parked = threading.Condition(self._lock)

    def now(self) -> float:
        with self._lock:
            return self._t

    def wait_on(self, cond, timeout):
        if timeout is None:
            cond.wait()                     # producer notify only
            return
        if timeout <= 0:
            return
        with self._lock:
            wid = self._next_id
            self._next_id += 1
            self._waiters[wid] = (cond, self._t + timeout)
            self._parked.notify_all()
        try:
            cond.wait()
        finally:
            with self._lock:
                self._waiters.pop(wid, None)

    def advance(self, dt: float) -> None:
        """Move virtual time forward and wake every timed waiter whose
        deadline has come due."""
        if dt < 0:
            raise ValueError(f"cannot advance by {dt}")
        with self._lock:
            self._t += dt
            due = [c for c, dl in self._waiters.values() if dl <= self._t]
        for cond in due:
            with cond:
                cond.notify_all()

    def n_waiters(self) -> int:
        """Timed waiters currently parked."""
        with self._lock:
            return len(self._waiters)

    def wait_for_waiters(self, n: int = 1, timeout: float = 10.0) -> None:
        """Block (real time, bounded) until >= ``n`` timed waiters are
        parked. This is synchronization, not a timing assertion: it
        returns the moment the condition holds, and the real-time bound
        only guards against a deadlocked test.
        """
        deadline = time.monotonic() + timeout
        with self._lock:
            while len(self._waiters) < n:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"only {len(self._waiters)} timed waiter(s) "
                        f"parked after {timeout}s (wanted {n})")
                self._parked.wait(timeout=remaining)
