"""Metric-space retrieval serving: index -> engine -> micro-batcher.

Query-side subsystem for the learned metric M = L^T L: a pre-projected,
mesh-sharded gallery index (index.py), a bucketed jitted execution engine
(engine.py), and a request-coalescing front door (batcher.py). The fused
device path is kernels/metric_topk.
"""

from repro.serve.batcher import MicroBatcher  # noqa: F401
from repro.serve.engine import RetrievalEngine  # noqa: F401
from repro.serve.index import GalleryIndex  # noqa: F401
