"""Metric-space retrieval serving: index hierarchy -> engine -> batcher.

Query-side subsystem for the learned metric M = L^T L: a pluggable index
hierarchy (index.py MetricIndex protocol, ExactIndex full scan; ivf.py
IVFIndex cluster-pruned ANN; pq.py IVFPQIndex residual-product-quantized
segments with ADC scoring + exact rerank) over the shared
projection/shard/merge substrate (scan.py), the mutation lifecycle layer
(mutable.py MutableIndex streaming upserts/deletes + compaction + metric
hot-swap; snapshot.py save/load without re-projection), a bucketed jitted
execution engine with a hot-query LRU cache (engine.py), and a
request-coalescing front door (batcher.py). The fused device path is
kernels/metric_topk.
"""

from repro.serve.batcher import MicroBatcher  # noqa: F401
from repro.serve.engine import RetrievalEngine  # noqa: F401
from repro.serve.index import (ExactIndex, GalleryIndex,  # noqa: F401
                               MetricIndex)
from repro.serve.ivf import IVFIndex, kmeans_projected  # noqa: F401
from repro.serve.mutable import MutableIndex  # noqa: F401
from repro.serve.pq import IVFPQIndex, ProductQuantizer  # noqa: F401
from repro.serve.scan import recall_at_k  # noqa: F401
from repro.serve.snapshot import (has_snapshot, l_fingerprint,  # noqa: F401
                                  load_index, save_index)
