"""Metric-space retrieval serving: index hierarchy -> engine -> front end.

Query-side subsystem for the learned metric M = L^T L: a pluggable index
hierarchy (index.py MetricIndex protocol, ExactIndex full scan; ivf.py
IVFIndex cluster-pruned ANN; pq.py IVFPQIndex residual-product-quantized
segments with ADC scoring + exact rerank) over the shared
projection/shard/merge substrate (scan.py), the mutation lifecycle layer
(mutable.py MutableIndex streaming upserts/deletes + compaction + metric
hot-swap; snapshot.py save/load without re-projection), a bucketed jitted
execution engine with a hot-query LRU cache (engine.py), and two front
doors: a request-coalescing micro-batcher (batcher.py) and the
traffic-shaped scheduler above it (scheduler.py: bounded admission,
priority/deadline classes, adaptive degradation). All front-end timing
runs on the injectable clock (clock.py) so tests are deterministic. The
fused device path is kernels/metric_topk.
"""

from repro.serve.batcher import MicroBatcher  # noqa: F401
from repro.serve.clock import (Clock, FakeClock,  # noqa: F401
                               SystemClock)
from repro.serve.engine import RetrievalEngine  # noqa: F401
from repro.serve.scheduler import (DEFAULT_CLASSES,  # noqa: F401
                                   DeadlineExceededError, DegradeTransition,
                                   LatencyWindow, LoadController,
                                   PriorityClass, RejectedError,
                                   RequestScheduler, SchedulerError,
                                   default_ladder)
from repro.serve.index import (ExactIndex, GalleryIndex,  # noqa: F401
                               MetricIndex)
from repro.serve.ivf import IVFIndex, kmeans_projected  # noqa: F401
from repro.serve.mutable import MutableIndex  # noqa: F401
from repro.serve.pq import IVFPQIndex, ProductQuantizer  # noqa: F401
from repro.serve.scan import recall_at_k  # noqa: F401
from repro.serve.snapshot import (has_snapshot, l_fingerprint,  # noqa: F401
                                  load_index, save_index)
from repro.serve.tenant import (ShadowArm, Tenant,  # noqa: F401
                                TenantError, TenantFingerprintError,
                                TenantRouter, attach_view, load_tenants,
                                save_tenants)
