"""IVF (inverted-file) cluster-pruned ANN index under the learned metric.

The exact scan (serve/index.py) touches all M gallery rows per query; at
paper scale (ImageNet-1M, Xie & Xing 2014 §5) that caps QPS. This backend
trades a bounded recall loss for skipping most of the gallery, the
low-rank-projection-plus-pruning recipe Qian et al. 2015 argue makes
high-d learned-metric retrieval practical:

  build:  k-means in the *projected* k-dim metric space (Lloyd's,
          jit-scanned, with a farthest-point reseed for empty clusters)
          partitions the pre-projected gallery into ``n_clusters``
          contiguous segments, each padded to a common capacity so the
          layout stays static-shaped for jit; a (C, k) centroid table is
          kept replicated.
  query:  score the C centroids (cheap: C << M), keep the ``nprobe``
          nearest clusters, gather only their segments, run the same
          factored distance + (distance, id) merge the exact scan uses.

Per-query row visits drop from M to ``nprobe * capacity``. With
``nprobe == n_clusters`` every row is visited and the result matches
ExactIndex on indices (the correctness oracle the tests pin) whenever
distances are distinct; exactly duplicated gallery rows tied at the k_top
boundary may resolve to a different (equal-distance) copy — see
scan.topk_by_distance.

Padding slots carry ``gn = +BIG`` / ``id = -1`` sentinels; they can reach
the output only when the probed clusters hold fewer than k_top real rows
(raise nprobe if callers see -1 ids). Sharded build places whole clusters
per shard (n_clusters rounds up to a multiple of the shard count) and
composes scan.build_sharded_topk, with non-local probes routed to an
all-sentinel cluster so every shard does identical static-shaped work.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ivf_scan import ivf_scan_topk
from repro.kernels.metric_topk import metric_sqdist_factored, project_gallery
from repro.kernels.metric_topk.kernel import BIG
from repro.kernels.pairwise_dist.ref import pairwise_sqdist_ref
from repro.serve import scan


# -- metric-space k-means ----------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block_rows",))
def _assign(gp, centroids, block_rows: int):
    """Nearest-centroid assignment, chunked over rows so the (M, C)
    distance matrix never materializes at big M. Returns (assign (M,)
    int32, min_sqdist (M,) f32)."""
    M, k = gp.shape
    B = min(block_rows, M)
    Mp = ((M + B - 1) // B) * B
    gpp = jnp.pad(gp, ((0, Mp - M), (0, 0)))

    def blk(g):
        d = pairwise_sqdist_ref(g, centroids)
        return jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1)

    a, md = jax.lax.map(blk, gpp.reshape(Mp // B, B, k))
    return a.reshape(-1)[:M], md.reshape(-1)[:M]


@functools.partial(jax.jit, static_argnames=("n_clusters",))
def _farthest_init(gp, n_clusters: int, key):
    """k-center greedy ("maxmin") seeding: start anywhere, then repeatedly
    take the point farthest from every seed so far. One O(M*k) pass per
    seed (same total cost as one Lloyd iteration) and — unlike random row
    draws — never stacks several seeds inside one dense cluster, which is
    what splits a blob's neighbors across segments and caps recall."""
    M = gp.shape[0]
    first = gp[jax.random.randint(key, (), 0, M)]

    def step(carry, _):
        mind, last = carry
        d = jnp.sum(jnp.square(gp - last), axis=1)
        mind = jnp.minimum(mind, d)
        nxt = gp[jnp.argmax(mind)]
        return (mind, nxt), last

    (_, last), seeds = jax.lax.scan(
        step, (jnp.full((M,), jnp.inf, jnp.float32), first), None,
        length=n_clusters)
    return seeds


@functools.partial(jax.jit, static_argnames=("iters", "block_rows"))
def _lloyd(gp, cent0, iters: int, block_rows: int):
    M = gp.shape[0]
    C = cent0.shape[0]

    def step(cent, _):
        a, md = _assign(gp, cent, block_rows)
        counts = jnp.zeros((C,), jnp.float32).at[a].add(1.0)
        sums = jnp.zeros_like(cent).at[a].add(gp)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # balanced-assignment fallback: each empty cluster reseeds at a
        # distinct currently-worst-served point (largest min-distance),
        # which splits overloaded regions instead of leaving dead segments
        empty = counts == 0.0
        far = jnp.argsort(-md)
        rank = jnp.clip(jnp.cumsum(empty) - 1, 0, M - 1)
        new = jnp.where(empty[:, None], gp[far[rank]], new)
        return new, md.mean()

    return jax.lax.scan(step, cent0, None, length=iters)


def kmeans_projected(gp, n_clusters: int, *, iters: int = 10, seed: int = 0,
                     block_rows: int = 16384, init: str = "farthest"):
    """Lloyd's k-means over pre-projected gallery rows (M, k).

    ``init``: "farthest" (k-center greedy; default) or "random" (row
    draws). Returns (centroids (C, k) f32, assign (M,) int32, objective
    (iters,) f32) — objective[t] is the mean squared distance to the
    nearest centroid *entering* iteration t, so it is non-increasing for
    pure Lloyd steps (empty-cluster reseeds may bump it transiently).
    """
    gp = jnp.asarray(gp, jnp.float32)
    M = gp.shape[0]
    if n_clusters > M:
        raise ValueError(f"n_clusters={n_clusters} > gallery size {M}")
    key = jax.random.PRNGKey(seed)
    if init == "farthest":
        cent0 = _farthest_init(gp, n_clusters, key)
    elif init == "random":
        cent0 = gp[jax.random.permutation(key, M)[:n_clusters]]
    else:
        raise ValueError(f"unknown init {init!r}")
    centroids, objective = _lloyd(gp, cent0, iters, block_rows)
    assign, _ = _assign(gp, centroids, block_rows)
    return centroids, assign, objective


def _balance_assign(gp, centroids, assign, cap: int) -> np.ndarray:
    """Capacity-bounded assignment: clusters keep their ``cap`` closest
    rows; overflow rows move to the nearest cluster with free space.

    Host-side one-time build step (numpy). Total capacity C*cap >= M is
    guaranteed by cap >= ceil(M/C), so the greedy pass always places
    every row.
    """
    C = centroids.shape[0]
    counts = np.bincount(assign, minlength=C)
    if counts.max() <= cap:
        return assign
    assign = assign.copy()
    spilled = []
    for c in np.flatnonzero(counts > cap):
        rows = np.flatnonzero(assign == c)
        d = np.sum((gp[rows] - centroids[c]) ** 2, axis=1)
        spilled.extend(rows[np.argsort(d)[cap:]])
        counts[c] = cap
    d_all = (np.sum(gp[spilled] ** 2, axis=1)[:, None]
             + np.sum(centroids ** 2, axis=1)[None, :]
             - 2.0 * gp[spilled] @ centroids.T)             # (S, C)
    for i, row in enumerate(spilled):
        for c in np.argsort(d_all[i]):
            if counts[c] < cap:
                assign[row] = c
                counts[c] += 1
                break
    return assign


# -- the index ---------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class IVFIndex:
    """Cluster-pruned approximate retrieval index (MetricIndex backend).

    Invariants: segments are cluster-major with a common capacity
    (static shapes keep the jitted query paths hot); pad slots carry
    ``gn = +BIG`` / ``id = -1`` sentinels and can only surface when the
    probed clusters hold fewer than k_top real rows; at ``nprobe ==
    n_clusters`` answers match ExactIndex on indices (ties at the k_top
    boundary between exactly duplicated rows excepted — see
    scan.topk_by_distance).
    """

    L: jax.Array                    # (k, d) replicated metric factor
    centroids: jax.Array            # (C, k) cluster centers, replicated
    gp_pad: jax.Array               # (C*cap, k) cluster-major padded rows
    gn_pad: jax.Array               # (C*cap,) row norms; BIG on pad slots
    ids_pad: jax.Array              # (C*cap,) original row ids; -1 on pads
    cap: int                        # per-cluster segment capacity
    n_clusters: int
    nprobe: int                     # default clusters scanned per query
    n_rows: int                     # real (unpadded) gallery size M
    block_q: int = 16               # query chunk for the segment gather
    # segment-scan implementation: "auto" (Pallas kernel on TPU, XLA
    # elsewhere), "xla", or "pallas" (kernels/ivf_scan; single-shard only)
    scan_impl: str = "auto"
    mesh: Optional[jax.sharding.Mesh] = None
    axes: Tuple[str, ...] = ()
    version: int = 0
    _fns: dict = dataclasses.field(default_factory=dict, repr=False)

    @classmethod
    def build(cls, L, gallery, n_clusters: int = 64, nprobe: int = 8,
              *, iters: int = 10, seed: int = 0, cap_factor: float = 1.25,
              scan_impl: str = "auto", mesh=None, rules=None) -> "IVFIndex":
        """Project the gallery, cluster it, lay out padded segments.

        ``cap_factor`` bounds segment capacity at ~cap_factor * M/C rows:
        k-means clusters larger than that spill their farthest rows to the
        nearest cluster with free space (balanced assignment). Query cost
        is nprobe * cap, so capping it keeps skewed galleries from paying
        the worst cluster's size on every probe; spilled rows are only
        found via their adoptive cluster (a bounded recall trade).
        ``scan_impl`` picks the default segment-scan implementation —
        "auto" (kernels/ivf_scan fused Pallas kernel on TPU, XLA
        elsewhere), "xla", or "pallas" (overridable per topk call).
        """
        gp, gn = project_gallery(L, gallery)
        return cls.build_projected(L, gp, gn, n_clusters=n_clusters,
                                   nprobe=nprobe, iters=iters, seed=seed,
                                   cap_factor=cap_factor,
                                   scan_impl=scan_impl, mesh=mesh,
                                   rules=rules)

    @classmethod
    def build_projected(cls, L, gp, gn, n_clusters: int = 64,
                        nprobe: int = 8, *, iters: int = 10, seed: int = 0,
                        cap_factor: float = 1.25, scan_impl: str = "auto",
                        mesh=None, rules=None) -> "IVFIndex":
        """Cluster + lay out already-projected rows (gp (M,k), gn (M,)).

        The compaction-triggered rebuild and metric hot-swap
        (serve/mutable.py) enter here: they already hold projected rows
        and must not pay a second gallery projection.
        """
        if scan_impl not in scan.SCAN_IMPLS:
            raise ValueError(f"unknown scan_impl {scan_impl!r} "
                             f"({'|'.join(scan.SCAN_IMPLS)})")
        scan.check_metric_factor(L)
        gp = jnp.asarray(gp, jnp.float32)
        gn = jnp.asarray(gn, jnp.float32)
        M, k = gp.shape
        if k != jnp.shape(L)[0]:
            raise ValueError(
                f"projected rows have dim {k} but L is "
                f"{tuple(jnp.shape(L))}; gp must be sized d_out")
        axes: Tuple[str, ...] = ()
        if mesh is not None:
            axes = scan.gallery_axes(mesh, None, rules)
        shards = scan.n_shards(mesh, axes)
        C = ((n_clusters + shards - 1) // shards) * shards  # whole clusters
        if C > M:                                           # per shard
            raise ValueError(f"n_clusters={C} (after shard round-up) > "
                             f"gallery size {M}")
        centroids, assign, _ = kmeans_projected(gp, C, iters=iters,
                                                seed=seed)

        gp_np = np.asarray(gp)
        cap = int(-((-max(cap_factor, 1.0) * M) // C))      # ceil
        cap = ((cap + 7) // 8) * 8
        assign = _balance_assign(gp_np, np.asarray(centroids),
                                 np.asarray(assign), cap)
        counts = np.bincount(assign, minlength=C)
        order = np.argsort(assign, kind="stable")           # cluster-major
        offsets = np.cumsum(counts) - counts
        within = np.arange(M) - offsets[assign[order]]
        slots = assign[order] * cap + within

        gp_pad = np.zeros((C * cap, k), np.float32)
        gn_pad = np.full((C * cap,), BIG, np.float32)
        ids_pad = np.full((C * cap,), -1, np.int32)
        gp_pad[slots] = gp_np[order]
        gn_pad[slots] = np.asarray(gn)[order]
        ids_pad[slots] = order.astype(np.int32)

        gp_pad, gn_pad, ids_pad = map(jnp.asarray, (gp_pad, gn_pad, ids_pad))
        if axes:
            gp_pad = scan.put_row_sharded(mesh, axes, gp_pad)
            gn_pad = scan.put_row_sharded(mesh, axes, gn_pad)
            ids_pad = scan.put_row_sharded(mesh, axes, ids_pad)
            L = scan.put_replicated(mesh, L)
            centroids = scan.put_replicated(mesh, centroids)
        return cls(L=jnp.asarray(L), centroids=centroids, gp_pad=gp_pad,
                   gn_pad=gn_pad, ids_pad=ids_pad, cap=cap, n_clusters=C,
                   nprobe=min(nprobe, C), n_rows=M, scan_impl=scan_impl,
                   mesh=mesh, axes=axes)

    @property
    def size(self) -> int:
        """Real (unpadded) gallery rows."""
        return self.n_rows

    @property
    def n_shards(self) -> int:
        """Mesh shards the segments live on (1 when unsharded)."""
        return scan.n_shards(self.mesh, self.axes)

    def topk(self, queries, k_top: int, backend: str = "xla",
             nprobe: Optional[int] = None,
             scan_impl: Optional[str] = None):
        """Approximate k nearest gallery rows per query.

        Args:
          queries: (Nq, d) raw queries (projected through L here).
          k_top: neighbors per query (<= size and <= nprobe * cap — the
            candidate pool actually scanned).
          backend: "xla" only.
          nprobe: clusters scanned per query (defaults to the build-time
            setting; ``n_clusters`` scans everything = exact).
          scan_impl: segment-scan implementation for this call — "auto" /
            "xla" / "pallas" (defaults to the build setting; see
            scan.resolve_scan_impl). "pallas" requires a single-shard
            index; ids match the xla path exactly, distances to f32
            rounding.

        Returns (dists (Nq, k_top) f32 ascending, global row indices
        (Nq, k_top) int32); -1 ids mark under-filled probes (raise
        nprobe if callers see them).
        """
        if backend != "xla":
            raise NotImplementedError(
                "IVFIndex only supports the xla backend")
        if k_top > self.size:
            raise ValueError(f"k_top={k_top} > gallery size {self.size}")
        # `is None`, not truthiness: `nprobe or default` would silently
        # map an explicit nprobe=0 to the default (the k_top=0 bug class)
        np_ = self.nprobe if nprobe is None else nprobe
        if np_ < 1:
            raise ValueError(f"nprobe must be >= 1, got {np_}")
        np_ = min(np_, self.n_clusters)
        if k_top > np_ * self.cap:
            raise ValueError(
                f"k_top={k_top} > nprobe*cap={np_ * self.cap} scanned "
                f"rows per query; raise nprobe")
        impl = scan.resolve_scan_impl(self.scan_impl, scan_impl)
        if impl == "pallas" and self.n_shards > 1:
            raise NotImplementedError(
                "scan_impl='pallas' is single-shard only (the fused "
                "kernel does not compose with shard_map yet)")
        key = (k_top, np_, impl)
        fn = self._fns.get(key)
        if fn is None:
            build = (self._build_topk_sharded if self.n_shards > 1
                     else self._build_topk)
            fn = self._fns[key] = build(k_top, np_, impl)
        return fn(queries)

    # -- single-device query path -------------------------------------------

    def _build_topk(self, k_top: int, nprobe: int, impl: str):
        C, cap = self.n_clusters, self.cap
        k = self.centroids.shape[1]
        g = self.gp_pad.reshape(C, cap, k)
        gn = self.gn_pad.reshape(C, cap)
        ids = self.ids_pad.reshape(C, cap)

        @jax.jit
        def run(queries):
            qp = scan.project_queries(self.L, queries)
            probes = self._probe(qp, nprobe)
            return ivf_scan_topk(qp, probes, g, gn, ids, kk=k_top,
                                 block_q=self.block_q,
                                 use_kernel=(impl == "pallas"))

        return run

    # -- sharded query path (whole clusters per shard) -----------------------

    def _build_topk_sharded(self, k_top: int, nprobe: int, impl: str):
        # impl is always "xla" here (topk rejects pallas when sharded);
        # the per-shard body below is the same pure-jnp reference the
        # single-device xla path runs, via kernels/ivf_scan.
        del impl
        C, cap = self.n_clusters, self.cap
        C_loc = C // self.n_shards
        kk = min(k_top, nprobe * cap)

        def local_candidates(shard, qp, extras, locals_):
            (probes,) = extras
            gp_loc, gn_loc, ids_loc = locals_
            k = gp_loc.shape[1]
            # slot C_loc is an appended all-sentinel cluster; probes owned
            # by other shards land there so shapes stay static
            g = jnp.concatenate([gp_loc.reshape(C_loc, cap, k),
                                 jnp.zeros((1, cap, k), jnp.float32)])
            gn = jnp.concatenate([gn_loc.reshape(C_loc, cap),
                                  jnp.full((1, cap), BIG, jnp.float32)])
            ids = jnp.concatenate([ids_loc.reshape(C_loc, cap),
                                   jnp.full((1, cap), -1, jnp.int32)])
            slot = probes - shard * C_loc
            slot = jnp.where((slot >= 0) & (slot < C_loc), slot, C_loc)
            return _probed_topk(qp, slot, g, gn, ids, kk, self.block_q)

        inner = scan.build_sharded_topk(
            self.mesh, self.axes, (self.gp_pad, self.gn_pad, self.ids_pad),
            local_candidates, k_top, n_extras=1)

        @jax.jit
        def run(queries):
            qp = scan.project_queries(self.L, queries)
            return inner(qp, self._probe(qp, nprobe))

        return run

    def _probe(self, qp, nprobe: int):
        """Coarse quantizer: ids of the nprobe nearest centroids (Nq, np)."""
        cd = metric_sqdist_factored(qp, self.centroids)
        _, probes = jax.lax.top_k(-cd, nprobe)
        return probes.astype(jnp.int32)


def _probed_topk(qp, cluster_slots, g, gn, ids, kk: int, block_q: int):
    """Top-kk candidates per query from its probed segments.

    Thin alias for ``kernels.ivf_scan.ivf_scan_topk(use_kernel=False)``
    — the chunked XLA reference scan, which is also the pure-jnp
    per-shard body the sharded path runs inside shard_map (the appended
    all-sentinel cluster at slot C_loc is reached via the reference's
    ``mode="clip"`` gathers)."""
    return ivf_scan_topk(qp, cluster_slots, g, gn, ids, kk=kk,
                         block_q=block_q, use_kernel=False)
