"""Sharded pre-projected gallery index — the query-side data structure.

Index build amortizes the learned metric once (``gp = G @ L^T`` plus row
norms; kernels/metric_topk.project_gallery), after which every query costs
O(d*k + M*k/P) instead of O(M*d*k). Gallery rows shard across the worker
mesh via the logical ``"gallery"`` axis (sharding/partition.py maps it to
the (pod, data) axes); the metric factor L is replicated.

Query path on a sharded index: a shard_map computes each shard's local
top-k over its gallery rows (with indices offset to global row ids), the
per-shard candidates concatenate along the neighbor axis, and a final
lax.top_k merges them — exact, because each shard contributes
min(k_top, local_rows) candidates.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.metric_topk import (metric_sqdist_factored, metric_topk,
                                       metric_topk_xla, project_gallery)
from repro.sharding import partition


def _gallery_axes(mesh: Mesh, n_rows: int, rules=None) -> Tuple[str, ...]:
    """Physical mesh axes the gallery rows shard over (possibly empty)."""
    spec = partition.logical_to_physical(("gallery", None), mesh, rules,
                                         shape=(n_rows, 1))
    ax = spec[0]
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(ax)


@dataclasses.dataclass(eq=False)
class GalleryIndex:
    """Immutable retrieval index over a pre-projected gallery."""

    L: jax.Array                    # (k, d) replicated metric factor
    gp: jax.Array                   # (M, k) projected gallery rows
    gn: jax.Array                   # (M,) row norms of gp
    mesh: Optional[Mesh] = None
    axes: Tuple[str, ...] = ()      # mesh axes the rows are sharded over
    # per-instance k_top -> jitted sharded query fn (an lru_cache here would
    # pin the whole index in a class-level cache past its lifetime)
    _sharded_fns: dict = dataclasses.field(default_factory=dict, repr=False)

    @classmethod
    def build(cls, L, gallery, mesh: Optional[Mesh] = None,
              rules=None) -> "GalleryIndex":
        """Project the gallery through L once and (optionally) shard it."""
        gp, gn = project_gallery(L, gallery)
        axes: Tuple[str, ...] = ()
        if mesh is not None:
            axes = _gallery_axes(mesh, gp.shape[0], rules)
        if axes:
            row_ax = axes if len(axes) > 1 else axes[0]
            gp = jax.device_put(gp, NamedSharding(mesh, P(row_ax, None)))
            gn = jax.device_put(gn, NamedSharding(mesh, P(row_ax)))
            L = jax.device_put(jnp.asarray(L), NamedSharding(mesh, P()))
        return cls(L=jnp.asarray(L), gp=gp, gn=gn, mesh=mesh, axes=axes)

    @property
    def size(self) -> int:
        return self.gp.shape[0]

    @property
    def n_shards(self) -> int:
        if not self.axes:
            return 1
        n = 1
        for a in self.axes:
            n *= self.mesh.shape[a]
        return n

    def topk(self, queries, k_top: int, backend: str = "xla"):
        """(dists (Nq, k_top) ascending, global indices (Nq, k_top)).

        backend: "xla" (factored fast path; the only sharded option) or
        "pallas" (fused kernel, single-device; interpret off-TPU).
        """
        if k_top > self.size:
            raise ValueError(f"k_top={k_top} > gallery size {self.size}")
        if self.n_shards > 1:
            if backend != "xla":
                raise NotImplementedError(
                    "sharded index only supports the xla backend")
            return self._topk_sharded(k_top)(queries)
        if backend == "pallas":
            return metric_topk(self.L, queries, self.gp, self.gn,
                               k_top=k_top)
        return metric_topk_xla(self.L, queries, self.gp, self.gn, k_top)

    def _topk_sharded(self, k_top: int):
        fn = self._sharded_fns.get(k_top)
        if fn is None:
            fn = self._sharded_fns[k_top] = self._build_topk_sharded(k_top)
        return fn

    def _build_topk_sharded(self, k_top: int):
        mesh, axes = self.mesh, self.axes
        rows_local = self.size // self.n_shards
        kk = min(k_top, rows_local)     # per-shard candidates => exact merge
        row_ax = axes if len(axes) > 1 else axes[0]

        def local_topk(qp, gp_loc, gn_loc):
            d = metric_sqdist_factored(qp, gp_loc, gn_loc)
            neg, idx = jax.lax.top_k(-d, kk)
            shard = jnp.int32(0)
            for a in axes:              # spec-major order = global row order
                shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
            return -neg, (idx + shard * gp_loc.shape[0]).astype(jnp.int32)

        inner = partition.shard_map(
            local_topk, mesh=mesh,
            in_specs=(P(), P(row_ax, None), P(row_ax)),
            out_specs=(P(None, row_ax), P(None, row_ax)))

        @jax.jit
        def run(queries):
            qp = queries.astype(jnp.float32) @ self.L.astype(jnp.float32).T
            cand_d, cand_i = inner(qp, self.gp, self.gn)   # (Nq, kk*P)
            neg, pos = jax.lax.top_k(-cand_d, k_top)
            return -neg, jnp.take_along_axis(cand_i, pos, axis=1)

        return run
