"""Index hierarchy: the MetricIndex protocol and the exact scan backend.

``MetricIndex`` is the contract the engine (serve/engine.py) programs
against — build once, answer ``topk`` forever, expose ``size`` /
``n_shards`` for stats and ``version`` for cache invalidation. Two
implementations ship:

  * ``ExactIndex`` (this module) — scans every pre-projected gallery row;
    exact by construction. O(M*k/P) per query.
  * ``IVFIndex`` (serve/ivf.py) — cluster-pruned approximate scan that
    visits only the ``nprobe`` nearest gallery segments. Exact when
    ``nprobe == n_clusters``.

Both compose serve/scan.py for the shared substrate: query projection,
"gallery"-axis row sharding, and the shard_map local-topk/global-merge
skeleton that keeps sharded answers identical to single-device ones.

Index build amortizes the learned metric once (``gp = G @ L^T`` plus row
norms; kernels/metric_topk.project_gallery), after which every query costs
O(d*k + M*k/P) instead of O(M*d*k). Gallery rows shard across the worker
mesh via the logical ``"gallery"`` axis (sharding/partition.py maps it to
the (pod, data) axes); the metric factor L is replicated.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.kernels.metric_topk import (metric_sqdist_factored, metric_topk,
                                       metric_topk_xla, project_gallery)
from repro.serve import scan


@runtime_checkable
class MetricIndex(Protocol):
    """What the serving engine needs from any retrieval index backend.

    Implementations additionally provide a ``build(L, gallery, ...)``
    classmethod constructor; it is not part of the runtime-checked
    protocol because its signature is backend-specific.
    """

    version: int        # bumped on gallery mutation -> engine cache flush

    @property
    def size(self) -> int: ...          # number of real gallery rows

    @property
    def n_shards(self) -> int: ...      # mesh shards the rows live on

    def topk(self, queries, k_top: int, backend: str = "xla"):
        """(dists (Nq, k_top) ascending, global row ids (Nq, k_top)).

        ``queries`` are raw (Nq, d) vectors; implementations project
        them through L internally. Distances are squared metric
        distances; approximate backends may accept extra keywords
        (``nprobe``, ``rerank``) and mark unservable slots with id -1.
        """
        ...


@dataclasses.dataclass(eq=False)
class ExactIndex:
    """Immutable exact retrieval index over a pre-projected gallery.

    Invariants: ``gp`` holds ``gallery @ L^T`` and ``gn`` its row norms
    (never recomputed after build); answers are exact for the stored
    rows, deterministic across backends and shardings (equal distances
    tie toward the smaller row id); ``version`` only changes when a
    wrapper (MutableIndex / snapshot load) assigns it — this class never
    mutates itself.
    """

    L: jax.Array                    # (k, d) replicated metric factor
    gp: jax.Array                   # (M, k) projected gallery rows
    gn: jax.Array                   # (M,) row norms of gp
    mesh: Optional[jax.sharding.Mesh] = None
    axes: Tuple[str, ...] = ()      # mesh axes the rows are sharded over
    version: int = 0
    # per-instance k_top -> jitted sharded query fn (an lru_cache here would
    # pin the whole index in a class-level cache past its lifetime)
    _sharded_fns: dict = dataclasses.field(default_factory=dict, repr=False)

    @classmethod
    def build(cls, L, gallery, mesh=None, rules=None) -> "ExactIndex":
        """Project the gallery through L once and (optionally) shard it.

        Args:
          L: (k, d) metric factor (replicated across the mesh).
          gallery: (M, d) raw gallery rows.
          mesh / rules: optional jax Mesh + partition rules; when given,
            rows shard over the logical "gallery" axis (M must divide by
            the shard count — scan.gallery_axes checks).

        Returns a ready-to-query index (the one-time O(M*d*k) cost).
        """
        gp, gn = project_gallery(L, gallery)
        return cls.from_projected(L, gp, gn, mesh=mesh, rules=rules)

    @classmethod
    def from_projected(cls, L, gp, gn, mesh=None, rules=None) -> "ExactIndex":
        """Construct from already-projected rows (gp (M,k), gn (M,)).

        The mutation/snapshot layer (serve/mutable.py, serve/snapshot.py)
        enters here: compaction folds delta rows and snapshot load restores
        segments without ever re-projecting the gallery through L.
        """
        scan.check_metric_factor(L)
        gp = jnp.asarray(gp, jnp.float32)
        if gp.shape[1] != jnp.shape(L)[0]:
            raise ValueError(
                f"projected rows have dim {gp.shape[1]} but L is "
                f"{tuple(jnp.shape(L))}; gp must be sized d_out")
        gn = jnp.asarray(gn, jnp.float32)
        axes: Tuple[str, ...] = ()
        if mesh is not None:
            axes = scan.gallery_axes(mesh, gp.shape[0], rules)
        if axes:
            gp = scan.put_row_sharded(mesh, axes, gp)
            gn = scan.put_row_sharded(mesh, axes, gn)
            L = scan.put_replicated(mesh, L)
        return cls(L=jnp.asarray(L), gp=gp, gn=gn, mesh=mesh, axes=axes)

    @property
    def size(self) -> int:
        """Number of (real) gallery rows."""
        return self.gp.shape[0]

    @property
    def n_shards(self) -> int:
        """Mesh shards the rows live on (1 when unsharded)."""
        return scan.n_shards(self.mesh, self.axes)

    def topk(self, queries, k_top: int, backend: str = "xla"):
        """Exact k nearest gallery rows per query.

        Args:
          queries: (Nq, d) raw queries (projected through L here).
          k_top: neighbors per query (1 <= k_top <= size).
          backend: "xla" (factored fast path; the only sharded option)
            or "pallas" (fused kernel, single-device; interpret
            off-TPU).

        Returns (dists (Nq, k_top) f32 ascending, global row indices
        (Nq, k_top) int32); equal distances tie toward the smaller id.
        """
        if k_top > self.size:
            raise ValueError(f"k_top={k_top} > gallery size {self.size}")
        if self.n_shards > 1:
            if backend != "xla":
                raise NotImplementedError(
                    "sharded index only supports the xla backend")
            return self._topk_sharded(k_top)(queries)
        if backend == "pallas":
            return metric_topk(self.L, queries, self.gp, self.gn,
                               k_top=k_top)
        return metric_topk_xla(self.L, queries, self.gp, self.gn, k_top)

    def _topk_sharded(self, k_top: int):
        fn = self._sharded_fns.get(k_top)
        if fn is None:
            fn = self._sharded_fns[k_top] = self._build_topk_sharded(k_top)
        return fn

    def _build_topk_sharded(self, k_top: int):
        rows_local = self.size // self.n_shards
        kk = min(k_top, rows_local)     # per-shard candidates => exact merge

        def local_candidates(shard, qp, extras, locals_):
            gp_loc, gn_loc = locals_
            d = metric_sqdist_factored(qp, gp_loc, gn_loc)
            ids = shard * gp_loc.shape[0] + jnp.arange(gp_loc.shape[0],
                                                       dtype=jnp.int32)
            # contiguous row scan: candidate position order == global-id
            # order, so the cheap positional tie-break is already exact
            return scan.local_topk(d, jnp.broadcast_to(ids, d.shape), kk)

        inner = scan.build_sharded_topk(self.mesh, self.axes,
                                        (self.gp, self.gn),
                                        local_candidates, k_top)

        @jax.jit
        def run(queries):
            return inner(scan.project_queries(self.L, queries))

        return run


# Back-compat: PR 1 shipped the exact backend under this name.
GalleryIndex = ExactIndex
