"""Retrieval engine: bucketed, jitted, cached query execution over an index.

The engine owns the serving concerns the index should not know about:

  * **batch bucketing** — incoming batches pad up to a small set of
    power-of-two bucket sizes so jit compiles once per bucket instead of
    once per distinct batch size (pad queries are sliced off the result);
  * **backend choice** — factored XLA path (default, sharded-capable) or
    the fused Pallas kernel (kernels/metric_topk; ExactIndex only);
  * **hot-query cache** — a bounded LRU keyed by (query bytes, k). Repeat
    queries (think: trending items, retried requests) skip the device
    entirely when every row of a batch hits. ``index.version`` is the
    invalidation hook: any bump (gallery mutation, index swap-in) flushes
    the cache before the next lookup;
  * **counters** — requests / queries / wall-clock / cache hit-miss for
    QPS reporting via ``stats()``.

Works against any MetricIndex backend (serve/index.py exact scan,
serve/ivf.py cluster-pruned, serve/pq.py product-quantized, and
serve/mutable.py wrapping any of them).
"""

from __future__ import annotations

import collections
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.index import MetricIndex

DEFAULT_BUCKETS = (8, 32, 128, 512)
DEFAULT_CACHE = 1024


class RetrievalEngine:
    """Query executor over a MetricIndex: bucketing + caching + counters.

    One engine serves one index (swap ``engine.index`` to repoint it; the
    cache notices the identity change and flushes). Thread-safety: calls
    are expected from a single worker thread — the MicroBatcher front
    door provides exactly that.
    """

    def __init__(self, index: MetricIndex, k_top: int = 10,
                 backend: str = "xla",
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 cache_size: int = DEFAULT_CACHE):
        """Args:
          index: any MetricIndex backend (Exact / IVF / IVFPQ / Mutable).
          k_top: default neighbors per query (>= 1; per-call override in
            ``search``).
          backend: "xla" (default; the only option for IVF/IVFPQ/sharded)
            or "pallas" (fused kernel, single-device ExactIndex).
          buckets: ascending jit batch sizes; batches pad up to the next
            bucket (an oversized batch is served as-is, one extra
            compile).
          cache_size: hot-query LRU entries (0 disables caching).
        """
        if backend not in ("xla", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        if k_top < 1:
            raise ValueError(f"k_top must be >= 1, got {k_top}")
        self.index = index
        self.k_top = k_top
        self.backend = backend
        self.buckets = tuple(sorted(buckets))
        self.cache_size = cache_size
        # attached traffic front end (serve/scheduler.py RequestScheduler
        # sets this); stats() merges its observability block when present
        self.frontend = None
        self.n_requests = 0
        self.n_queries = 0
        self.n_device_queries = 0
        self.busy_s = 0.0
        self.cache_hits = 0
        self.cache_misses = 0
        # (query f32 bytes, k) -> (dists (k,), idxs (k,)) numpy rows
        self._cache: "collections.OrderedDict" = collections.OrderedDict()
        # identity + version: a freshly built replacement index also has
        # version 0, so version alone cannot detect an index swap-in
        self._cache_index = index
        self._cache_version = index.version

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return n    # oversized batch: serve as-is (one extra compile)

    # -- hot-query LRU -------------------------------------------------------

    def _cache_lookup(self, keys):
        """Per-row lookup, refreshing LRU recency. Hit/miss counters are
        settled by the caller: hits count only rows actually served from
        cache (i.e. the whole batch hit and the device was skipped) — a
        row that was present but recomputed anyway saved nothing."""
        if (self.index is not self._cache_index
                or self.index.version != self._cache_version):
            self.invalidate_cache()                      # invalidation hook
        rows = []
        for key in keys:
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
            rows.append(hit)
        return rows

    def _cache_store(self, keys, dists, idxs):
        if self.cache_size <= 0:
            return
        for row, key in enumerate(keys):
            # copies: the returned arrays are the caller's to mutate
            self._cache[key] = (dists[row].copy(), idxs[row].copy())
            self._cache.move_to_end(key)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    def invalidate_cache(self):
        """Manual flush (version bumps and index swaps do this lazily on
        the next search)."""
        self._cache.clear()
        self._cache_index = self.index
        self._cache_version = self.index.version

    # -- search --------------------------------------------------------------

    def search(self, queries, k_top: Optional[int] = None, **topk_kw):
        """queries (Nq, d) or a single (d,) vector. Returns
        (dists (Nq, k_top), indices (Nq, k_top)) as numpy arrays.

        Extra keyword args forward to ``index.topk`` — the degradation
        hook: the scheduler passes per-request quality knobs (``nprobe``,
        ``rerank``) here without the engine knowing their meaning. Knobs
        join the cache key, so answers computed at degraded quality are
        never served to full-quality lookups (or vice versa)."""
        # `is None`, not truthiness: `k_top or default` silently mapped an
        # explicit k_top=0 to the default instead of rejecting it
        k = self.k_top if k_top is None else k_top
        if k < 1:
            raise ValueError(f"k_top must be >= 1, got {k}")
        knobs = tuple(sorted(topk_kw.items()))
        caching = self.cache_size > 0
        # keys come from host bytes, so with the cache on, stay in numpy
        # until the hit check fails — a full hit never touches the device
        q = (np.asarray(queries, np.float32) if caching
             else jnp.asarray(queries, jnp.float32))
        single = q.ndim == 1
        if single:
            q = q[None, :]
        n = q.shape[0]
        self.n_requests += 1
        self.n_queries += n
        if n == 0:
            return (np.zeros((0, k), np.float32),
                    np.zeros((0, k), np.int32))

        keys = None
        if caching:                 # disabled cache pays no hashing
            keys = [(row.tobytes(), k, knobs) for row in q]
            cached = self._cache_lookup(keys)
            if all(c is not None for c in cached):  # full hit: skip device
                self.cache_hits += n
                dists = np.stack([c[0] for c in cached])
                idxs = np.stack([c[1] for c in cached])
                return (dists[0], idxs[0]) if single else (dists, idxs)
            self.cache_misses += n
            q = jnp.asarray(q)

        self.n_device_queries += n
        b = self._bucket(n)
        if b != n:      # pad rows are real compute but sliced from results
            q = jnp.concatenate([q, jnp.zeros((b - n, q.shape[1]), q.dtype)])

        t0 = time.perf_counter()
        dists, idxs = self.index.topk(q, k, backend=self.backend, **topk_kw)
        dists, idxs = jax.block_until_ready((dists, idxs))
        self.busy_s += time.perf_counter() - t0

        dists = np.asarray(dists[:n])
        idxs = np.asarray(idxs[:n])
        if keys is not None:
            self._cache_store(keys, dists, idxs)
        if single:
            return dists[0], idxs[0]
        return dists, idxs

    def warmup(self, ks: Optional[Sequence[int]] = None):
        """Compile every (bucket, k) combination up front so first
        requests don't pay jit. ``ks`` defaults to just the engine's
        ``k_top``; pass the non-default k values clients will request
        (each distinct k is its own compile)."""
        ks = (self.k_top,) if ks is None else tuple(ks)
        for k in ks:
            if k < 1:
                raise ValueError(f"k_top must be >= 1, got {k}")
        d = self.index.L.shape[1]
        for k in ks:
            for b in self.buckets:
                self.index.topk(jnp.zeros((b, d), jnp.float32), k,
                                backend=self.backend)

    def stats(self) -> dict:
        """Serving counters as a plain dict (safe to log/serialize).

        Always present: n_requests / n_queries / n_device_queries,
        busy_s, qps (device-side), gallery_size, n_shards, backend,
        index (class name), cache_hits / cache_misses / cache_entries.
        Backend extras appear when the index exposes them: delta_rows /
        tombstones / compactions (MutableIndex), code_bytes_per_row /
        compression_ratio (IVFPQIndex), scan_impl (IVF/IVFPQ segment-scan
        implementation knob). With a traffic front end attached
        (serve/scheduler.py), a ``frontend`` sub-dict adds per-class
        latency percentiles, queue depths, admission/rejection/expiry
        counters, and the current degradation level.
        """
        # device qps over device-served queries only: cache hits add no
        # busy time and would inflate the ratio under repeat traffic
        qps = self.n_device_queries / self.busy_s if self.busy_s > 0 else 0.0
        out = {
            "n_requests": self.n_requests,
            "n_queries": self.n_queries,
            "n_device_queries": self.n_device_queries,
            "busy_s": self.busy_s,
            "qps": qps,
            "gallery_size": self.index.size,
            "n_shards": self.index.n_shards,
            "backend": self.backend,
            "index": type(self.index).__name__,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_entries": len(self._cache),
        }
        # backend-specific extras, surfaced when the index has them:
        # mutation lifecycle counters (serve/mutable.py MutableIndex) and
        # compression figures (serve/pq.py IVFPQIndex)
        for key, attr in (("delta_rows", "delta_rows"),
                          ("tombstones", "tombstones"),
                          ("compactions", "n_compactions"),
                          ("code_bytes_per_row", "code_bytes_per_row"),
                          ("compression_ratio", "compression_ratio"),
                          ("scan_impl", "scan_impl")):
            value = getattr(self.index, attr, None)
            if value is not None:
                out[key] = value
        if self.frontend is not None:
            out["frontend"] = self.frontend.observability()
        return out
