"""Retrieval engine: bucketed, jitted, cached query execution over an index.

The engine owns the serving concerns the index should not know about:

  * **batch bucketing** — incoming batches pad up to a small set of
    power-of-two bucket sizes so jit compiles once per bucket instead of
    once per distinct batch size (pad queries are sliced off the result);
  * **backend choice** — factored XLA path (default, sharded-capable) or
    the fused Pallas kernel (kernels/metric_topk; ExactIndex only);
  * **hot-query cache** — a bounded LRU keyed by (query bytes, k). Repeat
    queries (think: trending items, retried requests) skip the device
    entirely when every row of a batch hits. ``index.version`` is the
    invalidation hook: any bump (gallery mutation, index swap-in) flushes
    the cache before the next lookup;
  * **observability** — the engine owns the stack-wide
    ``obs.MetricsRegistry`` and ``obs.Tracer``: request/query/cache
    counters, the device-path latency histogram, and per-index memory
    gauges all live on the registry, and every layer that attaches to
    the engine (scheduler, batcher, mutable index, miner, closed loop)
    records into the same instance. ``stats()`` is a backward-compatible
    *view* over the registry — same keys, same values as the old private
    counters. Counter updates are atomic under the registry lock: the
    old bare-attribute read-modify-writes lost increments when batcher
    and scheduler threads raced.

Works against any MetricIndex backend (serve/index.py exact scan,
serve/ivf.py cluster-pruned, serve/pq.py product-quantized, and
serve/mutable.py wrapping any of them).
"""

from __future__ import annotations

import collections
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import MetricsRegistry, Tracer, index_memory
from repro.obs.trace import NULL_SPAN
from repro.serve.clock import Clock, SystemClock
from repro.serve.index import MetricIndex

DEFAULT_BUCKETS = (8, 32, 128, 512)
DEFAULT_CACHE = 1024

# every component index_memory can report, so a collector can zero the
# ones the current index lacks (an index swap must not leave stale bytes)
_MEMORY_COMPONENTS = ("gallery", "codes", "centroids", "delta",
                      "host_store")


class RetrievalEngine:
    """Query executor over a MetricIndex: bucketing + caching + counters.

    One engine serves one index (swap ``engine.index`` to repoint it; the
    cache notices the identity change and flushes). Thread-safety: calls
    are expected from a single worker thread — the MicroBatcher front
    door provides exactly that — but the registry-backed counters are
    additionally safe under concurrent callers (each increment is atomic
    under the registry lock).
    """

    def __init__(self, index: MetricIndex, k_top: int = 10,
                 backend: str = "xla",
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 cache_size: int = DEFAULT_CACHE,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 clock: Optional[Clock] = None):
        """Args:
          index: any MetricIndex backend (Exact / IVF / IVFPQ / Mutable).
          k_top: default neighbors per query (>= 1; per-call override in
            ``search``).
          backend: "xla" (default; the only option for IVF/IVFPQ/sharded)
            or "pallas" (fused kernel, single-device ExactIndex).
          buckets: ascending jit batch sizes; batches pad up to the next
            bucket (an oversized batch is served as-is, one extra
            compile).
          cache_size: hot-query LRU entries (0 disables caching).
          registry: the stack's MetricsRegistry (default: a fresh one —
            pass an existing registry to merge several engines' metrics).
          tracer: the stack's Tracer (default: a fresh one with
            sample_rate 0 — tracing off until a front end raises it).
          clock: time source for busy-time/latency measurement (default
            SystemClock; FakeClock makes histogram tests exact).
        """
        if backend not in ("xla", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        if k_top < 1:
            raise ValueError(f"k_top must be >= 1, got {k_top}")
        self.index = index
        self.k_top = k_top
        self.backend = backend
        self.buckets = tuple(sorted(buckets))
        self.cache_size = cache_size
        self.clock = clock if clock is not None else SystemClock()
        # attached traffic front end (serve/scheduler.py RequestScheduler
        # sets this); stats() merges its observability block when present
        self.frontend = None
        self.registry = (registry if registry is not None
                         else MetricsRegistry(clock=self.clock))
        self.tracer = (tracer if tracer is not None
                       else Tracer(clock=self.clock, sample_rate=0.0))
        r = self.registry
        self._c_requests = r.counter(
            "engine_requests_total", "search() calls")
        self._c_queries = r.counter(
            "engine_queries_total", "query rows received")
        self._c_device_queries = r.counter(
            "engine_device_queries_total",
            "query rows that reached the device (cache misses, incl. "
            "bucket pad overhead excluded)")
        self._c_busy = r.counter(
            "engine_busy_seconds_total", "device-path wall time")
        self._c_cache_hits = r.counter(
            "engine_cache_hits_total",
            "query rows served from the hot-query LRU")
        self._c_cache_misses = r.counter(
            "engine_cache_misses_total",
            "query rows that missed the LRU")
        self._h_search = r.histogram(
            "engine_search_seconds",
            "device-path latency per searched batch")
        self._g_cache_entries = r.gauge(
            "engine_cache_entries", "hot-query LRU entries resident")
        self._g_gallery_rows = r.gauge(
            "index_gallery_rows", "rows the served index holds")
        self._g_memory = r.gauge(
            "index_memory_bytes",
            "resident bytes of the served index, by component",
            labelnames=("component",))
        r.register_collector(self._collect_gauges)
        # (query f32 bytes, k) -> (dists (k,), idxs (k,)) numpy rows
        self._cache: "collections.OrderedDict" = collections.OrderedDict()
        # identity + version: a freshly built replacement index also has
        # version 0, so version alone cannot detect an index swap-in
        self._cache_index = index
        self._cache_version = index.version
        self._adopt_index()

    def _adopt_index(self):
        """Point the index's lifecycle events (mutable compaction/swap,
        snapshot save) at this engine's registry. Re-run by the gauge
        collector so a swapped-in index is adopted too."""
        if (hasattr(self.index, "registry")
                and getattr(self.index, "registry", None) is None):
            self.index.registry = self.registry

    def _collect_gauges(self):
        """Snapshot-time gauges: LRU residency, gallery rows, and the
        per-component memory budget (ROADMAP's paper-scale accounting).
        Components the current index lacks are zeroed — an index swap
        must not leave another backend's bytes dangling."""
        self._adopt_index()
        self._g_cache_entries.set(len(self._cache))
        self._g_gallery_rows.set(self.index.size)
        mem = index_memory(self.index)
        for comp in _MEMORY_COMPONENTS:
            self._g_memory.set(mem.get(comp, 0), component=comp)

    # -- backward-compatible counter attributes ------------------------------
    # (tests and the miner read these; writes go through the registry)

    @property
    def n_requests(self) -> int:
        return int(self._c_requests.value())

    @property
    def n_queries(self) -> int:
        return int(self._c_queries.value())

    @property
    def n_device_queries(self) -> int:
        return int(self._c_device_queries.value())

    @property
    def busy_s(self) -> float:
        return self._c_busy.value()

    @property
    def cache_hits(self) -> int:
        return int(self._c_cache_hits.value())

    @property
    def cache_misses(self) -> int:
        return int(self._c_cache_misses.value())

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return n    # oversized batch: serve as-is (one extra compile)

    # -- hot-query LRU -------------------------------------------------------

    def _cache_lookup(self, keys):
        """Per-row lookup, refreshing LRU recency. Hit/miss counters are
        settled by the caller: hits count only rows actually served from
        cache (i.e. the whole batch hit and the device was skipped) — a
        row that was present but recomputed anyway saved nothing."""
        if (self.index is not self._cache_index
                or self.index.version != self._cache_version):
            self.invalidate_cache()                      # invalidation hook
        rows = []
        for key in keys:
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
            rows.append(hit)
        return rows

    def _cache_store(self, keys, dists, idxs):
        if self.cache_size <= 0:
            return
        for row, key in enumerate(keys):
            # copies: the returned arrays are the caller's to mutate
            self._cache[key] = (dists[row].copy(), idxs[row].copy())
            self._cache.move_to_end(key)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    def invalidate_cache(self):
        """Manual flush (version bumps and index swaps do this lazily on
        the next search)."""
        self._cache.clear()
        self._cache_index = self.index
        self._cache_version = self.index.version

    # -- search --------------------------------------------------------------

    def search(self, queries, k_top: Optional[int] = None, *,
               span=None, **topk_kw):
        """queries (Nq, d) or a single (d,) vector. Returns
        (dists (Nq, k_top), indices (Nq, k_top)) as numpy arrays.

        Extra keyword args forward to ``index.topk`` — the degradation
        hook: the scheduler passes per-request quality knobs (``nprobe``,
        ``rerank``) here without the engine knowing their meaning. Knobs
        join the cache key, so answers computed at degraded quality are
        never served to full-quality lookups (or vice versa).

        ``span`` (keyword-only, never forwarded to the index) is an
        obs.Span under which the engine records its internal stages —
        cache_lookup / pad / device_topk — with scan_impl, nprobe,
        rerank_depth, and batch size as attributes; front ends pass the
        sampled request's span here."""
        sp = span if span is not None else NULL_SPAN
        # `is None`, not truthiness: `k_top or default` silently mapped an
        # explicit k_top=0 to the default instead of rejecting it
        k = self.k_top if k_top is None else k_top
        if k < 1:
            raise ValueError(f"k_top must be >= 1, got {k}")
        knobs = tuple(sorted(topk_kw.items()))
        caching = self.cache_size > 0
        # keys come from host bytes, so with the cache on, stay in numpy
        # until the hit check fails — a full hit never touches the device
        q = (np.asarray(queries, np.float32) if caching
             else jnp.asarray(queries, jnp.float32))
        single = q.ndim == 1
        if single:
            q = q[None, :]
        n = q.shape[0]
        self._c_requests.inc()
        self._c_queries.inc(n)
        if n == 0:
            return (np.zeros((0, k), np.float32),
                    np.zeros((0, k), np.int32))

        keys = None
        if caching:                 # disabled cache pays no hashing
            c_sp = sp.child("cache_lookup")
            keys = [(row.tobytes(), k, knobs) for row in q]
            cached = self._cache_lookup(keys)
            if all(c is not None for c in cached):  # full hit: skip device
                self._c_cache_hits.inc(n)
                c_sp.set_attrs(hit=True, rows=n).end()
                dists = np.stack([c[0] for c in cached])
                idxs = np.stack([c[1] for c in cached])
                return (dists[0], idxs[0]) if single else (dists, idxs)
            self._c_cache_misses.inc(n)
            c_sp.set_attrs(hit=False, rows=n).end()
            q = jnp.asarray(q)

        self._c_device_queries.inc(n)
        b = self._bucket(n)
        if b != n:      # pad rows are real compute but sliced from results
            with sp.child("pad").set_attrs(rows=n, bucket=b):
                q = jnp.concatenate(
                    [q, jnp.zeros((b - n, q.shape[1]), q.dtype)])

        d_sp = sp.child("device_topk").set_attrs(
            batch=b, k=k,
            scan_impl=getattr(self.index, "scan_impl", None),
            nprobe=topk_kw.get("nprobe",
                               getattr(self.index, "nprobe", None)),
            rerank_depth=topk_kw.get("rerank",
                                     getattr(self.index, "rerank_depth",
                                             None)))
        t0 = self.clock.now()
        dists, idxs = self.index.topk(q, k, backend=self.backend, **topk_kw)
        dists, idxs = jax.block_until_ready((dists, idxs))
        dt = self.clock.now() - t0
        d_sp.end()
        self._c_busy.inc(dt)
        self._h_search.observe(dt)

        dists = np.asarray(dists[:n])
        idxs = np.asarray(idxs[:n])
        if keys is not None:
            self._cache_store(keys, dists, idxs)
        if single:
            return dists[0], idxs[0]
        return dists, idxs

    def warmup(self, ks: Optional[Sequence[int]] = None):
        """Compile every (bucket, k) combination up front so first
        requests don't pay jit. ``ks`` defaults to just the engine's
        ``k_top``; pass the non-default k values clients will request
        (each distinct k is its own compile)."""
        ks = (self.k_top,) if ks is None else tuple(ks)
        for k in ks:
            if k < 1:
                raise ValueError(f"k_top must be >= 1, got {k}")
        d = self.index.L.shape[1]
        for k in ks:
            for b in self.buckets:
                self.index.topk(jnp.zeros((b, d), jnp.float32), k,
                                backend=self.backend)

    def stats(self) -> dict:
        """Serving counters as a plain dict (safe to log/serialize) — a
        backward-compatible view over the MetricsRegistry (the registry
        snapshot is the superset; this keeps every pre-registry consumer
        working unmodified).

        Always present: n_requests / n_queries / n_device_queries,
        busy_s, qps (device-side), gallery_size, n_shards, backend,
        index (class name), cache_hits / cache_misses / cache_entries.
        Backend extras appear when the index exposes them: delta_rows /
        tombstones / compactions (MutableIndex), code_bytes_per_row /
        compression_ratio (IVFPQIndex), scan_impl (IVF/IVFPQ segment-scan
        implementation knob). With a traffic front end attached
        (serve/scheduler.py), a ``frontend`` sub-dict adds per-class
        latency percentiles, queue depths, admission/rejection/expiry
        counters, and the current degradation level.
        """
        # device qps over device-served queries only: cache hits add no
        # busy time and would inflate the ratio under repeat traffic
        busy = self.busy_s
        qps = self.n_device_queries / busy if busy > 0 else 0.0
        out = {
            "n_requests": self.n_requests,
            "n_queries": self.n_queries,
            "n_device_queries": self.n_device_queries,
            "busy_s": busy,
            "qps": qps,
            "gallery_size": self.index.size,
            "n_shards": self.index.n_shards,
            "backend": self.backend,
            "index": type(self.index).__name__,
            # the (d_out, d_in) metric-factor contract: d_out sizes every
            # projected/coded artifact, d_in is the raw feature dim
            "l_shape": list(np.shape(self.index.L)),
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_entries": len(self._cache),
        }
        # backend-specific extras, surfaced when the index has them:
        # mutation lifecycle counters (serve/mutable.py MutableIndex) and
        # compression figures (serve/pq.py IVFPQIndex)
        for key, attr in (("delta_rows", "delta_rows"),
                          ("tombstones", "tombstones"),
                          ("compactions", "n_compactions"),
                          ("code_bytes_per_row", "code_bytes_per_row"),
                          ("compression_ratio", "compression_ratio"),
                          ("scan_impl", "scan_impl")):
            value = getattr(self.index, attr, None)
            if value is not None:
                out[key] = value
        if self.frontend is not None:
            out["frontend"] = self.frontend.observability()
        return out
