"""Retrieval engine: bucketed, jitted query execution over a GalleryIndex.

The engine owns the serving concerns the index should not know about:

  * **batch bucketing** — incoming batches pad up to a small set of
    power-of-two bucket sizes so jit compiles once per bucket instead of
    once per distinct batch size (pad queries are sliced off the result);
  * **backend choice** — factored XLA path (default, sharded-capable) or
    the fused Pallas kernel (kernels/metric_topk);
  * **counters** — requests / queries / wall-clock for QPS reporting.
"""

from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.index import GalleryIndex

DEFAULT_BUCKETS = (8, 32, 128, 512)


class RetrievalEngine:
    def __init__(self, index: GalleryIndex, k_top: int = 10,
                 backend: str = "xla",
                 buckets: Sequence[int] = DEFAULT_BUCKETS):
        if backend not in ("xla", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.index = index
        self.k_top = k_top
        self.backend = backend
        self.buckets = tuple(sorted(buckets))
        self.n_requests = 0
        self.n_queries = 0
        self.busy_s = 0.0

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return n    # oversized batch: serve as-is (one extra compile)

    def search(self, queries, k_top: Optional[int] = None):
        """queries (Nq, d) or a single (d,) vector. Returns
        (dists (Nq, k_top), indices (Nq, k_top)) as numpy arrays."""
        k = k_top or self.k_top
        q = jnp.asarray(queries, jnp.float32)
        single = q.ndim == 1
        if single:
            q = q[None, :]
        n = q.shape[0]
        b = self._bucket(n)
        if b != n:      # pad rows are real compute but sliced from results
            q = jnp.concatenate([q, jnp.zeros((b - n, q.shape[1]), q.dtype)])

        t0 = time.perf_counter()
        dists, idxs = self.index.topk(q, k, backend=self.backend)
        dists, idxs = jax.block_until_ready((dists, idxs))
        self.busy_s += time.perf_counter() - t0
        self.n_requests += 1
        self.n_queries += n

        dists = np.asarray(dists[:n])
        idxs = np.asarray(idxs[:n])
        if single:
            return dists[0], idxs[0]
        return dists, idxs

    def warmup(self):
        """Compile every bucket up front so first requests don't pay jit."""
        d = self.index.L.shape[1]
        for b in self.buckets:
            self.index.topk(jnp.zeros((b, d), jnp.float32), self.k_top,
                            backend=self.backend)

    def stats(self) -> dict:
        qps = self.n_queries / self.busy_s if self.busy_s > 0 else 0.0
        return {
            "n_requests": self.n_requests,
            "n_queries": self.n_queries,
            "busy_s": self.busy_s,
            "qps": qps,
            "gallery_size": self.index.size,
            "n_shards": self.index.n_shards,
            "backend": self.backend,
        }
