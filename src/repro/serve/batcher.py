"""Request micro-batcher: coalesce single-query requests into engine batches.

The serving front door. Callers submit one query vector at a time and get a
``concurrent.futures.Future`` back; a background thread drains the queue,
stacks up to ``max_batch`` queries (waiting at most ``max_wait_ms`` past
the first request so a lone query is never stranded), runs one engine
search, and distributes per-row results to the waiting futures.

Batching here is what turns the engine's bucketed jit batches into high
device utilization under many concurrent low-latency clients — the same
shape as the async parameter-server's request queue on the training side.

All timing (the coalescing wait) goes through an injectable ``Clock``
(serve/clock.py): production uses ``SystemClock``; tests drive the wait
deterministically with ``FakeClock.advance`` instead of sleeping. For
traffic shaping *above* this layer — admission control, priorities,
deadlines, adaptive degradation — see serve/scheduler.py, which forms its
own deadline-aware batches on the same clock contract.
"""

from __future__ import annotations

import collections
import threading
from concurrent.futures import Future
from typing import Optional

import numpy as np

from repro.serve.clock import Clock, SystemClock
from repro.serve.engine import RetrievalEngine


class MicroBatcher:
    def __init__(self, engine: RetrievalEngine, max_batch: int = 64,
                 max_wait_ms: float = 2.0, clock: Optional[Clock] = None):
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.clock = clock if clock is not None else SystemClock()
        self._pending: collections.deque = collections.deque()
        self._closed = False
        # one condition guards the deque and the closed flag: every submit
        # lands before close() flips the flag, so no request can arrive
        # after the worker's exit signal
        self._cond = threading.Condition()
        self.n_batches = 0
        # bounded: a long-lived server would otherwise grow this forever
        self.batch_sizes: collections.deque = collections.deque(maxlen=4096)
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, query, k_top: Optional[int] = None) -> Future:
        """Enqueue one (d,) query. Future resolves to (dists, indices),
        each (k_top,). k_top defaults to the engine's and must not exceed
        it (results are sliced from one shared engine batch)."""
        # `is None`, not truthiness: `k_top or default` silently mapped an
        # explicit k_top=0 to the default instead of rejecting it
        k = self.engine.k_top if k_top is None else k_top
        if k < 1:
            raise ValueError(f"k_top must be >= 1, got {k}")
        if k > self.engine.k_top:
            raise ValueError(f"k_top={k} > engine k_top={self.engine.k_top}")
        q = np.asarray(query, np.float32)
        d = self.engine.index.L.shape[1]
        if q.shape != (d,):     # reject here, not in the shared worker
            raise ValueError(f"query shape {q.shape} != ({d},)")
        fut: Future = Future()
        with self._cond:
            if self._closed:
                raise RuntimeError("batcher is closed")
            self._pending.append((q, k, fut))
            self._cond.notify_all()
        return fut

    def close(self, timeout: float = 10.0) -> bool:
        """Drain outstanding requests and stop the worker thread.

        Returns True when the worker exited within ``timeout`` (real)
        seconds, False when it is still alive — the join timing out used
        to pass silently, leaving a live thread with no signal to the
        caller. Idempotent; a False return may be retried.
        """
        with self._cond:
            self._closed = True
            self._cond.notify_all()         # wake the worker
        self._thread.join(timeout=timeout)
        return not self._thread.is_alive()

    # -- worker ------------------------------------------------------------

    def _collect(self):
        """Block for the first request, then gather more until the batch is
        full or the first request has waited max_wait_s (clock time)."""
        with self._cond:
            while not self._pending:
                if self._closed:
                    return None
                self.clock.wait_on(self._cond, None)
            batch = [self._pending.popleft()]
            deadline = self.clock.now() + self.max_wait_s
            while len(batch) < self.max_batch:
                if self._pending:
                    batch.append(self._pending.popleft())
                    continue
                if self._closed:            # nothing more is coming
                    break
                remaining = deadline - self.clock.now()
                if remaining <= 0:
                    break
                self.clock.wait_on(self._cond, remaining)
        return batch

    def _loop(self):
        while True:
            batch = self._collect()
            if batch:
                self._run_batch(batch)
            with self._cond:
                if self._closed and not self._pending:
                    return

    def _run_batch(self, batch):
        # set_running_or_notify_cancel guards every resolution: a rider the
        # client cancelled while pending is skipped (resolving it would
        # raise InvalidStateError and kill the worker thread)
        try:
            qs = np.stack([q for q, _, _ in batch])
            dists, idxs = self.engine.search(qs)
        except Exception as e:          # fail every rider, keep serving
            for _, _, fut in batch:
                if fut.set_running_or_notify_cancel():
                    fut.set_exception(e)
            return
        self.n_batches += 1
        self.batch_sizes.append(len(batch))
        for row, (_, k, fut) in enumerate(batch):
            if fut.set_running_or_notify_cancel():
                fut.set_result((dists[row, :k], idxs[row, :k]))
