"""Request micro-batcher: coalesce single-query requests into engine batches.

The serving front door. Callers submit one query vector at a time and get a
``concurrent.futures.Future`` back; a background thread drains the queue,
stacks up to ``max_batch`` queries (waiting at most ``max_wait_ms`` past
the first request so a lone query is never stranded), runs one engine
search, and distributes per-row results to the waiting futures.

Batching here is what turns the engine's bucketed jit batches into high
device utilization under many concurrent low-latency clients — the same
shape as the async parameter-server's request queue on the training side.
"""

from __future__ import annotations

import collections
import queue
import threading
import time
from concurrent.futures import Future
from typing import Optional

import numpy as np

from repro.serve.engine import RetrievalEngine


class MicroBatcher:
    def __init__(self, engine: RetrievalEngine, max_batch: int = 64,
                 max_wait_ms: float = 2.0):
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self._queue: "queue.Queue" = queue.Queue()
        self._closed = False
        # orders every submit put before close()'s sentinel put, so no
        # request can land in the queue after the worker's exit signal
        self._lock = threading.Lock()
        self.n_batches = 0
        # bounded: a long-lived server would otherwise grow this forever
        self.batch_sizes: collections.deque = collections.deque(maxlen=4096)
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, query, k_top: Optional[int] = None) -> Future:
        """Enqueue one (d,) query. Future resolves to (dists, indices),
        each (k_top,). k_top defaults to the engine's and must not exceed
        it (results are sliced from one shared engine batch)."""
        # `is None`, not truthiness: `k_top or default` silently mapped an
        # explicit k_top=0 to the default instead of rejecting it
        k = self.engine.k_top if k_top is None else k_top
        if k < 1:
            raise ValueError(f"k_top must be >= 1, got {k}")
        if k > self.engine.k_top:
            raise ValueError(f"k_top={k} > engine k_top={self.engine.k_top}")
        q = np.asarray(query, np.float32)
        d = self.engine.index.L.shape[1]
        if q.shape != (d,):     # reject here, not in the shared worker
            raise ValueError(f"query shape {q.shape} != ({d},)")
        fut: Future = Future()
        with self._lock:
            if self._closed:
                raise RuntimeError("batcher is closed")
            self._queue.put((q, k, fut))
        return fut

    def close(self, timeout: float = 10.0):
        """Drain outstanding requests and stop the worker thread."""
        with self._lock:
            self._closed = True
            self._queue.put(None)           # wake the worker
        self._thread.join(timeout=timeout)

    # -- worker ------------------------------------------------------------

    def _collect(self):
        """Block for the first request, then gather more until the batch is
        full or the first request has waited max_wait_s."""
        first = self._queue.get()
        if first is None:
            return None
        batch = [first]
        deadline = time.perf_counter() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                item = self._queue.get(timeout=remaining)
            except queue.Empty:
                break
            if item is None:
                break
            batch.append(item)
        return batch

    def _loop(self):
        while True:
            batch = self._collect()
            if batch:
                self._run_batch(batch)
            if self._closed and self._queue.empty():
                return

    def _run_batch(self, batch):
        # set_running_or_notify_cancel guards every resolution: a rider the
        # client cancelled while pending is skipped (resolving it would
        # raise InvalidStateError and kill the worker thread)
        try:
            qs = np.stack([q for q, _, _ in batch])
            dists, idxs = self.engine.search(qs)
        except Exception as e:          # fail every rider, keep serving
            for _, _, fut in batch:
                if fut.set_running_or_notify_cancel():
                    fut.set_exception(e)
            return
        self.n_batches += 1
        self.batch_sizes.append(len(batch))
        for row, (_, k, fut) in enumerate(batch):
            if fut.set_running_or_notify_cancel():
                fut.set_result((dists[row, :k], idxs[row, :k]))
