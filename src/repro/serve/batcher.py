"""Request micro-batcher: coalesce single-query requests into engine batches.

The serving front door. Callers submit one query vector at a time and get a
``concurrent.futures.Future`` back; a background thread drains the queue,
stacks up to ``max_batch`` queries (waiting at most ``max_wait_ms`` past
the first request so a lone query is never stranded), runs one engine
search, and distributes per-row results to the waiting futures.

Batching here is what turns the engine's bucketed jit batches into high
device utilization under many concurrent low-latency clients — the same
shape as the async parameter-server's request queue on the training side.

All timing (the coalescing wait) goes through an injectable ``Clock``
(serve/clock.py): production uses ``SystemClock``; tests drive the wait
deterministically with ``FakeClock.advance`` instead of sleeping. For
traffic shaping *above* this layer — admission control, priorities,
deadlines, adaptive degradation — see serve/scheduler.py, which forms its
own deadline-aware batches on the same clock contract.

Observability: batch counters live on the engine's ``MetricsRegistry``
(``batcher_batches_total`` / ``batcher_batch_size``), and when the
engine's ``Tracer`` is sampling, a trace minted at ``submit`` carries
queue-wait and coalesce spans into ``engine.search``.
"""

from __future__ import annotations

import collections
import threading
from concurrent.futures import Future
from typing import Optional

import numpy as np

from repro.obs import MetricsRegistry
from repro.serve.clock import Clock, SystemClock
from repro.serve.engine import RetrievalEngine


class MicroBatcher:
    def __init__(self, engine: RetrievalEngine, max_batch: int = 64,
                 max_wait_ms: float = 2.0, clock: Optional[Clock] = None):
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.clock = clock if clock is not None else SystemClock()
        # record into the engine's registry/tracer so the whole stack
        # shares one; a bare test double gets a private registry
        reg = getattr(engine, "registry", None)
        self.registry = (reg if reg is not None
                         else MetricsRegistry(clock=self.clock))
        self.tracer = getattr(engine, "tracer", None)
        self._c_batches = self.registry.counter(
            "batcher_batches_total", "micro-batches sent to the engine")
        self._h_batch = self.registry.histogram(
            "batcher_batch_size", "coalesced requests per micro-batch",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256))
        self._pending: collections.deque = collections.deque()
        self._closed = False
        # one condition guards the deque and the closed flag: every submit
        # lands before close() flips the flag, so no request can arrive
        # after the worker's exit signal
        self._cond = threading.Condition()
        # bounded: a long-lived server would otherwise grow this forever
        self.batch_sizes: collections.deque = collections.deque(maxlen=4096)
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    @property
    def n_batches(self) -> int:
        return int(self._c_batches.value())

    def submit(self, query, k_top: Optional[int] = None) -> Future:
        """Enqueue one (d,) query. Future resolves to (dists, indices),
        each (k_top,). k_top defaults to the engine's and must not exceed
        it (results are sliced from one shared engine batch)."""
        # `is None`, not truthiness: `k_top or default` silently mapped an
        # explicit k_top=0 to the default instead of rejecting it
        k = self.engine.k_top if k_top is None else k_top
        if k < 1:
            raise ValueError(f"k_top must be >= 1, got {k}")
        if k > self.engine.k_top:
            raise ValueError(f"k_top={k} > engine k_top={self.engine.k_top}")
        q = np.asarray(query, np.float32)
        d = self.engine.index.L.shape[1]
        if q.shape != (d,):     # reject here, not in the shared worker
            raise ValueError(f"query shape {q.shape} != ({d},)")
        fut: Future = Future()
        trace = q_span = None
        if self.tracer is not None and self.tracer.sample_rate > 0:
            trace = self.tracer.start_trace("request")
            trace.root.set_attrs(k=k)
            q_span = trace.span("queue")
        with self._cond:
            if self._closed:
                raise RuntimeError("batcher is closed")
            self._pending.append((q, k, fut, trace, q_span))
            self._cond.notify_all()
        return fut

    def close(self, timeout: float = 10.0) -> bool:
        """Drain outstanding requests and stop the worker thread.

        Returns True when the worker exited within ``timeout`` (real)
        seconds, False when it is still alive — the join timing out used
        to pass silently, leaving a live thread with no signal to the
        caller. Idempotent; a False return may be retried.
        """
        with self._cond:
            self._closed = True
            self._cond.notify_all()         # wake the worker
        self._thread.join(timeout=timeout)
        return not self._thread.is_alive()

    # -- worker ------------------------------------------------------------

    def _collect(self):
        """Block for the first request, then gather more until the batch is
        full or the first request has waited max_wait_s (clock time)."""
        with self._cond:
            while not self._pending:
                if self._closed:
                    return None
                self.clock.wait_on(self._cond, None)
            batch = [self._pending.popleft()]
            deadline = self.clock.now() + self.max_wait_s
            while len(batch) < self.max_batch:
                if self._pending:
                    batch.append(self._pending.popleft())
                    continue
                if self._closed:            # nothing more is coming
                    break
                remaining = deadline - self.clock.now()
                if remaining <= 0:
                    break
                self.clock.wait_on(self._cond, remaining)
        return batch

    def _loop(self):
        while True:
            batch = self._collect()
            if batch:
                self._run_batch(batch)
            with self._cond:
                if self._closed and not self._pending:
                    return

    def _finish_traces(self, batch, outcome: str) -> None:
        for _, _, _, trace, q_span in batch:
            if trace is None:
                continue
            trace.root.set_attrs(outcome=outcome)
            self.tracer.finish(trace)

    def _run_batch(self, batch):
        # dequeued: queue wait is over for every rider (end is idempotent)
        for _, _, _, _, q_span in batch:
            if q_span is not None:
                q_span.end()
        # one batch serves many requests but the engine takes one span:
        # the first *sampled* rider carries the coalesce + engine detail
        carrier = next((tr for _, _, _, tr, _ in batch
                        if tr is not None and tr.sampled), None)
        c_span = e_span = None
        if carrier is not None:
            c_span = carrier.span("coalesce").set_attrs(size=len(batch))
            e_span = carrier.span("engine", parent=c_span)
        # set_running_or_notify_cancel guards every resolution: a rider the
        # client cancelled while pending is skipped (resolving it would
        # raise InvalidStateError and kill the worker thread)
        try:
            qs = np.stack([q for q, _, _, _, _ in batch])
            if e_span is not None:
                dists, idxs = self.engine.search(qs, span=e_span)
            else:
                dists, idxs = self.engine.search(qs)
        except Exception as e:          # fail every rider, keep serving
            if c_span is not None:
                e_span.set_attrs(error=repr(e)).end()
                c_span.end()
            for _, _, fut, _, _ in batch:
                if fut.set_running_or_notify_cancel():
                    fut.set_exception(e)
            self._finish_traces(batch, "failed")
            return
        if c_span is not None:
            e_span.end()
            c_span.end()
        self._c_batches.inc()
        self._h_batch.observe(len(batch))
        self.batch_sizes.append(len(batch))
        for row, (_, k, fut, _, _) in enumerate(batch):
            if fut.set_running_or_notify_cancel():
                fut.set_result((dists[row, :k], idxs[row, :k]))
        self._finish_traces(batch, "completed")
