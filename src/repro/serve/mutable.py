"""Mutable gallery index: streaming upserts/deletes over a frozen base.

``ExactIndex`` and ``IVFIndex`` are build-once: their device layouts are
immutable by design (static shapes keep the jitted query paths hot). A
production gallery is not — rows arrive and expire continuously, and the
async PS trainer keeps producing fresh L factors. ``MutableIndex`` closes
that gap with the classic LSM split:

  base      any frozen MetricIndex (Exact, IVF, or IVFPQ), untouched by
            mutations;
  delta     an append-only buffer of *pre-projected* new rows, scanned
            exactly (it stays small between compactions);
  tombstones  dead slots — deleted rows, and rows superseded by an upsert
            of the same external id. Masked at merge time, never eagerly
            rewritten into device arrays.

External ids are stable across every mutation and compaction: the id->slot
map tracks where each id currently lives ("base" slot or "delta" slot),
and ``topk`` returns external ids, not layout positions. Every mutation
*batch* bumps ``version``, so the engine's hot-query LRU invalidates for
free (serve/engine.py keys its flush on ``index.version``).

Query path: oversample the base past its dead slots (k_top + #dead base
slots, clamped to the base's candidate pool), scan the delta buffer with
the same factored distance the exact path uses (scan.py's deterministic
(dist, id) select), then lexicographically merge (distance, external id)
on the host while masking tombstones. No rebuild ever happens on the
query path.

Compaction folds the delta into the base and drops tombstones:

  exact base  live base rows + live delta rows concatenate (already
              projected) in ascending-external-id order and a fresh
              ExactIndex wraps them — no re-projection, no re-clustering.
  IVF base    delta rows land in their nearest centroid's capacity
              headroom (the ``cap_factor`` slack from the build, plus
              slots freed by tombstones); if the live delta outgrows the
              total free capacity, the fold *spills* and triggers a full
              rebuild (fresh k-means over all live projected rows).
  IVFPQ base  same headroom fold, with each folded row *encoded* against
              the existing residual codebooks (delta rows are served
              full-precision until then); a spill-triggered rebuild
              re-trains k-means and the codebooks together.

``compact()`` can be called explicitly; ``auto_compact_delta`` /
``auto_compact_dead`` thresholds (fractions of the base size) trigger it
from the mutation path.

Metric hot-swap (``swap_metric``): with ``retain_raw=True`` the index
keeps the raw d-dim rows, so a fresh L from the trainer re-projects the
whole live gallery in blocks, rebuilds the base off to the side, and
swaps it in atomically — queries in flight keep hitting the old base
until the new one is fully built. This closes the trainer -> server loop.

Single-host only for now: wrapping a sharded base raises (the multi-host
gallery item on the ROADMAP covers that axis). Mutation calls (upsert /
delete / compact / swap_metric) must be serialized with in-flight topk
calls by the caller — the engine/batcher stack already issues queries
from one worker thread, and mutations belong on the control plane.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.metric_topk import metric_sqdist_factored, project_gallery
from repro.kernels.metric_topk.kernel import BIG
from repro.serve import scan
from repro.serve.index import ExactIndex
from repro.serve.ivf import IVFIndex
from repro.serve.pq import IVFPQIndex, _t_term

_DELTA_MIN_CAP = 256    # device delta buffer floor; grows by doubling so
                        # the jitted delta scan retraces O(log growth) times


class MutableIndex:
    """MetricIndex wrapper adding upsert/delete/compact/snapshot/hot-swap."""

    def __init__(self, base, L, *, ids=None, raw=None, base_kwargs=None,
                 auto_compact_delta: float = 0.5,
                 auto_compact_dead: float = 0.25):
        if base.n_shards > 1:
            raise NotImplementedError(
                "MutableIndex wraps single-shard bases only (multi-host "
                "gallery mutation is a ROADMAP item)")
        if not isinstance(base, (ExactIndex, IVFIndex, IVFPQIndex)):
            raise TypeError(f"unsupported base index {type(base).__name__}")
        if isinstance(base, IVFPQIndex) and base.rerank_depth < 1:
            # the (distance, id) merge against the exact delta scan is
            # only sound when the base returns exact distances too —
            # raw ADC scores would mis-order against delta candidates
            raise ValueError(
                "MutableIndex over an IVFPQ base requires rerank_depth "
                ">= 1 (exact base distances for the delta merge)")
        M = base.size
        self.base = base
        self.L = jnp.asarray(scan.check_metric_factor(L), jnp.float32)
        self.base_ids = (np.arange(M, dtype=np.int64) if ids is None
                         else np.asarray(ids, np.int64).copy())
        if self.base_ids.shape != (M,):
            raise ValueError(f"ids shape {self.base_ids.shape} != ({M},)")
        if len(np.unique(self.base_ids)) != M:
            raise ValueError("external ids must be unique")
        self.dead_base = np.zeros(M, bool)
        k = self.L.shape[0]
        self.delta_gp = np.zeros((0, k), np.float32)
        self.delta_gn = np.zeros((0,), np.float32)
        self.delta_ids = np.zeros((0,), np.int64)
        self.dead_delta = np.zeros((0,), bool)
        self.raw_base: Optional[np.ndarray] = None
        self.raw_delta: Optional[np.ndarray] = None
        if raw is not None:
            raw = np.asarray(raw, np.float32)
            if raw.shape[0] != M:
                raise ValueError(f"raw rows {raw.shape[0]} != base size {M}")
            self.raw_base = raw.copy()
            self.raw_delta = np.zeros((0, raw.shape[1]), np.float32)
        self._loc = {int(e): ("base", i)
                     for i, e in enumerate(self.base_ids)}
        self._next_id = int(self.base_ids.max()) + 1 if M else 0
        self.auto_compact_delta = auto_compact_delta
        self.auto_compact_dead = auto_compact_dead
        self._base_kwargs = dict(base_kwargs or {})
        self.version = base.version
        self.n_upserts = 0
        self.n_deletes = 0
        self.n_compactions = 0
        self.n_rebuilds = 0          # compactions that fell back to k-means
        self.n_swaps = 0
        # obs hook: the engine points this at its MetricsRegistry on
        # adoption; lifecycle transitions (compaction, spill rebuild,
        # metric swap) then land as structured events + counters
        self.registry = None
        self._delta_dev = None       # (version, cap, gp, gn, slot ids)
        self._delta_fns: dict = {}   # (cap, kk) -> jitted delta scan

    # -- construction --------------------------------------------------------

    @classmethod
    def build(cls, L, gallery, *, base: str = "exact", ids=None,
              retain_raw: bool = False, auto_compact_delta: float = 0.5,
              auto_compact_dead: float = 0.25, **base_kwargs):
        """Build the base index and wrap it.

        ``base``: "exact", "ivf", or "ivfpq" (``base_kwargs`` forward to
        the base build — n_clusters, nprobe, cap_factor, n_subspaces,
        ...). ``ids`` assigns external ids to the initial rows (default
        0..M-1, which keeps the deterministic smallest-id tie-break
        aligned with the base's positional one). ``retain_raw=True``
        keeps the raw feature rows so ``swap_metric`` can re-project
        under a fresh L.

        An IVFPQ base serves its frozen rows from uint8 codes while the
        delta buffer stays full-precision and exact; compaction encodes
        folded rows with the existing codebooks (see ``compact``).
        """
        gallery = np.asarray(gallery, np.float32)
        if base == "exact":
            b = ExactIndex.build(L, jnp.asarray(gallery), **base_kwargs)
        elif base == "ivf":
            b = IVFIndex.build(L, jnp.asarray(gallery), **base_kwargs)
        elif base == "ivfpq":
            b = IVFPQIndex.build(L, jnp.asarray(gallery), **base_kwargs)
        else:
            raise ValueError(f"unknown base {base!r} (exact|ivf|ivfpq)")
        return cls(b, L, ids=ids, raw=gallery if retain_raw else None,
                   base_kwargs=base_kwargs,
                   auto_compact_delta=auto_compact_delta,
                   auto_compact_dead=auto_compact_dead)

    # -- MetricIndex surface -------------------------------------------------

    @property
    def size(self) -> int:
        """Live rows (upserts minus deletes); what k_top is bounded by."""
        return len(self._loc)

    @property
    def n_shards(self) -> int:
        return 1

    @property
    def delta_rows(self) -> int:
        """Live rows currently served from the delta buffer."""
        return int((~self.dead_delta).sum())

    @property
    def code_bytes_per_row(self):
        """Forwarded from an IVFPQ base (None otherwise) so engine
        stats() surfaces compression figures through the wrapper."""
        return getattr(self.base, "code_bytes_per_row", None)

    @property
    def compression_ratio(self):
        """Forwarded from an IVFPQ base (None otherwise)."""
        return getattr(self.base, "compression_ratio", None)

    @property
    def scan_impl(self):
        """Forwarded from an IVF/IVFPQ base (None for exact) so engine
        stats() reports which segment-scan implementation serves."""
        return getattr(self.base, "scan_impl", None)

    @property
    def tombstones(self) -> int:
        """Dead slots awaiting compaction (base + delta)."""
        return int(self.dead_base.sum() + self.dead_delta.sum())

    def live_ids(self) -> np.ndarray:
        """Ascending external ids of every live row ((size,) int64)."""
        return np.sort(np.fromiter(self._loc, np.int64, len(self._loc)))

    def contains(self, ext_id: int) -> bool:
        return int(ext_id) in self._loc

    def topk(self, queries, k_top: int, backend: str = "xla", **kw):
        """(dists (Nq, k_top) ascending, external ids (Nq, k_top) int64).

        Extra kwargs (e.g. ``nprobe``) forward to the base. Returns host
        numpy arrays — the merge over (base ∪ delta) \\ tombstones runs on
        the host, where int64 external ids are cheap.
        """
        if k_top < 1:
            raise ValueError(f"k_top must be >= 1, got {k_top}")
        if k_top > self.size:
            raise ValueError(f"k_top={k_top} > live gallery size "
                             f"{self.size}")
        if isinstance(self.base, IVFPQIndex) and kw.get("rerank") == 0:
            # same soundness rule the ctor enforces for rerank_depth:
            # raw ADC base distances cannot merge against the exact
            # delta scan
            raise ValueError(
                "rerank=0 is unsupported through MutableIndex (the "
                "(distance, id) delta merge needs exact base distances)")
        queries = jnp.asarray(queries, jnp.float32)
        if queries.ndim != 2:
            raise ValueError(f"queries must be (Nq, d), got "
                             f"{queries.shape}")
        parts_d, parts_i = [], []

        n_dead_base = int(self.dead_base.sum())
        k_base = min(self.base.size, k_top + n_dead_base)
        pool = self._base_pool(kw)
        if pool is not None:
            k_base = min(k_base, pool)
        if k_base > 0:
            d_b, i_b = self.base.topk(queries, k_base, backend=backend,
                                      **kw)
            d_b = np.asarray(d_b, np.float32)
            i_b = np.asarray(i_b)
            valid = i_b >= 0                 # IVF under-filled probes: -1
            safe = np.where(valid, i_b, 0)
            dead = self.dead_base[safe] | ~valid
            parts_d.append(np.where(dead, np.inf, d_b))
            parts_i.append(np.where(dead, np.int64(-1),
                                    self.base_ids[safe]))

        if len(self.delta_ids):
            kk = min(k_top, self._delta_cap())
            d_d, s_d = self._delta_topk(queries, kk)
            d_d = np.asarray(d_d, np.float32)
            s_d = np.asarray(s_d)
            valid = s_d >= 0                 # pad / tombstoned slots
            safe = np.where(valid, s_d, 0)
            parts_d.append(np.where(valid, d_d, np.inf))
            parts_i.append(np.where(valid, self.delta_ids[safe],
                                    np.int64(-1)))

        dists = np.concatenate(parts_d, axis=1)
        ids = np.concatenate(parts_i, axis=1)
        order = np.lexsort((ids, dists), axis=-1)[:, :k_top]
        return (np.take_along_axis(dists, order, 1),
                np.take_along_axis(ids, order, 1))

    def _base_pool(self, kw) -> Optional[int]:
        """Candidate pool the base can actually return (IVF/IVFPQ:
        nprobe*cap). Oversampling past it would make the base raise;
        clamping instead costs only the (already approximate) recall of
        dead-slot oversamples."""
        if isinstance(self.base, (IVFIndex, IVFPQIndex)):
            np_ = kw.get("nprobe")
            if np_ is not None and np_ < 1:
                # reject here: a 0 pool would silently skip the base
                # scan before the base's own nprobe validation can fire
                raise ValueError(f"nprobe must be >= 1, got {np_}")
            np_ = self.base.nprobe if np_ is None else np_
            return min(np_, self.base.n_clusters) * self.base.cap
        return None

    # -- delta scan ----------------------------------------------------------

    def _delta_cap(self) -> int:
        n = len(self.delta_ids)
        if n <= _DELTA_MIN_CAP:
            return _DELTA_MIN_CAP
        return 1 << (n - 1).bit_length()

    def _delta_device(self):
        """Padded device mirror of the delta buffer, rebuilt per version.

        Tombstoned and pad slots carry gn = +BIG / slot id = -1 sentinels
        (same convention as the IVF segments), so they can only surface
        when fewer than kk live delta rows exist — and are masked then.
        """
        if self._delta_dev is not None and self._delta_dev[0] == self.version:
            return self._delta_dev
        cap = self._delta_cap()
        n = len(self.delta_ids)
        k = self.delta_gp.shape[1]
        gp = np.zeros((cap, k), np.float32)
        gn = np.full((cap,), BIG, np.float32)
        slots = np.full((cap,), -1, np.int32)
        gp[:n] = self.delta_gp
        gn[:n] = np.where(self.dead_delta, BIG, self.delta_gn)
        slots[:n] = np.where(self.dead_delta, -1,
                             np.arange(n, dtype=np.int32))
        self._delta_dev = (self.version, cap, jnp.asarray(gp),
                           jnp.asarray(gn), jnp.asarray(slots))
        return self._delta_dev

    def _delta_topk(self, queries, kk: int):
        _, cap, gp, gn, slots = self._delta_device()
        fn = self._delta_fns.get((cap, kk))
        if fn is None:
            @jax.jit
            def fn(q, L, gp, gn, slots):
                qp = scan.project_queries(L, q)
                d = metric_sqdist_factored(qp, gp, gn)
                return scan.topk_by_distance(
                    d, jnp.broadcast_to(slots, d.shape), kk)
            self._delta_fns[(cap, kk)] = fn
        return fn(queries, self.L, gp, gn, slots)

    # -- mutation ------------------------------------------------------------

    def upsert(self, rows, ids=None) -> np.ndarray:
        """Insert or replace rows; returns the external ids (n,) int64.

        ``rows`` (n, d) raw feature rows (projected through L here, once).
        ``ids=None`` auto-assigns fresh ids; an existing id tombstones its
        old slot and re-lands in the delta (last write wins, also within a
        batch). One call = one version bump = one engine cache flush.
        """
        rows = np.asarray(rows, np.float32)
        if rows.ndim == 1:
            rows = rows[None, :]
        n = rows.shape[0]
        if ids is None:
            ids = np.arange(self._next_id, self._next_id + n,
                            dtype=np.int64)
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if ids.shape != (n,):
            raise ValueError(f"ids shape {ids.shape} != ({n},)")
        if (ids < 0).any():
            raise ValueError("external ids must be >= 0 (negative ids are "
                             "sentinels)")
        if n == 0:
            return ids
        gp, gn = project_gallery(self.L, jnp.asarray(rows))
        start = len(self.delta_ids)
        self.delta_gp = np.concatenate([self.delta_gp, np.asarray(gp)])
        self.delta_gn = np.concatenate([self.delta_gn, np.asarray(gn)])
        self.delta_ids = np.concatenate([self.delta_ids, ids])
        self.dead_delta = np.concatenate([self.dead_delta,
                                          np.zeros(n, bool)])
        if self.raw_base is not None:
            self.raw_delta = np.concatenate([self.raw_delta, rows])
        for j, e in enumerate(ids.tolist()):
            old = self._loc.get(e)
            if old is not None:
                self._kill(old)
            self._loc[e] = ("delta", start + j)
        self._next_id = max(self._next_id, int(ids.max()) + 1)
        self.n_upserts += n
        self._bump()
        self._maybe_compact()
        return ids

    def delete(self, ids) -> None:
        """Tombstone rows by external id. Unknown ids raise KeyError (and
        the batch is rejected whole); one call = one version bump."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if len(np.unique(ids)) != len(ids):
            raise ValueError("duplicate ids in delete batch")
        missing = [int(e) for e in ids.tolist() if e not in self._loc]
        if missing:
            raise KeyError(f"ids not in index: {missing[:5]}"
                           f"{'...' if len(missing) > 5 else ''}")
        for e in ids.tolist():
            self._kill(self._loc.pop(int(e)))
        self.n_deletes += len(ids)
        self._bump()
        self._maybe_compact()

    def _kill(self, loc):
        kind, i = loc
        if kind == "base":
            self.dead_base[i] = True
        else:
            self.dead_delta[i] = True

    def _bump(self):
        self.version += 1           # engine LRU flushes on the next search

    def _maybe_compact(self):
        ref = max(self.base.size, 1)
        if ((self.auto_compact_delta
             and self.delta_rows > self.auto_compact_delta * ref)
                or (self.auto_compact_dead
                    and self.tombstones > self.auto_compact_dead * ref)):
            self.compact()

    # -- compaction ----------------------------------------------------------

    def _live_state(self):
        """Live (gp, gn, ids[, raw]) in ascending-external-id order — the
        canonical layout a from-scratch rebuild over live rows would use,
        so positional tie-breaks keep matching external-id tie-breaks."""
        lb = ~self.dead_base
        ld = ~self.dead_delta
        if isinstance(self.base, ExactIndex):
            gp_b = np.asarray(self.base.gp)[lb]
            gn_b = np.asarray(self.base.gn)[lb]
        elif isinstance(self.base, IVFPQIndex):
            # the PQ base keeps exact rows in its (host) rerank store,
            # already in base-position order — codes are never decoded
            gp_b = self.base.gp_full[lb]
            gn_b = self.base.gn_full[lb]
        else:
            gp_b, gn_b = self._ivf_live_gp(lb)
        ids = np.concatenate([self.base_ids[lb], self.delta_ids[ld]])
        gp = np.concatenate([gp_b, self.delta_gp[ld]])
        gn = np.concatenate([gn_b, self.delta_gn[ld]])
        order = np.argsort(ids)
        raw = None
        if self.raw_base is not None:
            raw = np.concatenate([self.raw_base[lb], self.raw_delta[ld]])
            raw = raw[order]
        return gp[order], gn[order], ids[order], raw

    def _ivf_live_gp(self, live_mask):
        """Base rows of an IVF index, gathered out of the cluster-major
        padded segments back into base-position order, then masked live."""
        occ = np.asarray(self.base.ids_pad) >= 0
        pos = np.asarray(self.base.ids_pad)[occ]            # base positions
        k = np.asarray(self.base.gp_pad).shape[1]
        gp = np.empty((self.base.size, k), np.float32)
        gn = np.empty((self.base.size,), np.float32)
        gp[pos] = np.asarray(self.base.gp_pad)[occ]
        gn[pos] = np.asarray(self.base.gn_pad)[occ]
        return gp[live_mask], gn[live_mask]

    def compact(self) -> bool:
        """Fold the delta into the base and drop tombstones.

        Exact base: concatenate + re-wrap (no re-projection). IVF base:
        delta rows land in nearest-centroid capacity headroom; if the live
        delta exceeds the total free capacity the fold spills and triggers
        a full rebuild (fresh k-means). IVFPQ base: same headroom fold,
        but each folded row is *encoded* with the existing residual
        codebooks (no PQ retrain — quantization quality can drift if the
        live distribution shifts far from the build-time residuals; a
        spill-triggered rebuild re-trains both k-means and the
        codebooks). Returns True if anything changed.
        """
        if self.delta_rows == 0 and self.tombstones == 0:
            return False
        folded, dropped = self.delta_rows, self.tombstones
        rebuilds_before = self.n_rebuilds
        if isinstance(self.base, IVFPQIndex):
            self._compact_ivfpq()
        elif isinstance(self.base, IVFIndex):
            self._compact_ivf()
        else:
            self._compact_exact()
        self.n_compactions += 1
        self._event("compaction", base=type(self.base).__name__,
                    delta_rows=folded, tombstones=dropped,
                    spill_rebuild=self.n_rebuilds > rebuilds_before,
                    size=self.base.size)
        self._reset_delta()
        self._bump()
        return True

    def _event(self, name: str, **attrs) -> None:
        """Structured lifecycle event onto the adopting engine's registry
        (no-op while unadopted — a bare index carries no obs plumbing)."""
        if self.registry is not None:
            self.registry.event(f"index_{name}", **attrs)
            self.registry.counter(
                "index_lifecycle_total",
                "mutable-index lifecycle transitions by kind",
                labelnames=("event",)).inc(event=name)

    def _reset_delta(self):
        # fresh buffers size off the *current* L, not the old delta_gp:
        # after a rank-changing swap_metric the old buffer's d_out is
        # stale and new upserts (projected at the new rank) must fit
        k = self.L.shape[0]
        self.delta_gp = np.zeros((0, k), np.float32)
        self.delta_gn = np.zeros((0,), np.float32)
        self.delta_ids = np.zeros((0,), np.int64)
        self.dead_delta = np.zeros((0,), bool)
        self.dead_base = np.zeros(self.base.size, bool)
        if self.raw_delta is not None:
            self.raw_delta = np.zeros((0, self.raw_delta.shape[1]),
                                      np.float32)
        self._loc = {int(e): ("base", i)
                     for i, e in enumerate(self.base_ids)}
        self._delta_dev = None
        # _delta_fns survives: the jitted scans are shape-keyed and take
        # the delta arrays as arguments, so steady-state churn re-uses
        # them instead of re-paying a compile after every compaction

    def _compact_exact(self):
        gp, gn, ids, raw = self._live_state()
        self.base = ExactIndex.from_projected(self.L, gp, gn)
        self.base_ids = ids
        if raw is not None:
            self.raw_base = raw

    def _fold_segments(self, clear_dead, place_delta, rebuild, remake):
        """Shared IVF/IVFPQ compaction skeleton (one copy of the
        invariant-bearing bookkeeping; the payload differs per backend).

        Steps: free dead slots, remap kept slots' ids to the new
        ascending-external-id order, spill-check the headroom (falling
        back to a full rebuild), then greedily place each live delta row
        in its nearest centroid with a free slot — the same rule as the
        build's balanced assignment. The callbacks own the payload
        arrays:

          clear_dead(dead_slots)                wipe freed slots
          place_delta(slots, clusters, rows)    write placed delta rows
          rebuild(gp, gn)                       spill path: rebuild
                                                self.base from live rows
          remake(ids_pad, new_ids, lb, live_d, order)
                                                construct the folded base
        """
        base = self.base
        C, cap = base.n_clusters, base.cap
        live_d = np.flatnonzero(~self.dead_delta)
        lb = ~self.dead_base
        ext_live = np.concatenate([self.base_ids[lb],
                                   self.delta_ids[live_d]])
        new_ids = np.sort(ext_live)

        ids_pad = np.asarray(base.ids_pad).copy()
        occ_slots = np.flatnonzero(ids_pad >= 0)
        old_pos = ids_pad[occ_slots]
        keep = lb[old_pos]
        dead_slots = occ_slots[~keep]
        clear_dead(dead_slots)
        ids_pad[dead_slots] = -1
        kept_slots = occ_slots[keep]
        ids_pad[kept_slots] = np.searchsorted(
            new_ids, self.base_ids[old_pos[keep]]).astype(np.int32)

        n_free = C * cap - len(kept_slots)
        if n_free < len(live_d):            # headroom spill -> full rebuild
            gp, gn, ids, raw = self._live_state()
            rebuild(gp, gn)
            self.base_ids = ids
            if raw is not None:
                self.raw_base = raw
            self.n_rebuilds += 1
            self._event("spill_rebuild", free_slots=int(n_free),
                        live_delta=int(len(live_d)))
            return

        # in-place fold: each delta row takes a free slot in its nearest
        # centroid (spilling to the next-nearest with space)
        free = [list(np.flatnonzero(ids_pad[c * cap:(c + 1) * cap] == -1))
                for c in range(C)]
        cent = np.asarray(base.centroids)
        d_dc = (np.sum(self.delta_gp[live_d] ** 2, axis=1)[:, None]
                + np.sum(cent ** 2, axis=1)[None, :]
                - 2.0 * self.delta_gp[live_d] @ cent.T)     # (live, C)
        slots = np.empty(len(live_d), np.int64)
        clusters = np.empty(len(live_d), np.int64)
        for i in range(len(live_d)):
            for c in np.argsort(d_dc[i]):
                if free[c]:
                    slots[i] = c * cap + free[c].pop(0)
                    clusters[i] = c
                    break
        place_delta(slots, clusters, live_d)
        ids_pad[slots] = np.searchsorted(
            new_ids, self.delta_ids[live_d]).astype(np.int32)

        order = np.argsort(ext_live)
        if self.raw_base is not None:
            self.raw_base = np.concatenate(
                [self.raw_base[lb], self.raw_delta[live_d]])[order]
        # remake returns a fresh base instance: the old one's jitted fns
        # close over the old segment arrays and must not be reused
        remake(ids_pad, new_ids, lb, live_d, order)
        self.base_ids = new_ids

    def _rebuild_kwargs(self):
        return {k: v for k, v in self._base_kwargs.items()
                if k in ("iters", "seed", "cap_factor")}

    def _compact_ivf(self):
        """IVF fold: delta rows land full-precision in nearest-centroid
        capacity headroom (see ``_fold_segments``)."""
        base = self.base
        gp_pad = np.asarray(base.gp_pad).copy()
        gn_pad = np.asarray(base.gn_pad).copy()

        def clear_dead(dead_slots):
            gp_pad[dead_slots] = 0.0
            gn_pad[dead_slots] = BIG

        def place_delta(slots, clusters, rows):
            gp_pad[slots] = self.delta_gp[rows]
            gn_pad[slots] = self.delta_gn[rows]

        def rebuild(gp, gn):
            self.base = IVFIndex.build_projected(
                self.L, gp, gn, n_clusters=base.n_clusters,
                nprobe=base.nprobe, scan_impl=base.scan_impl,
                **self._rebuild_kwargs())

        def remake(ids_pad, new_ids, lb, live_d, order):
            self.base = IVFIndex(
                L=base.L, centroids=base.centroids,
                gp_pad=jnp.asarray(gp_pad), gn_pad=jnp.asarray(gn_pad),
                ids_pad=jnp.asarray(ids_pad), cap=base.cap,
                n_clusters=base.n_clusters, nprobe=base.nprobe,
                n_rows=len(new_ids), block_q=base.block_q,
                scan_impl=base.scan_impl)

        self._fold_segments(clear_dead, place_delta, rebuild, remake)

    def _compact_ivfpq(self):
        """IVFPQ fold: each placed delta row is encoded against the
        *existing* codebooks (one batched encode per compaction) and the
        host full-precision store rebuilds in external-id order; a
        headroom spill rebuilds k-means *and* codebooks (see
        ``_fold_segments``)."""
        base = self.base
        codes_pad = np.asarray(base.codes_pad).copy()
        t_pad = np.asarray(base.t_pad).copy()

        def clear_dead(dead_slots):
            codes_pad[dead_slots] = 0
            t_pad[dead_slots] = BIG

        def place_delta(slots, clusters, rows):
            if not len(rows):
                return
            cent = np.asarray(base.centroids)[clusters]
            res = self.delta_gp[rows] - cent
            codes = np.asarray(base.pq.encode(jnp.asarray(res)))
            codes_pad[slots] = codes
            t_pad[slots] = _t_term(base.pq, codes, cent)

        def rebuild(gp, gn):
            self.base = IVFPQIndex.build_projected(
                self.L, gp, gn, n_clusters=base.n_clusters,
                nprobe=base.nprobe, n_subspaces=base.pq.n_subspaces,
                bits=base.pq.bits, rerank_depth=base.rerank_depth,
                store=base.store, scan_impl=base.scan_impl,
                **self._rebuild_kwargs())

        def remake(ids_pad, new_ids, lb, live_d, order):
            gp_full = np.concatenate([base.gp_full[lb],
                                      self.delta_gp[live_d]])[order]
            gn_full = np.concatenate([base.gn_full[lb],
                                      self.delta_gn[live_d]])[order]
            self.base = IVFPQIndex(
                L=base.L, centroids=base.centroids, pq=base.pq,
                codes_pad=jnp.asarray(codes_pad),
                t_pad=jnp.asarray(t_pad), ids_pad=jnp.asarray(ids_pad),
                gp_full=gp_full, gn_full=gn_full, cap=base.cap,
                n_clusters=base.n_clusters, nprobe=base.nprobe,
                n_rows=len(new_ids), rerank_depth=base.rerank_depth,
                store=base.store, scan_impl=base.scan_impl,
                block_q=base.block_q)

        self._fold_segments(clear_dead, place_delta, rebuild, remake)

    # -- metric hot-swap -----------------------------------------------------

    def swap_metric(self, L_new, block_rows: int = 65536) -> None:
        """Re-project the live gallery under a fresh metric factor and swap.

        Requires ``retain_raw=True`` at build. The live raw rows (base +
        delta, tombstones dropped, ascending-external-id order)
        re-project in ``block_rows`` chunks and a replacement base builds
        entirely off to the side — served state is first touched by the
        final flip, so no query ever pays the re-projection or sees a
        half-projected gallery. One version bump at the end flushes the
        engine cache. Closes the trainer -> server loop.

        ``L_new`` may have a *different rank* than the serving factor —
        the retained raw rows make swapping square -> rectangular (or
        back) legal; only ``d_in`` must keep matching the raw feature
        dim. All projected state (base segments, delta buffer) comes
        back sized at the new ``d_out``.

        (The flip itself is a few attribute writes, not one atomic store:
        like ``upsert``/``delete``/``compact``, calls must be serialized
        with in-flight ``topk`` calls by the caller — the engine/batcher
        stack already issues queries from a single worker thread.)
        """
        if self.raw_base is None:
            raise ValueError("swap_metric requires retain_raw=True at "
                             "build (raw features were not kept)")
        scan.check_metric_factor(L_new, self.raw_base.shape[1],
                                 what="L_new")
        L_new = jnp.asarray(L_new, jnp.float32)
        ids = np.concatenate([self.base_ids[~self.dead_base],
                              self.delta_ids[~self.dead_delta]])
        raw = np.concatenate([self.raw_base[~self.dead_base],
                              self.raw_delta[~self.dead_delta]])
        order = np.argsort(ids)
        ids, raw = ids[order], raw[order]
        gps, gns = [], []
        for s in range(0, raw.shape[0], block_rows):
            gp_b, gn_b = project_gallery(L_new,
                                         jnp.asarray(raw[s:s + block_rows]))
            gps.append(np.asarray(gp_b))
            gns.append(np.asarray(gn_b))
        gp = np.concatenate(gps)
        gn = np.concatenate(gns)
        if isinstance(self.base, IVFPQIndex):
            new_base = IVFPQIndex.build_projected(
                L_new, gp, gn, n_clusters=self.base.n_clusters,
                nprobe=self.base.nprobe,
                # a lower-rank L may have fewer projected dims than the
                # old code layout split over; PQ needs n_subspaces <= k
                n_subspaces=min(self.base.pq.n_subspaces,
                                int(L_new.shape[0])),
                bits=self.base.pq.bits,
                rerank_depth=self.base.rerank_depth,
                store=self.base.store, scan_impl=self.base.scan_impl,
                **self._rebuild_kwargs())
        elif isinstance(self.base, IVFIndex):
            new_base = IVFIndex.build_projected(
                L_new, gp, gn, n_clusters=self.base.n_clusters,
                nprobe=self.base.nprobe, scan_impl=self.base.scan_impl,
                **self._rebuild_kwargs())
        else:
            new_base = ExactIndex.from_projected(L_new, gp, gn)
        # the flip: nothing above mutated served state
        self.base = new_base
        self.base_ids = ids
        self.raw_base = raw
        self.L = L_new
        self.n_swaps += 1
        self._event("swap_metric", base=type(new_base).__name__,
                    rows=int(raw.shape[0]), block_rows=block_rows)
        self._reset_delta()
        self._bump()
