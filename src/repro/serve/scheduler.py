"""Traffic-shaped request front end: admission, deadlines, degradation.

The MicroBatcher coalesces requests; this layer models *traffic*. It sits
between clients and the RetrievalEngine and owns the four serving
behaviors an index alone cannot provide:

  admission control   bounded per-class queues; a full queue rejects the
                      submit with a typed ``RejectedError`` immediately
                      (backpressure the client can act on) instead of
                      letting latency grow without bound;
  priority classes    each request belongs to a ``PriorityClass``
                      (``interactive`` / ``batch`` / ``mining`` by
                      default); batches are formed highest-priority-first,
                      FIFO within a class, so cheap interactive lookups
                      are never stuck behind a deep mining sweep;
  deadlines           every request carries an absolute deadline; one that
                      expires while queued fails fast with
                      ``DeadlineExceededError`` and never occupies a batch
                      slot or touches the engine;
  adaptive degradation a ``LoadController`` watches queue depth and steps
                      a quality ladder — per-level ``index.topk`` knob
                      overrides (``nprobe``, ``rerank``) — down under
                      sustained pressure and back up when it drains,
                      spending less compute per query exactly when the
                      queue says the budget is tight (the serving-side
                      mirror of adaptive-sampling training, 1304.1192).
                      Every transition is recorded with its trigger.

All time — request expiry, batch-formation waits, degradation windows —
flows through the injectable ``Clock`` (serve/clock.py), so the entire
front end runs deterministically under ``FakeClock`` in tests: no sleeps,
no timing races.

Threading model: ``submit`` may be called from any number of client
threads; ``n_workers`` worker threads form batches and feed the engine
under one engine lock (the engine itself is single-caller by contract).
Futures resolve exactly once — result, typed rejection, or client
cancellation — guarded by ``set_running_or_notify_cancel``.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from concurrent.futures import Future
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.serve.clock import Clock, SystemClock
from repro.serve.engine import RetrievalEngine


# -- typed request outcomes --------------------------------------------------

class SchedulerError(Exception):
    """Base for every typed front-end failure."""


class RejectedError(SchedulerError):
    """Admission refused: class queue at capacity, or scheduler closed."""


class DeadlineExceededError(SchedulerError):
    """The request's deadline passed while it waited in the queue."""


# -- priority classes --------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PriorityClass:
    """One traffic class: who goes first, how long they may wait, and how
    many of them may queue.

    priority: lower numbers are served first (strict: a batch never takes
      a lower-priority request while a higher-priority one is admissible).
    deadline_s: default per-request deadline (submit may override).
    queue_cap: bounded admission queue; submits beyond it are rejected.
    """
    name: str
    priority: int
    deadline_s: float
    queue_cap: int


DEFAULT_CLASSES: Tuple[PriorityClass, ...] = (
    PriorityClass("interactive", priority=0, deadline_s=0.100,
                  queue_cap=256),
    PriorityClass("batch", priority=1, deadline_s=1.0, queue_cap=1024),
    PriorityClass("mining", priority=2, deadline_s=10.0, queue_cap=4096),
)


# -- per-class latency/counter stats -----------------------------------------

class LatencyWindow:
    """Bounded window of latency samples with percentile readout.

    Thread-safe: ``record`` may race with ``percentile``/``snapshot``
    (the lock makes each a consistent atomic snapshot). The window keeps
    the most recent ``maxlen`` samples — a long-lived server reports
    recent tail behavior, not its lifetime average.
    """

    def __init__(self, maxlen: int = 4096):
        self._lock = threading.Lock()
        self._samples: collections.deque = collections.deque(maxlen=maxlen)

    def record(self, value: float) -> None:
        with self._lock:
            self._samples.append(float(value))

    def __len__(self) -> int:
        with self._lock:
            return len(self._samples)

    def percentile(self, q) -> float:
        """obs.percentile (linear interpolation, as np.percentile) over
        the current window; NaN when empty. ``q`` may be a scalar or a
        sequence. This used to be one of three ad-hoc percentile
        implementations; all of them now route through obs."""
        with self._lock:
            samples = list(self._samples)
        return obs_metrics.percentile(samples, q)


_OUTCOMES = ("admitted", "rejected", "expired", "completed", "failed",
             "cancelled")


class _ClassStats:
    """Per-priority-class counters + latency, re-homed onto the stack's
    MetricsRegistry: ``frontend_requests_total{class,outcome}`` and the
    ``frontend_latency_seconds{class}`` histogram. Increments are atomic
    under the registry lock; the windowed percentile readout stays local
    (recent tail, not lifetime) via LatencyWindow."""

    def __init__(self, name: str, registry: obs_metrics.MetricsRegistry):
        self.name = name
        self._c = registry.counter(
            "frontend_requests_total",
            "front-end requests by priority class and outcome "
            "(admitted counts entry; the others are terminal)",
            labelnames=("cls", "outcome"))
        self._h = registry.histogram(
            "frontend_latency_seconds",
            "submit-to-resolve latency of completed requests",
            labelnames=("cls",))
        self.latency = LatencyWindow()

    def bump(self, field: str, by: int = 1) -> None:
        if field not in _OUTCOMES:
            raise ValueError(f"unknown outcome {field!r}")
        self._c.inc(by, cls=self.name, outcome=field)

    def record_latency(self, seconds: float) -> None:
        self.latency.record(seconds)
        self._h.observe(seconds, cls=self.name)

    def __getattr__(self, field):
        # back-compat reads (st.admitted, st.completed, ...) resolve to
        # the registry counter; only reached when not a real attribute
        if field in _OUTCOMES:
            return int(self._c.value(cls=self.name, outcome=field))
        raise AttributeError(field)

    def snapshot(self) -> dict:
        out = {f: int(self._c.value(cls=self.name, outcome=f))
               for f in _OUTCOMES}
        p50, p99 = self.latency.percentile((50.0, 99.0))
        out["p50_ms"] = p50 * 1e3
        out["p99_ms"] = p99 * 1e3
        return out


# -- adaptive degradation ----------------------------------------------------

def default_ladder(index, k_top: int, n_levels: int = 3) -> Tuple[dict, ...]:
    """Derive a quality ladder from the index's own knobs.

    Level 0 is always ``{}`` (build-time quality). For PQ bases the first
    rung shrinks only the exact-rerank pool (``rerank`` halved, floored at
    ``k_top`` — IVFPQ clamps there anyway, and MutableIndex rejects
    ``rerank=0``): the rerank gather is the cheapest lever, and cutting
    it leaves the ADC candidate scan untouched, so recall dips least per
    unit of saved compute. Each deeper level then halves ``nprobe``
    (floored so ``k_top`` still fits in the scanned candidate pool)
    together with the rerank pool. Indexes with no knobs (ExactIndex)
    get the single full-quality level: the controller then has nothing
    to trade, and admission control alone carries overload.
    """
    base = getattr(index, "base", index)       # MutableIndex wraps
    nprobe = getattr(base, "nprobe", None)
    if nprobe is None:
        return ({},)
    cap = base.cap
    nprobe_floor = max(1, -(-k_top // cap))    # ceil(k_top / cap)
    rerank = getattr(base, "rerank_depth", None)
    ladder = [{}]
    if rerank:                                 # 0 = ADC-only build: leave
        knobs = {"rerank": max(k_top, rerank >> 1)}
        if knobs["rerank"] < rerank:           # already at the floor: skip
            ladder.append(knobs)
    for step in range(1, n_levels):
        knobs = {"nprobe": max(nprobe_floor, nprobe >> step)}
        if rerank:
            knobs["rerank"] = max(k_top, rerank >> step)
        if ladder[-1] != knobs:                # stop once floored flat
            ladder.append(knobs)
    return tuple(ladder)


@dataclasses.dataclass(frozen=True)
class DegradeTransition:
    """One recorded ladder move (t is clock time at the decision)."""
    t: float
    level_from: int
    level_to: int
    queue_depth: int
    reason: str


class LoadController:
    """Queue-pressure feedback loop over a quality ladder.

    The worker calls ``observe(queue_depth)`` before forming each batch;
    sustained depth above ``high_watermark`` for ``degrade_window_s``
    steps one ladder level down (cheaper queries), sustained depth at or
    below ``low_watermark`` for ``restore_window_s`` steps back up.
    Windows are measured on the injected clock, so hysteresis is
    deterministic under FakeClock. Single-caller (the worker holding the
    scheduler lock); readers see ``level`` / ``transitions`` atomically
    under the GIL.
    """

    def __init__(self, ladder: Sequence[dict], clock: Clock,
                 high_watermark: int = 32, low_watermark: int = 4,
                 degrade_window_s: float = 0.05,
                 restore_window_s: float = 0.5):
        if not ladder or ladder[0] != {}:
            raise ValueError("ladder[0] must be {} (full quality)")
        if low_watermark >= high_watermark:
            raise ValueError(f"low_watermark={low_watermark} must be < "
                             f"high_watermark={high_watermark}")
        self.ladder = tuple(dict(lv) for lv in ladder)
        self.clock = clock
        self.high_watermark = high_watermark
        self.low_watermark = low_watermark
        self.degrade_window_s = degrade_window_s
        self.restore_window_s = restore_window_s
        self.level = 0
        self.transitions: list = []
        self._over_since: Optional[float] = None
        self._under_since: Optional[float] = None

    def _move(self, to: int, depth: int, reason: str) -> None:
        self.transitions.append(DegradeTransition(
            self.clock.now(), self.level, to, depth, reason))
        self.level = to
        self._over_since = None
        self._under_since = None

    def observe(self, queue_depth: int) -> dict:
        """Update pressure windows, maybe move a level, and return the
        knob overrides to serve the next batch with."""
        now = self.clock.now()
        if queue_depth > self.high_watermark:
            self._under_since = None
            if self._over_since is None:
                self._over_since = now
            elif (now - self._over_since >= self.degrade_window_s
                  and self.level < len(self.ladder) - 1):
                self._move(self.level + 1, queue_depth,
                           f"depth {queue_depth} > {self.high_watermark} "
                           f"for {self.degrade_window_s}s")
        elif queue_depth <= self.low_watermark:
            self._over_since = None
            if self._under_since is None:
                self._under_since = now
            elif (now - self._under_since >= self.restore_window_s
                  and self.level > 0):
                self._move(self.level - 1, queue_depth,
                           f"depth {queue_depth} <= {self.low_watermark} "
                           f"for {self.restore_window_s}s")
        else:                       # between watermarks: hold the level
            self._over_since = None
            self._under_since = None
        return self.ladder[self.level]


# -- the scheduler -----------------------------------------------------------

@dataclasses.dataclass
class _Request:
    q: np.ndarray
    k: int
    fut: Future
    cls: PriorityClass
    t_submit: float
    t_deadline: float
    trace: object = None        # obs.Trace minted at submit (or None)
    q_span: object = None       # open "queue" span, ended at dequeue
    route: object = None        # tenant route name (None = default engine)


_ANY_ROUTE = object()           # _pop_live_locked sentinel: no route filter


class RequestScheduler:
    """Async request front end over a RetrievalEngine (module docstring
    has the model). Construct, ``submit`` from any thread, ``close`` when
    done; attach-time side effect: ``engine.frontend = self`` so
    ``engine.stats()`` grows the front-end observability block.
    """

    def __init__(self, engine: RetrievalEngine,
                 classes: Sequence[PriorityClass] = DEFAULT_CLASSES,
                 max_batch: int = 64, max_wait_ms: float = 2.0,
                 n_workers: int = 1, clock: Optional[Clock] = None,
                 degrade: bool = True,
                 ladder: Optional[Sequence[dict]] = None,
                 high_watermark: int = 32, low_watermark: int = 4,
                 degrade_window_s: float = 0.05,
                 restore_window_s: float = 0.5,
                 registry: Optional[obs_metrics.MetricsRegistry] = None):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        names = [c.name for c in classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate class names in {names}")
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.clock = clock if clock is not None else SystemClock()
        # share the engine's registry/tracer when it has them (the real
        # RetrievalEngine always does), so the whole stack records into
        # one instance; a bare test double gets a private registry. An
        # explicit ``registry`` overrides — a multi-tenant front end
        # (serve/tenant.py) serves tenant-scoped engines but its own
        # frontend_* metrics belong on the unscoped base registry.
        reg = (registry if registry is not None
               else getattr(engine, "registry", None))
        self.registry = (reg if reg is not None
                         else obs_metrics.MetricsRegistry(clock=self.clock))
        self.tracer = getattr(engine, "tracer", None)
        # strict priority: queues iterated in ascending priority order
        self._classes: Dict[str, PriorityClass] = {
            c.name: c for c in sorted(classes, key=lambda c: c.priority)}
        self._queues: Dict[str, collections.deque] = {
            name: collections.deque() for name in self._classes}
        self._stats: Dict[str, _ClassStats] = {
            name: _ClassStats(name, self.registry)
            for name in self._classes}
        self._cond = threading.Condition()
        self._closed = False
        self._c_batches = self.registry.counter(
            "frontend_batches_total", "batches dispatched to the engine")
        self._h_batch = self.registry.histogram(
            "frontend_batch_size", "live requests per dispatched batch",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256))
        self._g_depth = self.registry.gauge(
            "frontend_queue_depth", "requests waiting, by priority class",
            labelnames=("cls",))
        self._g_level = self.registry.gauge(
            "frontend_degradation_level",
            "current quality-ladder level (0 = full quality)")
        self._c_tenant = self.registry.counter(
            "frontend_tenant_requests_total",
            "front-end requests by tenant route and outcome",
            labelnames=("tenant", "outcome"))
        self.registry.register_collector(self._collect_gauges)
        self.batch_sizes: collections.deque = collections.deque(maxlen=4096)
        # tenant routes: name -> (engine, per-route LoadController). A
        # routed submit validates and serves against its route's engine;
        # batches never mix routes (one engine call per batch).
        self._routes: Dict[object, tuple] = {}
        self._ctrl_kw = dict(high_watermark=high_watermark,
                             low_watermark=low_watermark,
                             degrade_window_s=degrade_window_s,
                             restore_window_s=restore_window_s)
        self._degrade = degrade
        if degrade:
            lad = (tuple(ladder) if ladder is not None
                   else default_ladder(engine.index, engine.k_top))
            self.controller: Optional[LoadController] = LoadController(
                lad, self.clock, high_watermark=high_watermark,
                low_watermark=low_watermark,
                degrade_window_s=degrade_window_s,
                restore_window_s=restore_window_s)
        else:
            self.controller = None
        # engine calls are serialized: the engine contract is one caller
        # at a time (stats counters, LRU) — extra workers overlap only on
        # host-side batch formation and future resolution
        self._engine_lock = threading.Lock()
        engine.frontend = self
        self._threads = [
            threading.Thread(target=self._loop, daemon=True,
                             name=f"scheduler-worker-{i}")
            for i in range(n_workers)]
        for t in self._threads:
            t.start()

    def _collect_gauges(self):
        """Snapshot-time gauges: per-class queue depth + ladder level
        (the ROADMAP's dashboard gauges). No-ops once another scheduler
        has attached to the same engine — collectors registered on a
        shared registry outlive this front end."""
        if self.engine.frontend is not self:
            return
        with self._cond:
            depths = {name: len(q) for name, q in self._queues.items()}
        for name, depth in depths.items():
            self._g_depth.set(depth, cls=name)
        ctrl = self.controller
        self._g_level.set(0 if ctrl is None else ctrl.level)

    @property
    def n_batches(self) -> int:
        return int(self._c_batches.value())

    # -- tenant routes -------------------------------------------------------

    def add_route(self, name: str, engine: RetrievalEngine,
                  ladder: Optional[Sequence[dict]] = None) -> None:
        """Register a tenant route: submits with ``route=name`` validate
        against and are served by ``engine``, under a per-route quality
        ladder (derived from the route engine's own index unless given).
        Re-registering a name repoints it (the tenant router does this
        after a promotion rebuilds a view)."""
        ctrl = None
        if self._degrade:
            lad = (tuple(ladder) if ladder is not None
                   else default_ladder(engine.index, engine.k_top))
            ctrl = LoadController(lad, self.clock, **self._ctrl_kw)
        with self._cond:
            self._routes[name] = (engine, ctrl)

    def routes(self) -> tuple:
        with self._cond:
            return tuple(self._routes)

    def _resolve_route(self, route):
        """(engine, controller) serving ``route`` (None = the default)."""
        if route is None:
            return self.engine, self.controller
        with self._cond:
            entry = self._routes.get(route)
        if entry is None:
            raise ValueError(f"unknown route {route!r} "
                             f"(have {sorted(map(str, self._routes))})")
        return entry

    def _settle(self, r: _Request, outcome: str) -> None:
        """Terminal bookkeeping for one request: class counters, the
        per-tenant outcome counter (routed requests only), and trace
        close — every resolution path funnels here."""
        self._stats[r.cls.name].bump(outcome)
        if r.route is not None:
            self._c_tenant.inc(tenant=str(r.route), outcome=outcome)
        self._finish_trace(r, outcome)

    def _finish_trace(self, r: _Request, outcome: str) -> None:
        """Close a request's trace (no-op for untraced requests): end the
        queue span if still open, stamp the outcome, hand the tree to the
        tracer."""
        if r.trace is None:
            return
        r.q_span.end()
        r.trace.root.set_attrs(outcome=outcome)
        self.tracer.finish(r.trace)

    # -- client side --------------------------------------------------------

    def submit(self, query, k_top: Optional[int] = None,
               priority: str = "interactive",
               deadline_s: Optional[float] = None,
               route: Optional[str] = None) -> Future:
        """Enqueue one (d,) query under a priority class.

        Returns a Future resolving to (dists (k,), ids (k,)). Admission
        failures raise ``RejectedError`` *here* — a rejected request
        never holds a queue slot. An admitted request always resolves:
        result, ``DeadlineExceededError``, engine exception, or client
        cancellation. ``deadline_s`` overrides the class default
        (relative to now; must be > 0). ``route`` targets a tenant route
        registered with ``add_route`` (validation and service happen
        against that route's engine; batches never mix routes).
        """
        cls = self._classes.get(priority)
        if cls is None:
            raise ValueError(f"unknown priority class {priority!r} "
                             f"(have {list(self._classes)})")
        engine, _ = self._resolve_route(route)
        k = engine.k_top if k_top is None else k_top
        if k < 1:
            raise ValueError(f"k_top must be >= 1, got {k}")
        if k > engine.k_top:
            raise ValueError(f"k_top={k} > engine k_top="
                             f"{engine.k_top}")
        dl = cls.deadline_s if deadline_s is None else deadline_s
        if dl <= 0:
            raise ValueError(f"deadline_s must be > 0, got {dl}")
        q = np.asarray(query, np.float32)
        d = engine.index.L.shape[1]
        if q.shape != (d,):     # reject here, not in the shared worker
            raise ValueError(f"query shape {q.shape} != ({d},)")
        st = self._stats[cls.name]
        with self._cond:
            if self._closed:
                st.bump("rejected")
                raise RejectedError("scheduler is closed")
            queue = self._queues[cls.name]
            if len(queue) >= cls.queue_cap:
                st.bump("rejected")
                raise RejectedError(
                    f"{cls.name} queue full ({cls.queue_cap}); retry "
                    f"with backoff or shed load upstream")
            now = self.clock.now()
            fut: Future = Future()
            r = _Request(q, k, fut, cls, now, now + dl, route=route)
            if self.tracer is not None and self.tracer.sample_rate > 0:
                # the trace id is minted here, at admission; the "queue"
                # span stays open until a worker dequeues the request
                r.trace = self.tracer.start_trace("request")
                r.trace.root.set_attrs(cls=cls.name, k=k)
                if route is not None:
                    r.trace.root.set_attrs(tenant=str(route))
                r.q_span = r.trace.span("queue")
            queue.append(r)
            st.bump("admitted")
            if route is not None:
                self._c_tenant.inc(tenant=str(route), outcome="admitted")
            self._cond.notify_all()
        return fut

    def close(self, timeout: float = 10.0, drain: bool = True) -> bool:
        """Stop the workers. ``drain=True`` serves already-admitted
        requests first; ``drain=False`` fails them fast with
        ``RejectedError``. Returns True when every worker exited within
        ``timeout`` real seconds (False = at least one still alive, same
        contract as ``MicroBatcher.close``)."""
        with self._cond:
            self._closed = True
            if not drain:
                for name, queue in self._queues.items():
                    while queue:
                        r = queue.popleft()
                        if r.fut.set_running_or_notify_cancel():
                            r.fut.set_exception(
                                RejectedError("scheduler closed before "
                                              "the request was served"))
                            self._settle(r, "rejected")
                        else:
                            self._settle(r, "cancelled")
            self._cond.notify_all()
        for t in self._threads:
            t.join(timeout=timeout)
        return not any(t.is_alive() for t in self._threads)

    # -- worker side --------------------------------------------------------

    def _depth_locked(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _pop_live_locked(self, route=_ANY_ROUTE) -> Optional[_Request]:
        """Pop the highest-priority non-expired request, failing expired
        ones fast (typed error; they never occupy a batch slot). With a
        ``route`` filter, only requests of that route are considered —
        others stay queued in place (their FIFO position is preserved;
        their deadlines are judged when they are actually popped)."""
        now = self.clock.now()
        for name, queue in self._queues.items():   # ascending priority
            i = 0
            while i < len(queue):
                r = queue[i]
                if route is not _ANY_ROUTE and r.route != route:
                    i += 1
                    continue
                del queue[i]
                if r.fut.cancelled():   # client walked away while queued
                    self._settle(r, "cancelled")
                    continue
                if r.t_deadline <= now:
                    if r.fut.set_running_or_notify_cancel():
                        r.fut.set_exception(DeadlineExceededError(
                            f"{name} deadline "
                            f"{r.t_deadline - r.t_submit:.3f}s expired "
                            f"in queue"))
                        self._settle(r, "expired")
                    else:
                        self._settle(r, "cancelled")
                    continue
                return r
        return None

    def _collect(self) -> Optional[list]:
        """Form one batch: highest-priority-first, FIFO within a class,
        waiting at most ``max_wait_s`` past the first member — and never
        past any collected member's deadline (deadline-aware formation:
        idling a member into expiry would waste its admission). The first
        member fixes the batch's route: one batch is one engine call, so
        riders must share its engine."""
        with self._cond:
            batch: list = []
            while not batch:
                r = self._pop_live_locked()
                if r is not None:
                    batch.append(r)
                    break
                if self._closed:
                    return None
                self.clock.wait_on(self._cond, None)
            route = batch[0].route
            wait_until = self.clock.now() + self.max_wait_s
            while len(batch) < self.max_batch:
                r = self._pop_live_locked(route)
                if r is not None:
                    batch.append(r)
                    continue
                if self._closed:            # nothing more is coming
                    break
                bound = min(wait_until,
                            min(m.t_deadline for m in batch))
                remaining = bound - self.clock.now()
                if remaining <= 0:
                    break
                self.clock.wait_on(self._cond, remaining)
            return batch

    def _loop(self):
        while True:
            batch = self._collect()
            if batch:
                self._run_batch(batch)
            with self._cond:
                if self._closed and self._depth_locked() == 0:
                    return

    def _run_batch(self, batch):
        # claim every member exactly once before dispatch: a cancelled
        # rider drops out here (it must not reach the engine), an expired
        # one fails fast, and survivors are RUNNING — no InvalidStateError
        # window between resolution paths
        now = self.clock.now()
        live = []
        for r in batch:
            if not r.fut.set_running_or_notify_cancel():
                self._settle(r, "cancelled")
            elif r.t_deadline <= now:   # expired during batch formation
                r.fut.set_exception(DeadlineExceededError(
                    f"{r.cls.name} deadline expired during batch "
                    f"formation"))
                self._settle(r, "expired")
            else:
                if r.q_span is not None:
                    r.q_span.end()      # dequeued: queue wait is over
                live.append(r)
        if not live:
            return
        # routed batches serve their route's engine under its own quality
        # ladder (_collect guarantees one route per batch); pressure is
        # still judged on the TOTAL queue depth — one worker drains every
        # route, so the backlog any route sees is the shared one
        engine, controller = self._resolve_route(live[0].route)
        if controller is not None:
            with self._cond:
                depth = self._depth_locked()
            knobs = controller.observe(depth)
        else:
            knobs = {}
        # one batch serves many requests but the engine takes one span:
        # the first *sampled* rider carries the batch + engine detail
        # (other sampled riders in the same batch keep their queue span
        # and outcome, without the shared-stage duplication)
        carrier = next((r for r in live
                        if r.trace is not None and r.trace.sampled), None)
        b_span = e_span = None
        if carrier is not None:
            b_span = carrier.trace.span("batch").set_attrs(
                size=len(live), level=(0 if controller is None
                                       else controller.level),
                **{f"knob_{k}": v for k, v in knobs.items()})
            if live[0].route is not None:
                b_span.set_attrs(tenant=str(live[0].route))
            e_span = carrier.trace.span("engine", parent=b_span)
        try:
            qs = np.stack([r.q for r in live])
            with self._engine_lock:
                if e_span is not None:
                    dists, idxs = engine.search(qs, span=e_span,
                                                **knobs)
                else:
                    dists, idxs = engine.search(qs, **knobs)
        except Exception as e:          # fail every rider, keep serving
            if b_span is not None:
                e_span.set_attrs(error=repr(e)).end()
                b_span.end()
            for r in live:              # already RUNNING: resolve directly
                r.fut.set_exception(e)
                self._settle(r, "failed")
            return
        if b_span is not None:
            e_span.end()
            b_span.end()
        self._c_batches.inc()
        self._h_batch.observe(len(live))
        self.batch_sizes.append(len(live))
        done = self.clock.now()
        for row, r in enumerate(live):
            st = self._stats[r.cls.name]
            r.fut.set_result((dists[row, :r.k], idxs[row, :r.k]))
            st.record_latency(done - r.t_submit)
            self._settle(r, "completed")

    # -- warmup / observability ---------------------------------------------

    def warmup(self, ks: Optional[Sequence[int]] = None) -> None:
        """Pre-compile every (bucket, k) combination at every ladder
        level, so the first degraded batch doesn't pay jit exactly when
        the system is already overloaded."""
        import jax.numpy as jnp
        self.engine.warmup(ks=ks)                  # level 0
        if self.controller is None:
            return
        ks = (self.engine.k_top,) if ks is None else tuple(ks)
        d = self.engine.index.L.shape[1]
        for knobs in self.controller.ladder[1:]:
            for k in ks:
                for b in self.engine.buckets:
                    self.engine.index.topk(
                        jnp.zeros((b, d), jnp.float32), k,
                        backend=self.engine.backend, **knobs)

    def observability(self) -> dict:
        """The front-end block ``engine.stats()`` embeds: per-class
        counters + latency percentiles + queue depths, plus the
        degradation state. Safe to call from any thread (class counters
        lock per class; queue depths snapshot under the scheduler lock)."""
        with self._cond:
            depths = {name: len(q) for name, q in self._queues.items()}
            closed = self._closed
        classes = {}
        for name, st in self._stats.items():
            snap = st.snapshot()
            snap["queue_depth"] = depths[name]
            classes[name] = snap
        ctrl = self.controller
        out = {
            "classes": classes,
            "queue_depth": sum(depths.values()),
            "rejections": sum(c["rejected"] for c in classes.values()),
            "expired": sum(c["expired"] for c in classes.values()),
            "n_batches": self.n_batches,
            "closed": closed,
            "degradation_level": 0 if ctrl is None else ctrl.level,
            "degradation_knobs": ({} if ctrl is None
                                  else dict(ctrl.ladder[ctrl.level])),
            "n_transitions": (0 if ctrl is None
                              else len(ctrl.transitions)),
        }
        tenants: Dict[str, Dict[str, int]] = {}
        for key in self._c_tenant.label_keys():
            labels = dict(obs_metrics.parse_label_key(key))
            per = tenants.setdefault(labels["tenant"], {})
            per[labels["outcome"]] = int(self._c_tenant.value(**labels))
        if tenants:
            out["tenants"] = tenants
        return out

    stats = observability
