"""Residual product quantization: uint8 codes + ADC scoring for IVF retrieval.

The IVF scan (serve/ivf.py) is gather-bound: every probe pulls ``cap``
full-precision projected rows (k * 4 bytes each) out of the segment
arrays, which is the LLC/HBM bandwidth cliff the block_q chunking only
softens. This module compresses those rows ~16x so the same byte budget
scans a proportionally larger slice of the gallery — the
exactness-for-bandwidth trade Qian et al. 2015 argue makes high-d
learned-metric retrieval practical at scale:

  * ``ProductQuantizer`` — splits the k-dim *residual* space (row minus
    its IVF centroid) into ``n_subspaces`` contiguous subspaces and
    k-means-quantizes each independently (``2**bits`` codewords, so a row
    encodes to ``n_subspaces`` uint8 codes). Residuals, not raw rows:
    after subtracting the coarse centroid the remaining variance is small
    and near-isotropic, so the same code budget buys far less distortion.
  * ``IVFPQIndex`` — the IVF layout (cluster-major capacity-padded
    segments) with codes instead of rows, scored by **asymmetric distance
    computation** (ADC): the query stays full-precision, and

        ||qp - (c + r̂)||² = ||qp - c||² - 2⟨qp, r̂⟩ + (||r̂||² + 2⟨c, r̂⟩)

    where r̂ is the decoded residual. The first term is the centroid scan
    (already computed to pick probes), the last is a per-row f32 baked at
    encode time (``t_pad``, the 4-byte analogue of ``gn_pad``), and the
    middle splits per subspace into ⟨qp_s, codebook[s, code]⟩ — one
    (n_subspaces, 2**bits) lookup table per query, built once, *independent
    of which clusters are probed* (inner products are linear, so the
    centroid never enters the table). Scanning a segment is then a uint8
    gather plus table lookups: no decode, no k-dim arithmetic per row.
  * optional **exact re-rank** — ADC distances are approximate, so the top
    ``rerank_depth`` ADC candidates re-score against a full-precision row
    store and the top k_top of that exact ordering is returned. The store
    placement is a knob: ``store="device"`` fuses the re-rank into the
    same jit (it gathers only ``rerank_depth`` rows per query, so it never
    re-enters the bandwidth cliff the codes avoid — but the f32 rows stay
    in HBM); ``store="host"`` keeps them in numpy/RAM, trading a
    host-gather round trip per batch for an HBM footprint of just codes —
    the paper-scale-M configuration. With ``nprobe == n_clusters`` and a
    deep enough ``rerank_depth``, the result matches ExactIndex (the
    correctness oracle tests pin); rerank recall is capped by the probed
    clusters' candidate recall, not by quantization error.

Single-shard only: the sharded IVF path re-places arrays at build and the
host-resident rerank store has no mesh story yet (the multi-host gallery
ROADMAP item covers this axis). ``MutableIndex`` can wrap an IVFPQIndex:
delta rows stay full-precision and exact, compaction encodes them into
segment headroom with the *existing* codebooks (serve/mutable.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.metric_topk import metric_sqdist_factored, project_gallery
from repro.kernels.metric_topk.kernel import BIG
from repro.kernels.pq_adc import pq_adc_topk
from repro.serve import scan
from repro.serve.ivf import _balance_assign, kmeans_projected


@dataclasses.dataclass(eq=False)
class ProductQuantizer:
    """Per-subspace k-means codebooks over a k-dim vector space.

    Attributes:
      codebooks: (n_subspaces, 2**bits, sub_dim) f32 codeword table.
      dim: the un-padded input dimensionality k (``encode``/``decode``
        operate on (N, dim); internally dim zero-pads up to
        ``n_subspaces * sub_dim``, and zero pad columns are
        distance-neutral, the same rule the kernels use).
      bits: code width; codes are uint8, so 1 <= bits <= 8.

    Invariant: ``decode(encode(x))`` is the per-subspace nearest-codeword
    reconstruction — squared error is bounded by the per-subspace k-means
    quantization error, and ADC scoring against the tables from
    ``sqdist_tables``/``ip_tables`` equals decode-then-score exactly
    (up to f32 rounding), which tests/test_serve_pq.py pins.
    """

    codebooks: jax.Array
    dim: int

    @property
    def n_subspaces(self) -> int:
        return self.codebooks.shape[0]

    @property
    def n_codes(self) -> int:
        return self.codebooks.shape[1]

    @property
    def sub_dim(self) -> int:
        return self.codebooks.shape[2]

    @property
    def bits(self) -> int:
        return int(self.n_codes - 1).bit_length() if self.n_codes > 1 else 1

    @property
    def code_bytes(self) -> int:
        """Stored bytes per encoded vector (one uint8 per subspace)."""
        return self.n_subspaces

    @classmethod
    def train(cls, vecs, n_subspaces: int = 8, bits: int = 8, *,
              iters: int = 10, seed: int = 0) -> "ProductQuantizer":
        """Fit per-subspace codebooks on training vectors.

        Args:
          vecs: (N, dim) f32 training set — for the IVF use case, the
            *residuals* of projected gallery rows to their centroids.
          n_subspaces: how many contiguous subspaces dim splits into
            (dim zero-pads up to a multiple; more subspaces = finer
            reconstruction and more code bytes per row).
          bits: log2 codewords per subspace (uint8 codes: 1..8). When N
            < 2**bits the codebook pads by repeating real codewords
            (harmless: encode picks the nearest, duplicates never win
            uniquely).
          iters / seed: Lloyd iterations / PRNG seed per subspace
            (each subspace reuses serve/ivf.py's jit-scanned k-means).

        Returns: the fitted ProductQuantizer.
        """
        if not 1 <= bits <= 8:
            raise ValueError(f"bits must be in 1..8 (uint8 codes), "
                             f"got {bits}")
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim != 2:
            raise ValueError(f"vecs must be (N, dim), got {vecs.shape}")
        N, dim = vecs.shape
        if N < 1:
            raise ValueError("cannot train on an empty set")
        if n_subspaces < 1 or n_subspaces > dim:
            raise ValueError(f"n_subspaces={n_subspaces} outside 1..{dim}")
        sub = -(-dim // n_subspaces)                       # ceil
        padded = sub * n_subspaces
        if padded != dim:
            vecs = np.pad(vecs, ((0, 0), (0, padded - dim)))
        n_codes = 1 << bits
        books = np.empty((n_subspaces, n_codes, sub), np.float32)
        for s in range(n_subspaces):
            part = jnp.asarray(vecs[:, s * sub:(s + 1) * sub])
            c = min(n_codes, N)
            cent, _, _ = kmeans_projected(part, c, iters=iters,
                                          seed=seed + s)
            cent = np.asarray(cent)
            if c < n_codes:                   # pad by repeating real rows
                cent = cent[np.arange(n_codes) % c]
            books[s] = cent
        return cls(codebooks=jnp.asarray(books), dim=dim)

    def _split(self, vecs):
        """(N, dim) -> (N, n_subspaces, sub_dim), zero-padding dim."""
        vecs = jnp.asarray(vecs, jnp.float32)
        padded = self.n_subspaces * self.sub_dim
        if vecs.shape[1] != self.dim:
            raise ValueError(f"expected dim {self.dim}, got "
                             f"{vecs.shape[1]}")
        if padded != self.dim:
            vecs = jnp.pad(vecs, ((0, 0), (0, padded - self.dim)))
        return vecs.reshape(vecs.shape[0], self.n_subspaces, self.sub_dim)

    def encode(self, vecs, block_rows: int = 16384) -> jax.Array:
        """Quantize (N, dim) vectors to (N, n_subspaces) uint8 codes
        (independent nearest codeword per subspace, ties to the smaller
        code — argmin semantics). Chunked over ``block_rows`` so the
        (block, n_subspaces, 2**bits) distance tensor stays bounded at
        paper-scale N (a build/compaction-time host loop, not a jit
        path)."""
        parts = self._split(vecs)                     # (N, S, sub)
        cn = jnp.sum(jnp.square(self.codebooks), axis=2)    # (S, K)
        out = []
        for s in range(0, parts.shape[0], block_rows):
            blk = parts[s:s + block_rows]
            # ||p-c||^2 = ||p||^2 - 2<p,c> + ||c||^2; ||p||^2 const in c
            cross = jnp.einsum("nsd,skd->nsk", blk, self.codebooks)
            out.append(jnp.argmin(cn[None] - 2.0 * cross,
                                  axis=2).astype(jnp.uint8))
        return jnp.concatenate(out) if len(out) != 1 else out[0]

    def decode(self, codes) -> jax.Array:
        """Reconstruct (N, dim) f32 vectors from (N, n_subspaces) codes
        (the per-subspace codeword concatenation; pad columns sliced
        off)."""
        codes = jnp.asarray(codes)
        gathered = jnp.take_along_axis(
            self.codebooks[None], codes.astype(jnp.int32)[:, :, None, None],
            axis=2)                                   # (N, S, 1, sub)
        out = gathered.reshape(codes.shape[0], -1)
        return out[:, :self.dim]

    def ip_tables(self, q) -> jax.Array:
        """Per-query inner-product lookup tables (Nq, n_subspaces,
        2**bits): entry [i, s, b] = <q_i restricted to subspace s,
        codebook[s, b]>. Linear in q, so for residual ADC the *projected
        query* works directly — the probed centroid never enters the
        table (see the module docstring identity)."""
        return jnp.einsum("nsd,skd->nsk", self._split(q), self.codebooks)

    def sqdist_tables(self, q) -> jax.Array:
        """Per-query squared-distance tables (Nq, n_subspaces, 2**bits):
        entry [i, s, b] = ||q_i|_s - codebook[s, b]||². Summing entries
        at a row's codes gives the symmetric-free ADC distance
        ||q - decode(codes)||² exactly (subspaces are orthogonal
        coordinate blocks)."""
        split = self._split(q)                        # (Nq, S, sub)
        qn = jnp.sum(jnp.square(split), axis=2)       # (Nq, S)
        cn = jnp.sum(jnp.square(self.codebooks), axis=2)
        cross = jnp.einsum("nsd,skd->nsk", split, self.codebooks)
        return qn[:, :, None] + cn[None] - 2.0 * cross

    def adc(self, tables, codes) -> jax.Array:
        """Sum per-subspace table entries at each row's codes.

        Args:
          tables: (Nq, n_subspaces, 2**bits) from ``ip_tables`` or
            ``sqdist_tables``.
          codes: (N, n_subspaces) uint8.

        Returns (Nq, N) f32: tables[i].sum over s at codes[j]. One fused
        gather over a flattened (s, code) index — the scan hot path.
        """
        S, K = self.n_subspaces, self.n_codes
        flat = (jnp.arange(S, dtype=jnp.int32) * K
                + jnp.asarray(codes).astype(jnp.int32))      # (N, S)
        t = tables.reshape(tables.shape[0], S * K)
        picked = jnp.take(t, flat.reshape(-1), axis=1)       # (Nq, N*S)
        return picked.reshape(tables.shape[0], -1, S).sum(axis=2)


# -- the index ---------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class IVFPQIndex:
    """IVF segments over uint8 PQ codes + ADC scan + optional exact rerank.

    MetricIndex backend (serve/index.py protocol). Same cluster-major
    capacity-padded layout as IVFIndex, but segments hold ``code_bytes``
    per row instead of ``4k`` — the gather the scan pays shrinks
    accordingly. ``gp_full``/``gn_full`` keep the full-precision projected
    rows **host-resident** (numpy) for the rerank pass, mutable-gallery
    compaction, and snapshotting; they are never gathered on the ADC path.
    """

    L: jax.Array                    # (k, d) replicated metric factor
    centroids: jax.Array            # (C, k) cluster centers
    pq: ProductQuantizer            # residual codebooks
    codes_pad: jax.Array            # (C*cap, S) uint8; 0 on pad slots
    t_pad: jax.Array                # (C*cap,) ||r̂||²+2⟨c,r̂⟩; BIG on pads
    ids_pad: jax.Array              # (C*cap,) original row ids; -1 on pads
    gp_full: np.ndarray             # (M, k) host copy of the exact rows
    gn_full: np.ndarray             # (M,) their norms
    cap: int                        # per-cluster segment capacity
    n_clusters: int
    nprobe: int                     # default clusters scanned per query
    n_rows: int                     # real (unpadded) gallery size M
    rerank_depth: int = 50          # default exact-rerank pool (0 = off)
    store: str = "device"           # rerank row store: "device" | "host"
    # ADC segment-scan implementation: "auto" (Pallas kernel on TPU, XLA
    # elsewhere), "xla", or "pallas" (kernels/pq_adc; interpret mode off
    # TPU — a correctness tool, not a serving path)
    scan_impl: str = "auto"
    # query chunk for the segment gather; 4x the IVF default because the
    # gathered code blocks are ~16x smaller than full-precision rows, so
    # bigger chunks stay cache-sized and amortize per-block overhead
    block_q: int = 64
    version: int = 0
    # device mirror of (gp_full, gn_full) when store == "device"
    _dev_store: Optional[tuple] = dataclasses.field(default=None,
                                                    repr=False)
    _fns: dict = dataclasses.field(default_factory=dict, repr=False)

    @classmethod
    def build(cls, L, gallery, n_clusters: int = 64, nprobe: int = 8, *,
              n_subspaces: int = 8, bits: int = 8, rerank_depth: int = 50,
              store: str = "device", scan_impl: str = "auto",
              iters: int = 10, seed: int = 0,
              cap_factor: float = 1.25, mesh=None,
              rules=None) -> "IVFPQIndex":
        """Project the gallery, cluster, train PQ on residuals, encode.

        Args:
          L: (k, d) metric factor; gallery: (M, d) raw rows.
          n_clusters / nprobe / iters / seed / cap_factor: the IVF coarse
            quantizer knobs (see IVFIndex.build).
          n_subspaces / bits: PQ shape — ``n_subspaces`` uint8 codes per
            row, ``2**bits`` codewords per subspace. Code bytes per row =
            n_subspaces (vs 4k full precision).
          rerank_depth: default exact-rerank pool per query (0 disables;
            overridable per topk call).
          store: where the full-precision rerank rows live — "device"
            (fused in-jit rerank, f32 rows stay in HBM) or "host" (RAM
            only; a host gather round trip per reranked batch).
          scan_impl: default ADC segment-scan implementation — "auto"
            (kernels/pq_adc fused Pallas kernel on TPU, XLA elsewhere),
            "xla", or "pallas" (overridable per topk call).
          mesh/rules: accepted for API symmetry; a multi-device mesh
            raises (single-shard backend, see module docstring).

        Returns the built index.
        """
        gp, gn = project_gallery(L, gallery)
        return cls.build_projected(
            L, gp, gn, n_clusters=n_clusters, nprobe=nprobe,
            n_subspaces=n_subspaces, bits=bits, rerank_depth=rerank_depth,
            store=store, scan_impl=scan_impl, iters=iters, seed=seed,
            cap_factor=cap_factor, mesh=mesh, rules=rules)

    @classmethod
    def build_projected(cls, L, gp, gn, n_clusters: int = 64,
                        nprobe: int = 8, *, n_subspaces: int = 8,
                        bits: int = 8, rerank_depth: int = 50,
                        store: str = "device", scan_impl: str = "auto",
                        iters: int = 10,
                        seed: int = 0, cap_factor: float = 1.25,
                        pq_train_rows: int = 20_000, mesh=None,
                        rules=None) -> "IVFPQIndex":
        """Cluster + encode already-projected rows (gp (M,k), gn (M,)).

        Mutable-gallery compaction rebuilds and metric hot-swap
        (serve/mutable.py) enter here — they hold projected rows already.
        Same layout contract as IVFIndex.build_projected; additionally
        trains the residual ProductQuantizer and encodes every row.
        ``pq_train_rows`` bounds the codebook training set (a seeded
        subsample of the residuals — with <= 2**bits codewords per small
        subspace, tens of thousands of rows saturate the fit and training
        on all of paper-scale M would only slow the build).
        """
        if store not in ("device", "host"):
            raise ValueError(f"unknown store {store!r} (device|host)")
        if scan_impl not in scan.SCAN_IMPLS:
            raise ValueError(f"unknown scan_impl {scan_impl!r} "
                             f"({'|'.join(scan.SCAN_IMPLS)})")
        if mesh is not None and scan.n_shards(
                mesh, scan.gallery_axes(mesh, None, rules)) > 1:
            raise NotImplementedError(
                "IVFPQIndex is single-shard (the rerank row store has no "
                "mesh story; multi-host gallery is a ROADMAP item)")
        scan.check_metric_factor(L)
        gp = jnp.asarray(gp, jnp.float32)
        gn = jnp.asarray(gn, jnp.float32)
        M, k = gp.shape
        if k != jnp.shape(L)[0]:
            raise ValueError(
                f"projected rows have dim {k} but L is "
                f"{tuple(jnp.shape(L))}; gp must be sized d_out")
        C = n_clusters
        if C > M:
            raise ValueError(f"n_clusters={C} > gallery size {M}")
        centroids, assign, _ = kmeans_projected(gp, C, iters=iters,
                                                seed=seed)
        gp_np = np.asarray(gp)
        cap = int(-((-max(cap_factor, 1.0) * M) // C))      # ceil
        cap = ((cap + 7) // 8) * 8
        assign = _balance_assign(gp_np, np.asarray(centroids),
                                 np.asarray(assign), cap)

        cent_np = np.asarray(centroids)
        residuals = gp_np - cent_np[assign]
        train = residuals
        if 0 < pq_train_rows < M:
            sel = np.random.RandomState(seed).choice(M, pq_train_rows,
                                                     replace=False)
            train = residuals[sel]
        pq = ProductQuantizer.train(train, n_subspaces=n_subspaces,
                                    bits=bits, iters=iters, seed=seed)
        codes = np.asarray(pq.encode(jnp.asarray(residuals)))
        t = _t_term(pq, codes, cent_np[assign])

        counts = np.bincount(assign, minlength=C)
        order = np.argsort(assign, kind="stable")           # cluster-major
        offsets = np.cumsum(counts) - counts
        within = np.arange(M) - offsets[assign[order]]
        slots = assign[order] * cap + within

        codes_pad = np.zeros((C * cap, pq.n_subspaces), np.uint8)
        t_pad = np.full((C * cap,), BIG, np.float32)
        ids_pad = np.full((C * cap,), -1, np.int32)
        codes_pad[slots] = codes[order]
        t_pad[slots] = t[order]
        ids_pad[slots] = order.astype(np.int32)

        return cls(L=jnp.asarray(L, jnp.float32), centroids=centroids,
                   pq=pq, codes_pad=jnp.asarray(codes_pad),
                   t_pad=jnp.asarray(t_pad), ids_pad=jnp.asarray(ids_pad),
                   gp_full=gp_np, gn_full=np.asarray(gn), cap=cap,
                   n_clusters=C, nprobe=min(nprobe, C), n_rows=M,
                   rerank_depth=rerank_depth, store=store,
                   scan_impl=scan_impl)

    # -- MetricIndex surface -------------------------------------------------

    @property
    def size(self) -> int:
        """Real (unpadded) gallery rows."""
        return self.n_rows

    @property
    def n_shards(self) -> int:
        return 1

    @property
    def code_bytes_per_row(self) -> int:
        """Device bytes gathered per scanned row: uint8 codes + the f32
        ``t`` term (vs ``4k + 4`` for the full-precision IVF segment)."""
        return self.pq.code_bytes + 4

    @property
    def compression_ratio(self) -> float:
        """Full-precision segment bytes / PQ segment bytes per row."""
        k = self.gp_full.shape[1]
        return (4 * k + 4) / self.code_bytes_per_row

    def topk(self, queries, k_top: int, backend: str = "xla",
             nprobe: Optional[int] = None,
             rerank: Optional[int] = None,
             scan_impl: Optional[str] = None
             ) -> Tuple[jax.Array, jax.Array]:
        """(dists (Nq, k_top) ascending, global row ids (Nq, k_top)).

        Args:
          queries: (Nq, d) raw queries (projected through L here).
          k_top: neighbors per query (<= size).
          backend: "xla" only (no sharded path; the fused ADC kernel is
            the ``scan_impl`` knob, not an engine backend).
          nprobe: clusters scanned (defaults to the build setting;
            ``n_clusters`` scans everything).
          rerank: exact-rerank pool (defaults to build ``rerank_depth``;
            0 returns raw ADC distances, > 0 re-scores that many ADC
            candidates against the full-precision row store — device or
            host per ``store`` — and returns exact distances for the
            survivors).
          scan_impl: ADC segment-scan implementation for this call —
            "auto" / "xla" / "pallas" (defaults to the build setting;
            see scan.resolve_scan_impl). The pallas path returns
            bit-identical results to the xla path.

        Invariants: with rerank on, returned distances are exact squared
        metric distances for the returned ids. Ids match ExactIndex when
        ``nprobe == n_clusters`` *and* the rerank pool is deep enough
        that the true top-k survives ADC preselection — quantization can
        mis-rank a true neighbor below the ADC top-``rerank``, so only
        ``rerank == size`` guarantees equality (the tests' oracle);
        shallower pools trade that tail recall for speed. -1 ids can
        appear only when the probed clusters hold fewer than k_top real
        rows.
        """
        if backend != "xla":
            raise NotImplementedError(
                "IVFPQIndex only supports the xla backend")
        if k_top > self.size:
            raise ValueError(f"k_top={k_top} > gallery size {self.size}")
        # `is None`, not truthiness: `nprobe or default` would silently
        # map an explicit nprobe=0 to the default (the k_top=0 bug class)
        np_ = self.nprobe if nprobe is None else nprobe
        if np_ < 1:
            raise ValueError(f"nprobe must be >= 1, got {np_}")
        np_ = min(np_, self.n_clusters)
        rr = self.rerank_depth if rerank is None else rerank
        rr = min(rr, np_ * self.cap)
        if rr:
            rr = max(rr, k_top)
        if max(k_top, rr) > np_ * self.cap:
            raise ValueError(
                f"k_top={k_top} > nprobe*cap={np_ * self.cap} scanned "
                f"rows per query; raise nprobe")
        impl = scan.resolve_scan_impl(self.scan_impl, scan_impl)
        queries = jnp.asarray(queries, jnp.float32)
        fused = rr > 0 and self.store == "device"
        key = (k_top, np_, rr, fused, impl)
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = self._build_topk(k_top, np_, rr, fused,
                                                   impl)
        if fused or rr == 0:
            return fn(queries)
        # host store: two-phase rerank (the scan fn hands back the
        # projected queries so the rerank pass doesn't re-project)
        adc_d, adc_i, qp = fn(queries)
        return self._rerank_host(qp, adc_i, k_top)

    # -- ADC scan (+ fused device rerank) ------------------------------------

    def _device_store(self):
        """Lazy device mirror of the full-precision rows (store="device")."""
        if self._dev_store is None:
            self._dev_store = (jnp.asarray(self.gp_full),
                               jnp.asarray(self.gn_full))
        return self._dev_store

    def _build_topk(self, k_top: int, nprobe: int, rr: int, fused: bool,
                    impl: str):
        """Jitted query fn for one (k_top, nprobe, rerank, store, impl)
        combo.

        ``fused`` appends the device-store exact rerank inside the same
        jit; otherwise the fn returns the top max(k_top, rr) ADC
        candidates — plus the projected queries when rr > 0, for the
        host-store rerank phase that follows. ``impl`` is the resolved
        segment-scan implementation ("xla" | "pallas"); both route
        through kernels/pq_adc and return bit-identical results.
        """
        C, cap = self.n_clusters, self.cap
        S, K = self.pq.n_subspaces, self.pq.n_codes
        codes = self.codes_pad.reshape(C, cap, S)
        t = self.t_pad.reshape(C, cap)
        ids = self.ids_pad.reshape(C, cap)
        block_q = self.block_q
        kk = max(k_top, rr)
        gp_dev, gn_dev = self._device_store() if fused else (None, None)

        @jax.jit
        def run(queries):
            qp = scan.project_queries(self.L, queries)
            cd = metric_sqdist_factored(qp, self.centroids)
            neg, probes = jax.lax.top_k(-cd, nprobe)
            tables = self.pq.ip_tables(qp).reshape(qp.shape[0], S * K)
            d, i = pq_adc_topk(tables, -neg, probes, codes, t, ids,
                               kk=kk, block_q=block_q,
                               use_kernel=(impl == "pallas"))
            if not fused:
                return (d, i, qp) if rr > 0 else (d, i)
            # fused exact rerank: gather only kk full-precision rows per
            # query from the device store (never re-entering the segment
            # gather the codes avoided) and re-sort by exact distance
            safe = jnp.maximum(i, 0)
            rows = jnp.take(gp_dev, safe, axis=0)        # (Nq, kk, k)
            norms = jnp.where(i >= 0, jnp.take(gn_dev, safe, axis=0), BIG)
            return _exact_rerank(qp, rows, norms, i, k_top)

        return run

    # -- host-store exact re-rank --------------------------------------------

    def _rerank_host(self, qp, cand_ids, k_top: int):
        """Re-score ADC candidates against the host full-precision rows.

        ``qp`` is the already-projected query batch (computed once by the
        scan jit). The candidate gather runs in numpy (host RAM — the
        point of ``store="host"`` is keeping the f32 rows out of device
        memory), then one jitted exact-distance + merge pass runs on
        device with static shapes. Costs a device->host->device round
        trip per batch; ``store="device"`` fuses the same math into the
        scan jit instead.

        Sentinel candidates (-1 ids from under-filled probes) keep their
        id and a BIG distance, so they sort last and surface only when
        fewer than k_top real candidates exist — the same convention as
        IVFIndex.
        """
        ci = np.asarray(cand_ids)
        safe = np.where(ci >= 0, ci, 0)
        rows = jnp.asarray(self.gp_full[safe])        # (Nq, rr, k)
        norms = jnp.asarray(
            np.where(ci >= 0, self.gn_full[safe], BIG).astype(np.float32))
        key = ("rerank_host", ci.shape[1], k_top)
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = jax.jit(
                lambda qp, rows, norms, ids:
                _exact_rerank(qp, rows, norms, ids, k_top))
        return fn(qp, rows, norms, jnp.asarray(ci))

    def probe_stats(self, queries, nprobe: Optional[int] = None):
        """Diagnostic: (probes (Nq, nprobe), centroid dists) for a batch —
        which segments a query would scan. Host helper for docs/tests."""
        qp = scan.project_queries(self.L, jnp.asarray(queries, jnp.float32))
        cd = metric_sqdist_factored(qp, self.centroids)
        np_ = self.nprobe if nprobe is None else nprobe
        np_ = min(np_, self.n_clusters)
        neg, probes = jax.lax.top_k(-cd, np_)
        return np.asarray(probes), np.asarray(-neg)


def _exact_rerank(qp, rows, norms, ids, k_top: int):
    """Exact (projected-space) rescore of gathered candidate rows.

    qp (Nq, k) projected queries; rows (Nq, R, k) candidate rows; norms
    (Nq, R) their norms with BIG on -1 sentinels; ids (Nq, R). Returns
    the (distance, id)-merged exact top k_top — the same deterministic
    select (scan.topk_by_distance) every other backend ends on.
    """
    cross = jnp.einsum("qrk,qk->qr", rows, qp)
    qn = jnp.sum(jnp.square(qp), axis=1)
    d = jnp.maximum(qn[:, None] + norms - 2.0 * cross, 0.0)
    d = jnp.where(ids < 0, BIG, d)
    return scan.topk_by_distance(d, ids, k_top)


def _t_term(pq: ProductQuantizer, codes: np.ndarray,
            cents: np.ndarray) -> np.ndarray:
    """Per-row additive ADC term ||r̂||² + 2⟨c, r̂⟩ (f32 (N,)).

    ``codes`` (N, S) uint8, ``cents`` (N, k) the row's own centroid. Baked
    at encode time so the scan never touches the decoded residual.
    """
    dec = np.asarray(pq.decode(jnp.asarray(codes)))
    return (np.sum(dec * dec, axis=1)
            + 2.0 * np.sum(cents * dec, axis=1)).astype(np.float32)
