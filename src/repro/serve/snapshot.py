"""Index snapshots: npz segments + a manifest, restart without re-projecting.

A serving process that restarts loses its index; rebuilding means
re-projecting the whole gallery through L (and, for IVF, re-running
k-means) before the first query can be answered. Snapshots persist the
*built* device layout instead:

  base.npz      the frozen base index arrays — ExactIndex: L, gp, gn;
                IVFIndex: L, centroids, gp_pad, gn_pad, ids_pad;
                IVFPQIndex: L, centroids, codebooks, codes_pad, t_pad,
                ids_pad plus the full-precision rerank store
                (gp_full/gn_full);
  mutable.npz   (MutableIndex only) the mutation state: base_ids,
                tombstone masks, the pre-projected delta buffer;
  raw.npz       (MutableIndex with retain_raw) the raw feature rows that
                power ``swap_metric``;
  manifest.json written **last** — a partial snapshot has no manifest and
                ``load_index`` refuses it. Carries the format number, the
                index type, the ``version`` counter, array shapes, scalar
                build parameters, and an L fingerprint (sha256 prefix of
                the f32 factor bytes).

Because the stored arrays are the exact f32 device contents, a loaded
index answers top-k **bit-for-bit** identically to the index that was
saved — the property tests/test_serve_mutable.py pins. The fingerprint
lets a caller holding an L (say, fresh from the trainer) check whether
the snapshot was built under the same metric before serving from it:
``load_index(dir, expect_L=L)`` raises on mismatch (recover by loading
without ``expect_L`` and calling ``swap_metric(L)``).
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.serve.index import ExactIndex
from repro.serve.ivf import IVFIndex
from repro.serve.mutable import MutableIndex
from repro.serve.pq import IVFPQIndex, ProductQuantizer

FORMAT = 1
MANIFEST = "manifest.json"


def l_fingerprint(L) -> str:
    """Stable short id of a metric factor: sha256 of its f32 bytes."""
    a = np.ascontiguousarray(np.asarray(L, np.float32))
    return hashlib.sha256(a.tobytes()).hexdigest()[:16]


def has_snapshot(snapshot_dir: str) -> bool:
    """True iff ``snapshot_dir`` holds a *complete* snapshot — i.e. its
    manifest exists (the manifest is written last, so segments without
    one are an interrupted save and load_index refuses them)."""
    return os.path.isfile(os.path.join(snapshot_dir, MANIFEST))


def _require_unsharded(index):
    if index.n_shards > 1:
        raise NotImplementedError(
            "snapshots cover single-shard indexes only (a sharded index "
            "re-places arrays at build; snapshot the per-host state "
            "instead)")


def _base_payload(index):
    """(arrays dict, meta dict) for a frozen base index."""
    if isinstance(index, ExactIndex):
        return ({"L": np.asarray(index.L), "gp": np.asarray(index.gp),
                 "gn": np.asarray(index.gn)},
                {"base_type": "exact"})
    if isinstance(index, IVFIndex):
        return ({"L": np.asarray(index.L),
                 "centroids": np.asarray(index.centroids),
                 "gp_pad": np.asarray(index.gp_pad),
                 "gn_pad": np.asarray(index.gn_pad),
                 "ids_pad": np.asarray(index.ids_pad)},
                {"base_type": "ivf", "cap": index.cap,
                 "n_clusters": index.n_clusters, "nprobe": index.nprobe,
                 "n_rows": index.n_rows, "block_q": index.block_q,
                 "scan_impl": index.scan_impl})
    if isinstance(index, IVFPQIndex):
        return ({"L": np.asarray(index.L),
                 "centroids": np.asarray(index.centroids),
                 "codebooks": np.asarray(index.pq.codebooks),
                 "codes_pad": np.asarray(index.codes_pad),
                 "t_pad": np.asarray(index.t_pad),
                 "ids_pad": np.asarray(index.ids_pad),
                 "gp_full": np.asarray(index.gp_full),
                 "gn_full": np.asarray(index.gn_full)},
                {"base_type": "ivfpq", "cap": index.cap,
                 "n_clusters": index.n_clusters, "nprobe": index.nprobe,
                 "n_rows": index.n_rows, "block_q": index.block_q,
                 "pq_dim": index.pq.dim,
                 "rerank_depth": index.rerank_depth,
                 "store": index.store, "scan_impl": index.scan_impl})
    raise TypeError(f"cannot snapshot {type(index).__name__}")


def _load_base(path: str, meta: dict):
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    L = jnp.asarray(arrays["L"])
    if meta["base_type"] == "exact":
        return ExactIndex.from_projected(L, arrays["gp"], arrays["gn"])
    if meta["base_type"] == "ivfpq":
        pq = ProductQuantizer(codebooks=jnp.asarray(arrays["codebooks"]),
                              dim=int(meta["pq_dim"]))
        return IVFPQIndex(
            L=L, centroids=jnp.asarray(arrays["centroids"]), pq=pq,
            codes_pad=jnp.asarray(arrays["codes_pad"]),
            t_pad=jnp.asarray(arrays["t_pad"]),
            ids_pad=jnp.asarray(arrays["ids_pad"]),
            gp_full=arrays["gp_full"].astype(np.float32),
            gn_full=arrays["gn_full"].astype(np.float32),
            cap=int(meta["cap"]), n_clusters=int(meta["n_clusters"]),
            nprobe=int(meta["nprobe"]), n_rows=int(meta["n_rows"]),
            rerank_depth=int(meta["rerank_depth"]),
            store=str(meta["store"]), block_q=int(meta["block_q"]),
            scan_impl=str(meta.get("scan_impl", "auto")))
    return IVFIndex(
        L=L, centroids=jnp.asarray(arrays["centroids"]),
        gp_pad=jnp.asarray(arrays["gp_pad"]),
        gn_pad=jnp.asarray(arrays["gn_pad"]),
        ids_pad=jnp.asarray(arrays["ids_pad"]), cap=int(meta["cap"]),
        n_clusters=int(meta["n_clusters"]), nprobe=int(meta["nprobe"]),
        n_rows=int(meta["n_rows"]), block_q=int(meta["block_q"]),
        scan_impl=str(meta.get("scan_impl", "auto")))


def save_index(index, snapshot_dir: str, *, registry=None) -> dict:
    """Persist an ExactIndex / IVFIndex / IVFPQIndex / MutableIndex
    (over any of those bases) to ``snapshot_dir``.

    Writes the npz segments first and the manifest last (its presence
    marks the snapshot complete; re-saving retracts the old manifest
    before touching segments). Returns the manifest dict. ``registry``
    (or the index's own adopting registry) gets an ``index_snapshot_save``
    event.
    """
    _require_unsharded(index)
    os.makedirs(snapshot_dir, exist_ok=True)
    # re-saving over an existing snapshot: retract the old manifest first,
    # so a crash mid-save leaves an (unloadable) incomplete snapshot
    # rather than the old manifest over new partial segments
    stale = os.path.join(snapshot_dir, MANIFEST)
    if os.path.isfile(stale):
        os.remove(stale)
    mutable = isinstance(index, MutableIndex)
    base = index.base if mutable else index
    arrays, base_meta = _base_payload(base)
    np.savez(os.path.join(snapshot_dir, "base.npz"), **arrays)
    segments = {"base": "base.npz"}

    manifest = {
        "format": FORMAT,
        "type": type(index).__name__,
        "version": index.version,
        "l_fingerprint": l_fingerprint(index.L),
        "l_shape": list(np.asarray(index.L).shape),
        "size": index.size,
        "base": base_meta,
        "segments": segments,
    }
    if mutable:
        np.savez(os.path.join(snapshot_dir, "mutable.npz"),
                 base_ids=index.base_ids, dead_base=index.dead_base,
                 delta_gp=index.delta_gp, delta_gn=index.delta_gn,
                 delta_ids=index.delta_ids, dead_delta=index.dead_delta)
        segments["mutable"] = "mutable.npz"
        if index.raw_base is not None:
            np.savez(os.path.join(snapshot_dir, "raw.npz"),
                     raw_base=index.raw_base, raw_delta=index.raw_delta)
            segments["raw"] = "raw.npz"
        manifest["mutable"] = {
            "next_id": index._next_id,
            "n_upserts": index.n_upserts, "n_deletes": index.n_deletes,
            "n_compactions": index.n_compactions,
            "n_rebuilds": index.n_rebuilds, "n_swaps": index.n_swaps,
            "auto_compact_delta": index.auto_compact_delta,
            "auto_compact_dead": index.auto_compact_dead,
            "base_kwargs": index._base_kwargs,
        }

    # manifest last: its presence marks the snapshot complete
    path = os.path.join(snapshot_dir, MANIFEST)
    with open(path + ".tmp", "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    os.replace(path + ".tmp", path)
    _emit(index, registry, "snapshot_save", type=manifest["type"],
          size=manifest["size"], version=manifest["version"],
          dir=snapshot_dir)
    return manifest


def _emit(index, registry, name: str, **attrs) -> None:
    """Structured obs event: the explicit registry wins, else the index's
    adopting registry (the engine attaches one to MutableIndex; frozen
    bases have none — no-op)."""
    registry = (registry if registry is not None
                else getattr(index, "registry", None))
    if registry is not None:
        registry.event(f"index_{name}", **attrs)
        registry.counter(
            "index_lifecycle_total", "index lifecycle transitions",
            labelnames=("event",)).inc(event=name)


def load_index(snapshot_dir: str, *, expect_L=None, registry=None):
    """Reconstruct a saved index; no gallery projection, no k-means.

    Args:
      snapshot_dir: directory written by ``save_index``.
      expect_L: optional metric factor to assert the snapshot was built
        under — a fingerprint mismatch raises ValueError before any
        array loads (callers can then load plain and ``swap_metric``).
      registry: optional obs MetricsRegistry to receive the
        ``index_snapshot_load`` event (a freshly loaded index has no
        adopting engine yet).

    Returns the restored index (same concrete type that was saved, same
    ``version``); its top-k answers are bit-for-bit identical to the
    saved index's. Raises FileNotFoundError on a missing/incomplete
    snapshot and ValueError on a format or fingerprint mismatch.
    """
    path = os.path.join(snapshot_dir, MANIFEST)
    if not os.path.isfile(path):
        raise FileNotFoundError(
            f"no snapshot manifest at {path} (incomplete or missing "
            f"snapshot)")
    with open(path) as f:
        manifest = json.load(f)
    if manifest["format"] != FORMAT:
        raise ValueError(f"snapshot format {manifest['format']} != "
                         f"supported {FORMAT}")
    if expect_L is not None:
        # shape first: a rank-mismatched factor can never fingerprint-
        # match, and the caller deserves the structural diagnosis (the
        # snapshot was built at a different d_out/d_in), not a generic
        # fingerprint complaint. Older manifests lack l_shape; only the
        # fingerprint gate applies then.
        saved_shape = manifest.get("l_shape")
        expect_shape = list(np.asarray(expect_L).shape)
        if saved_shape is not None and saved_shape != expect_shape:
            raise ValueError(
                f"snapshot metric factor has shape "
                f"{tuple(saved_shape)} but expect_L is "
                f"{tuple(expect_shape)}: rank-mismatched L (the gallery "
                f"was projected at a different (d_out, d_in); load "
                f"without expect_L and swap_metric, or rebuild)")
        got, want = manifest["l_fingerprint"], l_fingerprint(expect_L)
        if got != want:
            raise ValueError(
                f"snapshot metric fingerprint {got} != expected {want}: "
                f"the gallery was projected under a different L (load "
                f"without expect_L and swap_metric, or rebuild)")

    base = _load_base(os.path.join(snapshot_dir, "base.npz"),
                      manifest["base"])
    if manifest["type"] != "MutableIndex":
        base.version = manifest["version"]
        _emit(base, registry, "snapshot_load", type=manifest["type"],
              size=manifest["size"], version=manifest["version"],
              dir=snapshot_dir)
        return base

    with np.load(os.path.join(snapshot_dir, "mutable.npz")) as z:
        mz = {k: z[k] for k in z.files}
    raw_base = raw_delta = None
    if "raw" in manifest["segments"]:
        with np.load(os.path.join(snapshot_dir, "raw.npz")) as z:
            raw_base, raw_delta = z["raw_base"], z["raw_delta"]
    meta = manifest["mutable"]
    mut = MutableIndex(base, base.L, ids=mz["base_ids"], raw=raw_base,
                       base_kwargs=meta["base_kwargs"],
                       auto_compact_delta=meta["auto_compact_delta"],
                       auto_compact_dead=meta["auto_compact_dead"])
    mut.dead_base = mz["dead_base"].astype(bool)
    mut.delta_gp = mz["delta_gp"].astype(np.float32)
    mut.delta_gn = mz["delta_gn"].astype(np.float32)
    mut.delta_ids = mz["delta_ids"].astype(np.int64)
    mut.dead_delta = mz["dead_delta"].astype(bool)
    if raw_delta is not None:
        mut.raw_delta = raw_delta.astype(np.float32)
    mut._loc = {}
    for i, e in enumerate(mut.base_ids.tolist()):
        if not mut.dead_base[i]:
            mut._loc[int(e)] = ("base", i)
    for j, e in enumerate(mut.delta_ids.tolist()):
        if not mut.dead_delta[j]:
            mut._loc[int(e)] = ("delta", j)
    mut._next_id = int(meta["next_id"])
    mut.n_upserts = int(meta["n_upserts"])
    mut.n_deletes = int(meta["n_deletes"])
    mut.n_compactions = int(meta["n_compactions"])
    mut.n_rebuilds = int(meta["n_rebuilds"])
    mut.n_swaps = int(meta["n_swaps"])
    mut.version = manifest["version"]
    _emit(mut, registry, "snapshot_load", type=manifest["type"],
          size=manifest["size"], version=manifest["version"],
          dir=snapshot_dir)
    return mut
